//! Observability-plane acceptance (ISSUE: correlated span tracing).
//!
//! The load-bearing claims, end to end through real federations:
//!
//! 1. ONE trace id stitches the whole causal chain across process
//!    boundaries — root controller round → dispatch → aggregator shard
//!    round → learner train/upload → the retried attempt of a
//!    chaos-severed upload → ingest — into a single connected tree,
//!    with child intervals causally ordered against their parents.
//! 2. Tracing is observation only: a spans-on run produces the bitwise
//!    identical community model to the spans-off run.
//! 3. Span batches ride the recorded MFTR1 trace without perturbing it:
//!    replay ignores them and still reproduces the digest bitwise.
//! 4. The exposition listener speaks enough HTTP that a plain GET
//!    returns the registry in Prometheus text format.

use metisfl::config::{FederationEnv, ModelSpec, ObservabilitySpec};
use metisfl::controller::hierarchy::{AggregatorNode, AggregatorServicer};
use metisfl::controller::{scheduling, Controller};
use metisfl::driver::run_simulated;
use metisfl::harness::{run_loadtest, LoadtestConfig};
use metisfl::learner::{Dataset, Learner, LearnerServicer, SyntheticTrainer};
use metisfl::net::chaos::ChaosSpec;
use metisfl::net::{serve, Service};
use metisfl::obs::{assert_single_tree, Span};
use metisfl::runtime::trace::{replay_trace, Trace, TraceEvent};
use metisfl::tensor::TensorModel;
use metisfl::util::Rng;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

#[test]
fn one_trace_id_spans_root_aggregator_learner_and_the_retry() {
    // Two-tier federation, streamed data plane, full quorum. Learner-1's
    // upload link is chaos-severed mid-stream with a short reconnect
    // window, so its upload fails once and succeeds on the retry — the
    // round still closes with every contribution.
    let env = FederationEnv::builder("obs-two-tier")
        .learners(2)
        .rounds(1)
        .model(ModelSpec::mlp(4, 2, 8))
        .samples_per_learner(12)
        .batch_size(6)
        .quorum_fraction(1.0)
        .stream_chunk_bytes(2048)
        .heartbeat_ms(5_000)
        .seed(0x0B5)
        .build();

    // Root controller sees exactly one learner-like peer: the aggregator.
    let mut root_env = env.clone();
    root_env.learners = 1;
    let ctrl = Controller::new(root_env, None).unwrap();
    ctrl.span_sink().enable();
    let ctrl_server =
        serve("inproc://obs-root", Arc::clone(&ctrl) as Arc<dyn Service>, None).unwrap();

    let node = AggregatorNode::new("agg-0", &ctrl_server.endpoint(), &env, 2, None).unwrap();
    node.inner().span_sink().enable();
    let agg_server = serve(
        "inproc://obs-agg",
        Arc::new(AggregatorServicer(Arc::clone(&node))) as Arc<dyn Service>,
        None,
    )
    .unwrap();

    let mut learners = Vec::new();
    let mut servers = Vec::new();
    for i in 0..2usize {
        let learner = Learner::new(
            &format!("learner-{i}"),
            &agg_server.endpoint(),
            None,
            Arc::new(SyntheticTrainer::new(0, 0.01)),
            Dataset::synthetic_housing(4, 12, 12, i as u64),
        );
        learner.set_stream_chunk(2048);
        learner.span_sink().enable();
        if i == 1 {
            // Send budget 3 = hello + register + Begin: the upload's
            // first chunk severs the link mid-stream. The retry backoff
            // (≥20 ms) outlasts the 10 ms reconnect window, so the
            // re-dial rejoins and attempt 2 delivers. (A one-learner
            // fleet makes the victim assignment trivially this plan.)
            let spec = ChaosSpec {
                sever_fraction: 1.0,
                sever_after_sends: 3,
                reconnect_after_ms: 10,
                ..ChaosSpec::default()
            };
            learner.set_chaos(spec.plan_fleet(1, 0).remove(0));
        }
        let server = serve(
            &format!("inproc://obs-l{i}"),
            Arc::new(LearnerServicer(Arc::clone(&learner))) as Arc<dyn Service>,
            None,
        )
        .unwrap();
        learner.register(&server.endpoint()).unwrap();
        servers.push(server);
        learners.push(learner);
    }
    node.inner().wait_for_learners(2, Duration::from_secs(10)).unwrap();
    node.register(&agg_server.endpoint(), 2 * env.samples_per_learner).unwrap();
    ctrl.wait_for_learners(1, Duration::from_secs(10)).unwrap();

    ctrl.ship_model(TensorModel::random_init(&env.model.tensor_layout(), &mut Rng::new(5)));
    let report = scheduling::run_round(&ctrl, 1, &mut Rng::new(6)).unwrap();
    assert_eq!(report.completed, 1, "the aggregator tier must complete the root round");

    // --- Claim 1: one connected tree across all three tiers -----------
    let mut spans: Vec<Span> = ctrl.span_sink().drain();
    spans.extend(node.inner().span_sink().drain());
    for l in &learners {
        spans.extend(l.span_sink().drain());
    }
    // The root controller's round span is the only parentless span of
    // the trace of record (the inner "round" parents under shard_round).
    let root = spans
        .iter()
        .find(|s| s.op == "round" && s.parent == 0)
        .expect("no root round span recorded")
        .clone();
    let trace: Vec<Span> =
        spans.iter().filter(|s| s.trace_id == root.trace_id).cloned().collect();
    let root_id = assert_single_tree(&trace)
        .unwrap_or_else(|e| panic!("spans do not form a single tree: {e}\n{trace:#?}"));
    assert_eq!(root_id, root.span_id);

    // Every tier contributed its op to the one trace.
    let count = |op: &str| trace.iter().filter(|s| s.op == op).count();
    for op in [
        "round",
        "barrier",
        "dispatch",
        "aggregate",
        "ingest",
        "shard_round",
        "partial_upload",
        "train",
        "upload",
        "upload_attempt",
    ] {
        assert!(count(op) > 0, "no '{op}' span in the trace: {trace:#?}");
    }

    // The severed learner's upload span has ≥ 2 attempt children — the
    // retry is part of the tree, not a fresh trace.
    let mut attempts_per_upload: HashMap<u64, usize> = HashMap::new();
    for s in trace.iter().filter(|s| s.op == "upload_attempt") {
        *attempts_per_upload.entry(s.parent).or_insert(0) += 1;
    }
    assert!(
        attempts_per_upload.values().any(|&n| n >= 2),
        "no upload recorded a retried attempt: {attempts_per_upload:?}"
    );

    // Causal interval ordering on the shared clock: no span ends before
    // it starts, and no child starts before its parent did.
    let by_id: HashMap<u64, &Span> = trace.iter().map(|s| (s.span_id, s)).collect();
    for s in &trace {
        assert!(s.t_end >= s.t_start, "span '{}' ends before it starts", s.op);
        if let Some(p) = by_id.get(&s.parent) {
            assert!(
                s.t_start >= p.t_start,
                "child '{}' ({:?}) starts before its parent '{}' ({:?})",
                s.op,
                s.t_start,
                p.op,
                p.t_start
            );
        }
    }
}

#[test]
fn spans_on_run_is_bitwise_identical_to_spans_off() {
    let mk = |name: &str, spans: bool| {
        FederationEnv::builder(name)
            .learners(3)
            .rounds(2)
            .model(ModelSpec::mlp(4, 2, 8))
            .samples_per_learner(12)
            .batch_size(6)
            .stream_chunk_bytes(2048)
            .heartbeat_ms(5_000)
            .seed(77)
            .observability(ObservabilitySpec { listen_addr: String::new(), spans })
            .build()
    };
    let off = run_simulated(&mk("obs-off", false)).unwrap();
    let on = run_simulated(&mk("obs-on", true)).unwrap();
    assert_ne!(on.community_digest, 0, "spans-on run produced no community model");
    assert_eq!(
        off.community_digest, on.community_digest,
        "span tracing perturbed the math"
    );
}

#[test]
fn recorded_trace_carries_span_batches_and_still_replays_bitwise() {
    let mut cfg = LoadtestConfig::quick();
    cfg.learners = 3;
    cfg.rate = 1000.0;
    cfg.record = true;
    cfg.spans = true;
    let report = run_loadtest(&cfg).unwrap();
    let bytes = report.trace.expect("recorded run produced no trace");

    let trace = Trace::decode(&bytes).unwrap();
    let recorded_spans: usize = trace
        .events
        .iter()
        .map(|(_, e)| match e {
            TraceEvent::Spans { spans } => spans.len(),
            _ => 0,
        })
        .sum();
    assert!(recorded_spans > 0, "no spans rode the recorded trace");

    // Replay must skip the observability payload and reproduce bitwise.
    let outcome = replay_trace(&bytes).unwrap();
    assert!(outcome.divergence.is_none(), "replay diverged: {:?}", outcome.divergence);
}

#[test]
fn exposition_listener_serves_prometheus_text_over_plain_get() {
    use metisfl::metrics::MetricsRegistry;
    use metisfl::obs::ExpoServer;
    use std::io::{Read, Write};

    let reg = MetricsRegistry::new();
    reg.counter("obs_test").add(7);
    reg.gauge("obs_test_open").set(3);
    reg.histogram("obs_test_latency").record(Duration::from_millis(12));

    let mut server = ExpoServer::serve("127.0.0.1:0", Arc::clone(&reg)).unwrap();
    let mut stream = std::net::TcpStream::connect(server.addr()).unwrap();
    stream.write_all(b"GET /metrics HTTP/1.0\r\nConnection: close\r\n\r\n").unwrap();
    let mut resp = String::new();
    stream.read_to_string(&mut resp).unwrap();
    server.stop();

    assert!(resp.starts_with("HTTP/1.0 200"), "bad status line: {resp}");
    let body = resp.split_once("\r\n\r\n").map(|(_, b)| b).unwrap_or("");
    assert!(body.contains("metisfl_obs_test_total 7"), "counter missing:\n{body}");
    assert!(body.contains("metisfl_obs_test_open 3"), "gauge missing:\n{body}");
    assert!(
        body.contains("metisfl_obs_test_latency_seconds_count 1"),
        "histogram summary missing:\n{body}"
    );
}
