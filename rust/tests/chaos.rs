//! Chaos acceptance: the ISSUE's graceful-degradation scenario — a 20-
//! learner fleet with 20% severed links, one slow-loris and one corrupt-
//! frame flooder must still close every round at a 0.7 quorum, and the
//! community model must match a chaos-free run over the survivors
//! **bitwise**. Same-seed reruns must reproduce the same victim
//! assignment and the same math.

use metisfl::config::{FederationEnv, ModelSpec};
use metisfl::driver::run_with_trainer;
use metisfl::harness::{verify_chaos_equivalence, LoadtestConfig};
use metisfl::learner::trainer::RustSgdTrainer;
use metisfl::learner::{SyntheticTrainer, Trainer};
use metisfl::net::chaos::ChaosSpec;
use std::sync::Arc;

/// The acceptance scenario from the issue: N=20, sever 20% (4 learners),
/// one slow-loris, one corrupt-frame flooder, quorum 0.7.
fn acceptance_cfg() -> LoadtestConfig {
    let mut cfg = LoadtestConfig::quick();
    cfg.learners = 20;
    cfg.rate = 400.0;
    cfg.rounds = 2;
    cfg.seed = 0xC4A05;
    cfg.quorum_fraction = 0.7;
    cfg.chaos = ChaosSpec {
        seed: 0xC4A05,
        sever_fraction: 0.2,
        slow_loris: 1,
        corrupt: 1,
        drip_ms: 5,
        ..ChaosSpec::default()
    };
    cfg
}

#[test]
fn acceptance_scenario_degrades_gracefully_and_preserves_the_math() {
    let cfg = acceptance_cfg();
    let eq = verify_chaos_equivalence(&cfg).expect("chaos equivalence gate");

    // 4 severed + 1 loris + 1 corruptor leave 14 = ceil(0.7 × 20).
    assert_eq!(eq.survivors.len(), 14, "survivors: {:?}", eq.survivors);
    assert_eq!(eq.chaos.completed_per_round, vec![14, 14], "quorum must fire every round");
    assert_eq!(eq.clean.completed_per_round, vec![14, 14]);
    assert_eq!(
        eq.chaos.community_digest, eq.clean.community_digest,
        "community model must be bitwise identical to the clean survivor run"
    );
    assert_eq!(eq.chaos.late_folds, 0, "no late completion may contaminate the aggregate");

    // The faults left evidence in the degradation counters: severed
    // learners exhausted their retries, and the loris / severed partials
    // were reclaimed by the forced GC sweep.
    assert!(eq.chaos.retry_give_ups > 0, "severed uploads should exhaust retries");
    assert!(eq.chaos.streams_gced > 0, "abandoned partial streams should be GC'd");
    assert_eq!(eq.clean.retry_give_ups, 0);
    assert_eq!(eq.clean.streams_gced, 0);
    assert_eq!(eq.clean.streams_refused, 0);
}

#[test]
fn same_seed_reruns_reproduce_victims_and_outcomes() {
    let cfg = acceptance_cfg();

    // Victim assignment is a pure function of (spec seed, run seed, n).
    let a = cfg.chaos.plan_fleet(cfg.learners, cfg.seed);
    let b = cfg.chaos.plan_fleet(cfg.learners, cfg.seed);
    let mask = |plans: &[metisfl::net::chaos::ChaosPlan]| -> Vec<(bool, bool, bool, bool)> {
        plans
            .iter()
            .map(|p| {
                (p.refuse_dial, p.sever_after_sends.is_some(), p.drip.is_some(), p.corrupt_frames)
            })
            .collect()
    };
    assert_eq!(mask(&a), mask(&b), "same-seed plans must pick the same victims");

    // And the end-to-end outcome is identical: same quorum trace, same
    // community model bits.
    let r1 = verify_chaos_equivalence(&cfg).unwrap();
    let r2 = verify_chaos_equivalence(&cfg).unwrap();
    assert_eq!(r1.survivors, r2.survivors);
    assert_eq!(r1.chaos.completed_per_round, r2.chaos.completed_per_round);
    assert_eq!(r1.chaos.community_digest, r2.chaos.community_digest);
    assert_eq!(r1.clean.community_digest, r2.clean.community_digest);
}

#[test]
fn driver_report_surfaces_degradation_counters() {
    // A plain driver run (not the loadtest harness) with severed links:
    // the round must close at quorum and the FederationReport must carry
    // the give-up evidence. A clean run reports all-zero counters.
    let chaos_env = FederationEnv::builder("chaos-driver")
        .learners(6)
        .rounds(1)
        .model(ModelSpec::mlp(4, 2, 8))
        .samples_per_learner(20)
        .batch_size(10)
        .stream_chunk_bytes(512)
        .quorum_fraction(0.66)
        .task_timeout_ms(8_000)
        .heartbeat_ms(10_000)
        .chaos(ChaosSpec { seed: 11, sever_fraction: 0.34, ..ChaosSpec::default() })
        .build();
    let report = run_with_trainer(&chaos_env, |_| {
        Arc::new(SyntheticTrainer::new(0, 0.01)) as Arc<dyn Trainer>
    })
    .unwrap();
    let r = &report.round_metrics[0];
    assert_eq!(r.participants, 6, "severed learners still register (sever ≠ refuse)");
    assert_eq!(r.completed, 4, "quorum closes the round over the 4 survivors");
    assert!(report.retry_give_ups > 0, "severed uploads must exhaust their retries");

    let clean_env = FederationEnv::builder("clean-driver")
        .learners(4)
        .rounds(1)
        .model(ModelSpec::mlp(4, 2, 8))
        .samples_per_learner(20)
        .batch_size(10)
        .stream_chunk_bytes(512)
        .heartbeat_ms(10_000)
        .build();
    let clean = run_with_trainer(&clean_env, |_| {
        Arc::new(SyntheticTrainer::new(0, 0.01)) as Arc<dyn Trainer>
    })
    .unwrap();
    assert_eq!(clean.retry_give_ups, 0);
    assert_eq!(clean.fallback_sends, 0);
    assert_eq!(clean.streams_refused, 0);
    assert_eq!(clean.streams_gced, 0);
}

#[test]
fn severed_learner_reconnects_and_its_retried_completions_stay_idempotent() {
    // Churn instead of permanent loss: one severed learner re-dials
    // after 10 ms — inside the rpc retry profile's 25 ms first backoff,
    // so the retried stream lands on attempt 2 and at quorum 1.0 every
    // round still closes over the full fleet. The retried uploads and
    // completion callbacks hit the controller's completed-task
    // watermark, which must absorb them idempotently.
    let churn_env = FederationEnv::builder("chaos-churn")
        .learners(4)
        .rounds(2)
        .model(ModelSpec::mlp(4, 2, 8))
        .samples_per_learner(20)
        .batch_size(10)
        .learning_rate(0.05)
        .stream_chunk_bytes(512)
        .quorum_fraction(1.0)
        .task_timeout_ms(8_000)
        .heartbeat_ms(10_000)
        .chaos(ChaosSpec {
            seed: 5,
            sever_fraction: 0.25,
            sever_after_sends: 4,
            reconnect_after_ms: 10,
            ..ChaosSpec::default()
        })
        .build();
    let report = run_with_trainer(&churn_env, |_| {
        Arc::new(RustSgdTrainer) as Arc<dyn Trainer>
    })
    .unwrap();
    for r in &report.round_metrics {
        assert_eq!(r.participants, 4, "severed learners still register (sever ≠ refuse)");
        assert_eq!(r.completed, 4, "round {}: the rejoined learner must complete", r.round);
    }
    assert_eq!(report.retry_give_ups, 0, "rejoin must resolve inside the retry budget");

    // Bitwise: churn is pure transport noise. The fold over the full
    // fleet must equal the chaos-free run's bits exactly — a retried
    // completion that double-folded would drift the digest.
    let mut clean_env = churn_env.clone();
    clean_env.name = "chaos-churn-clean".into();
    clean_env.chaos = ChaosSpec::default();
    let clean = run_with_trainer(&clean_env, |_| {
        Arc::new(RustSgdTrainer) as Arc<dyn Trainer>
    })
    .unwrap();
    assert_ne!(report.community_digest, 0, "churn run produced no community model");
    assert_eq!(
        report.community_digest, clean.community_digest,
        "rejoined fleet must match the chaos-free fold bitwise"
    );
}

#[test]
fn refused_dials_shrink_the_registered_fleet() {
    // refuse_fraction victims never manage to register; the driver must
    // proceed with the smaller fleet instead of hanging on a barrier.
    let env = FederationEnv::builder("chaos-refuse")
        .learners(5)
        .rounds(1)
        .model(ModelSpec::mlp(4, 2, 8))
        .samples_per_learner(20)
        .batch_size(10)
        .stream_chunk_bytes(512)
        .task_timeout_ms(8_000)
        .heartbeat_ms(10_000)
        .chaos(ChaosSpec { seed: 3, refuse_fraction: 0.2, ..ChaosSpec::default() })
        .build();
    let report = run_with_trainer(&env, |_| {
        Arc::new(SyntheticTrainer::new(0, 0.01)) as Arc<dyn Trainer>
    })
    .unwrap();
    let r = &report.round_metrics[0];
    assert_eq!(r.participants, 4, "the refused learner never joins");
    assert_eq!(r.completed, 4);
}
