//! End-to-end federation scenarios over the real stack: protocol
//! comparisons, secure TCP transport, multi-round convergence, and the
//! async staleness semantics.

use metisfl::config::{FederationEnv, ModelSpec, Protocol};
use metisfl::controller::{scheduling, Controller};
use metisfl::driver::{run_simulated, run_with_trainer};
use metisfl::learner::trainer::RustSgdTrainer;
use metisfl::learner::{Dataset, Learner, LearnerServicer, SyntheticTrainer};
use metisfl::net::{connect, serve, Service};
use metisfl::proto::Message;
use metisfl::tensor::TensorModel;
use metisfl::util::Rng;
use std::sync::Arc;

fn env(name: &str, learners: usize, rounds: usize) -> FederationEnv {
    FederationEnv::builder(name)
        .learners(learners)
        .rounds(rounds)
        .model(ModelSpec::mlp(4, 3, 8))
        .samples_per_learner(20)
        .batch_size(10)
        .heartbeat_ms(10_000)
        .build()
}

#[test]
fn federated_sgd_converges_across_protocols() {
    for (label, protocol) in [
        ("sync", Protocol::Synchronous),
        ("semisync", Protocol::SemiSynchronous { lambda: 2.0 }),
    ] {
        let mut e = env(&format!("e2e-{label}"), 4, 8);
        e.protocol = protocol;
        e.learning_rate = 0.02;
        let report = run_with_trainer(&e, |_| Arc::new(RustSgdTrainer)).unwrap();
        let first = report.round_metrics.first().unwrap().community_eval_loss.unwrap();
        let last = report.round_metrics.last().unwrap().community_eval_loss.unwrap();
        assert!(last < first, "{label}: {first} -> {last}");
    }
}

#[test]
fn async_session_makes_progress_and_discounts_staleness() {
    let mut e = env("e2e-async", 4, 3);
    e.protocol = Protocol::Asynchronous { staleness_alpha: 1.0 };
    let report = run_simulated(&e).unwrap();
    assert_eq!(report.round_metrics.len(), 3);
    // 3 rounds × 4 learners = 12 community updates expected.
    let completed: usize = report.round_metrics.iter().map(|r| r.completed).sum();
    assert!(completed >= 8, "too few async completions: {completed}");
}

#[test]
fn async_staleness_weight_shrinks_with_lag() {
    // Unit-style check against the controller's async mixing path.
    let mut e = env("e2e-staleness", 2, 1);
    e.protocol = Protocol::Asynchronous { staleness_alpha: 1.0 };
    let ctrl = Controller::new(e, None).unwrap();
    let layout = ModelSpec::mlp(4, 3, 8).tensor_layout();
    let mut rng = Rng::new(1);
    let base = TensorModel::random_init(&layout, &mut rng);
    ctrl.ship_model(base.clone());
    let update = TensorModel::random_init(&layout, &mut rng);
    let proto = metisfl::proto::ModelProto::from_model(
        &update,
        metisfl::tensor::DType::F32,
        metisfl::tensor::ByteOrder::Little,
    );
    // Fresh learner: staleness 0 ⇒ w = 0.5.
    ctrl.handle(Message::MarkTaskCompleted {
        task_id: 0,
        learner_id: "fresh".into(),
        model: proto.clone(),
        meta: metisfl::proto::TaskMeta { num_samples: 10, ..Default::default() },
    });
    let (c1, _) = ctrl.community().unwrap();
    // "stale" learner dispatched at round 0, community now at round 1 ⇒
    // staleness 1 ⇒ w = 0.5 * 2^-1 = 0.25.
    ctrl.handle(Message::MarkTaskCompleted {
        task_id: 0,
        learner_id: "stale".into(),
        model: proto,
        meta: metisfl::proto::TaskMeta { num_samples: 10, ..Default::default() },
    });
    let (c2, _) = ctrl.community().unwrap();
    let fresh_step = (c1.tensors[0].data[0] - base.tensors[0].data[0]).abs();
    let stale_step = (c2.tensors[0].data[0] - c1.tensors[0].data[0]).abs();
    assert!(
        stale_step < fresh_step,
        "stale update moved the model more: {stale_step} vs {fresh_step}"
    );
}

#[test]
fn secure_channel_federation_over_tcp() {
    // Manual wiring: controller + learners over TCP with a PSK channel
    // (the driver's serve path is plaintext; this exercises net::secure
    // end-to-end through real federation messages).
    let psk = Some([9u8; 32]);
    let env = env("e2e-secure-tcp", 2, 1);
    let ctrl = Controller::new(env.clone(), psk).unwrap();
    let ctrl_server =
        serve("tcp://127.0.0.1:0", Arc::clone(&ctrl) as Arc<dyn Service>, psk).unwrap();
    let ctrl_ep = ctrl_server.endpoint();

    let mut learner_servers = Vec::new();
    for i in 0..2 {
        let dataset = Dataset::synthetic_housing(4, 20, 20, i as u64);
        let learner = Learner::new(
            &format!("learner-{i}"),
            &ctrl_ep,
            psk,
            Arc::new(SyntheticTrainer::new(0, 0.01)),
            dataset,
        );
        let server = serve(
            "tcp://127.0.0.1:0",
            Arc::new(LearnerServicer(Arc::clone(&learner))) as Arc<dyn Service>,
            psk,
        )
        .unwrap();
        learner.register(&server.endpoint()).unwrap();
        learner_servers.push(server);
    }
    ctrl.wait_for_learners(2, std::time::Duration::from_secs(10)).unwrap();
    let layout = env.model.tensor_layout();
    ctrl.ship_model(TensorModel::random_init(&layout, &mut Rng::new(3)));
    let report = scheduling::run_round(&ctrl, 1, &mut Rng::new(4)).unwrap();
    assert_eq!(report.completed, 2);
    assert!(report.community_eval_loss.unwrap().is_finite());

    // Wrong-PSK client must be rejected by the handshake.
    let r = connect(&ctrl_ep, Some([1u8; 32]))
        .and_then(|mut c| c.rpc(&Message::Heartbeat { from: "evil".into() }));
    assert!(r.is_err(), "mismatched PSK accepted");
}

#[test]
fn large_federation_smoke() {
    // 20 learners, sync, one round — exercises dispatch pool saturation.
    let report = run_simulated(&env("e2e-large", 20, 1)).unwrap();
    assert_eq!(report.round_metrics[0].participants, 20);
    assert_eq!(report.round_metrics[0].completed, 20);
}

#[test]
fn multi_round_model_actually_changes() {
    let e = env("e2e-drift", 3, 3);
    let report = run_simulated(&e).unwrap();
    // Synthetic trainer perturbs weights; losses must differ across rounds
    // (community model is actually being replaced each round).
    let losses: Vec<f64> =
        report.round_metrics.iter().filter_map(|r| r.community_eval_loss).collect();
    assert_eq!(losses.len(), 3);
    assert!(
        losses.windows(2).any(|w| (w[0] - w[1]).abs() > 1e-12),
        "community model never changed: {losses:?}"
    );
}
