//! End-to-end tests for the straggler-aware scheduling subsystem:
//! pacing-aware semi-sync on a heterogeneous fleet, deadline-quorum
//! rounds under dropout, and the pacing selector.

use metisfl::config::{FederationEnv, ModelSpec, Protocol, SelectorSpec};
use metisfl::driver::run_with_trainer;
use metisfl::learner::{SyntheticTrainer, Trainer};
use std::sync::Arc;
use std::time::Duration;

fn base_env(name: &str) -> FederationEnv {
    FederationEnv::builder(name)
        .learners(4)
        .rounds(5)
        .model(ModelSpec::mlp(4, 2, 8))
        .samples_per_learner(80)
        .batch_size(10)
        .heartbeat_ms(10_000)
        .build()
}

/// 10× speed skew: three fast learners, one straggler.
fn skewed_trainer(idx: usize) -> Arc<dyn Trainer> {
    let step_us = if idx == 3 { 5_000 } else { 500 };
    Arc::new(SyntheticTrainer::new(step_us, 0.01))
}

#[test]
fn pacing_semi_sync_shrinks_straggler_spread_vs_sync() {
    // Fixed-budget sync: every learner runs the same 8 steps, so the
    // round's completion spread is dominated by the straggler
    // (~8 × 4.5ms). Pacing-aware semi-sync hands the straggler the
    // fallback budget and the fast learners ~10× more steps, so
    // everyone's wall clock converges once profiles exist (round 2+).
    let sync_report = run_with_trainer(&base_env("sched-sync"), skewed_trainer).unwrap();
    let mut semi_env = base_env("sched-semi");
    semi_env.protocol = Protocol::SemiSynchronous { lambda: 1.0 };
    let semi_report = run_with_trainer(&semi_env, skewed_trainer).unwrap();

    let mean_spread = |rounds: &[metisfl::metrics::RoundReport]| {
        let s: Vec<Duration> = rounds.iter().skip(1).map(|r| r.completion_spread).collect();
        s.iter().sum::<Duration>() / s.len().max(1) as u32
    };
    let sync_spread = mean_spread(&sync_report.round_metrics);
    let semi_spread = mean_spread(&semi_report.round_metrics);
    // The sync fleet's spread must reflect the 10× skew at all…
    assert!(
        sync_spread > Duration::from_millis(10),
        "sync spread implausibly small: {sync_spread:?}"
    );
    // …and pacing must at least halve it (in practice it's far more).
    assert!(
        semi_spread < sync_spread / 2,
        "pacing-aware semi-sync did not shrink the straggler tail: \
         sync {sync_spread:?} vs semi {semi_spread:?}"
    );
    // Everyone still participates and completes under both protocols.
    for r in semi_report.round_metrics.iter().chain(&sync_report.round_metrics) {
        assert_eq!(r.participants, 4);
        assert_eq!(r.completed, 4);
    }
}

#[test]
fn paced_budgets_ride_the_streamed_dispatch_plane() {
    // Same skewed fleet, but over the chunked data plane: per-learner
    // budgets only change each learner's (small) Begin frame — the
    // model chunks stay encode-once — and the spread still collapses.
    let mut semi_env = base_env("sched-semi-streamed");
    semi_env.protocol = Protocol::SemiSynchronous { lambda: 1.0 };
    semi_env.stream_chunk_bytes = 2048;
    let report = run_with_trainer(&semi_env, skewed_trainer).unwrap();
    let spreads: Vec<Duration> =
        report.round_metrics.iter().skip(1).map(|r| r.completion_spread).collect();
    let mean = spreads.iter().sum::<Duration>() / spreads.len().max(1) as u32;
    // Fixed-budget straggler tail would be ~8 steps × 4.5ms ≈ 36ms;
    // paced rounds must stay well under half of that.
    assert!(
        mean < Duration::from_millis(18),
        "streamed paced semi-sync kept a straggler tail: {mean:?}"
    );
    for r in &report.round_metrics {
        assert_eq!(r.completed, 4);
        assert!(r.community_eval_loss.unwrap().is_finite());
    }
}

#[test]
fn quorum_rounds_absorb_a_dropout_learner() {
    // Learner 3 never completes (dropout 1.0 at the trainer level);
    // with an 0.75 quorum the round aggregates the three survivors at
    // the cut instead of burning the whole task timeout.
    let mut env = base_env("sched-quorum");
    env.rounds = 3;
    env.quorum_fraction = 0.75;
    env.task_timeout_ms = 30_000;
    let start = metisfl::util::Stopwatch::start();
    let report = run_with_trainer(&env, |idx| {
        let dropout = if idx == 3 { 0.999_999 } else { 0.0 };
        Arc::new(SyntheticTrainer::with_profile(0, 0.01, 0.0, dropout, 7 + idx as u64))
            as Arc<dyn Trainer>
    })
    .unwrap();
    assert!(
        start.elapsed() < Duration::from_secs(20),
        "quorum rounds should not wait out the 30s timeout"
    );
    assert_eq!(report.round_metrics.len(), 3);
    for r in &report.round_metrics {
        assert_eq!(r.participants, 4);
        assert_eq!(r.completed, 3, "round {} should close at the quorum cut", r.round);
        assert!(r.community_eval_loss.unwrap().is_finite());
    }
}

#[test]
fn pacing_selector_runs_partial_rounds() {
    let mut env = base_env("sched-selector");
    env.rounds = 4;
    env.selector = SelectorSpec::Pacing { k: 2, freshness_rounds: 2 };
    let report = run_with_trainer(&env, skewed_trainer).unwrap();
    assert_eq!(report.round_metrics.len(), 4);
    for r in &report.round_metrics {
        assert_eq!(r.participants, 2, "pacing selector must pick exactly k learners");
        assert_eq!(r.completed, 2);
    }
}

#[test]
fn hetero_env_file_drives_a_federation() {
    // The shipped heterogeneous-fleet recipe, shrunk to test scale:
    // semi-sync + quorum + pacing selector all active at once.
    let mut env = FederationEnv::from_file("envs/hetero_semi_sync.yaml").unwrap();
    env.learners = 4;
    env.rounds = 2;
    env.selector = SelectorSpec::Pacing { k: 3, freshness_rounds: 2 };
    // Keep the test fast: shrink the modeled step time 10×.
    if let metisfl::config::TrainerKind::Synthetic { step_time_us, .. } = &mut env.trainer {
        *step_time_us = 50;
    }
    let report = metisfl::driver::run_simulated(&env).unwrap();
    assert_eq!(report.round_metrics.len(), 2);
    for r in &report.round_metrics {
        assert_eq!(r.participants, 3);
        assert!(r.completed >= 3 * 4 / 5, "quorum floor: {}", r.completed);
    }
}
