//! Failure injection: crashing/hanging learners, timeouts, bad payloads,
//! mid-session shutdown — the controller must degrade gracefully (finish
//! rounds with the survivors or fail with a clean error, never hang or
//! panic).

use metisfl::config::{FederationEnv, ModelSpec};
use metisfl::controller::{scheduling, Controller};
use metisfl::driver::run_with_trainer;
use metisfl::learner::{Dataset, Learner, LearnerServicer, SyntheticTrainer, Trainer};
use metisfl::net::{serve, Service};
use metisfl::proto::{ErrorCode, EvalResult, Message, TaskMeta, TaskSpec};
use metisfl::tensor::TensorModel;
use metisfl::util::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

fn env(name: &str, learners: usize, timeout_ms: u64) -> FederationEnv {
    FederationEnv::builder(name)
        .learners(learners)
        .rounds(1)
        .model(ModelSpec::mlp(4, 2, 8))
        .samples_per_learner(20)
        .batch_size(10)
        .task_timeout_ms(timeout_ms)
        .heartbeat_ms(10_000)
        .build()
}

/// Trainer that fails on selected invocations.
struct FlakyTrainer {
    inner: SyntheticTrainer,
    fail: bool,
}

impl Trainer for FlakyTrainer {
    fn train(
        &self,
        model: &TensorModel,
        data: &Dataset,
        spec: &TaskSpec,
    ) -> anyhow::Result<(TensorModel, TaskMeta)> {
        if self.fail {
            anyhow::bail!("injected training failure");
        }
        self.inner.train(model, data, spec)
    }

    fn evaluate(&self, model: &TensorModel, data: &Dataset) -> anyhow::Result<EvalResult> {
        if self.fail {
            anyhow::bail!("injected eval failure");
        }
        self.inner.evaluate(model, data)
    }

    fn name(&self) -> &'static str {
        "flaky"
    }
}

/// Trainer that never completes (hang simulation within the timeout).
struct HangingTrainer;

impl Trainer for HangingTrainer {
    fn train(
        &self,
        _model: &TensorModel,
        _data: &Dataset,
        _spec: &TaskSpec,
    ) -> anyhow::Result<(TensorModel, TaskMeta)> {
        metisfl::util::Clock::system().sleep(std::time::Duration::from_secs(3600));
        unreachable!()
    }

    fn evaluate(&self, _model: &TensorModel, _data: &Dataset) -> anyhow::Result<EvalResult> {
        anyhow::bail!("hanging learner never evaluates")
    }

    fn name(&self) -> &'static str {
        "hanging"
    }
}

#[test]
fn round_completes_with_survivors_when_one_learner_fails() {
    let e = env("fail-one", 4, 5_000);
    let report = run_with_trainer(&e, |idx| {
        Arc::new(FlakyTrainer { inner: SyntheticTrainer::new(0, 0.01), fail: idx == 2 })
            as Arc<dyn Trainer>
    })
    .unwrap();
    let r = &report.round_metrics[0];
    assert_eq!(r.participants, 4);
    assert_eq!(r.completed, 3, "round should aggregate the 3 survivors");
    assert!(r.community_eval_loss.unwrap().is_finite());
}

#[test]
fn round_times_out_on_hanging_learner_and_continues() {
    let e = env("fail-hang", 3, 500); // 500ms timeout
    let start = metisfl::util::Stopwatch::start();
    let report = run_with_trainer(&e, |idx| {
        if idx == 0 {
            Arc::new(HangingTrainer) as Arc<dyn Trainer>
        } else {
            Arc::new(SyntheticTrainer::new(0, 0.01)) as Arc<dyn Trainer>
        }
    })
    .unwrap();
    assert!(start.elapsed() < std::time::Duration::from_secs(30), "driver hung");
    let r = &report.round_metrics[0];
    assert_eq!(r.completed, 2, "only the live learners complete");
}

#[test]
fn all_learners_failing_is_a_clean_error() {
    let e = env("fail-all", 3, 500);
    let result = run_with_trainer(&e, |_| {
        Arc::new(FlakyTrainer { inner: SyntheticTrainer::new(0, 0.01), fail: true })
            as Arc<dyn Trainer>
    });
    let err = format!("{:#}", result.unwrap_err());
    assert!(err.contains("no learner completed"), "{err}");
}

#[test]
fn controller_rejects_malformed_completions() {
    let e = env("fail-badmsg", 2, 1_000);
    let ctrl = Controller::new(e, None).unwrap();
    let layout = ModelSpec::mlp(4, 2, 8).tensor_layout();
    ctrl.ship_model(TensorModel::random_init(&layout, &mut Rng::new(1)));
    // A completion with a mismatched model layout must be rejected via
    // Error, not panic, and must not tick the round barrier.
    let wrong = TensorModel::random_init(&ModelSpec::mlp(4, 1, 4).tensor_layout(), &mut Rng::new(2));
    let reply = ctrl.handle(Message::MarkTaskCompleted {
        task_id: 1,
        learner_id: "evil".into(),
        model: metisfl::proto::ModelProto::from_model(
            &wrong,
            metisfl::tensor::DType::F32,
            metisfl::tensor::ByteOrder::Little,
        ),
        meta: TaskMeta::default(),
    });
    // Stored fine (layout is validated at aggregation), but aggregation
    // with the mismatched model must fail cleanly.
    match reply {
        Message::Ack { .. } | Message::Error { .. } => {}
        other => panic!("unexpected reply {other:?}"),
    }
}

#[test]
fn unknown_messages_get_error_replies() {
    let e = env("fail-unknown", 2, 1_000);
    let ctrl = Controller::new(e, None).unwrap();
    let reply = ctrl.handle(Message::Ack { task_id: 0, ok: true });
    assert!(matches!(reply, Message::Error { .. }));
}

#[test]
fn dead_learner_endpoint_fails_dispatch_not_process() {
    // Register a learner whose endpoint doesn't exist; the round must
    // fail cleanly (it was the only learner) without hanging.
    let e = env("fail-dead-ep", 1, 500);
    let ctrl = Controller::new(e, None).unwrap();
    ctrl.register_learner("ghost", "tcp://127.0.0.1:1", 10);
    let layout = ModelSpec::mlp(4, 2, 8).tensor_layout();
    ctrl.ship_model(TensorModel::random_init(&layout, &mut Rng::new(3)));
    let result = scheduling::run_round(&ctrl, 1, &mut Rng::new(4));
    let err = format!("{:#}", result.unwrap_err());
    assert!(err.contains("dispatch failed") || err.contains("every train dispatch failed"), "{err}");
}

#[test]
fn shutdown_mid_session_is_clean() {
    let e = env("fail-shutdown", 2, 5_000);
    let ctrl = Controller::new(e, None).unwrap();
    let server = serve("inproc://fail-shutdown-ctrl", Arc::clone(&ctrl) as Arc<dyn Service>, None)
        .unwrap();
    let mut conn = metisfl::net::connect(&server.endpoint(), None).unwrap();
    assert!(matches!(
        conn.rpc(&Message::Shutdown).unwrap(),
        Message::Ack { .. }
    ));
    // Further RPCs get clean errors.
    assert!(matches!(
        conn.rpc(&Message::GetModel).unwrap(),
        Message::Error { .. }
    ));
}

/// Service that drops the connection mid-reply (TCP-level fault).
struct Slammer(AtomicUsize);
impl Service for Slammer {
    fn handle(&self, _msg: Message) -> Message {
        self.0.fetch_add(1, Ordering::SeqCst);
        // Reply with an unparseable error body? The transport writes a
        // valid frame, so simulate a server bug via Error reply instead.
        Message::error(ErrorCode::Internal, "server fault injected")
    }
}

#[test]
fn rpc_surfaces_server_faults_as_errors() {
    let server = serve("tcp://127.0.0.1:0", Arc::new(Slammer(AtomicUsize::new(0))), None).unwrap();
    let mut c = metisfl::net::connect(&server.endpoint(), None).unwrap();
    match c.rpc(&Message::GetModel).unwrap() {
        Message::Error { code, detail } => {
            assert_eq!(code, ErrorCode::Internal);
            assert!(detail.contains("injected"));
        }
        other => panic!("unexpected {other:?}"),
    }
}

#[test]
fn learner_connection_broken_mid_recv_is_reestablished_on_next_dispatch() {
    // The flaky learner's first accepted connection swallows the request
    // and slams the socket shut, leaving the controller blocked in
    // `recv()` until EOF. `LearnerHandle::rpc_inner` must surface the
    // error, drop the cached connection, and re-dial on the *next*
    // dispatch — after which the round completes with every learner.
    use metisfl::net::frame::{read_frame, write_frame};

    let mut e = env("fail-reconnect", 2, 2_000);
    e.transport = metisfl::config::TransportKind::Tcp { base_port: 0 };
    let ctrl = Controller::new(e, None).unwrap();
    let ctrl_server =
        serve("tcp://127.0.0.1:0", Arc::clone(&ctrl) as Arc<dyn Service>, None).unwrap();
    let ctrl_ep = ctrl_server.endpoint();

    // Healthy learner on the stock TCP server.
    let healthy = Learner::new(
        "healthy",
        &ctrl_ep,
        None,
        Arc::new(SyntheticTrainer::new(0, 0.01)),
        Dataset::synthetic_housing(4, 20, 20, 1),
    );
    let healthy_server = serve(
        "tcp://127.0.0.1:0",
        Arc::new(LearnerServicer(Arc::clone(&healthy))) as Arc<dyn Service>,
        None,
    )
    .unwrap();
    healthy.register(&healthy_server.endpoint()).unwrap();

    // Flaky learner behind a hand-rolled accept loop.
    let flaky = Learner::new(
        "flaky",
        &ctrl_ep,
        None,
        Arc::new(SyntheticTrainer::new(0, 0.01)),
        Dataset::synthetic_housing(4, 20, 20, 2),
    );
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let flaky_ep = format!("tcp://{}", listener.local_addr().unwrap());
    let servicer = LearnerServicer(Arc::clone(&flaky));
    std::thread::spawn(move || {
        let mut first = true;
        for stream in listener.incoming() {
            let Ok(mut stream) = stream else { break };
            if first {
                first = false;
                // Consume the request, then close without replying.
                let _ = read_frame(&mut stream);
                drop(stream);
                continue;
            }
            while let Ok(Some(raw)) = read_frame(&mut stream) {
                let reply = match Message::decode(&raw) {
                    Ok(msg) => servicer.handle(msg),
                    Err(e) => Message::error(ErrorCode::Internal, format!("{e:#}")),
                };
                if write_frame(&mut stream, &reply.encode()).is_err() {
                    break;
                }
            }
        }
    });
    flaky.register(&flaky_ep).unwrap();
    ctrl.wait_for_learners(2, std::time::Duration::from_secs(10)).unwrap();

    let layout = ModelSpec::mlp(4, 2, 8).tensor_layout();
    ctrl.ship_model(TensorModel::random_init(&layout, &mut Rng::new(5)));

    // Round 1: the flaky dispatch dies mid-recv; survivors carry it.
    let r1 = scheduling::run_round(&ctrl, 1, &mut Rng::new(6)).unwrap();
    assert_eq!(r1.completed, 1, "flaky learner should have missed round 1");
    // Round 2: the handle re-dials and the full round completes.
    let r2 = scheduling::run_round(&ctrl, 2, &mut Rng::new(7)).unwrap();
    assert_eq!(r2.completed, 2, "connection was not re-established");
    assert!(r2.community_eval_loss.unwrap().is_finite());
}
