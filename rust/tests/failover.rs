//! Fleet health & aggregator failover acceptance (ISSUE: robustness).
//!
//! The load-bearing claims, end to end through a real two-tier
//! federation with a chaos-scheduled aggregator crash:
//!
//! 1. The driver detects the death through heartbeat probes (not by
//!    fiat), re-homes the orphaned shard's learners onto the survivors
//!    mid-run, and the fleet recovers within `rounds_to_recover <= 2`.
//! 2. The round barrier and quorum re-target the new topology: every
//!    round completes with the full surviving tier.
//! 3. **Bitwise**: the post-failover community model equals the flat
//!    fold regrouped over the surviving-plus-re-homed topology —
//!    failover is pure plumbing, zero math drift.
//! 4. The same env + seed reproduces the same victim and outcome.

use metisfl::config::{
    AggregationBackend, AggregationSpec, FederationEnv, ModelSpec, TopologySpec,
};
use metisfl::controller::aggregation::{Backend, Contribution};
use metisfl::controller::health::HealthSpec;
use metisfl::controller::hierarchy::{rehome_assignments, two_tier_reference};
use metisfl::driver::{self, run_with_trainer};
use metisfl::harness::loadtest::model_digest;
use metisfl::learner::trainer::RustSgdTrainer;
use metisfl::learner::Trainer;
use metisfl::net::chaos::ChaosSpec;
use metisfl::proto::TaskSpec;
use std::sync::Arc;

const LEARNERS: usize = 6;
const AGGS: usize = 3;
const ROUNDS: usize = 3;
const KILL_ROUND: u64 = 2;

/// A deterministic two-tier env with one aggregator scheduled to
/// crash-stop right before round 2 opens. Millisecond-scale health
/// thresholds keep the detection loop fast without changing its shape.
fn failover_env(name: &str) -> FederationEnv {
    let mut e = FederationEnv::builder(name)
        .learners(LEARNERS)
        .rounds(ROUNDS)
        .model(ModelSpec::mlp(8, 3, 32))
        .aggregation(AggregationSpec {
            backend: AggregationBackend::Sequential,
            ..AggregationSpec::default()
        })
        .samples_per_learner(12)
        .batch_size(6)
        .learning_rate(0.05)
        .quorum_fraction(1.0)
        .stream_chunk_bytes(2048)
        .heartbeat_ms(5_000)
        .health(HealthSpec { interval_ms: 2, suspect_after: 2, dead_after: 3, ewma_alpha: 0.2 })
        .seed(0xFA_11)
        .build();
    e.topology = TopologySpec { aggregators: AGGS, shard_quorum: 0.0 };
    e.chaos = ChaosSpec { kill_aggregator_at_round: KILL_ROUND, ..ChaosSpec::default() };
    e
}

fn sgd(_idx: usize) -> Arc<dyn Trainer> {
    Arc::new(RustSgdTrainer)
}

/// Replicate what every tier saw, round for round: each learner trains
/// the previous community model on its deterministic dataset, lands in
/// its (round-dependent) shard, each shard folds arrivals in id-sorted
/// order, and the root folds the shard partials. Rounds at or past the
/// kill use the post-failover grouping; the victim's slot goes empty
/// and [`two_tier_reference`] skips it.
fn reference_digest(env: &FederationEnv, pre: &[usize], post: &[usize]) -> u64 {
    let spec = TaskSpec {
        epochs: env.local_epochs,
        batch_size: env.batch_size,
        learning_rate: env.learning_rate,
        step_budget: 0,
    };
    let mut community = driver::initial_model(env);
    for round in 1..=ROUNDS as u64 {
        let assign = if round >= KILL_ROUND { post } else { pre };
        let mut shards: Vec<Vec<(String, Contribution)>> =
            (0..AGGS).map(|_| Vec::new()).collect();
        for i in 0..LEARNERS {
            let data = driver::learner_dataset(env, i);
            let (model, meta) = RustSgdTrainer.train(&community, &data, &spec).unwrap();
            shards[assign[i]].push((
                format!("learner-{i}"),
                Contribution { model: Arc::new(model), weight: meta.num_samples as f64 },
            ));
        }
        let shards: Vec<Vec<Contribution>> = shards
            .into_iter()
            .map(|mut shard| {
                shard.sort_by(|a, b| a.0.cmp(&b.0)); // the barrier sorts ids as strings
                shard.into_iter().map(|(_, c)| c).collect()
            })
            .collect();
        community = two_tier_reference(&community, &shards, &Backend::Sequential).unwrap();
    }
    model_digest(&community)
}

#[test]
fn aggregator_death_rehomes_shard_and_stays_bitwise() {
    let env = failover_env("failover-e2e");
    let victim = env.chaos.kill_victim(AGGS, env.seed).expect("kill plan armed");
    let report = run_with_trainer(&env, sgd).unwrap();

    // --- Claim 1: one failover, fast recovery -------------------------
    let orphans: Vec<usize> =
        (0..LEARNERS).filter(|&i| env.topology.shard_of(i) == victim).collect();
    assert_eq!(report.failovers, 1);
    assert_eq!(report.rehomed_learners, orphans.len() as u64);
    assert!(
        (1..=2).contains(&report.rounds_to_recover),
        "fleet took {} round(s) to recover (acceptance bar: <= 2)",
        report.rounds_to_recover
    );
    assert_eq!(report.retry_give_ups, 0, "failover must not burn retry budgets");

    // --- Claim 2: quorum fires every round on the live topology -------
    assert_eq!(report.round_metrics.len(), ROUNDS);
    for r in &report.round_metrics {
        let expect = if r.round < KILL_ROUND { AGGS } else { AGGS - 1 };
        assert_eq!(r.participants, expect, "round {} participants", r.round);
        assert_eq!(r.completed, expect, "round {} incomplete", r.round);
    }

    // --- Claim 3: bitwise equal to the re-homed reference fold --------
    let pre: Vec<usize> = (0..LEARNERS).map(|i| env.topology.shard_of(i)).collect();
    let survivors: Vec<usize> = (0..AGGS).filter(|&s| s != victim).collect();
    let plan = rehome_assignments(orphans.len(), survivors.len());
    let mut post = pre.clone();
    for (j, &i) in orphans.iter().enumerate() {
        post[i] = survivors[plan[j]];
    }
    assert_ne!(report.community_digest, 0, "run produced no community model");
    assert_eq!(
        report.community_digest,
        reference_digest(&env, &pre, &post),
        "post-failover community drifted from the re-homed reference fold"
    );
}

#[test]
fn same_seed_reproduces_the_same_victim_and_outcome() {
    let a = run_with_trainer(&failover_env("failover-repro"), sgd).unwrap();
    let b = run_with_trainer(&failover_env("failover-repro"), sgd).unwrap();
    assert_ne!(a.community_digest, 0);
    assert_eq!(a.community_digest, b.community_digest, "same env + seed must be bitwise stable");
    assert_eq!(a.failovers, b.failovers);
    assert_eq!(a.rehomed_learners, b.rehomed_learners);
    assert_eq!(a.rounds_to_recover, b.rounds_to_recover);
}
