//! Cross-module integration: config → controller → learner → driver over
//! both transports, protocol variants, aggregation rules/backends, stores,
//! and the YAML config surface.

use metisfl::config::{
    AggregationBackend, AggregationSpec, FederationEnv, ModelSpec, TransportKind,
};
use metisfl::controller::store::{InMemoryStore, ModelStore, OnDiskStore, StoredModel};
use metisfl::driver::{run_simulated, run_with_trainer};
use metisfl::learner::trainer::RustSgdTrainer;
use metisfl::learner::SyntheticTrainer;
use metisfl::metrics::FedOp;
use metisfl::proto::TaskMeta;
use metisfl::tensor::TensorModel;
use metisfl::util::Rng;
use std::sync::Arc;

fn base_env(name: &str) -> FederationEnv {
    FederationEnv::builder(name)
        .learners(4)
        .rounds(2)
        .model(ModelSpec::mlp(4, 3, 8))
        .samples_per_learner(20)
        .batch_size(10)
        .heartbeat_ms(50)
        .build()
}

#[test]
fn sync_round_metrics_are_complete_and_ordered() {
    let report = run_simulated(&base_env("int-sync")).unwrap();
    assert_eq!(report.round_metrics.len(), 2);
    for r in &report.round_metrics {
        assert_eq!(r.completed, 4);
        assert!(r.train_round >= r.train_dispatch, "{r:?}");
        assert!(r.eval_round >= r.eval_dispatch, "{r:?}");
        assert!(
            r.federation_round >= r.train_round + r.aggregation,
            "round total must cover train + aggregation: {r:?}"
        );
    }
    // Controller-side op metrics were recorded too.
    assert!(report.op_metrics.count(FedOp::Aggregation) >= 2);
    assert!(report.op_metrics.count(FedOp::TrainDispatch) >= 2);
    assert!(report.op_metrics.count(FedOp::StoreInsert) >= 8);
}

#[test]
fn all_aggregation_rules_run_end_to_end() {
    for rule in ["fedavg", "fedadam", "fedyogi", "fedadagrad"] {
        let mut env = base_env(&format!("int-rule-{rule}"));
        env.aggregation = AggregationSpec { rule: rule.into(), ..Default::default() };
        let report = run_simulated(&env).unwrap();
        assert_eq!(report.round_metrics.len(), 2, "{rule}");
        assert!(report.final_loss.unwrap().is_finite(), "{rule}");
    }
}

#[test]
fn sequential_and_parallel_backends_agree_on_learned_model() {
    // Identical seeds + deterministic trainers ⇒ same community loss.
    let mut seq_env = base_env("int-backend-seq");
    seq_env.aggregation.backend = AggregationBackend::Sequential;
    let mut par_env = base_env("int-backend-par");
    par_env.aggregation.backend = AggregationBackend::Parallel;
    par_env.aggregation.threads = 3;
    let a = run_with_trainer(&seq_env, |_| Arc::new(RustSgdTrainer)).unwrap();
    let b = run_with_trainer(&par_env, |_| Arc::new(RustSgdTrainer)).unwrap();
    let la = a.final_loss.unwrap();
    let lb = b.final_loss.unwrap();
    assert!((la - lb).abs() < 1e-9, "{la} vs {lb}");
}

#[test]
fn chunked_backend_agrees_end_to_end_and_over_multiple_rounds() {
    // The chunked backend drives whole federations to the same losses as
    // sequential aggregation, across several rounds of scratch reuse.
    let mut seq_env = base_env("int-backend-seq2");
    seq_env.rounds = 4;
    seq_env.aggregation.backend = AggregationBackend::Sequential;
    let mut chk_env = base_env("int-backend-chunked");
    chk_env.rounds = 4;
    chk_env.aggregation.backend = AggregationBackend::Chunked;
    chk_env.aggregation.threads = 3;
    let a = run_with_trainer(&seq_env, |_| Arc::new(RustSgdTrainer)).unwrap();
    let b = run_with_trainer(&chk_env, |_| Arc::new(RustSgdTrainer)).unwrap();
    assert_eq!(a.round_metrics.len(), b.round_metrics.len());
    for (ra, rb) in a.round_metrics.iter().zip(&b.round_metrics) {
        let (la, lb) = (ra.community_eval_loss.unwrap(), rb.community_eval_loss.unwrap());
        assert!((la - lb).abs() < 1e-12, "round {}: {la} vs {lb}", ra.round);
    }
}

#[test]
fn tcp_and_inproc_transports_agree() {
    let mut tcp_env = base_env("int-tcp");
    tcp_env.transport = TransportKind::Tcp { base_port: 0 };
    let a = run_with_trainer(&tcp_env, |_| Arc::new(RustSgdTrainer)).unwrap();
    let b = run_with_trainer(&base_env("int-inproc"), |_| Arc::new(RustSgdTrainer)).unwrap();
    assert!((a.final_loss.unwrap() - b.final_loss.unwrap()).abs() < 1e-9);
    assert_eq!(a.round_metrics.len(), b.round_metrics.len());
}

#[test]
fn on_disk_store_survives_completions() {
    // Exercise the §5 future-work store through the controller service.
    use metisfl::controller::Controller;
    use metisfl::net::Service;
    use metisfl::proto::{Message, ModelProto};
    use metisfl::tensor::{ByteOrder, DType};

    let dir = std::env::temp_dir().join(format!("metisfl-int-store-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let env = base_env("int-disk-store");
    let ctrl = Controller::new(env, None).unwrap();
    ctrl.set_store(Box::new(OnDiskStore::open(&dir).unwrap()));

    let layout = ModelSpec::mlp(4, 3, 8).tensor_layout();
    let mut rng = Rng::new(5);
    ctrl.ship_model(TensorModel::random_init(&layout, &mut rng));
    for id in ["a", "b"] {
        let m = TensorModel::random_init(&layout, &mut rng);
        let reply = ctrl.handle(Message::MarkTaskCompleted {
            task_id: 1,
            learner_id: id.into(),
            model: ModelProto::from_model(&m, DType::F32, ByteOrder::Little),
            meta: TaskMeta { num_samples: 10, ..Default::default() },
        });
        assert!(matches!(reply, Message::Ack { ok: true, .. }), "{reply:?}");
    }
    // Entries landed on disk and survive reopen.
    let reopened = OnDiskStore::open(&dir).unwrap();
    assert_eq!(reopened.len(), 2);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn store_parity_memory_vs_disk() {
    let dir = std::env::temp_dir().join(format!("metisfl-int-parity-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let layout = ModelSpec::mlp(4, 2, 8).tensor_layout();
    let mut rng = Rng::new(6);
    let mut mem = InMemoryStore::new();
    let mut disk = OnDiskStore::open(&dir).unwrap();
    for round in 0..3u64 {
        for learner in ["x", "y"] {
            let entry = StoredModel {
                learner_id: learner.into(),
                round,
                meta: TaskMeta { num_samples: 7, ..Default::default() },
                model: Arc::new(TensorModel::random_init(&layout, &mut rng)),
            };
            mem.insert(entry.clone()).unwrap();
            disk.insert(entry).unwrap();
        }
    }
    for learner in ["x", "y"] {
        let a = mem.latest(learner).unwrap().unwrap();
        let b = disk.latest(learner).unwrap().unwrap();
        assert_eq!(a.round, b.round);
        assert_eq!(a.model, b.model);
    }
    assert_eq!(mem.len(), disk.len());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn participation_fraction_selects_subset() {
    let mut env = base_env("int-participation");
    env.learners = 6;
    env.participation = 0.5;
    let report = run_simulated(&env).unwrap();
    for r in &report.round_metrics {
        assert_eq!(r.participants, 3, "{r:?}");
        assert_eq!(r.completed, 3);
    }
}

#[test]
fn heterogeneous_trainers_still_synchronize() {
    let env = base_env("int-hetero");
    let report = run_with_trainer(&env, |idx| {
        Arc::new(SyntheticTrainer::new(200 * idx as u64, 0.01))
            as Arc<dyn metisfl::learner::Trainer>
    })
    .unwrap();
    for r in &report.round_metrics {
        assert_eq!(r.completed, 4);
    }
}

#[test]
fn yaml_env_file_drives_a_federation() {
    let yaml = r#"
name: from-yaml
learners: 3
rounds: 1
model:
  input_dim: 4
  hidden_layers: 2
  hidden_units: 8
samples_per_learner: 20
batch_size: 10
trainer:
  kind: synthetic
  step_time_us: 0
"#;
    let env = FederationEnv::from_yaml(yaml).unwrap();
    let report = run_simulated(&env).unwrap();
    assert_eq!(report.env_name, "from-yaml");
    assert_eq!(report.round_metrics.len(), 1);
}

#[test]
fn monitor_reports_zero_missed_heartbeats_on_healthy_run() {
    let mut env = base_env("int-heartbeat");
    env.heartbeat_ms = 5;
    let report = run_simulated(&env).unwrap();
    assert_eq!(report.missed_heartbeats, 0);
}

#[test]
fn shipped_env_files_parse_and_validate() {
    for f in [
        "envs/quickstart.yaml",
        "envs/xla_training.yaml",
        "envs/paper_stress_100k.yaml",
        "envs/async_semi.yaml",
        "envs/streamed_delta.yaml",
        "envs/streamed_delta_rle.yaml",
        "envs/hetero_semi_sync.yaml",
    ] {
        let env = FederationEnv::from_file(f).unwrap_or_else(|e| panic!("{f}: {e:#}"));
        env.validate().unwrap_or_else(|e| panic!("{f}: {e:#}"));
    }
    // The paper-scale env really is ~100k params.
    let env = FederationEnv::from_file("envs/paper_stress_100k.yaml").unwrap();
    assert!((90_000..130_000).contains(&env.model.param_count()));
}

#[test]
fn dp_privatized_federation_round() {
    // Learner-side DP (Table 1 "Private Training"): wrap the trainer so
    // every upload is clipped + noised before it leaves the learner.
    use metisfl::crypto::{privatize_update, DpConfig};
    use metisfl::learner::{Dataset, Trainer};
    use metisfl::proto::{EvalResult, TaskSpec};

    struct DpTrainer(SyntheticTrainer, DpConfig);
    impl Trainer for DpTrainer {
        fn train(
            &self,
            model: &TensorModel,
            data: &Dataset,
            spec: &TaskSpec,
        ) -> anyhow::Result<(TensorModel, metisfl::proto::TaskMeta)> {
            let (mut out, meta) = self.0.train(model, data, spec)?;
            let mut rng = Rng::new(0xD9);
            privatize_update(&mut out, model, &self.1, &mut rng);
            Ok((out, meta))
        }
        fn evaluate(&self, model: &TensorModel, data: &Dataset) -> anyhow::Result<EvalResult> {
            self.0.evaluate(model, data)
        }
        fn name(&self) -> &'static str {
            "dp"
        }
    }

    let env = base_env("int-dp");
    let cfg = DpConfig { clip_norm: 0.5, noise_multiplier: 0.01 };
    let report = run_with_trainer(&env, move |_| {
        Arc::new(DpTrainer(SyntheticTrainer::new(0, 0.05), cfg)) as Arc<dyn Trainer>
    })
    .unwrap();
    assert_eq!(report.round_metrics.len(), 2);
    assert!(report.final_loss.unwrap().is_finite());
    // ε accounting sanity for the chosen σ.
    assert!(cfg.epsilon(1e-5) > 0.0);
}
