//! Trace record/replay determinism gates.
//!
//! Property swept: for every wire codec × chaos seed × quorum fraction
//! cell, recording a loadtest run and re-driving the trace through a
//! fresh controller on a simulated clock must reproduce the recorded
//! community model **bitwise** (and, for chaos-free runs, the full
//! replayable counter set). The chaos cells exercise the interesting
//! timeline shapes: partial streams, quorum cuts with stragglers, and
//! late completions folded through the staleness path.

use metisfl::config::{FederationEnv, ModelSpec, TopologySpec, WireCodecChoice};
use metisfl::controller::health::HealthSpec;
use metisfl::driver::run_recorded;
use metisfl::harness::{run_loadtest, LoadtestConfig};
use metisfl::net::chaos::ChaosSpec;
use metisfl::runtime::trace::{replay_trace, Trace};

fn record_cfg(codec: WireCodecChoice, chaos_seed: u64, quorum: f64) -> LoadtestConfig {
    let mut cfg = LoadtestConfig::quick();
    cfg.learners = 6;
    cfg.rounds = 2;
    cfg.quorum_fraction = quorum;
    cfg.wire_codec = codec;
    cfg.record = true;
    cfg.seed = 0x7E57 ^ chaos_seed;
    if chaos_seed != 0 {
        cfg.chaos = ChaosSpec {
            seed: chaos_seed,
            sever_fraction: 0.2,
            sever_after_sends: 4,
            ..ChaosSpec::default()
        };
    }
    cfg
}

/// Record one run, replay its trace, and return `(report digest,
/// replay outcome)` after asserting the bitwise gate.
fn record_and_replay(cfg: &LoadtestConfig) -> (u64, metisfl::runtime::trace::ReplayOutcome) {
    let report = run_loadtest(cfg).expect("recorded loadtest run");
    let trace = report.trace.as_ref().expect("cfg.record must yield a trace");
    let outcome = replay_trace(trace).expect("replay must apply cleanly");
    assert!(
        outcome.matches(),
        "replay diverged (codec {:?}, chaos seed {}, quorum {}): {:?}",
        cfg.wire_codec,
        cfg.chaos.seed,
        cfg.quorum_fraction,
        outcome.divergence
    );
    assert_eq!(outcome.replayed_digest, outcome.recorded_digest);
    (report.community_digest, outcome)
}

#[test]
fn replay_reproduces_clean_runs_bitwise_across_codecs() {
    for codec in [WireCodecChoice::F32, WireCodecChoice::Delta, WireCodecChoice::DeltaRle] {
        let cfg = record_cfg(codec, 0, 1.0);
        let (report_digest, outcome) = record_and_replay(&cfg);
        // A full-quorum clean run seals with nothing in flight: the
        // report's digest is the footer's digest, and every replayable
        // counter must match exactly.
        assert_eq!(
            outcome.recorded_digest, report_digest,
            "codec {codec:?}: footer digest != report digest"
        );
        assert!(
            outcome.counter_diffs().is_empty(),
            "codec {codec:?}: counter drift {:?}",
            outcome.counter_diffs()
        );
        assert!(outcome.events > 0);
    }
}

#[test]
fn replay_reproduces_chaos_quorum_runs_bitwise_across_codecs() {
    // Severed links + deadline quorums: rounds close at the cut, doomed
    // partial streams litter the timeline, and stragglers may late-fold.
    // The digest gate is absolute; counters are informational here (a
    // victim's decode work can still be in flight when the trace seals).
    for (codec, chaos_seed) in [
        (WireCodecChoice::F32, 7),
        (WireCodecChoice::Delta, 9),
        (WireCodecChoice::DeltaRle, 11),
    ] {
        let cfg = record_cfg(codec, chaos_seed, 0.6);
        record_and_replay(&cfg);
    }
}

#[test]
fn replay_reproduces_a_simulated_clock_recording() {
    // Recording on a virtual clock: ticks are discrete-event times, and
    // the replay (also sim-clocked) must land on the same bits.
    let mut cfg = record_cfg(WireCodecChoice::DeltaRle, 0, 1.0);
    cfg.sim = true;
    let (report_digest, outcome) = record_and_replay(&cfg);
    assert_eq!(outcome.recorded_digest, report_digest);
    assert!(outcome.counter_diffs().is_empty(), "{:?}", outcome.counter_diffs());
}

#[test]
fn replaying_twice_is_itself_deterministic() {
    let cfg = record_cfg(WireCodecChoice::Delta, 7, 0.6);
    let report = run_loadtest(&cfg).expect("recorded loadtest run");
    let trace = report.trace.expect("trace");
    let a = replay_trace(&trace).expect("first replay");
    let b = replay_trace(&trace).expect("second replay");
    assert!(a.matches() && b.matches());
    assert_eq!(a.replayed_digest, b.replayed_digest);
    assert_eq!(a.replayed_counters, b.replayed_counters);
}

/// A two-tier driver env for the hierarchical replay gates; `kill > 0`
/// arms the chaos kill (with millisecond health thresholds so the
/// detection loop stays fast).
fn two_tier_env(name: &str, kill: u64) -> FederationEnv {
    let mut e = FederationEnv::builder(name)
        .learners(6)
        .rounds(2)
        .model(ModelSpec::mlp(6, 2, 16))
        .quorum_fraction(1.0)
        .stream_chunk_bytes(2048)
        .heartbeat_ms(5_000)
        .seed(0x7133)
        .build();
    e.topology = TopologySpec { aggregators: 3, shard_quorum: 0.0 };
    if kill > 0 {
        e.chaos = ChaosSpec { kill_aggregator_at_round: kill, ..ChaosSpec::default() };
        e.health = HealthSpec { interval_ms: 2, suspect_after: 2, dead_after: 3, ewma_alpha: 0.2 };
    }
    e
}

#[test]
fn replay_reproduces_a_two_tier_driver_recording() {
    // Hierarchical topology through the driver's recorder: the trace
    // captures only the ROOT's frames (the aggregator tier's
    // registrations and partial-sum uploads), so a fresh sim-clocked
    // controller must re-fold the tier's partials to the same bits.
    let (report, trace) = run_recorded(&two_tier_env("replay-two-tier", 0)).unwrap();
    let trace = trace.expect("driver recording must yield a trace");
    let outcome = replay_trace(&trace).expect("replay must apply cleanly");
    assert!(outcome.matches(), "two-tier replay diverged: {:?}", outcome.divergence);
    assert_ne!(report.community_digest, 0);
    assert_eq!(outcome.recorded_digest, report.community_digest);
    assert_eq!(outcome.replayed_digest, report.community_digest);
}

#[test]
fn replay_reproduces_a_failover_run_including_the_rehomed_rounds() {
    // The failover's root-side mutations (the dead aggregator's
    // deregistration, the survivors' refreshed weights) travel over the
    // wire, so the recorded timeline replays the re-homed topology
    // exactly — registrations, partial sums, and all.
    let (report, trace) = run_recorded(&two_tier_env("replay-failover", 2)).unwrap();
    assert_eq!(report.failovers, 1, "the kill plan must have fired");
    let trace = trace.expect("driver recording must yield a trace");
    let outcome = replay_trace(&trace).expect("replay must apply cleanly");
    assert!(outcome.matches(), "failover replay diverged: {:?}", outcome.divergence);
    assert_eq!(outcome.replayed_digest, report.community_digest);
}

#[test]
fn trace_embeds_a_parsable_environment() {
    let cfg = record_cfg(WireCodecChoice::F32, 0, 1.0);
    let report = run_loadtest(&cfg).expect("recorded loadtest run");
    let trace = Trace::decode(report.trace.as_ref().unwrap()).expect("decode");
    let env = metisfl::config::FederationEnv::from_yaml(&trace.env_source)
        .expect("embedded env must round-trip");
    assert_eq!(env.learners, cfg.learners);
    assert_eq!(env.rounds, cfg.rounds);
    assert_eq!(env.wire_codec, cfg.wire_codec);
    assert_eq!(env.seed, cfg.seed);
    assert_eq!(trace.community_digest, report.community_digest);
}
