//! Trace record/replay determinism gates.
//!
//! Property swept: for every wire codec × chaos seed × quorum fraction
//! cell, recording a loadtest run and re-driving the trace through a
//! fresh controller on a simulated clock must reproduce the recorded
//! community model **bitwise** (and, for chaos-free runs, the full
//! replayable counter set). The chaos cells exercise the interesting
//! timeline shapes: partial streams, quorum cuts with stragglers, and
//! late completions folded through the staleness path.

use metisfl::config::WireCodecChoice;
use metisfl::harness::{run_loadtest, LoadtestConfig};
use metisfl::net::chaos::ChaosSpec;
use metisfl::runtime::trace::{replay_trace, Trace};

fn record_cfg(codec: WireCodecChoice, chaos_seed: u64, quorum: f64) -> LoadtestConfig {
    let mut cfg = LoadtestConfig::quick();
    cfg.learners = 6;
    cfg.rounds = 2;
    cfg.quorum_fraction = quorum;
    cfg.wire_codec = codec;
    cfg.record = true;
    cfg.seed = 0x7E57 ^ chaos_seed;
    if chaos_seed != 0 {
        cfg.chaos = ChaosSpec {
            seed: chaos_seed,
            sever_fraction: 0.2,
            sever_after_sends: 4,
            ..ChaosSpec::default()
        };
    }
    cfg
}

/// Record one run, replay its trace, and return `(report digest,
/// replay outcome)` after asserting the bitwise gate.
fn record_and_replay(cfg: &LoadtestConfig) -> (u64, metisfl::runtime::trace::ReplayOutcome) {
    let report = run_loadtest(cfg).expect("recorded loadtest run");
    let trace = report.trace.as_ref().expect("cfg.record must yield a trace");
    let outcome = replay_trace(trace).expect("replay must apply cleanly");
    assert!(
        outcome.matches(),
        "replay diverged (codec {:?}, chaos seed {}, quorum {}): {:?}",
        cfg.wire_codec,
        cfg.chaos.seed,
        cfg.quorum_fraction,
        outcome.divergence
    );
    assert_eq!(outcome.replayed_digest, outcome.recorded_digest);
    (report.community_digest, outcome)
}

#[test]
fn replay_reproduces_clean_runs_bitwise_across_codecs() {
    for codec in [WireCodecChoice::F32, WireCodecChoice::Delta, WireCodecChoice::DeltaRle] {
        let cfg = record_cfg(codec, 0, 1.0);
        let (report_digest, outcome) = record_and_replay(&cfg);
        // A full-quorum clean run seals with nothing in flight: the
        // report's digest is the footer's digest, and every replayable
        // counter must match exactly.
        assert_eq!(
            outcome.recorded_digest, report_digest,
            "codec {codec:?}: footer digest != report digest"
        );
        assert!(
            outcome.counter_diffs().is_empty(),
            "codec {codec:?}: counter drift {:?}",
            outcome.counter_diffs()
        );
        assert!(outcome.events > 0);
    }
}

#[test]
fn replay_reproduces_chaos_quorum_runs_bitwise_across_codecs() {
    // Severed links + deadline quorums: rounds close at the cut, doomed
    // partial streams litter the timeline, and stragglers may late-fold.
    // The digest gate is absolute; counters are informational here (a
    // victim's decode work can still be in flight when the trace seals).
    for (codec, chaos_seed) in [
        (WireCodecChoice::F32, 7),
        (WireCodecChoice::Delta, 9),
        (WireCodecChoice::DeltaRle, 11),
    ] {
        let cfg = record_cfg(codec, chaos_seed, 0.6);
        record_and_replay(&cfg);
    }
}

#[test]
fn replay_reproduces_a_simulated_clock_recording() {
    // Recording on a virtual clock: ticks are discrete-event times, and
    // the replay (also sim-clocked) must land on the same bits.
    let mut cfg = record_cfg(WireCodecChoice::DeltaRle, 0, 1.0);
    cfg.sim = true;
    let (report_digest, outcome) = record_and_replay(&cfg);
    assert_eq!(outcome.recorded_digest, report_digest);
    assert!(outcome.counter_diffs().is_empty(), "{:?}", outcome.counter_diffs());
}

#[test]
fn replaying_twice_is_itself_deterministic() {
    let cfg = record_cfg(WireCodecChoice::Delta, 7, 0.6);
    let report = run_loadtest(&cfg).expect("recorded loadtest run");
    let trace = report.trace.expect("trace");
    let a = replay_trace(&trace).expect("first replay");
    let b = replay_trace(&trace).expect("second replay");
    assert!(a.matches() && b.matches());
    assert_eq!(a.replayed_digest, b.replayed_digest);
    assert_eq!(a.replayed_counters, b.replayed_counters);
}

#[test]
fn trace_embeds_a_parsable_environment() {
    let cfg = record_cfg(WireCodecChoice::F32, 0, 1.0);
    let report = run_loadtest(&cfg).expect("recorded loadtest run");
    let trace = Trace::decode(report.trace.as_ref().unwrap()).expect("decode");
    let env = metisfl::config::FederationEnv::from_yaml(&trace.env_source)
        .expect("embedded env must round-trip");
    assert_eq!(env.learners, cfg.learners);
    assert_eq!(env.rounds, cfg.rounds);
    assert_eq!(env.wire_codec, cfg.wire_codec);
    assert_eq!(env.seed, cfg.seed);
    assert_eq!(trace.community_digest, report.community_digest);
}
