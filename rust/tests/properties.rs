//! Property-based tests on coordinator invariants: aggregation algebra,
//! codec roundtrips, wire-format robustness, selector guarantees, store
//! semantics, and crypto cancellation — all via the crate's own
//! mini-prop framework (`util::prop`).

use metisfl::config::ModelSpec;
use metisfl::controller::aggregation::{
    AggregationRule, Backend, Contribution, FedAvg, ScratchArena,
};
use metisfl::controller::selector::{SelectionCtx, Selector};
use metisfl::controller::store::{InMemoryStore, ModelStore, StoredModel};
use metisfl::crypto::PairwiseMasker;
use metisfl::proto::client;
use metisfl::proto::{
    Message, ModelProto, StreamPurpose, TaskMeta, TaskSpec, TensorLayoutProto,
};
use metisfl::tensor::{ByteOrder, DType, TensorModel};
use metisfl::util::prop::{prop_check, Gen};
use metisfl::util::{Rng, ThreadPool};
use std::collections::HashMap;
use std::sync::Arc;

fn rand_model(g: &mut Gen, spec: &ModelSpec) -> TensorModel {
    let seed = g.rng().next_u64();
    TensorModel::random_init(&spec.tensor_layout(), &mut Rng::new(seed))
}

fn rand_spec(g: &mut Gen) -> ModelSpec {
    ModelSpec::mlp(g.usize_in(1..6), g.usize_in(1..5), g.usize_in(1..12))
}

#[test]
fn prop_fedavg_idempotent_on_identical_models() {
    prop_check("fedavg(m, m, ..., m) == m", 40, |g| {
        let spec = rand_spec(g);
        let m = Arc::new(rand_model(g, &spec));
        let n = g.usize_in(1..6);
        let cs: Vec<Contribution> = (0..n)
            .map(|_| Contribution { model: Arc::clone(&m), weight: g.f64_in(0.5, 100.0) })
            .collect();
        let agg = FedAvg::new().aggregate(&m, &cs, &Backend::Sequential).unwrap();
        assert!(agg.max_abs_diff(&m) < 1e-4);
    });
}

fn mk(ms: &[Arc<TensorModel>], ws: &[f64]) -> Vec<Contribution> {
    ms.iter()
        .zip(ws)
        .map(|(m, &w)| Contribution { model: Arc::clone(m), weight: w })
        .collect()
}

#[test]
fn prop_fedavg_scale_invariant_in_weights() {
    prop_check("fedavg(w) == fedavg(c*w)", 40, |g| {
        let spec = rand_spec(g);
        let current = rand_model(g, &spec);
        let n = g.usize_in(2..5);
        let models: Vec<Arc<TensorModel>> =
            (0..n).map(|_| Arc::new(rand_model(g, &spec))).collect();
        let weights: Vec<f64> = (0..n).map(|_| g.f64_in(0.1, 10.0)).collect();
        let scale = g.f64_in(0.5, 50.0);
        let scaled: Vec<f64> = weights.iter().map(|w| w * scale).collect();
        let a = FedAvg::new()
            .aggregate(&current, &mk(&models, &weights), &Backend::Sequential)
            .unwrap();
        let b = FedAvg::new()
            .aggregate(&current, &mk(&models, &scaled), &Backend::Sequential)
            .unwrap();
        assert!(a.max_abs_diff(&b) < 1e-4);
    });
}

#[test]
fn prop_parallel_equals_sequential_bitwise() {
    let pool = Arc::new(ThreadPool::new(3));
    prop_check("parallel == sequential", 30, |g| {
        let spec = rand_spec(g);
        let current = rand_model(g, &spec);
        let n = g.usize_in(1..7);
        let models: Vec<Arc<TensorModel>> =
            (0..n).map(|_| Arc::new(rand_model(g, &spec))).collect();
        let weights: Vec<f64> = models.iter().map(|_| 1.0).collect();
        let seq = FedAvg::new()
            .aggregate(&current, &mk(&models, &weights), &Backend::Sequential)
            .unwrap();
        let par = FedAvg::new()
            .aggregate(&current, &mk(&models, &weights), &Backend::Parallel(Arc::clone(&pool)))
            .unwrap();
        assert_eq!(seq, par);
    });
}

/// The chunked backend must be bitwise identical to the sequential one
/// across arbitrary tensor layouts, learner counts, and pool sizes —
/// including the adversarial layouts where per-tensor parallelism
/// degenerates (one giant tensor; hundreds of tiny tensors).
#[test]
fn prop_chunked_equals_sequential_bitwise() {
    fn layout_model(g: &mut Gen, layout: &[(String, Vec<usize>)]) -> Arc<TensorModel> {
        let seed = g.rng().next_u64();
        Arc::new(TensorModel::random_init(layout, &mut Rng::new(seed)))
    }

    prop_check("chunked == sequential (random mlp layouts)", 30, |g| {
        let spec = rand_spec(g);
        let current = rand_model(g, &spec);
        let n = g.usize_in(1..7);
        let models: Vec<Arc<TensorModel>> =
            (0..n).map(|_| Arc::new(rand_model(g, &spec))).collect();
        let weights: Vec<f64> = (0..n).map(|_| g.f64_in(0.1, 10.0)).collect();
        let threads = g.usize_in(1..6);
        let backend = Backend::Chunked {
            pool: Arc::new(ThreadPool::new(threads)),
            scratch: Arc::new(ScratchArena::new()),
        };
        let seq = FedAvg::new()
            .aggregate(&current, &mk(&models, &weights), &Backend::Sequential)
            .unwrap();
        let chk = FedAvg::new()
            .aggregate(&current, &mk(&models, &weights), &backend)
            .unwrap();
        assert_eq!(seq, chk, "{threads} threads, layout {:?}", current.layout());
    });

    // Degenerate layouts: one giant tensor (per-tensor parallelism caps
    // at 1) and 500 tiny tensors (per-tensor task overhead dominates).
    let giant: Vec<(String, Vec<usize>)> = vec![("giant".into(), vec![1 << 15])];
    let tiny: Vec<(String, Vec<usize>)> =
        (0..500).map(|i| (format!("t{i}"), vec![7])).collect();
    for (label, layout) in [("giant", &giant), ("tiny", &tiny)] {
        prop_check(&format!("chunked == sequential ({label} layout)"), 10, |g| {
            let current = layout_model(g, layout);
            let n = g.usize_in(1..5);
            let models: Vec<Arc<TensorModel>> =
                (0..n).map(|_| layout_model(g, layout)).collect();
            let weights: Vec<f64> = (0..n).map(|_| g.f64_in(0.1, 10.0)).collect();
            let threads = g.usize_in(1..6);
            let backend = Backend::Chunked {
                pool: Arc::new(ThreadPool::new(threads)),
                scratch: Arc::new(ScratchArena::new()),
            };
            let seq = FedAvg::new()
                .aggregate(&current, &mk(&models, &weights), &Backend::Sequential)
                .unwrap();
            let chk = FedAvg::new()
                .aggregate(&current, &mk(&models, &weights), &backend)
                .unwrap();
            assert_eq!(seq, chk, "{label}: {threads} threads");
        });
    }
}

#[test]
fn prop_model_proto_roundtrip_any_shape() {
    prop_check("ModelProto roundtrip", 50, |g| {
        let spec = rand_spec(g);
        let m = rand_model(g, &spec);
        let order = if g.bool() { ByteOrder::Little } else { ByteOrder::Big };
        let proto = ModelProto::from_model(&m, DType::F32, order);
        let back = proto.to_model().unwrap();
        assert_eq!(back, m);
    });
}

#[test]
fn prop_message_decode_never_panics_on_corruption() {
    prop_check("decode(corrupt) is Err or Ok, never panic", 100, |g| {
        let spec = ModelSpec::mlp(3, 2, 4);
        let m = TensorModel::random_init(&spec.tensor_layout(), &mut Rng::new(7));
        let mut bytes = Message::RunTask {
            task_id: 1,
            round: 1,
            model: ModelProto::from_model(&m, DType::F32, ByteOrder::Little),
            spec: TaskSpec { epochs: 1, batch_size: 10, learning_rate: 0.1, step_budget: 0 },
        }
        .encode();
        // Random corruption: flip bytes, truncate, or extend.
        match g.usize_in(0..3) {
            0 => {
                for _ in 0..g.usize_in(1..8) {
                    let i = g.usize_in(0..bytes.len());
                    bytes[i] ^= (g.rng().next_u64() & 0xFF) as u8;
                }
            }
            1 => {
                let keep = g.usize_in(0..bytes.len());
                bytes.truncate(keep);
            }
            _ => bytes.extend(g.bytes(1..16)),
        }
        let _ = Message::decode(&bytes); // must not panic
    });
}

#[test]
fn prop_streaming_trio_roundtrips_any_layout() {
    prop_check("stream messages roundtrip", 50, |g| {
        let n_tensors = g.usize_in(1..6);
        let layout: Vec<TensorLayoutProto> = (0..n_tensors)
            .map(|i| TensorLayoutProto {
                name: format!("t{i}"),
                dtype: match g.usize_in(0..3) {
                    0 => DType::F32,
                    1 => DType::F64,
                    _ => DType::Bf16,
                },
                byte_order: if g.bool() { ByteOrder::Little } else { ByteOrder::Big },
                shape: g.shape(3, 64),
            })
            .collect();
        let begin = Message::ModelStreamBegin {
            stream_id: g.rng().next_u64(),
            task_id: g.rng().next_u64(),
            round: g.rng().next_u64(),
            purpose: match g.usize_in(0..4) {
                0 => StreamPurpose::ShipModel,
                1 => StreamPurpose::TaskCompletion,
                2 => StreamPurpose::RunTask,
                _ => StreamPurpose::Evaluate,
            },
            learner_id: format!("learner-{}", g.usize_in(0..100)),
            codec: {
                let all = metisfl::tensor::CodecId::ALL;
                all[g.usize_in(0..all.len())]
            },
            base_round: g.rng().next_u64(),
            layout,
            meta: TaskMeta {
                train_time_per_batch_us: g.rng().next_u64() % 10_000,
                completed_steps: g.usize_in(0..500),
                completed_epochs: g.usize_in(0..10),
                num_samples: g.usize_in(0..10_000),
                train_loss: g.f64_in(-10.0, 10.0),
                steps_per_sec: g.f64_in(0.0, 10_000.0),
                train_wall_time_us: g.rng().next_u64() % 100_000_000,
                trace_id: g.rng().next_u64(),
                parent_span: g.rng().next_u64(),
            },
            spec: TaskSpec {
                epochs: g.usize_in(0..10),
                batch_size: g.usize_in(0..1000),
                learning_rate: g.f64_in(0.0, 1.0),
                step_budget: g.usize_in(0..100),
            },
        };
        let chunk = Message::ModelChunk {
            stream_id: g.rng().next_u64(),
            seq: g.rng().next_u64(),
            bytes: g.bytes(0..512),
        };
        let end = Message::ModelStreamEnd {
            stream_id: g.rng().next_u64(),
            digest: g.rng().next_u64(),
        };
        for m in [begin, chunk, end] {
            let back = Message::decode(&m.encode()).unwrap();
            assert_eq!(back, m, "roundtrip failed for {}", m.kind());
        }
    });
}

/// Same update delivered one-shot vs streamed (at an adversarial chunk
/// size) must leave two identical controllers bitwise identical. Uses
/// the async protocol so ingest alone advances the community model.
#[test]
fn prop_streamed_ingest_equals_one_shot_bitwise() {
    use metisfl::config::{FederationEnv, Protocol};
    use metisfl::controller::Controller;
    use metisfl::net::Service;

    prop_check("streamed == one-shot ingest", 15, |g| {
        let spec = rand_spec(g);
        let mk_ctrl = |name: &str| {
            let env = FederationEnv::builder(name)
                .learners(2)
                .model(spec.clone())
                .protocol(Protocol::Asynchronous { staleness_alpha: 1.0 })
                .build();
            Controller::new(env, None).unwrap()
        };
        let one_shot = mk_ctrl("prop-oneshot");
        let streamed = mk_ctrl("prop-streamed");
        let base = rand_model(g, &spec);
        one_shot.ship_model(base.clone());
        streamed.ship_model(base.clone());
        let update = rand_model(g, &spec);
        let meta = TaskMeta { num_samples: g.usize_in(1..500), ..Default::default() };

        let reply = one_shot.handle(Message::MarkTaskCompleted {
            task_id: 1,
            learner_id: "a".into(),
            model: ModelProto::from_model(&update, DType::F32, ByteOrder::Little),
            meta: meta.clone(),
        });
        assert!(matches!(reply, Message::Ack { ok: true, .. }), "{reply:?}");

        // Stream the identical update in 1..64-byte chunks through the
        // real (unclamped) sender walk, under a random lossless codec
        // (delta codecs encode against the shipped community model,
        // which the receiver resolves from base_round 0).
        use metisfl::tensor::CodecId;
        let codec = [CodecId::F32, CodecId::Delta, CodecId::DeltaRle][g.usize_in(0..3)];
        let chunk_size = g.usize_in(1..64);
        let spec = TaskSpec::default();
        client::stream_model_with(
            &mut |msg| Ok(streamed.handle(msg)),
            &client::StreamSend {
                purpose: StreamPurpose::TaskCompletion,
                task_id: 1,
                round: 0,
                learner_id: "a",
                model: &update,
                meta: &meta,
                spec: &spec,
                codec,
                base: codec.needs_base().then_some(&base),
                base_round: 0,
                chunk_bytes: chunk_size,
            },
        )
        .unwrap();

        let (a, ra) = one_shot.community().unwrap();
        let (b, rb) = streamed.community().unwrap();
        assert_eq!(ra, rb);
        assert_eq!(*a, *b, "streamed ingest diverged ({codec}, chunk {chunk_size})");
    });
}

#[test]
fn prop_selector_never_exceeds_population_and_is_distinct() {
    prop_check("selector invariants", 60, |g| {
        let n = g.usize_in(1..30);
        let ids: Vec<String> = (0..n).map(|i| format!("l{i}")).collect();
        let mut rng = Rng::new(g.rng().next_u64());
        let sel = match g.usize_in(0..4) {
            0 => Selector::All,
            1 => Selector::RandomFraction(g.f64_in(0.01, 1.0)),
            2 => Selector::FreshnessAware { k: g.usize_in(1..40) },
            _ => Selector::PacingAware {
                k: g.usize_in(1..40),
                freshness_rounds: g.usize_in(1..10) as u64,
            },
        };
        // Random partial histories/scores: invariants must hold for
        // any mix of seen/unseen learners.
        let mut last = HashMap::new();
        let mut scores = HashMap::new();
        for id in &ids {
            if g.rng().next_u64() % 2 == 0 {
                last.insert(id.clone(), g.rng().next_u64() % 20);
            }
            if g.rng().next_u64() % 2 == 0 {
                scores.insert(id.clone(), g.f64_in(0.0, 100.0));
            }
        }
        let ctx = SelectionCtx {
            last_round: &last,
            scores: &scores,
            round: g.rng().next_u64() % 25,
        };
        let chosen = sel.select(&ids, &ctx, &mut rng);
        assert!(!chosen.is_empty());
        assert!(chosen.len() <= n);
        let mut d = chosen.clone();
        d.sort();
        d.dedup();
        assert_eq!(d.len(), chosen.len(), "duplicates from {sel:?}");
        for c in &chosen {
            assert!(ids.contains(c));
        }
    });
}

#[test]
fn prop_store_latest_is_max_round() {
    prop_check("store.latest == max round inserted", 40, |g| {
        let spec = ModelSpec::mlp(2, 1, 4);
        let mut store = InMemoryStore::new();
        let n_inserts = g.usize_in(1..20);
        let mut max_round: HashMap<String, u64> = HashMap::new();
        for _ in 0..n_inserts {
            let learner = format!("l{}", g.usize_in(0..4));
            let round = g.rng().next_u64() % 50;
            store
                .insert(StoredModel {
                    learner_id: learner.clone(),
                    round,
                    meta: TaskMeta::default(),
                    model: Arc::new(rand_model(g, &spec)),
                })
                .unwrap();
            let e = max_round.entry(learner).or_insert(0);
            *e = (*e).max(round);
        }
        for (learner, expect) in max_round {
            assert_eq!(store.latest(&learner).unwrap().unwrap().round, expect);
        }
    });
}

#[test]
fn prop_store_eviction_preserves_latest() {
    prop_check("evict keeps newest", 30, |g| {
        let spec = ModelSpec::mlp(2, 1, 4);
        let mut store = InMemoryStore::new();
        let rounds: Vec<u64> = (0..g.usize_in(2..10)).map(|i| i as u64).collect();
        for &r in &rounds {
            store
                .insert(StoredModel {
                    learner_id: "x".into(),
                    round: r,
                    meta: TaskMeta::default(),
                    model: Arc::new(rand_model(g, &spec)),
                })
                .unwrap();
        }
        let keep = g.usize_in(1..4);
        store.evict(keep).unwrap();
        assert_eq!(store.len(), keep.min(rounds.len()));
        assert_eq!(store.latest("x").unwrap().unwrap().round, *rounds.last().unwrap());
    });
}

#[test]
fn prop_masking_sum_matches_plaintext() {
    prop_check("pairwise masks cancel", 15, |g| {
        let n = g.usize_in(2..5);
        let dim = g.usize_in(1..64);
        let secret = [(g.rng().next_u64() & 0xFF) as u8; 32];
        let round = g.rng().next_u64() % 100;
        let updates: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..dim).map(|_| g.f32_in(-2.0, 2.0)).collect())
            .collect();
        let masked: Vec<Vec<i64>> = updates
            .iter()
            .enumerate()
            .map(|(i, u)| PairwiseMasker::new(i, n, round, secret).mask(u))
            .collect();
        let sum = PairwiseMasker::unmask_sum(&masked);
        for d in 0..dim {
            let expect: f32 = updates.iter().map(|u| u[d]).sum();
            let eps = PairwiseMasker::quantization_eps(n) * 4.0 + 1e-3;
            assert!((sum[d] - expect).abs() <= eps, "dim {d}");
        }
    });
}

#[test]
fn prop_flat_roundtrip_any_model() {
    prop_check("to_flat/from_flat identity", 60, |g| {
        let spec = rand_spec(g);
        let m = rand_model(g, &spec);
        let layout = m.layout();
        let flat = m.to_flat();
        let back = TensorModel::from_flat(&layout, &flat).unwrap();
        assert_eq!(back, m);
    });
}
