//! Data-plane end-to-end scenarios: streamed vs one-shot federations
//! must be bitwise identical over both transports, streamed ingest must
//! bound controller wire memory by chunk × in-flight learners (not
//! learners × model), and the typed control-plane stubs must handshake
//! against the real controller.

use metisfl::config::{FederationEnv, ModelSpec, TransportKind};
use metisfl::controller::Controller;
use metisfl::driver::run_with_trainer;
use metisfl::learner::trainer::RustSgdTrainer;
use metisfl::learner::SyntheticTrainer;
use metisfl::net::{serve, Service};
use metisfl::proto::client::{ControllerClient, RpcError};
use metisfl::proto::{ErrorCode, Message, PROTO_VERSION};
use metisfl::tensor::TensorModel;
use metisfl::util::Rng;
use std::sync::Arc;

fn env(name: &str, stream_chunk_bytes: usize) -> FederationEnv {
    FederationEnv::builder(name)
        .learners(3)
        .rounds(3)
        // ~3.5k params ≈ 14 KiB f32 — several MIN_CHUNK_BYTES chunks.
        .model(ModelSpec::mlp(8, 4, 32))
        .samples_per_learner(20)
        .batch_size(10)
        .heartbeat_ms(10_000)
        .stream_chunk_bytes(stream_chunk_bytes)
        .build()
}

/// Round-by-round losses of two runs must agree to the last bit: the
/// deterministic trainer + sorted aggregation order make any data-plane
/// divergence (one mis-decoded element) visible in the loss bits.
fn assert_bitwise_equal_runs(a: &metisfl::driver::FederationReport, b: &metisfl::driver::FederationReport) {
    assert_eq!(a.round_metrics.len(), b.round_metrics.len());
    for (ra, rb) in a.round_metrics.iter().zip(&b.round_metrics) {
        let (la, lb) = (
            ra.community_eval_loss.expect("one-shot round evaluated"),
            rb.community_eval_loss.expect("streamed round evaluated"),
        );
        assert_eq!(
            la.to_bits(),
            lb.to_bits(),
            "round {}: one-shot {la} != streamed {lb}",
            ra.round
        );
        assert_eq!(ra.completed, rb.completed, "round {}", ra.round);
    }
}

#[test]
fn streamed_and_one_shot_federations_agree_bitwise_inproc() {
    let one_shot = run_with_trainer(&env("stream-eq-inproc-a", 0), |_| Arc::new(RustSgdTrainer))
        .unwrap();
    let streamed =
        run_with_trainer(&env("stream-eq-inproc-b", 2048), |_| Arc::new(RustSgdTrainer)).unwrap();
    assert_bitwise_equal_runs(&one_shot, &streamed);
}

#[test]
fn streamed_and_one_shot_federations_agree_bitwise_tcp() {
    let mut a = env("stream-eq-tcp-a", 0);
    a.transport = TransportKind::Tcp { base_port: 0 };
    let mut b = env("stream-eq-tcp-b", 2048);
    b.transport = TransportKind::Tcp { base_port: 0 };
    let one_shot = run_with_trainer(&a, |_| Arc::new(RustSgdTrainer)).unwrap();
    let streamed = run_with_trainer(&b, |_| Arc::new(RustSgdTrainer)).unwrap();
    assert_bitwise_equal_runs(&one_shot, &streamed);
}

#[test]
fn streaming_bounds_controller_ingest_memory_by_chunks_not_models() {
    // Same federation twice; the only difference is the upload path.
    // One-shot: the controller holds ≥ one whole model of wire payload
    // per in-flight completion. Streamed: the high-water mark is bounded
    // by chunk × learners — the ISSUE's O(model + in-flight chunks)
    // claim, asserted end to end through a real driver run.
    let learners = 3;
    let chunk = metisfl::proto::client::MIN_CHUNK_BYTES;
    let model_bytes = ModelSpec::mlp(8, 4, 32).param_count() * 4;
    assert!(
        model_bytes > learners * chunk * 2,
        "model too small for a meaningful bound: {model_bytes}"
    );

    let one_shot = run_with_trainer(&env("stream-mem-oneshot", 0), |_| {
        Arc::new(SyntheticTrainer::new(0, 0.01))
    })
    .unwrap();
    assert!(
        one_shot.peak_wire_ingest_bytes >= model_bytes,
        "one-shot ingest should hold at least one whole model: {} < {model_bytes}",
        one_shot.peak_wire_ingest_bytes
    );

    let streamed = run_with_trainer(&env("stream-mem-streamed", chunk), |_| {
        Arc::new(SyntheticTrainer::new(0, 0.01))
    })
    .unwrap();
    assert!(streamed.peak_wire_ingest_bytes > 0, "streamed run never ingested");
    assert!(
        streamed.peak_wire_ingest_bytes <= learners * chunk,
        "streamed ingest peak {} exceeds chunk ({chunk}) × learners ({learners})",
        streamed.peak_wire_ingest_bytes
    );
    assert!(
        streamed.peak_wire_ingest_bytes < model_bytes,
        "streamed ingest peak {} not below one model ({model_bytes})",
        streamed.peak_wire_ingest_bytes
    );
    // Both runs completed full rounds.
    assert_eq!(one_shot.round_metrics.last().unwrap().completed, learners);
    assert_eq!(streamed.round_metrics.last().unwrap().completed, learners);
}

#[test]
fn controller_client_handshake_and_error_taxonomy_over_tcp() {
    let e = env("stream-stub-tcp", 0);
    let ctrl = Controller::new(e, None).unwrap();
    let server = serve("tcp://127.0.0.1:0", Arc::clone(&ctrl) as Arc<dyn Service>, None).unwrap();

    // Versioned handshake succeeds and reports the controller's version.
    let mut client = ControllerClient::connect(&server.endpoint(), None).unwrap();
    assert_eq!(client.peer_version, PROTO_VERSION);

    // Before any model is shipped, GetModel is a typed NotFound.
    match client.get_model() {
        Err(RpcError::Remote { code, .. }) => assert_eq!(code, ErrorCode::NotFound),
        other => panic!("expected NotFound, got {other:?}"),
    }

    // A mismatched version is refused with VersionMismatch.
    let mut raw = metisfl::net::connect(&server.endpoint(), None).unwrap();
    match raw.rpc(&Message::Hello { proto_version: 1 }).unwrap() {
        Message::Error { code, .. } => assert_eq!(code, ErrorCode::VersionMismatch),
        other => panic!("unexpected {other:?}"),
    }

    // Ship a model through the streamed stub path and read it back.
    let layout = ModelSpec::mlp(8, 4, 32).tensor_layout();
    let m = TensorModel::random_init(&layout, &mut Rng::new(11));
    client.ship_model_streamed(&m, 2048).unwrap();
    let (proto, round) = client.get_model().unwrap();
    assert_eq!(round, 0);
    assert_eq!(proto.to_model().unwrap(), m);
    assert_eq!(ctrl.open_streams(), 0);

    client.shutdown().unwrap();
    // The controller now refuses RPCs with Unavailable.
    match ControllerClient::connect(&server.endpoint(), None) {
        Err(RpcError::Remote { code, .. }) => assert_eq!(code, ErrorCode::Unavailable),
        other => panic!("expected Unavailable, got {:?}", other.err().map(|e| e.to_string())),
    }
}
