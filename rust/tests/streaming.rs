//! Data-plane end-to-end scenarios: streamed vs one-shot federations
//! must be bitwise identical over both transports (including the
//! delta-coded symmetric data plane), streamed ingest must bound
//! controller wire memory by chunk × in-flight learners (not learners ×
//! model), streamed dispatch must encode the model once regardless of
//! fan-out width, idle streams must be reclaimed on a deterministic
//! clock, and the typed control-plane stubs must handshake against the
//! real controller.

use metisfl::config::{FederationEnv, ModelSpec, TransportKind, WireCodecChoice};
use metisfl::controller::{scheduling, Controller};
use metisfl::driver::run_with_trainer;
use metisfl::learner::trainer::RustSgdTrainer;
use metisfl::learner::{Dataset, Learner, LearnerServicer, SyntheticTrainer};
use metisfl::net::{serve, Service};
use metisfl::proto::client::{ControllerClient, RpcError};
use metisfl::proto::wire::FNV64_INIT;
use metisfl::proto::{
    ErrorCode, Message, StreamPurpose, TaskMeta, TaskSpec, TensorLayoutProto, PROTO_VERSION,
};
use metisfl::tensor::{CodecId, TensorModel};
use metisfl::util::{Clock, Rng};
use std::sync::Arc;
use std::time::Duration;

fn env(name: &str, stream_chunk_bytes: usize) -> FederationEnv {
    FederationEnv::builder(name)
        .learners(3)
        .rounds(3)
        // ~3.5k params ≈ 14 KiB f32 — several MIN_CHUNK_BYTES chunks.
        .model(ModelSpec::mlp(8, 4, 32))
        .samples_per_learner(20)
        .batch_size(10)
        .heartbeat_ms(10_000)
        .stream_chunk_bytes(stream_chunk_bytes)
        .build()
}

/// Round-by-round losses of two runs must agree to the last bit: the
/// deterministic trainer + sorted aggregation order make any data-plane
/// divergence (one mis-decoded element) visible in the loss bits.
fn assert_bitwise_equal_runs(a: &metisfl::driver::FederationReport, b: &metisfl::driver::FederationReport) {
    assert_eq!(a.round_metrics.len(), b.round_metrics.len());
    for (ra, rb) in a.round_metrics.iter().zip(&b.round_metrics) {
        let (la, lb) = (
            ra.community_eval_loss.expect("one-shot round evaluated"),
            rb.community_eval_loss.expect("streamed round evaluated"),
        );
        assert_eq!(
            la.to_bits(),
            lb.to_bits(),
            "round {}: one-shot {la} != streamed {lb}",
            ra.round
        );
        assert_eq!(ra.completed, rb.completed, "round {}", ra.round);
    }
}

#[test]
fn streamed_and_one_shot_federations_agree_bitwise_inproc() {
    let one_shot = run_with_trainer(&env("stream-eq-inproc-a", 0), |_| Arc::new(RustSgdTrainer))
        .unwrap();
    let streamed =
        run_with_trainer(&env("stream-eq-inproc-b", 2048), |_| Arc::new(RustSgdTrainer)).unwrap();
    assert_bitwise_equal_runs(&one_shot, &streamed);
}

#[test]
fn streamed_and_one_shot_federations_agree_bitwise_tcp() {
    let mut a = env("stream-eq-tcp-a", 0);
    a.transport = TransportKind::Tcp { base_port: 0 };
    let mut b = env("stream-eq-tcp-b", 2048);
    b.transport = TransportKind::Tcp { base_port: 0 };
    let one_shot = run_with_trainer(&a, |_| Arc::new(RustSgdTrainer)).unwrap();
    let streamed = run_with_trainer(&b, |_| Arc::new(RustSgdTrainer)).unwrap();
    assert_bitwise_equal_runs(&one_shot, &streamed);
}

#[test]
fn streaming_bounds_controller_ingest_memory_by_chunks_not_models() {
    // Same federation twice; the only difference is the upload path.
    // One-shot: the controller holds ≥ one whole model of wire payload
    // per in-flight completion. Streamed: the high-water mark is bounded
    // by chunk × learners — the ISSUE's O(model + in-flight chunks)
    // claim, asserted end to end through a real driver run.
    let learners = 3;
    let chunk = metisfl::proto::client::MIN_CHUNK_BYTES;
    let model_bytes = ModelSpec::mlp(8, 4, 32).param_count() * 4;
    assert!(
        model_bytes > learners * chunk * 2,
        "model too small for a meaningful bound: {model_bytes}"
    );

    let one_shot = run_with_trainer(&env("stream-mem-oneshot", 0), |_| {
        Arc::new(SyntheticTrainer::new(0, 0.01))
    })
    .unwrap();
    assert!(
        one_shot.peak_wire_ingest_bytes >= model_bytes,
        "one-shot ingest should hold at least one whole model: {} < {model_bytes}",
        one_shot.peak_wire_ingest_bytes
    );

    let streamed = run_with_trainer(&env("stream-mem-streamed", chunk), |_| {
        Arc::new(SyntheticTrainer::new(0, 0.01))
    })
    .unwrap();
    assert!(streamed.peak_wire_ingest_bytes > 0, "streamed run never ingested");
    assert!(
        streamed.peak_wire_ingest_bytes <= learners * chunk,
        "streamed ingest peak {} exceeds chunk ({chunk}) × learners ({learners})",
        streamed.peak_wire_ingest_bytes
    );
    assert!(
        streamed.peak_wire_ingest_bytes < model_bytes,
        "streamed ingest peak {} not below one model ({model_bytes})",
        streamed.peak_wire_ingest_bytes
    );
    // Both runs completed full rounds.
    assert_eq!(one_shot.round_metrics.last().unwrap().completed, learners);
    assert_eq!(streamed.round_metrics.last().unwrap().completed, learners);
}

#[test]
fn delta_codec_federation_is_bitwise_identical_to_one_shot() {
    // The XOR-delta codec is lossless: a fully delta-coded symmetric
    // data plane (streamed dispatch + streamed uploads, bases
    // established by the streams themselves) reproduces the one-shot
    // federation bit for bit.
    let one_shot =
        run_with_trainer(&env("delta-eq-a", 0), |_| Arc::new(RustSgdTrainer)).unwrap();
    let mut e = env("delta-eq-b", 2048);
    e.wire_codec = WireCodecChoice::Delta;
    let streamed = run_with_trainer(&e, |_| Arc::new(RustSgdTrainer)).unwrap();
    assert_bitwise_equal_runs(&one_shot, &streamed);
}

#[test]
fn delta_rle_federation_is_bitwise_identical_to_one_shot_inproc() {
    // The entropy-coded delta wire is lossless end to end: a fully
    // delta-rle symmetric data plane reproduces the one-shot federation
    // bit for bit, while moving (and accounting) fewer wire bytes.
    let one_shot =
        run_with_trainer(&env("rle-eq-a", 0), |_| Arc::new(RustSgdTrainer)).unwrap();
    let mut e = env("rle-eq-b", 2048);
    e.wire_codec = WireCodecChoice::DeltaRle;
    let streamed = run_with_trainer(&e, |_| Arc::new(RustSgdTrainer)).unwrap();
    assert_bitwise_equal_runs(&one_shot, &streamed);
    assert!(streamed.wire_bytes_sent > 0, "wire gauge never moved");
    assert!(streamed.wire_bytes_saved > 0, "delta-rle saved nothing");
    // One-shot runs bypass the streamed data plane entirely.
    assert_eq!(one_shot.wire_bytes_sent, 0);
}

#[test]
fn delta_rle_federation_is_bitwise_identical_to_one_shot_tcp() {
    let mut a = env("rle-eq-tcp-a", 0);
    a.transport = TransportKind::Tcp { base_port: 0 };
    let mut b = env("rle-eq-tcp-b", 2048);
    b.transport = TransportKind::Tcp { base_port: 0 };
    b.wire_codec = WireCodecChoice::DeltaRle;
    let one_shot = run_with_trainer(&a, |_| Arc::new(RustSgdTrainer)).unwrap();
    let streamed = run_with_trainer(&b, |_| Arc::new(RustSgdTrainer)).unwrap();
    assert_bitwise_equal_runs(&one_shot, &streamed);
}

#[test]
fn delta_rle_steady_state_wire_bytes_at_most_half_of_delta() {
    // The acceptance cell: on a steady-state federation whose model
    // moves only a little per round (small updates), the entropy-coded
    // wire moves ≤ 50% of plain delta's bytes. Plain delta ships 4 B/elem
    // of mostly-zero residual; delta-rle run-length-collapses them.
    let mk = |name: &str, codec: WireCodecChoice| {
        let mut e = env(name, 2048);
        e.rounds = 5;
        e.wire_codec = codec;
        e
    };
    let delta = run_with_trainer(&mk("wire-delta", WireCodecChoice::Delta), |_| {
        Arc::new(SyntheticTrainer::new(0, 1e-6))
    })
    .unwrap();
    let rle = run_with_trainer(&mk("wire-rle", WireCodecChoice::DeltaRle), |_| {
        Arc::new(SyntheticTrainer::new(0, 1e-6))
    })
    .unwrap();
    assert!(delta.wire_bytes_sent > 0 && rle.wire_bytes_sent > 0);
    assert!(
        2 * rle.wire_bytes_sent <= delta.wire_bytes_sent,
        "delta-rle moved {} wire bytes, plain delta {} — expected ≤ half",
        rle.wire_bytes_sent,
        delta.wire_bytes_sent
    );
    // Conservation: what was saved plus what was sent is the raw volume,
    // which is identical across the two lossless runs.
    let rle_raw = rle.wire_bytes_sent + rle.wire_bytes_saved;
    let delta_raw = delta.wire_bytes_sent + delta.wire_bytes_saved;
    assert_eq!(rle_raw, delta_raw, "raw f32-equivalent volume diverged");
    // Pipelined framed ingest may hold a few frames per stream, but
    // never a whole model per learner.
    assert!(
        rle.peak_wire_ingest_bytes <= 3 * 4 * (2048 + 64),
        "framed ingest held {} bytes",
        rle.peak_wire_ingest_bytes
    );
}

#[test]
fn delta_rle_dispatch_encodes_once_per_fanout() {
    // Encode-once probe for the framed codec: a fan-out to 3 learners
    // costs one encode per FRAME (not per learner). The first train
    // fan-out has no base yet and goes full f32 (tensor_count encodes);
    // every later fan-out is delta-rle (one encode per element block).
    let mut e = env("rle-encode-probe", 2048);
    e.wire_codec = WireCodecChoice::DeltaRle;
    let ctrl = Controller::new(e.clone(), None).unwrap();
    let _ctrl_server = serve(
        "inproc://rle-probe-ctrl",
        Arc::clone(&ctrl) as Arc<dyn Service>,
        None,
    )
    .unwrap();
    let mut learners = Vec::new();
    for i in 0..3 {
        let dataset = Dataset::synthetic_housing(8, 20, 20, 7 + i as u64);
        let learner = Learner::new(
            &format!("rle-probe-l{i}"),
            "inproc://rle-probe-ctrl",
            None,
            Arc::new(SyntheticTrainer::new(0, 0.01)),
            dataset,
        );
        learner.set_stream_chunk(e.effective_stream_chunk());
        learner.set_upload_codec(e.upload_codec());
        let ep = format!("inproc://rle-probe-l{i}");
        let server =
            serve(&ep, Arc::new(LearnerServicer(Arc::clone(&learner))) as Arc<dyn Service>, None)
                .unwrap();
        learner.register(&ep).unwrap();
        learners.push((learner, server));
    }
    let layout = e.model.tensor_layout();
    ctrl.ship_model(TensorModel::random_init(&layout, &mut Rng::new(5)));
    let block = e.effective_stream_chunk() / 4;
    let frames_per_fanout: u64 = layout
        .iter()
        .map(|(_, shape)| {
            let elems: usize = shape.iter().product();
            elems.div_ceil(block).max(1) as u64
        })
        .sum();
    let tensors = e.model.tensor_count() as u64;
    let mut rng = Rng::new(9);
    let report = scheduling::run_sync_round(&ctrl, 1, &mut rng).unwrap();
    assert_eq!(report.completed, 3);
    // Round 1: full-f32 train fan-out + delta-rle eval fan-out.
    assert_eq!(ctrl.dispatch_encode_count(), tensors + frames_per_fanout);
    // Round 2: both fan-outs are delta-rle. Still independent of the
    // 3-learner width.
    let report = scheduling::run_round(&ctrl, 2, &mut rng).unwrap();
    assert_eq!(report.completed, 3);
    assert_eq!(ctrl.dispatch_encode_count(), tensors + 3 * frames_per_fanout);
    assert_eq!(ctrl.open_streams(), 0);
}

#[test]
fn async_streamed_session_matches_one_shot_updates() {
    // The async protocol rides the data plane too: initial fan-out is a
    // shared stream, re-dispatches are per-learner streams delta-coded
    // against each learner's own base. The session completes the same
    // number of community updates as the one-shot path.
    use metisfl::config::Protocol;
    for codec in [WireCodecChoice::Delta, WireCodecChoice::DeltaRle] {
        let mut e = env(&format!("async-stream-{}", codec.name()), 2048);
        e.protocol = Protocol::Asynchronous { staleness_alpha: 0.5 };
        e.wire_codec = codec;
        e.rounds = 2;
        let report = run_with_trainer(&e, |_| Arc::new(SyntheticTrainer::new(0, 0.01))).unwrap();
        assert_eq!(report.round_metrics.len(), 2, "{}", codec.name());
        assert!(report.wire_bytes_sent > 0, "{}: async session never streamed", codec.name());
    }
}

#[test]
fn bf16_uploads_complete_with_bounded_loss_error() {
    // bf16 halves upload wire size at a bounded precision cost: the
    // federation completes every round and the per-round community loss
    // stays close to the f32 run (bf16 keeps 8 mantissa bits, so the
    // aggregated model moves by ≲2⁻⁸ relative per element).
    let f32_run =
        run_with_trainer(&env("bf16-eq-a", 2048), |_| Arc::new(RustSgdTrainer)).unwrap();
    let mut e = env("bf16-eq-b", 2048);
    e.wire_codec = WireCodecChoice::Bf16;
    let bf16_run = run_with_trainer(&e, |_| Arc::new(RustSgdTrainer)).unwrap();
    assert_eq!(f32_run.round_metrics.len(), bf16_run.round_metrics.len());
    for (ra, rb) in f32_run.round_metrics.iter().zip(&bf16_run.round_metrics) {
        assert_eq!(ra.completed, rb.completed, "round {}", ra.round);
        let (la, lb) = (
            ra.community_eval_loss.expect("f32 round evaluated"),
            rb.community_eval_loss.expect("bf16 round evaluated"),
        );
        assert!(lb.is_finite());
        assert!(
            (la - lb).abs() <= la.abs() * 0.15 + 0.05,
            "round {}: bf16 loss {lb} drifted too far from f32 loss {la}",
            ra.round
        );
    }
}

#[test]
fn streamed_dispatch_encodes_the_model_once_per_fanout() {
    // Encode-once probe: one streamed sync round against 3 learners
    // performs exactly tensor_count codec encodes per fan-out (train +
    // eval = 2 fan-outs), NOT learners × tensor_count — the controller
    // encodes each chunk once and fans the same bytes out.
    let e = env("encode-probe", 2048);
    let ctrl = Controller::new(e.clone(), None).unwrap();
    let _ctrl_server = serve(
        "inproc://encode-probe-ctrl",
        Arc::clone(&ctrl) as Arc<dyn Service>,
        None,
    )
    .unwrap();
    let mut learners = Vec::new();
    for i in 0..3 {
        let dataset = Dataset::synthetic_housing(8, 20, 20, 7 + i as u64);
        let learner = Learner::new(
            &format!("probe-l{i}"),
            "inproc://encode-probe-ctrl",
            None,
            Arc::new(SyntheticTrainer::new(0, 0.01)),
            dataset,
        );
        learner.set_stream_chunk(e.effective_stream_chunk());
        learner.set_upload_codec(e.upload_codec());
        let ep = format!("inproc://encode-probe-l{i}");
        let server =
            serve(&ep, Arc::new(LearnerServicer(Arc::clone(&learner))) as Arc<dyn Service>, None)
                .unwrap();
        learner.register(&ep).unwrap();
        learners.push((learner, server));
    }
    let layout = e.model.tensor_layout();
    ctrl.ship_model(TensorModel::random_init(&layout, &mut Rng::new(5)));
    assert_eq!(ctrl.dispatch_encode_count(), 0);
    let mut rng = Rng::new(9);
    let report = scheduling::run_sync_round(&ctrl, 1, &mut rng).unwrap();
    assert_eq!(report.completed, 3);
    assert!(report.community_eval_loss.unwrap().is_finite());
    let per_fanout = e.model.tensor_count() as u64;
    assert_eq!(
        ctrl.dispatch_encode_count(),
        2 * per_fanout,
        "dispatch encode work scaled with learner count"
    );
    // A second round doubles the fan-outs, still independent of width.
    let report = scheduling::run_round(&ctrl, 2, &mut rng).unwrap();
    assert_eq!(report.completed, 3);
    assert_eq!(ctrl.dispatch_encode_count(), 4 * per_fanout);
    assert_eq!(ctrl.open_streams(), 0);
}

fn begin_msg(m: &TensorModel, stream_id: u64) -> Message {
    Message::ModelStreamBegin {
        stream_id,
        task_id: 1,
        round: 0,
        purpose: StreamPurpose::TaskCompletion,
        learner_id: "a".into(),
        codec: CodecId::F32,
        base_round: 0,
        layout: TensorLayoutProto::f32_layout_of(m),
        meta: TaskMeta::default(),
        spec: TaskSpec::default(),
    }
}

#[test]
fn idle_streams_reclaimed_on_heartbeat_with_deterministic_clock() {
    // The 5-minute idle-GC path, driven by simulated time instead of
    // wall time: a learner that dies between Begin and End must not pin
    // its buffers or registry slot past the timeout.
    let ctrl = Controller::with_clock(env("idle-gc", 0), None, Clock::sim()).unwrap();

    let layout = ModelSpec::mlp(8, 4, 32).tensor_layout();
    let m = TensorModel::random_init(&layout, &mut Rng::new(3));
    assert!(matches!(ctrl.handle(begin_msg(&m, 41)), Message::Ack { ok: true, .. }));
    assert_eq!(ctrl.open_streams(), 1);
    // Heartbeats sweep idle streams; inside the window the stream lives.
    ctrl.clock().advance_to(Duration::from_secs(299));
    ctrl.handle(Message::Heartbeat { from: "driver".into() });
    assert_eq!(ctrl.open_streams(), 1);
    // Past the 5-minute timeout it is reclaimed…
    ctrl.clock().advance_to(Duration::from_secs(601));
    ctrl.handle(Message::Heartbeat { from: "driver".into() });
    assert_eq!(ctrl.open_streams(), 0);
    // …and both the slot and the announced-bytes budget are returned:
    // the same stream id opens again.
    assert!(matches!(ctrl.handle(begin_msg(&m, 41)), Message::Ack { ok: true, .. }));
    assert_eq!(ctrl.open_streams(), 1);
}

#[test]
fn chunk_racing_a_stream_close_fails_gracefully() {
    // The dead-flag path: a chunk handler that cloned the stream's Arc
    // just before a racing End must get a typed StreamProtocol error,
    // never a panic on the drained buffers.
    let ctrl = Controller::new(env("dead-flag", 0), None).unwrap();
    let layout = ModelSpec::mlp(8, 4, 32).tensor_layout();
    let m = TensorModel::random_init(&layout, &mut Rng::new(4));
    assert!(matches!(ctrl.handle(begin_msg(&m, 77)), Message::Ack { ok: true, .. }));
    // A racing handler holds the stream…
    let hold = ctrl.ingest().hold_for_test(77).unwrap();
    // …while End arrives: close refuses (chunks in flight), recycles.
    match ctrl.handle(Message::ModelStreamEnd { stream_id: 77, digest: FNV64_INIT }) {
        Message::Error { code, detail } => {
            assert_eq!(code, ErrorCode::StreamProtocol);
            assert!(detail.contains("in flight"), "{detail}");
        }
        other => panic!("unexpected {other:?}"),
    }
    assert_eq!(ctrl.open_streams(), 0);
    // The raced chunk lands on the dead stream: graceful typed error.
    match ctrl.ingest().chunk_into_held(&hold, 0, vec![0u8; 8]) {
        Message::Error { code, detail } => {
            assert_eq!(code, ErrorCode::StreamProtocol);
            assert!(detail.contains("closed stream"), "{detail}");
        }
        other => panic!("unexpected {other:?}"),
    }
}

#[test]
fn sub_floor_chunk_is_clamped_and_surfaced_in_the_report() {
    // stream_chunk_bytes below the 1 KiB sender floor used to clamp
    // silently; the effective value is now surfaced in the report.
    let floor = metisfl::proto::client::MIN_CHUNK_BYTES;
    let report = run_with_trainer(&env("clamp-report", 10), |_| {
        Arc::new(SyntheticTrainer::new(0, 0.01))
    })
    .unwrap();
    assert_eq!(report.effective_stream_chunk_bytes, floor);
    assert_eq!(report.round_metrics.last().unwrap().completed, 3);
    let report = run_with_trainer(&env("clamp-report-off", 0), |_| {
        Arc::new(SyntheticTrainer::new(0, 0.01))
    })
    .unwrap();
    assert_eq!(report.effective_stream_chunk_bytes, 0);
}

#[test]
fn controller_client_handshake_and_error_taxonomy_over_tcp() {
    let e = env("stream-stub-tcp", 0);
    let ctrl = Controller::new(e, None).unwrap();
    let server = serve("tcp://127.0.0.1:0", Arc::clone(&ctrl) as Arc<dyn Service>, None).unwrap();

    // Versioned handshake succeeds and reports the controller's version.
    let mut client = ControllerClient::connect(&server.endpoint(), None).unwrap();
    assert_eq!(client.peer_version, PROTO_VERSION);

    // Before any model is shipped, GetModel is a typed NotFound.
    match client.get_model() {
        Err(RpcError::Remote { code, .. }) => assert_eq!(code, ErrorCode::NotFound),
        other => panic!("expected NotFound, got {other:?}"),
    }

    // A mismatched version is refused with VersionMismatch.
    let mut raw = metisfl::net::connect(&server.endpoint(), None).unwrap();
    match raw.rpc(&Message::Hello { proto_version: 1, codecs: Vec::new() }).unwrap() {
        Message::Error { code, .. } => assert_eq!(code, ErrorCode::VersionMismatch),
        other => panic!("unexpected {other:?}"),
    }

    // Ship a model through the streamed stub path and read it back.
    let layout = ModelSpec::mlp(8, 4, 32).tensor_layout();
    let m = TensorModel::random_init(&layout, &mut Rng::new(11));
    client.ship_model_streamed(&m, 2048).unwrap();
    let (proto, round) = client.get_model().unwrap();
    assert_eq!(round, 0);
    assert_eq!(proto.to_model().unwrap(), m);
    assert_eq!(ctrl.open_streams(), 0);

    client.shutdown().unwrap();
    // The controller now refuses RPCs with Unavailable.
    match ControllerClient::connect(&server.endpoint(), None) {
        Err(RpcError::Remote { code, .. }) => assert_eq!(code, ErrorCode::Unavailable),
        other => panic!("expected Unavailable, got {:?}", other.err().map(|e| e.to_string())),
    }
}
