//! End-to-end runtime tests: load the AOT artifacts, execute the compiled
//! train/eval/lincomb modules via PJRT, and validate numerics against the
//! pure-rust reference trainer. Requires `make artifacts` (tiny+small
//! variants); tests self-skip when artifacts are absent so `cargo test`
//! stays green on a fresh checkout.

use metisfl::config::ModelSpec;
use metisfl::controller::aggregation::{Backend, WeightedSum};
use metisfl::learner::trainer::RustSgdTrainer;
use metisfl::learner::{Dataset, Trainer};
use metisfl::proto::TaskSpec;
use metisfl::runtime::{Artifacts, XlaTrainer};
use metisfl::tensor::TensorModel;
use metisfl::util::Rng;

const DIR: &str = "artifacts";

fn have_artifacts() -> bool {
    match Artifacts::load(DIR) {
        Ok(a) => a.variant("mlp_l2_u8_in4_out1").is_some(),
        Err(_) => false,
    }
}

fn tiny_spec() -> ModelSpec {
    ModelSpec::mlp(4, 2, 8)
}

fn tiny_model(seed: u64) -> TensorModel {
    TensorModel::random_init(&tiny_spec().tensor_layout(), &mut Rng::new(seed))
}

#[test]
fn xla_trainer_runs_and_matches_rust_reference() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let spec = tiny_spec();
    let xla = XlaTrainer::load(DIR, &spec).unwrap();
    let model = tiny_model(11);
    // Batch must match the compiled static batch (16 for tiny).
    let data = Dataset::synthetic_housing(4, 32, 32, 3);
    let task = TaskSpec { epochs: 1, batch_size: 16, learning_rate: 0.01, step_budget: 0 };

    let (xla_out, xla_meta) = xla.train(&model, &data, &task).unwrap();
    let (rust_out, rust_meta) = RustSgdTrainer.train(&model, &data, &task).unwrap();

    assert_eq!(xla_meta.completed_steps, 2);
    assert_eq!(rust_meta.completed_steps, 2);
    // Same SGD on the same batches: parameters must agree to fp tolerance.
    let diff = xla_out.max_abs_diff(&rust_out);
    assert!(diff < 1e-3, "xla vs rust param diff {diff}");
    assert!((xla_meta.train_loss - rust_meta.train_loss).abs() < 1e-2);
}

#[test]
fn xla_eval_matches_rust_reference() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let spec = tiny_spec();
    let xla = XlaTrainer::load(DIR, &spec).unwrap();
    let model = tiny_model(13);
    let data = Dataset::synthetic_housing(4, 16, 16, 5);
    let a = xla.evaluate(&model, &data).unwrap();
    let b = RustSgdTrainer.evaluate(&model, &data).unwrap();
    assert!((a.loss - b.loss).abs() / b.loss.max(1e-9) < 1e-3, "{} vs {}", a.loss, b.loss);
    assert_eq!(a.num_samples, 16);
}

#[test]
fn xla_training_reduces_loss_over_rounds() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let spec = tiny_spec();
    let xla = XlaTrainer::load(DIR, &spec).unwrap();
    let data = Dataset::synthetic_housing(4, 64, 32, 7);
    let mut model = tiny_model(17);
    let before = xla.evaluate(&model, &data).unwrap().loss;
    let task = TaskSpec { epochs: 2, batch_size: 16, learning_rate: 0.02, step_budget: 0 };
    for _ in 0..10 {
        let (next, _) = xla.train(&model, &data, &task).unwrap();
        model = next;
    }
    let after = xla.evaluate(&model, &data).unwrap().loss;
    assert!(after < before * 0.8, "loss did not decrease: {before} -> {after}");
}

#[test]
fn xla_lincomb_backend_matches_rust_weighted_sum() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let spec = tiny_spec();
    let backend_fn = metisfl::runtime::xla_fedavg_backend(DIR, &spec).unwrap();
    let models: Vec<std::sync::Arc<TensorModel>> =
        (0..4).map(|i| std::sync::Arc::new(tiny_model(100 + i))).collect();
    let coeffs = [0.4, 0.3, 0.2, 0.1];
    let xla_result = backend_fn(&models, &coeffs).unwrap();
    let rust_result = WeightedSum::compute(&models, &coeffs, &Backend::Sequential).unwrap();
    let diff = xla_result.max_abs_diff(&rust_result);
    assert!(diff < 1e-5, "xla vs rust aggregation diff {diff}");
}

#[test]
fn simulated_federation_with_xla_trainer() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    use metisfl::config::{FederationEnv, TrainerKind};
    let env = FederationEnv::builder("xla-fed")
        .learners(3)
        .rounds(3)
        .model(tiny_spec())
        .samples_per_learner(32)
        .batch_size(16)
        .learning_rate(0.02)
        .trainer(TrainerKind::Xla { artifacts_dir: DIR.into() })
        .build();
    let report = metisfl::driver::run_simulated(&env).unwrap();
    assert_eq!(report.round_metrics.len(), 3);
    let first = report.round_metrics.first().unwrap().community_eval_loss.unwrap();
    let last = report.round_metrics.last().unwrap().community_eval_loss.unwrap();
    assert!(last < first, "federated XLA training did not learn: {first} -> {last}");
}
