//! Hierarchical aggregation acceptance (ISSUE: aggregator tier).
//!
//! The load-bearing claims, end to end through real federations:
//!
//! 1. A fleet behind a single aggregator produces the **bitwise**
//!    identical community model to the same fleet talking to the
//!    controller directly — the tier is pure plumbing, zero math drift.
//! 2. A 4-shard fleet matches [`two_tier_reference`] — the flat fold
//!    regrouped associatively by shard — bitwise, round-for-round.
//! 3. The root's ingest shrinks from O(learners) to O(aggregators):
//!    its received stream bytes drop with the fan-in, and its peak
//!    buffered ingest stays bounded by chunk × aggregator count.

use metisfl::config::{
    AggregationBackend, AggregationSpec, FederationEnv, ModelSpec, Protocol, TopologySpec,
};
use metisfl::controller::aggregation::{Backend, Contribution};
use metisfl::controller::hierarchy::two_tier_reference;
use metisfl::driver::{self, run_with_trainer};
use metisfl::harness::loadtest::model_digest;
use metisfl::learner::trainer::RustSgdTrainer;
use metisfl::learner::Trainer;
use metisfl::proto::TaskSpec;
use std::sync::Arc;

/// A streaming env with deterministic SGD everywhere: any digest
/// mismatch is a real data-plane or fold-order bug, never noise.
fn env(name: &str, learners: usize, rounds: usize, aggregators: usize) -> FederationEnv {
    let mut e = FederationEnv::builder(name)
        .learners(learners)
        .rounds(rounds)
        .model(ModelSpec::mlp(8, 3, 32))
        .aggregation(AggregationSpec {
            backend: AggregationBackend::Sequential,
            ..AggregationSpec::default()
        })
        .samples_per_learner(12)
        .batch_size(6)
        .learning_rate(0.05)
        .quorum_fraction(1.0)
        .stream_chunk_bytes(2048)
        .heartbeat_ms(5_000)
        .seed(0x70_70)
        .build();
    if aggregators > 0 {
        e.topology = TopologySpec { aggregators, shard_quorum: 0.0 };
    }
    e
}

fn sgd(_idx: usize) -> Arc<dyn Trainer> {
    Arc::new(RustSgdTrainer)
}

#[test]
fn single_aggregator_matches_flat_bitwise() {
    let flat = run_with_trainer(&env("hier-flat1", 4, 3, 0), sgd).unwrap();
    let tiered = run_with_trainer(&env("hier-tier1", 4, 3, 1), sgd).unwrap();
    assert_ne!(flat.community_digest, 0, "flat run produced no community model");
    assert_eq!(
        flat.community_digest, tiered.community_digest,
        "a single-shard tier must reproduce the flat fold bitwise"
    );
    for (f, t) in flat.round_metrics.iter().zip(&tiered.round_metrics) {
        assert_eq!(f.completed, 4, "flat round {} incomplete", f.round);
        // The root sees exactly one learner-like peer: the aggregator.
        assert_eq!(t.participants, 1, "tiered round {} participants", t.round);
        assert_eq!(t.completed, 1, "tiered round {} incomplete", t.round);
    }
    assert_eq!(flat.retry_give_ups + tiered.retry_give_ups, 0);
}

#[test]
fn four_shard_fleet_matches_grouped_reference_and_bounds_root_ingest() {
    const LEARNERS: usize = 24;
    const AGGS: usize = 4;
    let flat_env = env("hier-flat4", LEARNERS, 1, 0);
    let tier_env = env("hier-tier4", LEARNERS, 1, AGGS);

    let flat = run_with_trainer(&flat_env, sgd).unwrap();
    let tiered = run_with_trainer(&tier_env, sgd).unwrap();
    assert_eq!(tiered.round_metrics.len(), 1);
    assert_eq!(tiered.round_metrics[0].completed, AGGS);

    // --- Claim 2: bitwise equal to the shard-grouped reference fold ---
    // Replicate exactly what each shard's barrier saw: learner `i`
    // trains the deterministic initial model on its deterministic shard
    // of data, lands in shard `i % AGGS`, and each tier folds arrivals
    // in id-sorted order.
    let initial = driver::initial_model(&tier_env);
    let spec = TaskSpec {
        epochs: tier_env.local_epochs,
        batch_size: tier_env.batch_size,
        learning_rate: tier_env.learning_rate,
        step_budget: 0,
    };
    let mut shards: Vec<Vec<(String, Contribution)>> = (0..AGGS).map(|_| Vec::new()).collect();
    for i in 0..LEARNERS {
        let data = driver::learner_dataset(&tier_env, i);
        let (model, meta) = RustSgdTrainer.train(&initial, &data, &spec).unwrap();
        shards[tier_env.topology.shard_of(i)].push((
            format!("learner-{i}"),
            Contribution { model: Arc::new(model), weight: meta.num_samples as f64 },
        ));
    }
    let shards: Vec<Vec<Contribution>> = shards
        .into_iter()
        .map(|mut shard| {
            shard.sort_by(|a, b| a.0.cmp(&b.0)); // the barrier sorts ids as strings
            shard.into_iter().map(|(_, c)| c).collect()
        })
        .collect();
    let reference = two_tier_reference(&initial, &shards, &Backend::Sequential).unwrap();
    assert_eq!(
        tiered.community_digest,
        model_digest(&reference),
        "tiered community model drifted from the shard-grouped flat fold"
    );
    assert_ne!(
        flat.community_digest, 0,
        "flat baseline produced no community model"
    );

    // --- Claim 3: the aggregator tier shields the root ----------------
    // Deterministic totals: the root ingests AGGS partial-sum streams
    // instead of LEARNERS uploads, so its received bytes drop with the
    // fan-in (~AGGS/LEARNERS; assert a loose 1/2 so codec-size noise
    // across model contents can never flake this).
    assert!(tiered.wire_ingest_bytes > 0, "tiered root ingested nothing");
    assert!(
        tiered.wire_ingest_bytes * 2 < flat.wire_ingest_bytes,
        "root ingest did not shrink: tiered {} B vs flat {} B",
        tiered.wire_ingest_bytes,
        flat.wire_ingest_bytes
    );
    // Peak buffered ingest is O(chunk × aggregators) — 8× margin covers
    // per-chunk framing and decode scratch, and stays far below the
    // O(learners × model) a flat 24-learner burst could pin.
    let bound = 8 * AGGS * tiered.effective_stream_chunk_bytes;
    assert!(
        tiered.peak_wire_ingest_bytes <= bound,
        "tiered root peak ingest {} B exceeds O(chunk × aggregators) bound {} B",
        tiered.peak_wire_ingest_bytes,
        bound
    );
}

#[test]
fn topology_env_misconfigurations_are_rejected() {
    // More shards than learners can never form full shards.
    let mut bad = env("hier-bad-shards", 2, 1, 0);
    bad.topology = TopologySpec { aggregators: 5, shard_quorum: 0.0 };
    let err = format!("{:#}", run_with_trainer(&bad, sgd).unwrap_err());
    assert!(err.contains("aggregators"), "{err}");

    // The tree round barrier is a synchronous construct.
    let mut async_env = env("hier-bad-async", 4, 1, 2);
    async_env.protocol = Protocol::Asynchronous { staleness_alpha: 0.5 };
    let err = format!("{:#}", run_with_trainer(&async_env, sgd).unwrap_err());
    assert!(err.contains("synchronous"), "{err}");
}
