//! Aggregation ablation (E6): the Fig.-4 claim — parallel per-tensor
//! aggregation is ~10x sequential and ~100x a Python-style controller —
//! plus the axpy-kernel micro-comparison and the in-memory vs on-disk
//! model-store trade-off (Discussion, §5).

use metisfl::baselines::calibration::{self, ParallelModel};
use metisfl::baselines::{numpy_style_aggregate, python_loop_aggregate};
use metisfl::config::ModelSpec;
use metisfl::controller::aggregation::{Backend, WeightedSum};
use metisfl::controller::store::{InMemoryStore, ModelStore, OnDiskStore, StoredModel};
use metisfl::harness::runner::{fmt_secs, full_scale, BenchRunner, ReportWriter};
use metisfl::proto::TaskMeta;
use metisfl::tensor::{ops, TensorModel};
use metisfl::util::{Rng, Stopwatch, ThreadPool};
use std::sync::Arc;

fn main() {
    let spec = if full_scale() { ModelSpec::paper_1m() } else { ModelSpec::mlp(8, 20, 64) };
    let learners = if full_scale() { 50 } else { 10 };
    let cal = calibration::measure();
    println!(
        "model: {} params, {} tensors; {} learners; {} hardware threads",
        spec.param_count(),
        spec.tensor_count(),
        learners,
        cal.hardware_threads
    );

    let layout = spec.tensor_layout();
    let mut rng = Rng::new(5);
    let models: Vec<TensorModel> =
        (0..learners).map(|_| TensorModel::random_init(&layout, &mut rng)).collect();
    let refs: Vec<&TensorModel> = models.iter().collect();
    let coeffs: Vec<f64> = vec![1.0 / learners as f64; learners];
    let runner = BenchRunner::new();
    let pool = Arc::new(ThreadPool::with_hardware_threads());

    // --- aggregation strategy comparison ------------------------------
    let mut report = ReportWriter::new(
        "agg_ablation_strategies",
        &["strategy", "time", "vs parallel(modeled)"],
    );
    let seq = runner.run(|| {
        let _ = WeightedSum::compute(&refs, &coeffs, &Backend::Sequential).unwrap();
    });
    let par_real = runner.run(|| {
        let _ =
            WeightedSum::compute(&refs, &coeffs, &Backend::Parallel(Arc::clone(&pool))).unwrap();
    });
    let numpy = runner.run(|| {
        let _ = numpy_style_aggregate(&refs, &coeffs);
    });
    let pyloop = runner.run(|| {
        let _ = python_loop_aggregate(&refs, &coeffs, calibration::PYTHON_LOOP_TAX);
    });
    // Modeled 32-core parallel time from the measured sequential time.
    let modeled = ParallelModel::paper_machine(&cal)
        .parallel_time(std::time::Duration::from_secs_f64(seq.mean), spec.tensor_count());
    let base = modeled.as_secs_f64();
    let mut row = |name: &str, secs: f64| {
        report.row(vec![
            name.into(),
            fmt_secs(std::time::Duration::from_secs_f64(secs)),
            format!("{:.1}x", secs / base),
        ]);
    };
    row("parallel per-tensor (modeled 32c)", base);
    row(&format!("parallel per-tensor (real {}t)", cal.hardware_threads), par_real.mean);
    row("sequential per-tensor", seq.mean);
    row("numpy-style temporaries", numpy.mean);
    row(
        &format!("python-loop (tax {})", calibration::PYTHON_LOOP_TAX),
        pyloop.mean,
    );
    report.emit().unwrap();
    println!(
        "paper claim: OMP ~10x sequential (got {:.1}x modeled), ~100x python-style (got {:.1}x)",
        seq.mean / base,
        pyloop.mean / base
    );

    // --- axpy kernel micro-ablation ------------------------------------
    // Interleaved best-of-N: this box is a noisy shared core, so paired
    // minima are the only stable comparison (see EXPERIMENTS.md §Perf).
    let n = 1 << 20;
    let x = vec![1.0f32; n];
    let mut acc = vec![0.0f32; n];
    let reps = 8;
    let mut best_zip = f64::MAX;
    let mut best_unrolled = f64::MAX;
    for _ in 0..12 {
        let sw = Stopwatch::start();
        for _ in 0..reps {
            ops::axpy(&mut acc, &x, 0.25);
        }
        best_zip = best_zip.min(sw.elapsed_secs() / reps as f64);
        let sw = Stopwatch::start();
        for _ in 0..reps {
            ops::axpy_unrolled(&mut acc, &x, 0.25);
        }
        best_unrolled = best_unrolled.min(sw.elapsed_secs() / reps as f64);
    }
    std::hint::black_box(&acc);
    let mut report = ReportWriter::new("agg_ablation_axpy", &["kernel", "GB/s (best)"]);
    let gbps = |secs: f64| format!("{:.2}", (n * 8) as f64 / secs / 1e9);
    report.row(vec!["axpy (zip loop, production)".into(), gbps(best_zip)]);
    report.row(vec!["axpy (hand-unrolled 8-wide)".into(), gbps(best_unrolled)]);
    report.emit().unwrap();

    // --- model store comparison (§5 future work) ------------------------
    let store_model = TensorModel::random_init(&layout, &mut Rng::new(7));
    let entry = |i: usize| StoredModel {
        learner_id: format!("l{i}"),
        round: 1,
        meta: TaskMeta { num_samples: 100, ..Default::default() },
        model: store_model.clone(),
    };
    let mut mem = InMemoryStore::new();
    let sw = Stopwatch::start();
    for i in 0..learners {
        mem.insert(entry(i)).unwrap();
    }
    let mem_insert = sw.elapsed();
    let sw = Stopwatch::start();
    let ids: Vec<String> = (0..learners).map(|i| format!("l{i}")).collect();
    let _ = mem.select_latest(&ids).unwrap();
    let mem_select = sw.elapsed();

    let disk_dir = std::env::temp_dir().join(format!("metisfl-bench-store-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&disk_dir);
    let mut disk = OnDiskStore::open(&disk_dir).unwrap();
    let sw = Stopwatch::start();
    for i in 0..learners {
        disk.insert(entry(i)).unwrap();
    }
    let disk_insert = sw.elapsed();
    let sw = Stopwatch::start();
    let _ = disk.select_latest(&ids).unwrap();
    let disk_select = sw.elapsed();
    std::fs::remove_dir_all(&disk_dir).ok();

    let mut report =
        ReportWriter::new("agg_ablation_stores", &["store", "insert all", "select all"]);
    report.row(vec!["in-memory hashmap".into(), fmt_secs(mem_insert), fmt_secs(mem_select)]);
    report.row(vec!["on-disk".into(), fmt_secs(disk_insert), fmt_secs(disk_select)]);
    report.emit().unwrap();
}
