//! Aggregation ablation (E6): the Fig.-4 claim — parallel per-tensor
//! aggregation is ~10x sequential and ~100x a Python-style controller —
//! extended with the chunk-partitioned backend (scratch reuse on/off),
//! the layout-degeneracy cell where per-tensor parallelism caps at the
//! tensor count, the axpy-kernel micro-comparison, and the in-memory vs
//! on-disk model-store trade-off (Discussion, §5).

use metisfl::baselines::calibration::{self, ParallelModel};
use metisfl::baselines::{numpy_style_aggregate, python_loop_aggregate};
use metisfl::config::ModelSpec;
use metisfl::controller::aggregation::{Backend, ScratchArena, WeightedSum};
use metisfl::controller::store::{InMemoryStore, ModelStore, OnDiskStore, StoredModel};
use metisfl::harness::runner::{fmt_secs, full_scale, BenchRunner, ReportWriter};
use metisfl::proto::TaskMeta;
use metisfl::tensor::{ops, TensorModel};
use metisfl::util::{Rng, Stopwatch, ThreadPool};
use std::sync::Arc;

fn main() {
    let spec = if full_scale() { ModelSpec::paper_1m() } else { ModelSpec::mlp(8, 20, 64) };
    let learners = if full_scale() { 50 } else { 10 };
    let cal = calibration::measure();
    println!(
        "model: {} params, {} tensors; {} learners; {} hardware threads",
        spec.param_count(),
        spec.tensor_count(),
        learners,
        cal.hardware_threads
    );

    let layout = spec.tensor_layout();
    let mut rng = Rng::new(5);
    let models: Vec<Arc<TensorModel>> = (0..learners)
        .map(|_| Arc::new(TensorModel::random_init(&layout, &mut rng)))
        .collect();
    let refs: Vec<&TensorModel> = models.iter().map(|m| m.as_ref()).collect();
    let coeffs: Vec<f64> = vec![1.0 / learners as f64; learners];
    let runner = BenchRunner::new();
    let pool = Arc::new(ThreadPool::with_hardware_threads());

    // --- aggregation strategy comparison ------------------------------
    let mut report = ReportWriter::new(
        "agg_ablation_strategies",
        &["strategy", "time", "vs parallel(modeled)"],
    );
    let seq = runner.run(|| {
        let _ = WeightedSum::compute(&models, &coeffs, &Backend::Sequential).unwrap();
    });
    let par_real = runner.run(|| {
        let _ =
            WeightedSum::compute(&models, &coeffs, &Backend::Parallel(Arc::clone(&pool))).unwrap();
    });
    // Chunked with scratch reuse: recycle each output so steady-state
    // iterations allocate nothing — the controller's configuration.
    let scratch = Arc::new(ScratchArena::new());
    let chunked_backend =
        Backend::Chunked { pool: Arc::clone(&pool), scratch: Arc::clone(&scratch) };
    let chunked_reuse = runner.run(|| {
        let out = WeightedSum::compute(&models, &coeffs, &chunked_backend).unwrap();
        scratch.reclaim_model(Arc::new(out));
    });
    let chunked_allocs = scratch.fresh_allocations();
    // Chunked without reuse: a fresh arena per call isolates the cost of
    // cold allocation in the otherwise identical sweep.
    let chunked_fresh = runner.run(|| {
        let cold = Backend::Chunked {
            pool: Arc::clone(&pool),
            scratch: Arc::new(ScratchArena::new()),
        };
        let _ = WeightedSum::compute(&models, &coeffs, &cold).unwrap();
    });
    let numpy = runner.run(|| {
        let _ = numpy_style_aggregate(&refs, &coeffs);
    });
    let pyloop = runner.run(|| {
        let _ = python_loop_aggregate(&refs, &coeffs, calibration::PYTHON_LOOP_TAX);
    });
    // Modeled 32-core parallel time from the measured sequential time.
    let modeled = ParallelModel::paper_machine(&cal)
        .parallel_time(std::time::Duration::from_secs_f64(seq.mean), spec.tensor_count());
    let base = modeled.as_secs_f64();
    let mut row = |name: &str, secs: f64| {
        report.row(vec![
            name.into(),
            fmt_secs(std::time::Duration::from_secs_f64(secs)),
            format!("{:.1}x", secs / base),
        ]);
    };
    row("parallel per-tensor (modeled 32c)", base);
    row(&format!("parallel per-tensor (real {}t)", cal.hardware_threads), par_real.mean);
    row(
        &format!("chunked + scratch reuse (real {}t)", cal.hardware_threads),
        chunked_reuse.mean,
    );
    row(
        &format!("chunked, fresh alloc (real {}t)", cal.hardware_threads),
        chunked_fresh.mean,
    );
    row("sequential per-tensor", seq.mean);
    row("numpy-style temporaries", numpy.mean);
    row(
        &format!("python-loop (tax {})", calibration::PYTHON_LOOP_TAX),
        pyloop.mean,
    );
    report.emit().unwrap();
    println!(
        "paper claim: OMP ~10x sequential (got {:.1}x modeled), ~100x python-style (got {:.1}x)",
        seq.mean / base,
        pyloop.mean / base
    );
    println!(
        "chunked steady state: {} fresh output allocations across {} timed runs",
        chunked_allocs,
        runner.warmup + runner.samples
    );

    // --- layout degeneracy: 2 giant tensors ----------------------------
    // Per-tensor parallelism caps at 2 threads here no matter the
    // machine; the chunked sweep still uses every core.
    let wide_n = if full_scale() { 1 << 21 } else { 1 << 18 };
    let wide_layout: Vec<(String, Vec<usize>)> =
        vec![("a".into(), vec![wide_n]), ("b".into(), vec![wide_n])];
    let wide_models: Vec<Arc<TensorModel>> = (0..learners)
        .map(|_| Arc::new(TensorModel::random_init(&wide_layout, &mut rng)))
        .collect();
    let mut report = ReportWriter::new(
        "agg_ablation_two_tensor",
        &["strategy (2 equal giant tensors)", "time", "speedup vs sequential"],
    );
    let wseq = runner.run(|| {
        let _ = WeightedSum::compute(&wide_models, &coeffs, &Backend::Sequential).unwrap();
    });
    let wpar = runner.run(|| {
        let _ = WeightedSum::compute(&wide_models, &coeffs, &Backend::Parallel(Arc::clone(&pool)))
            .unwrap();
    });
    let wide_scratch = Arc::new(ScratchArena::new());
    let wide_backend =
        Backend::Chunked { pool: Arc::clone(&pool), scratch: Arc::clone(&wide_scratch) };
    let wchk = runner.run(|| {
        let out = WeightedSum::compute(&wide_models, &coeffs, &wide_backend).unwrap();
        wide_scratch.reclaim_model(Arc::new(out));
    });
    let mut row = |name: &str, secs: f64| {
        report.row(vec![
            name.into(),
            fmt_secs(std::time::Duration::from_secs_f64(secs)),
            format!("{:.2}x", wseq.mean / secs),
        ]);
    };
    row("sequential", wseq.mean);
    row("parallel per-tensor (caps at 2 threads)", wpar.mean);
    row(&format!("chunked ({} threads)", pool.size()), wchk.mean);
    report.emit().unwrap();
    if pool.size() > 2 {
        println!(
            "two-tensor cell: chunked vs per-tensor parallel = {:.2}x (expect >= ~1 when cores > 2)",
            wpar.mean / wchk.mean
        );
    }

    // --- axpy kernel micro-ablation ------------------------------------
    // Interleaved best-of-N: this box is a noisy shared core, so paired
    // minima are the only stable comparison (see EXPERIMENTS.md §Perf).
    let n = 1 << 20;
    let x = vec![1.0f32; n];
    let mut acc = vec![0.0f32; n];
    let reps = 8;
    let mut best_zip = f64::MAX;
    let mut best_unrolled = f64::MAX;
    for _ in 0..12 {
        let sw = Stopwatch::start();
        for _ in 0..reps {
            ops::axpy(&mut acc, &x, 0.25);
        }
        best_zip = best_zip.min(sw.elapsed_secs() / reps as f64);
        let sw = Stopwatch::start();
        for _ in 0..reps {
            ops::axpy_unrolled(&mut acc, &x, 0.25);
        }
        best_unrolled = best_unrolled.min(sw.elapsed_secs() / reps as f64);
    }
    std::hint::black_box(&acc);
    let mut report = ReportWriter::new("agg_ablation_axpy", &["kernel", "GB/s (best)"]);
    let gbps = |secs: f64| format!("{:.2}", (n * 8) as f64 / secs / 1e9);
    report.row(vec!["axpy (zip loop, production)".into(), gbps(best_zip)]);
    report.row(vec!["axpy (hand-unrolled 8-wide)".into(), gbps(best_unrolled)]);
    report.emit().unwrap();

    // --- model store comparison (§5 future work) ------------------------
    let store_model = Arc::new(TensorModel::random_init(&layout, &mut Rng::new(7)));
    let entry = |i: usize| StoredModel {
        learner_id: format!("l{i}"),
        round: 1,
        meta: TaskMeta { num_samples: 100, ..Default::default() },
        model: Arc::clone(&store_model),
    };
    let mut mem = InMemoryStore::new();
    let sw = Stopwatch::start();
    for i in 0..learners {
        mem.insert(entry(i)).unwrap();
    }
    let mem_insert = sw.elapsed();
    let sw = Stopwatch::start();
    let ids: Vec<String> = (0..learners).map(|i| format!("l{i}")).collect();
    let _ = mem.select_latest(&ids).unwrap();
    let mem_select = sw.elapsed();

    let disk_dir = std::env::temp_dir().join(format!("metisfl-bench-store-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&disk_dir);
    let mut disk = OnDiskStore::open(&disk_dir).unwrap();
    let sw = Stopwatch::start();
    for i in 0..learners {
        disk.insert(entry(i)).unwrap();
    }
    let disk_insert = sw.elapsed();
    let sw = Stopwatch::start();
    let _ = disk.select_latest(&ids).unwrap();
    let disk_select = sw.elapsed();
    std::fs::remove_dir_all(&disk_dir).ok();

    let mut report =
        ReportWriter::new("agg_ablation_stores", &["store", "insert all", "select all"]);
    report.row(vec!["in-memory hashmap".into(), fmt_secs(mem_insert), fmt_secs(mem_select)]);
    report.row(vec!["on-disk".into(), fmt_secs(disk_insert), fmt_secs(disk_select)]);
    report.emit().unwrap();
}
