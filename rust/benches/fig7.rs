//! Figure 7: FL framework operations comparison, 10M-parameter model.
//! The most demanding grid: the paper reports NVFlare failing at >=100
//! learners and IBM FL at 200 (out-of-resource on their testbed; we run
//! them and report measured values). See fig5.rs for structure.

use metisfl::config::ModelSpec;
use metisfl::harness::{figure_sweep, FigureConfig};
use metisfl::metrics::FedOp;

fn main() {
    let config = FigureConfig::paper(
        "fig7",
        ModelSpec::paper_10m(),    // FULL=1: 100 layers x 320 units
        ModelSpec::mlp(8, 30, 64), // reduced: ~123k params
    );
    let result = figure_sweep(config);
    result.emit_panels().expect("emit fig7 panels");

    println!("\nfederation-round slowdowns vs MetisFL gRPC+OMP at max learners:");
    for (fw, ratio) in result.speedups(FedOp::FederationRound) {
        println!("  {fw:<18} {ratio:8.1}x");
    }
}
