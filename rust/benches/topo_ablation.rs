//! Topology ablation: flat single-tier controller vs the hierarchical
//! aggregation tier, over the real federation stack (in-proc transport,
//! streamed delta-rle data plane, synthetic trainers). The paper's
//! controller is "embarrassingly parallelized" inside one process; the
//! aggregator tier extends the same argument across processes — the
//! root folds one partial weighted sum per shard, so its ingest is
//! O(aggregators) while the flat root's is O(learners).
//!
//! The `root ingest frac of flat` column is gated by `metisfl
//! bench-check` (lower is better): it is the deterministic ratio of
//! encoded stream bytes the root *received* per run, 2-tier over flat
//! (≈ aggregators/learners). Drifting toward 1.0 means partial sums
//! stopped replacing per-learner uploads at the root.

use metisfl::config::{FederationEnv, ModelSpec, TopologySpec};
use metisfl::driver::{self, FederationReport};
use metisfl::harness::runner::{fmt_secs, full_scale, ReportWriter};
use metisfl::learner::SyntheticTrainer;
use std::sync::Arc;

fn run(name: &str, learners: usize, rounds: usize, aggregators: usize) -> FederationReport {
    let mut env = FederationEnv::builder(name)
        .learners(learners)
        .rounds(rounds)
        .model(ModelSpec::mlp(16, 4, 32))
        .samples_per_learner(20)
        .batch_size(10)
        .quorum_fraction(1.0)
        .stream_chunk_bytes(4096)
        .heartbeat_ms(10_000)
        .seed(0x70_70)
        .build();
    if aggregators > 0 {
        env.topology = TopologySpec { aggregators, shard_quorum: 0.0 };
    }
    driver::run_with_trainer(&env, |_| {
        Arc::new(SyntheticTrainer::new(60, 0.0)) as Arc<dyn metisfl::learner::Trainer>
    })
    .expect("federation run")
}

fn main() {
    // ISSUE scale: a 100-learner fleet behind 10 aggregators; the CI
    // quick preset keeps the same ~8:1 fan-in on a smaller fleet so the
    // gated ratio lands in the same regime either way.
    let (learners, aggregators) = if full_scale() { (100, 10) } else { (32, 4) };
    let rounds = 2;
    println!("{learners} learners, {rounds} rounds, flat vs {aggregators}-shard 2-tier");

    let flat = run("topo-flat", learners, rounds, 0);
    let tiered = run("topo-tiered", learners, rounds, aggregators);
    assert_eq!(
        flat.round_metrics.len(),
        tiered.round_metrics.len(),
        "both topologies must close every round"
    );

    let mut report = ReportWriter::new(
        "topo_ablation",
        &[
            "topology",
            "root ingest B/round",
            "root peak ingest B",
            "wall clock",
            "root ingest frac of flat",
        ],
    );
    let flat_ingest = flat.wire_ingest_bytes.max(1);
    for (label, r) in [("flat", &flat), ("2-tier", &tiered)] {
        report.row(vec![
            label.to_string(),
            format!("{}", r.wire_ingest_bytes / rounds as u64),
            format!("{}", r.peak_wire_ingest_bytes),
            fmt_secs(r.wall_clock),
            format!("{:.3}", r.wire_ingest_bytes as f64 / flat_ingest as f64),
        ]);
    }
    report.emit().unwrap();
    println!(
        "root ingested {} B flat vs {} B behind {aggregators} aggregators \
         (frac {:.3}; dispatch fan-out is a tree: encode once per tier)",
        flat.wire_ingest_bytes,
        tiered.wire_ingest_bytes,
        tiered.wire_ingest_bytes as f64 / flat_ingest as f64
    );
}
