//! Table 1: qualitative comparison of FL systems — regenerated from the
//! capability declarations in `baselines::capabilities`.

fn main() {
    println!("\n### Table 1 — qualitative comparison of FL systems\n");
    println!("{}", metisfl::baselines::capabilities::render_table());
    std::fs::create_dir_all("bench_out").ok();
    std::fs::write(
        "bench_out/table1.md",
        metisfl::baselines::capabilities::render_table(),
    )
    .expect("write table1.md");
    println!("wrote bench_out/table1.md");
}
