//! Figure 6: FL framework operations comparison, 1M-parameter model.
//! See fig5.rs for panel structure and FULL=1 behaviour.

use metisfl::config::ModelSpec;
use metisfl::harness::{figure_sweep, FigureConfig};

fn main() {
    let config = FigureConfig::paper(
        "fig6",
        ModelSpec::paper_1m(),     // FULL=1: 100 layers x 100 units
        ModelSpec::mlp(8, 20, 32), // reduced: ~24k params
    );
    let result = figure_sweep(config);
    result.emit_panels().expect("emit fig6 panels");
}
