//! Serialization ablation (E7): the §3 claim — tensor-as-bytes transfer
//! has much lower overhead than object-graph (pickle-style) encodings.
//! Compares encode+decode throughput and wire size for the bytes codec,
//! pickle-style, and pickle+base64 (IBM-FL-style envelope), plus the
//! secure-channel (TLS-sim) tax on the bytes path.
//!
//! Two extra reports cover the negotiated wire codecs:
//! `codec_ablation_wire` isolates f32 / bf16 / delta encode+decode
//! throughput and wire size, and `codec_ablation_federation` runs small
//! end-to-end federations per data-plane configuration (one-shot,
//! streamed f32/delta/bf16) — the dispatch-streaming ablation recipe in
//! EXPERIMENTS.md.

use metisfl::baselines::pyserde;
use metisfl::config::{FederationEnv, ModelSpec, WireCodecChoice};
use metisfl::harness::runner::{fmt_secs, full_scale, BenchRunner, ReportWriter};
use metisfl::learner::SyntheticTrainer;
use metisfl::net::secure::SecureSession;
use metisfl::proto::{Message, ModelProto};
use metisfl::tensor::{ByteOrder, CodecId, DType, TensorModel};
use metisfl::util::{fmt_bytes, Rng};
use std::sync::Arc;

fn main() {
    let spec = if full_scale() { ModelSpec::paper_1m() } else { ModelSpec::mlp(8, 20, 64) };
    let layout = spec.tensor_layout();
    let model = TensorModel::random_init(&layout, &mut Rng::new(11));
    let raw_bytes = model.byte_size_f32();
    println!("model: {} params ({} payload)", spec.param_count(), fmt_bytes(raw_bytes));
    let runner = BenchRunner::new();

    let mut report = ReportWriter::new(
        "codec_ablation",
        &["codec", "wire size", "expansion", "enc+dec MB/s"],
    );

    // Isolated tensor codec (no message framing): the raw flatten+dump
    // path of §3, best-of-12 interleaved (noisy shared core).
    {
        use metisfl::tensor::Tensor;
        let flat = model.to_flat();
        let t = Tensor::new("all", vec![flat.len()], flat);
        let mut best = f64::MAX;
        for _ in 0..12 {
            let sw = metisfl::util::Stopwatch::start();
            let enc = t.encode_data(DType::F32, ByteOrder::Little);
            let back =
                Tensor::decode_data("all", t.shape.clone(), DType::F32, ByteOrder::Little, &enc)
                    .unwrap();
            std::hint::black_box(&back);
            best = best.min(sw.elapsed_secs());
        }
        report.row(vec![
            "raw tensor codec (no framing)".into(),
            fmt_bytes(raw_bytes),
            "1.00x".into(),
            format!("{:.1}", raw_bytes as f64 / best / 1e6),
        ]);
    }

    // Bytes-tensor proto (MetisFL §3).
    let mut wire_len = 0usize;
    let s = runner.run(|| {
        let proto = ModelProto::from_model(&model, DType::F32, ByteOrder::Little);
        let msg = Message::ShipModel { model: proto }.encode();
        wire_len = msg.len();
        let back = Message::decode(&msg).unwrap();
        std::hint::black_box(&back);
    });
    let mbs = |secs: f64| format!("{:.1}", raw_bytes as f64 / secs / 1e6);
    report.row(vec![
        "tensor-as-bytes (MetisFL)".into(),
        fmt_bytes(wire_len),
        format!("{:.2}x", wire_len as f64 / raw_bytes as f64),
        mbs(s.mean),
    ]);

    // Pickle-style.
    let mut pickle_len = 0usize;
    let s = runner.run(|| {
        let bytes = pyserde::pickle_encode(&model, 1);
        pickle_len = bytes.len();
        let back = pyserde::pickle_decode(&bytes, 1).unwrap();
        std::hint::black_box(&back);
    });
    report.row(vec![
        "pickle-style object graph".into(),
        fmt_bytes(pickle_len),
        format!("{:.2}x", pickle_len as f64 / raw_bytes as f64),
        mbs(s.mean),
    ]);

    // Pickle + base64 envelope.
    let mut b64_len = 0usize;
    let s = runner.run(|| {
        let bytes = pyserde::pickle_encode(&model, 1);
        let enc = pyserde::base64_encode(&bytes);
        b64_len = enc.len();
        let dec = pyserde::base64_decode(&enc).unwrap();
        let back = pyserde::pickle_decode(&dec, 1).unwrap();
        std::hint::black_box(&back);
    });
    report.row(vec![
        "pickle + base64 (IBM-FL-style)".into(),
        fmt_bytes(b64_len),
        format!("{:.2}x", b64_len as f64 / raw_bytes as f64),
        mbs(s.mean),
    ]);

    // Bytes codec through the secure channel (TLS-sim seal+open).
    let psk = [3u8; 32];
    let nonce = [1u8; 16];
    let s = runner.run(|| {
        let mut tx = SecureSession::derive(&psk, &nonce, &nonce);
        let mut rx = SecureSession::derive(&psk, &nonce, &nonce);
        let proto = ModelProto::from_model(&model, DType::F32, ByteOrder::Little);
        let msg = Message::ShipModel { model: proto }.encode();
        let sealed = tx.seal(&msg);
        let opened = rx.open(&sealed).unwrap();
        let back = Message::decode(&opened).unwrap();
        std::hint::black_box(&back);
    });
    report.row(vec![
        "tensor-as-bytes + secure channel".into(),
        fmt_bytes(wire_len + 32),
        format!("{:.2}x", (wire_len + 32) as f64 / raw_bytes as f64),
        mbs(s.mean),
    ]);

    report.emit().unwrap();

    // --- negotiated wire codecs (f32 / bf16 / delta / delta-rle) -------
    // Encode+decode through the WireCodec trait the data plane uses; the
    // delta base is a nearby model (one training step away), the regime
    // the delta codecs are designed for. "wire frac of f32" is the
    // deterministic compression ratio the CI bench gate tracks
    // (lower is better; see `metisfl bench-check`).
    let mut wire_report = ReportWriter::new(
        "codec_ablation_wire",
        &["wire codec", "wire size", "wire frac of f32", "zero bytes", "enc+dec MB/s"],
    );
    let base: TensorModel = {
        let mut m = model.clone();
        for t in &mut m.tensors {
            for v in t.data.iter_mut().step_by(17) {
                *v += 1e-3;
            }
        }
        m
    };
    for id in CodecId::ALL {
        let codec = id.codec();
        let mut wire = 0usize;
        let mut zeros = 0usize;
        let s = runner.run(|| {
            wire = 0;
            zeros = 0;
            for (i, t) in model.tensors.iter().enumerate() {
                let b = id.needs_base().then(|| &base.tensors[i].data[..]);
                let enc = codec.encode(&t.data, b);
                wire += enc.len();
                zeros += enc.iter().filter(|&&x| x == 0).count();
                let mut dst = vec![0.0f32; t.data.len()];
                codec.decode_into(&enc, b, &mut dst);
                std::hint::black_box(&dst);
            }
        });
        wire_report.row(vec![
            id.name().into(),
            fmt_bytes(wire),
            format!("{:.3}", wire as f64 / raw_bytes as f64),
            format!("{:.0}%", 100.0 * zeros as f64 / wire as f64),
            mbs(s.mean),
        ]);
    }
    wire_report.emit().unwrap();

    // --- end-to-end federation rows (dispatch-streaming ablation) ------
    // Same small federation per data-plane configuration; wall-clock is
    // indicative only (not CI-gated). The load-bearing columns are the
    // wire gauge and the per-round wire-byte totals: the "steady-state"
    // cells shrink the synthetic update magnitude to the converged
    // regime, where the entropy-coded delta wire must move well under
    // half of plain delta's bytes (acceptance-tested in
    // tests/streaming.rs; tracked per row here).
    let mut fed_report = ReportWriter::new(
        "codec_ablation_federation",
        &[
            "data plane",
            "fed round mean",
            "peak wire ingest",
            "wire bytes/round",
            "wire frac of f32",
            "final loss",
        ],
    );
    let fed_spec =
        if full_scale() { ModelSpec::mlp(8, 40, 64) } else { ModelSpec::mlp(8, 10, 32) };
    let rounds = if full_scale() { 4 } else { 2 };
    let cells: &[(&str, usize, WireCodecChoice, f32)] = &[
        ("one-shot f32", 0, WireCodecChoice::F32, 0.01),
        ("streamed f32 (64 KiB chunks)", 64 * 1024, WireCodecChoice::F32, 0.01),
        ("streamed delta (64 KiB chunks)", 64 * 1024, WireCodecChoice::Delta, 0.01),
        ("streamed delta-rle (64 KiB chunks)", 64 * 1024, WireCodecChoice::DeltaRle, 0.01),
        ("steady-state delta (small updates)", 64 * 1024, WireCodecChoice::Delta, 1e-6),
        ("steady-state delta-rle (small updates)", 64 * 1024, WireCodecChoice::DeltaRle, 1e-6),
        ("streamed bf16 up+down (64 KiB)", 64 * 1024, WireCodecChoice::Bf16, 0.01),
    ];
    for (label, chunk, codec, update_scale) in cells {
        let env = FederationEnv::builder(&format!("codec-fed-{}", label.replace(' ', "-")))
            .learners(4)
            .rounds(rounds)
            .model(fed_spec.clone())
            .samples_per_learner(20)
            .batch_size(10)
            .stream_chunk_bytes(*chunk)
            .wire_codec(*codec)
            .bf16_dispatch(*codec == WireCodecChoice::Bf16)
            .build();
        let run = metisfl::driver::run_with_trainer(&env, |_| {
            Arc::new(SyntheticTrainer::new(0, *update_scale))
        });
        match run {
            Ok(report) => {
                let mean = report
                    .round_metrics
                    .iter()
                    .map(|r| r.federation_round)
                    .sum::<std::time::Duration>()
                    / report.round_metrics.len().max(1) as u32;
                let raw = report.wire_bytes_sent + report.wire_bytes_saved;
                fed_report.row(vec![
                    (*label).into(),
                    fmt_secs(mean),
                    fmt_bytes(report.peak_wire_ingest_bytes),
                    format!("{}", report.wire_bytes_sent / rounds as u64),
                    if raw > 0 {
                        format!("{:.3}", report.wire_bytes_sent as f64 / raw as f64)
                    } else {
                        "-".into()
                    },
                    report
                        .final_loss
                        .map(|l| format!("{l:.4}"))
                        .unwrap_or_else(|| "-".into()),
                ]);
            }
            Err(e) => fed_report.row(vec![
                (*label).into(),
                format!("failed: {e:#}"),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
            ]),
        }
    }
    fed_report.emit().unwrap();
}
