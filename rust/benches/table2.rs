//! Table 2: federation round time (secs) for the 10M-parameter model,
//! framework x learner count — the federation-round column of the
//! Fig.-7 sweep. We reproduce the *shape* (who wins, rough factors,
//! where failures/crossovers fall), not absolute numbers: learner
//! compute and the testbed differ (see EXPERIMENTS.md for the
//! paper-vs-measured comparison).

use metisfl::config::ModelSpec;
use metisfl::harness::{figure_sweep, FigureConfig};
use metisfl::metrics::FedOp;

fn main() {
    let config = FigureConfig::paper(
        "table2",
        ModelSpec::paper_10m(),
        ModelSpec::mlp(8, 30, 64), // reduced-scale default
    );
    let result = figure_sweep(config);
    result.emit_table2().expect("emit table2");

    // Shape checks from the paper (reported, not panicking, so the bench
    // still emits full output on reduced grids).
    let s = result.speedups(FedOp::FederationRound);
    println!("\nshape checks (paper: every framework slower than MetisFL gRPC+OMP):");
    for (fw, ratio) in &s {
        let verdict = if *ratio > 1.0 { "ok" } else { "UNEXPECTED" };
        println!("  {fw:<18} {ratio:8.1}x slower   [{verdict}]");
    }
}
