//! Scheduling ablation (E8): synchronous vs pacing-aware semi-sync vs
//! deadline-quorum vs asynchronous execution over the real federation
//! stack (in-proc transport, synthetic trainers with a 10× speed skew),
//! measuring wall-clock per community update AND the per-round
//! straggler spread (slowest-minus-fastest completion wall clock) —
//! the quantity the pacing subsystem exists to shrink.
//!
//! The `spread frac of sync` column is gated by `metisfl bench-check`
//! (lower is better): pacing-aware semi-sync budgets slow learners the
//! fixed λ-budget and fast learners proportionally more, so their
//! completions land together; a ratio drifting toward 1.0 means the
//! machinery regressed.

use metisfl::config::{FederationEnv, ModelSpec, Protocol};
use metisfl::driver;
use metisfl::harness::runner::{fmt_secs, full_scale, ReportWriter};
use metisfl::learner::SyntheticTrainer;
use std::sync::Arc;
use std::time::Duration;

struct Cell {
    wall: Duration,
    per_update: Duration,
    /// Mean completion spread over the profiled rounds (round 1 runs on
    /// fallback budgets while the pacing registry is still empty, so it
    /// is excluded).
    spread: Duration,
}

/// Step-time for learner `i` on an `n`-learner fleet with a 10× skew:
/// the fastest learner runs at `base`, the slowest at `10 × base`.
fn skewed_step_us(base: u64, i: usize, n: usize) -> u64 {
    let f = 1.0 + 9.0 * i as f64 / (n - 1).max(1) as f64;
    (base as f64 * f).round() as u64
}

fn run(protocol: Protocol, quorum: f64, learners: usize, rounds: usize, base_us: u64) -> Cell {
    let env = FederationEnv::builder("sched-ablation")
        .learners(learners)
        .rounds(rounds)
        .model(ModelSpec::mlp(8, 6, 16))
        .samples_per_learner(50)
        .batch_size(10)
        .protocol(protocol)
        .quorum_fraction(quorum)
        .heartbeat_ms(10_000)
        .build();
    let report = driver::run_with_trainer(&env, |idx| {
        Arc::new(SyntheticTrainer::new(skewed_step_us(base_us, idx, learners), 0.01))
            as Arc<dyn metisfl::learner::Trainer>
    })
    .expect("federation run");
    let total = report.wall_clock;
    let per_round = total / report.round_metrics.len().max(1) as u32;
    let profiled: Vec<Duration> = report
        .round_metrics
        .iter()
        .skip(1)
        .map(|r| r.completion_spread)
        .collect();
    let spread = if profiled.is_empty() {
        Duration::ZERO
    } else {
        profiled.iter().sum::<Duration>() / profiled.len() as u32
    };
    Cell { wall: total, per_update: per_round, spread }
}

fn main() {
    let learners = if full_scale() { 20 } else { 8 };
    let rounds = if full_scale() { 10 } else { 4 };
    let base_us = if full_scale() { 400 } else { 600 };
    println!(
        "{learners} learners, {rounds} rounds, 10x speed skew ({base_us}..{}us/step)",
        10 * base_us
    );

    let mut report = ReportWriter::new(
        "sched_ablation",
        &[
            "protocol",
            "wall clock",
            "per community update",
            "round spread",
            "spread frac of sync",
        ],
    );
    let cells: Vec<(&str, Cell)> = vec![
        (
            "sync fixed",
            run(Protocol::Synchronous, 1.0, learners, rounds, base_us),
        ),
        (
            "semi-sync paced (lambda=1)",
            run(Protocol::SemiSynchronous { lambda: 1.0 }, 1.0, learners, rounds, base_us),
        ),
        (
            "quorum sync (q=0.6)",
            run(Protocol::Synchronous, 0.6, learners, rounds, base_us),
        ),
        (
            "async (alpha=0.5)",
            run(Protocol::Asynchronous { staleness_alpha: 0.5 }, 1.0, learners, rounds, base_us),
        ),
    ];
    let sync_spread = cells[0].1.spread.as_secs_f64().max(1e-9);
    for (name, cell) in &cells {
        let frac = if cell.spread == Duration::ZERO && *name != "sync fixed" {
            // Async reports carry no round barrier, hence no spread.
            "-".to_string()
        } else {
            format!("{:.3}", cell.spread.as_secs_f64() / sync_spread)
        };
        report.row(vec![
            name.to_string(),
            fmt_secs(cell.wall),
            fmt_secs(cell.per_update),
            fmt_secs(cell.spread),
            frac,
        ]);
    }
    report.emit().unwrap();
    println!("paper context: only MetisFL supports async execution (Table 1);");
    println!("pacing-aware semi-sync gives learner i a budget of t_target*throughput_i so");
    println!("the 10x-skew fleet finishes together; quorum rounds aggregate at the cut and");
    println!("fold late completions through the async staleness path instead of dropping them.");
}
