//! Protocol ablation (E8): synchronous vs semi-synchronous vs
//! asynchronous execution over the real federation stack (in-proc
//! transport, synthetic trainers with heterogeneous speeds), measuring
//! wall-clock per community update — the Table-1 differentiator.

use metisfl::config::{FederationEnv, ModelSpec, Protocol};
use metisfl::driver;
use metisfl::harness::runner::{fmt_secs, full_scale, ReportWriter};
use metisfl::learner::SyntheticTrainer;
use std::sync::Arc;
use std::time::Duration;

fn run(protocol: Protocol, learners: usize, rounds: usize) -> (Duration, Duration) {
    let env = FederationEnv::builder("sched-ablation")
        .learners(learners)
        .rounds(rounds)
        .model(ModelSpec::mlp(8, 6, 16))
        .samples_per_learner(50)
        .batch_size(10)
        .protocol(protocol)
        .heartbeat_ms(10_000)
        .build();
    // Heterogeneous learner speeds: learner i sleeps i*300us per step —
    // the straggler pattern semi-sync/async are designed to absorb.
    let report = driver::run_with_trainer(&env, |idx| {
        Arc::new(SyntheticTrainer::new(300 * idx as u64, 0.01)) as Arc<dyn metisfl::learner::Trainer>
    })
    .expect("federation run");
    let total = report.wall_clock;
    let per_round = total / report.round_metrics.len().max(1) as u32;
    (total, per_round)
}

fn main() {
    let learners = if full_scale() { 20 } else { 8 };
    let rounds = if full_scale() { 10 } else { 4 };
    println!("{learners} learners, {rounds} rounds, straggler spread 0..{}us/step", 300 * (learners - 1));

    let mut report = ReportWriter::new(
        "sched_ablation",
        &["protocol", "wall clock", "per community update"],
    );
    for (name, protocol) in [
        ("synchronous", Protocol::Synchronous),
        ("semi-synchronous (λ=1)", Protocol::SemiSynchronous { lambda: 1.0 }),
        ("asynchronous (α=0.5)", Protocol::Asynchronous { staleness_alpha: 0.5 }),
    ] {
        let (total, per_update) = run(protocol, learners, rounds);
        report.row(vec![name.into(), fmt_secs(total), fmt_secs(per_update)]);
    }
    report.emit().unwrap();
    println!("paper context: only MetisFL supports async execution (Table 1);");
    println!("semi-sync bounds straggler stalls; async removes the round barrier.");
}
