//! Figure 5: FL framework operations comparison, 100k-parameter model.
//!
//! Panels (a)–(f): train dispatch, train round, aggregation, eval
//! dispatch, eval round, federation round — framework × learner count.
//! Default run uses a reduced grid (learners {10,25,50}, smaller model)
//! so `cargo bench` stays minutes-scale on 1 core; `FULL=1 cargo bench
//! --bench fig5` reproduces the paper's grid (100k params, up to 200
//! learners).

use metisfl::config::ModelSpec;
use metisfl::harness::{figure_sweep, FigureConfig};

fn main() {
    let config = FigureConfig::paper(
        "fig5",
        ModelSpec::paper_100k(),   // FULL=1: 100 layers x 32 units
        ModelSpec::mlp(8, 10, 16), // reduced: ~3k params, same shape
    );
    let result = figure_sweep(config);
    result.emit_panels().expect("emit fig5 panels");
    // Shape check the paper's claim: MetisFL+OMP aggregation beats the
    // Python-style controllers by a large factor.
    let speedups = result.speedups(metisfl::metrics::FedOp::Aggregation);
    println!("\naggregation slowdowns vs MetisFL gRPC+OMP at max learners:");
    for (fw, ratio) in speedups {
        println!("  {fw:<18} {ratio:8.1}x");
    }
}
