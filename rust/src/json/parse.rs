//! Recursive-descent JSON parser.

use super::value::Value;
use std::collections::BTreeMap;

/// Parse failure with byte offset context.
#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(src: &str) -> Result<Value, ParseError> {
    let mut p = Parser { b: src.as_bytes(), pos: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::String(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        let ch = char::from_u32(cp as u32)
                            .ok_or_else(|| self.err("invalid \\u escape"))?;
                        out.push(ch);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode multibyte UTF-8 from the source slice.
                    let start = self.pos - 1;
                    let width = utf8_width(c);
                    let end = start + width;
                    if end > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u16, ParseError> {
        let mut v: u16 = 0;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = (v << 4) | d as u16;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| ParseError { offset: start, msg: format!("bad number '{text}'") })
    }
}

fn utf8_width(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("3.25").unwrap(), Value::Number(3.25));
        assert_eq!(parse("-2e3").unwrap(), Value::Number(-2000.0));
        assert_eq!(parse(r#""hi""#).unwrap(), Value::String("hi".into()));
    }

    #[test]
    fn parses_escapes_and_unicode() {
        assert_eq!(
            parse(r#""a\nb\t\"c\" A""#).unwrap(),
            Value::String("a\nb\t\"c\" A".into())
        );
        assert_eq!(parse("\"π≈3\"").unwrap(), Value::String("π≈3".into()));
    }

    #[test]
    fn rejects_malformed_documents() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\":}").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("{}").unwrap(), Value::Object(Default::default()));
        assert_eq!(parse("[]").unwrap(), Value::Array(vec![]));
        assert_eq!(parse(" [ ] ").unwrap(), Value::Array(vec![]));
    }

    #[test]
    fn error_carries_offset() {
        let e = parse("[1, x]").unwrap_err();
        assert_eq!(e.offset, 4);
    }
}
