//! JSON value tree.

use std::collections::BTreeMap;

/// A parsed JSON value. Objects use `BTreeMap` for deterministic
/// serialization order.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Number(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|u| u as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Number(n) if n.fract() == 0.0 && n.abs() <= i64::MAX as f64 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_object().and_then(|o| o.get(key))
    }

    /// Array index lookup.
    pub fn idx(&self, i: usize) -> Option<&Value> {
        self.as_array().and_then(|a| a.get(i))
    }

    /// Build an object from pairs.
    pub fn object(pairs: Vec<(&str, Value)>) -> Value {
        Value::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build an array of numbers from usizes.
    pub fn array_usize(v: &[usize]) -> Value {
        Value::Array(v.iter().map(|&u| Value::Number(u as f64)).collect())
    }

    /// Build an array of numbers from f64s.
    pub fn array_f64(v: &[f64]) -> Value {
        Value::Array(v.iter().map(|&f| Value::Number(f)).collect())
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::String(s.to_string())
    }
}

impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::String(s)
    }
}

impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Number(n)
    }
}

impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Number(n as f64)
    }
}

impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors_reject_wrong_types() {
        let v = Value::String("x".into());
        assert!(v.as_f64().is_none());
        assert!(v.as_bool().is_none());
        assert!(v.as_array().is_none());
        assert_eq!(v.as_str(), Some("x"));
    }

    #[test]
    fn integer_coercions_guard_fractions() {
        assert_eq!(Value::Number(3.0).as_u64(), Some(3));
        assert_eq!(Value::Number(3.5).as_u64(), None);
        assert_eq!(Value::Number(-3.0).as_u64(), None);
        assert_eq!(Value::Number(-3.0).as_i64(), Some(-3));
    }

    #[test]
    fn object_builder_and_lookup() {
        let v = Value::object(vec![("a", 1usize.into()), ("b", "two".into())]);
        assert_eq!(v.get("a").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("b").unwrap().as_str(), Some("two"));
        assert!(v.get("c").is_none());
    }
}
