//! Minimal JSON parser + writer (serde_json replacement).
//!
//! Used for the AOT `artifacts/manifest.json`, metrics export, and bench
//! result files. Supports the full JSON grammar except `\u` surrogate
//! pairs beyond the BMP (sufficient for our machine-generated inputs).

mod parse;
mod value;
mod write;

pub use parse::{parse, ParseError};
pub use value::Value;
pub use write::{to_string, to_string_pretty};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested_document() {
        let src = r#"{"name":"mlp","sizes":[1,2,3],"meta":{"ok":true,"x":null,"f":1.5}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("name").unwrap().as_str().unwrap(), "mlp");
        assert_eq!(v.get("sizes").unwrap().as_array().unwrap().len(), 3);
        assert!(v.get("meta").unwrap().get("ok").unwrap().as_bool().unwrap());
        assert!(v.get("meta").unwrap().get("x").unwrap().is_null());
        let back = parse(&to_string(&v)).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = parse(r#"{"a":[1,{"b":"c"}],"d":2.25}"#).unwrap();
        let pretty = to_string_pretty(&v);
        assert!(pretty.contains('\n'));
        assert_eq!(parse(&pretty).unwrap(), v);
    }
}
