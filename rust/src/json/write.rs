//! JSON serialization (compact + pretty).

use super::value::Value;

/// Serialize compactly.
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out, None, 0);
    out
}

/// Serialize with 2-space indentation.
pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out, Some(2), 0);
    out
}

fn write_value(v: &Value, out: &mut String, indent: Option<usize>, depth: usize) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Number(n) => write_number(*n, out),
        Value::String(s) => write_string(s, out),
        Value::Array(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_value(item, out, indent, depth + 1);
            }
            newline_indent(out, indent, depth);
            out.push(']');
        }
        Value::Object(map) => {
            if map.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_string(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(val, out, indent, depth + 1);
            }
            newline_indent(out, indent, depth);
            out.push('}');
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_number(n: f64, out: &mut String) {
    if !n.is_finite() {
        // JSON has no NaN/Inf; encode as null like most writers.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::super::parse::parse;
    use super::*;

    #[test]
    fn integers_render_without_decimal_point() {
        assert_eq!(to_string(&Value::Number(3.0)), "3");
        assert_eq!(to_string(&Value::Number(3.5)), "3.5");
        assert_eq!(to_string(&Value::Number(-0.0)), "0");
    }

    #[test]
    fn non_finite_becomes_null() {
        assert_eq!(to_string(&Value::Number(f64::NAN)), "null");
        assert_eq!(to_string(&Value::Number(f64::INFINITY)), "null");
    }

    #[test]
    fn string_escaping_roundtrips() {
        let s = Value::String("a\"b\\c\nd\u{0001}".into());
        assert_eq!(parse(&to_string(&s)).unwrap(), s);
    }

    #[test]
    fn control_chars_are_escaped() {
        assert_eq!(to_string(&Value::String("\u{0002}".into())), "\"\\u0002\"");
    }
}
