//! Log-bucketed latency histogram (HDR-style, fixed memory).
//!
//! `LatencyHistogram` records nanosecond durations into buckets whose
//! width grows geometrically: 32 sub-buckets per power-of-two octave,
//! which bounds the relative quantile error at ~3% regardless of the
//! recorded range (1 ns … ~584 years fits in the same 1920 buckets).
//! This is the open-loop loadtest's measurement substrate: recording is
//! O(1) with no allocation after construction, so the arrival threads
//! can stamp every phase without perturbing the latencies they measure.

use std::time::Duration;

/// Sub-buckets per octave. 32 ⇒ worst-case relative error of one part
/// in 32 (~3.1%) on any reported quantile.
const SUBS: u64 = 32;

/// Highest bucket index + 1 for 64-bit nanosecond values (see
/// [`bucket_of`]: shift ∈ [0, 58] ⇒ max index 59·32 + 31 = 1919).
const N_BUCKETS: usize = 1920;

/// Bucket index for a nanosecond value.
fn bucket_of(ns: u64) -> usize {
    if ns < SUBS {
        // Values below one octave of sub-buckets are exact.
        return ns as usize;
    }
    let msb = 63 - ns.leading_zeros() as u64; // ≥ 5 here
    let shift = msb - 5;
    ((shift + 1) * SUBS + ((ns >> shift) - SUBS)) as usize
}

/// Upper edge (inclusive) of a bucket, in nanoseconds — quantiles report
/// this edge, so they over-estimate by at most one sub-bucket width.
fn bucket_value(idx: usize) -> u64 {
    let idx = idx as u64;
    if idx < SUBS {
        return idx;
    }
    let shift = idx / SUBS - 1;
    let m = idx % SUBS;
    ((SUBS + m + 1) << shift) - 1
}

/// Fixed-size log-bucketed histogram of durations.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    counts: Vec<u64>,
    count: u64,
    total_ns: u128,
    max_ns: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        LatencyHistogram { counts: vec![0; N_BUCKETS], count: 0, total_ns: 0, max_ns: 0 }
    }

    pub fn record(&mut self, d: Duration) {
        self.record_ns(u64::try_from(d.as_nanos()).unwrap_or(u64::MAX));
    }

    pub fn record_ns(&mut self, ns: u64) {
        self.counts[bucket_of(ns)] += 1;
        self.count += 1;
        self.total_ns += ns as u128;
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// The exact maximum recorded value (not bucket-quantized).
    pub fn max(&self) -> Duration {
        Duration::from_nanos(self.max_ns)
    }

    /// Exact sum of all recorded values (Prometheus `_sum`).
    pub fn total(&self) -> Duration {
        Duration::from_nanos(u64::try_from(self.total_ns).unwrap_or(u64::MAX))
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos((self.total_ns / self.count as u128) as u64)
    }

    /// Quantile `q ∈ [0, 1]` as the upper edge of the bucket holding the
    /// `ceil(q·n)`-th smallest sample (so `quantile(1.0)` covers the
    /// maximum and `quantile(0.0)` degrades to the smallest sample).
    /// `None` when nothing has been recorded — an empty histogram has
    /// no quantiles, and reporting 0 here has twice been misread as "a
    /// phase with zero latency" instead of "a phase that never ran".
    pub fn quantile(&self, q: f64) -> Option<Duration> {
        if self.count == 0 {
            return None;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                // Never report past the true maximum: the top bucket's
                // edge can exceed it by a sub-bucket width.
                return Some(Duration::from_nanos(bucket_value(idx).min(self.max_ns)));
            }
        }
        Some(self.max())
    }

    pub fn p50(&self) -> Option<Duration> {
        self.quantile(0.50)
    }

    pub fn p99(&self) -> Option<Duration> {
        self.quantile(0.99)
    }

    pub fn p999(&self) -> Option<Duration> {
        self.quantile(0.999)
    }

    /// Fold another histogram into this one.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.total_ns += other.total_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_monotone_and_contiguous() {
        // Every value maps into a bucket whose upper edge is ≥ it, and
        // bucket indices never decrease as values grow.
        let mut prev = (0u64, 0usize);
        for exp in 0..63u32 {
            for probe in [1u64 << exp, (1u64 << exp) + 1, (1u64 << exp) + (1u64 << exp) / 2] {
                let idx = bucket_of(probe);
                if probe >= prev.0 {
                    assert!(idx >= prev.1, "non-monotone at {probe}");
                    prev = (probe, idx);
                }
                assert!(bucket_value(idx) >= probe, "edge below value at {probe}");
                assert!(idx < N_BUCKETS);
            }
        }
        assert_eq!(bucket_of(u64::MAX), N_BUCKETS - 1);
    }

    #[test]
    fn relative_error_is_bounded() {
        // The reported edge overshoots the true value by < 1/32 + one
        // bucket's rounding for values above the exact range.
        for &v in &[100u64, 1_000, 50_000, 1_000_000, 123_456_789] {
            let edge = bucket_value(bucket_of(v));
            assert!(edge >= v);
            assert!(
                (edge - v) as f64 / v as f64 <= 1.0 / 32.0 + 1e-9,
                "error too large for {v}: edge {edge}"
            );
        }
    }

    #[test]
    fn quantiles_of_a_known_distribution() {
        let mut h = LatencyHistogram::new();
        // 100 samples: 1ms … 100ms.
        for i in 1..=100u64 {
            h.record(Duration::from_millis(i));
        }
        assert_eq!(h.count(), 100);
        let p50 = h.p50().unwrap().as_millis() as f64;
        let p99 = h.p99().unwrap().as_millis() as f64;
        assert!((48.0..=53.0).contains(&p50), "p50 = {p50}ms");
        assert!((96.0..=103.0).contains(&p99), "p99 = {p99}ms");
        assert_eq!(h.max(), Duration::from_millis(100));
        // p999 of 100 samples is the max bucket, capped at true max.
        assert!(h.p999().unwrap() <= h.max());
        let mean = h.mean().as_millis();
        assert!((50..=51).contains(&mean), "mean = {mean}ms");
    }

    #[test]
    fn empty_histogram_has_no_quantiles() {
        let h = LatencyHistogram::new();
        assert!(h.is_empty());
        assert_eq!(h.quantile(0.99), None);
        assert_eq!(h.p50(), None);
        assert_eq!(h.p99(), None);
        assert_eq!(h.p999(), None);
        assert_eq!(h.mean(), Duration::ZERO);
        assert_eq!(h.max(), Duration::ZERO);
    }

    #[test]
    fn quantiles_appear_exactly_when_nonempty() {
        // Property: for any single recorded value v, every quantile is
        // Some and lands in v's bucket (edge ≥ v, capped at true max).
        let mut rng = crate::util::Rng::new(7);
        for _ in 0..200 {
            let v = rng.next_u64() >> (rng.next_u64() % 48);
            let mut h = LatencyHistogram::new();
            h.record_ns(v);
            for q in [0.0, 0.5, 0.99, 0.999, 1.0] {
                let got = h.quantile(q).expect("nonempty histogram must have quantiles");
                // The bucket edge is ≥ v but the report caps at the
                // true max (= v here), so it must be exact.
                assert_eq!(got.as_nanos() as u64, v);
            }
        }
    }

    #[test]
    fn merge_is_equivalent_to_recording_everything_in_one() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        let mut all = LatencyHistogram::new();
        for i in 0..50u64 {
            a.record_ns(i * 1000);
            all.record_ns(i * 1000);
        }
        for i in 50..90u64 {
            b.record_ns(i * 777);
            all.record_ns(i * 777);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert_eq!(a.max(), all.max());
        for q in [0.5, 0.9, 0.99, 0.999] {
            assert_eq!(a.quantile(q), all.quantile(q));
        }
    }

    #[test]
    fn merge_of_disjoint_octaves_is_loss_free() {
        // Property: merging histograms whose samples live in entirely
        // different octaves (one sub-microsecond, one around a
        // terasecond bucket) must preserve count, max, mean, and every
        // quantile vs. a histogram that recorded everything directly —
        // i.e. merge is bucket-exact, not approximate, regardless of
        // how the population splits.
        let mut rng = crate::util::Rng::new(11);
        for _ in 0..50 {
            let mut a = LatencyHistogram::new();
            let mut b = LatencyHistogram::new();
            let mut all = LatencyHistogram::new();
            for _ in 0..(1 + rng.gen_range(40)) {
                let v = rng.next_u64() % 4096; // octaves 0..12
                a.record_ns(v);
                all.record_ns(v);
            }
            for _ in 0..rng.gen_range(40) {
                let v = (1u64 << 40) + rng.next_u64() % (1 << 30); // octave ~40
                b.record_ns(v);
                all.record_ns(v);
            }
            a.merge(&b);
            assert_eq!(a.count(), all.count());
            assert_eq!(a.max(), all.max());
            assert_eq!(a.mean(), all.mean());
            for q in [0.0, 0.25, 0.5, 0.9, 0.99, 0.999, 1.0] {
                assert_eq!(a.quantile(q), all.quantile(q));
            }
            // Merging an empty histogram is the identity.
            let before = a.clone();
            a.merge(&LatencyHistogram::new());
            assert_eq!(a.count(), before.count());
            for q in [0.5, 0.99] {
                assert_eq!(a.quantile(q), before.quantile(q));
            }
        }
    }
}
