//! Operation-level metrics: the paper's T1–T9 federation-round timeline.
//!
//! Figure 1 decomposes a federation round into the operations the
//! evaluation measures in isolation (Figs. 5–7): train-task dispatch,
//! training round, aggregation, eval-task dispatch, evaluation round, and
//! the whole federation round. [`FedOp`] enumerates them; [`OpMetrics`]
//! accumulates wall-clock samples per op; [`RoundReport`] is the per-round
//! record the driver returns and the bench harness aggregates.

pub mod counters;
pub mod histogram;
pub mod registry;

pub use counters::{Counter, CounterRegistry};
pub use registry::{Gauge, Histogram, MetricsRegistry, MetricsSnapshot};

use crate::util::stopwatch::OpTimer;
use crate::util::Summary;
use std::collections::BTreeMap;
use std::time::Duration;

/// The federated operations measured by the paper's stress tests.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum FedOp {
    /// T7–T9 for training: controller → learners `RunTask` submission.
    TrainDispatch,
    /// T1–T4: local training wall-clock (dispatch → last completion).
    TrainRound,
    /// T4–T7: storing + selecting + aggregating learner models.
    Aggregation,
    /// Controller → learners `EvaluateModel` submission.
    EvalDispatch,
    /// Dispatch → last evaluation reply.
    EvalRound,
    /// T1–T9: the whole federation round.
    FederationRound,
    /// Model (de)serialization on the controller (codec ablation).
    Serialization,
    /// Learner-model insertion into the model store.
    StoreInsert,
}

impl FedOp {
    pub const ALL: [FedOp; 8] = [
        FedOp::TrainDispatch,
        FedOp::TrainRound,
        FedOp::Aggregation,
        FedOp::EvalDispatch,
        FedOp::EvalRound,
        FedOp::FederationRound,
        FedOp::Serialization,
        FedOp::StoreInsert,
    ];

    /// Stable name used in reports / CSV headers.
    pub fn name(self) -> &'static str {
        match self {
            FedOp::TrainDispatch => "train_dispatch",
            FedOp::TrainRound => "train_round",
            FedOp::Aggregation => "aggregation",
            FedOp::EvalDispatch => "eval_dispatch",
            FedOp::EvalRound => "eval_round",
            FedOp::FederationRound => "federation_round",
            FedOp::Serialization => "serialization",
            FedOp::StoreInsert => "store_insert",
        }
    }

    /// The six panels of Figs. 5–7, in the paper's (a)–(f) order.
    pub fn figure_panels() -> [FedOp; 6] {
        [
            FedOp::TrainDispatch,
            FedOp::TrainRound,
            FedOp::Aggregation,
            FedOp::EvalDispatch,
            FedOp::EvalRound,
            FedOp::FederationRound,
        ]
    }
}

/// Accumulates duration samples per operation.
#[derive(Debug, Default, Clone)]
pub struct OpMetrics {
    timers: BTreeMap<FedOp, OpTimer>,
    samples: BTreeMap<FedOp, Vec<Duration>>,
}

impl OpMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, op: FedOp, d: Duration) {
        self.timers.entry(op).or_default().record(d);
        self.samples.entry(op).or_default().push(d);
    }

    /// Time a closure under `op`.
    pub fn time<T>(&mut self, op: FedOp, f: impl FnOnce() -> T) -> T {
        let sw = crate::util::Stopwatch::start();
        let r = f();
        self.record(op, sw.elapsed());
        r
    }

    pub fn total(&self, op: FedOp) -> Duration {
        self.timers.get(&op).map(|t| t.total()).unwrap_or(Duration::ZERO)
    }

    pub fn count(&self, op: FedOp) -> u64 {
        self.timers.get(&op).map(|t| t.count()).unwrap_or(0)
    }

    pub fn mean(&self, op: FedOp) -> Duration {
        self.timers.get(&op).map(|t| t.mean()).unwrap_or(Duration::ZERO)
    }

    pub fn samples(&self, op: FedOp) -> &[Duration] {
        self.samples.get(&op).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn summary(&self, op: FedOp) -> Option<Summary> {
        let s = self.samples(op);
        if s.is_empty() {
            None
        } else {
            Some(Summary::of_durations(s))
        }
    }

    /// Merge another metrics set into this one.
    pub fn merge(&mut self, other: &OpMetrics) {
        for (op, samples) in &other.samples {
            for d in samples {
                self.record(*op, *d);
            }
        }
    }

    /// Export as a JSON object `{op: {mean, p50, ...}}` (seconds).
    pub fn to_json(&self) -> crate::json::Value {
        let mut obj = std::collections::BTreeMap::new();
        for op in FedOp::ALL {
            if let Some(s) = self.summary(op) {
                obj.insert(
                    op.name().to_string(),
                    crate::json::Value::object(vec![
                        ("n", (s.n).into()),
                        ("mean_s", s.mean.into()),
                        ("p50_s", s.p50.into()),
                        ("p90_s", s.p90.into()),
                        ("p99_s", s.p99.into()),
                        ("max_s", s.max.into()),
                    ]),
                );
            }
        }
        crate::json::Value::Object(obj)
    }
}

/// Per-round record returned by the driver.
#[derive(Debug, Clone)]
pub struct RoundReport {
    pub round: u64,
    pub participants: usize,
    pub completed: usize,
    /// Sample-weighted mean learner eval loss on the post-aggregation
    /// community model (None when the round ran without evaluation).
    pub community_eval_loss: Option<f64>,
    pub train_dispatch: Duration,
    pub train_round: Duration,
    pub aggregation: Duration,
    pub eval_dispatch: Duration,
    pub eval_round: Duration,
    pub federation_round: Duration,
    /// Wall clock between the round's first and last counted training
    /// completion — the straggler spread pacing-aware semi-sync
    /// shrinks (ZERO for async reports, which have no round barrier).
    pub completion_spread: Duration,
}

impl RoundReport {
    pub fn value(&self, op: FedOp) -> Duration {
        match op {
            FedOp::TrainDispatch => self.train_dispatch,
            FedOp::TrainRound => self.train_round,
            FedOp::Aggregation => self.aggregation,
            FedOp::EvalDispatch => self.eval_dispatch,
            FedOp::EvalRound => self.eval_round,
            FedOp::FederationRound => self.federation_round,
            _ => Duration::ZERO,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_summarize() {
        let mut m = OpMetrics::new();
        m.record(FedOp::Aggregation, Duration::from_millis(10));
        m.record(FedOp::Aggregation, Duration::from_millis(20));
        assert_eq!(m.count(FedOp::Aggregation), 2);
        assert_eq!(m.mean(FedOp::Aggregation), Duration::from_millis(15));
        let s = m.summary(FedOp::Aggregation).unwrap();
        assert_eq!(s.n, 2);
        assert!(m.summary(FedOp::EvalRound).is_none());
    }

    #[test]
    fn time_closure_records() {
        let mut m = OpMetrics::new();
        let v = m.time(FedOp::TrainDispatch, || 5);
        assert_eq!(v, 5);
        assert_eq!(m.count(FedOp::TrainDispatch), 1);
    }

    #[test]
    fn merge_combines_samples() {
        let mut a = OpMetrics::new();
        let mut b = OpMetrics::new();
        a.record(FedOp::TrainRound, Duration::from_millis(1));
        b.record(FedOp::TrainRound, Duration::from_millis(3));
        b.record(FedOp::EvalRound, Duration::from_millis(2));
        a.merge(&b);
        assert_eq!(a.count(FedOp::TrainRound), 2);
        assert_eq!(a.count(FedOp::EvalRound), 1);
    }

    #[test]
    fn json_export_has_all_recorded_ops() {
        let mut m = OpMetrics::new();
        m.record(FedOp::Aggregation, Duration::from_millis(5));
        let j = m.to_json();
        assert!(j.get("aggregation").is_some());
        assert!(j.get("eval_round").is_none());
        assert_eq!(j.get("aggregation").unwrap().get("n").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn figure_panels_order_matches_paper() {
        let p = FedOp::figure_panels();
        assert_eq!(p[0], FedOp::TrainDispatch); // (a)
        assert_eq!(p[2], FedOp::Aggregation); // (c)
        assert_eq!(p[5], FedOp::FederationRound); // (f)
    }
}
