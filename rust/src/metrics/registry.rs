//! Unified metrics registry: counters, gauges, and latency histograms
//! behind one get-or-create surface with a single snapshot call.
//!
//! PR-3..9 grew three parallel metric mechanisms: the named
//! [`Counter`]s (this module's predecessor `CounterRegistry`), ad-hoc
//! peak/level gauges riding counters via `fetch_max`, and the loadtest's
//! hand-threaded per-phase [`LatencyHistogram`]s. A [`MetricsRegistry`]
//! folds them into one registry with typed handles:
//!
//! * [`Counter`] — monotone u64, relaxed-atomic hot path (unchanged).
//! * [`Gauge`] — a settable i64 level (open streams, fleet size).
//! * [`Histogram`] — a shared [`LatencyHistogram`] behind a mutex, for
//!   multi-thread phase recording.
//!
//! `snapshot()` keeps the historical counters-only map (trace footers,
//! `FederationReport`, cross-component merging are all keyed on it);
//! [`full_snapshot`](MetricsRegistry::full_snapshot) returns the whole
//! typed set for the Prometheus exposition path (`metisfl metrics`,
//! the `observability.listen_addr` side listener).
//!
//! `CounterRegistry` remains as a name for this type, so every existing
//! construction/threading site keeps compiling unchanged.

use super::counters::Counter;
use super::histogram::LatencyHistogram;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// A cheap cloneable handle to one named level (may go down, unlike a
/// [`Counter`]).
#[derive(Clone, Debug, Default)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, n: i64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn sub(&self, n: i64) {
        self.0.fetch_sub(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A cheap cloneable handle to one named latency histogram. Recording
/// takes the histogram's own mutex (not the registry's), so concurrent
/// recorders of *different* histograms never contend.
#[derive(Clone, Debug, Default)]
pub struct Histogram(Arc<Mutex<LatencyHistogram>>);

impl Histogram {
    pub fn record(&self, d: Duration) {
        self.0.lock().unwrap().record(d);
    }

    pub fn record_ns(&self, ns: u64) {
        self.0.lock().unwrap().record_ns(ns);
    }

    /// Point-in-time copy of the underlying histogram.
    pub fn get(&self) -> LatencyHistogram {
        self.0.lock().unwrap().clone()
    }
}

/// Typed point-in-time view of every registered metric.
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, i64>,
    pub histograms: BTreeMap<String, LatencyHistogram>,
}

/// Get-or-create registry of named counters, gauges, and histograms.
/// Metric names are `&'static str` by design: the set is a closed,
/// code-defined vocabulary (see [`super::counters::names`]), not user
/// data.
#[derive(Default, Debug)]
pub struct MetricsRegistry {
    counters: Mutex<BTreeMap<&'static str, Counter>>,
    gauges: Mutex<BTreeMap<&'static str, Gauge>>,
    histograms: Mutex<BTreeMap<&'static str, Histogram>>,
}

impl MetricsRegistry {
    pub fn new() -> Arc<MetricsRegistry> {
        Arc::new(MetricsRegistry::default())
    }

    /// Handle for counter `name`, registering it (at zero) on first use.
    pub fn counter(&self, name: &'static str) -> Counter {
        self.counters.lock().unwrap().entry(name).or_default().clone()
    }

    /// Handle for gauge `name`, registering it (at zero) on first use.
    pub fn gauge(&self, name: &'static str) -> Gauge {
        self.gauges.lock().unwrap().entry(name).or_default().clone()
    }

    /// Handle for histogram `name`, registering it (empty) on first use.
    pub fn histogram(&self, name: &'static str) -> Histogram {
        self.histograms.lock().unwrap().entry(name).or_default().clone()
    }

    /// Point-in-time view of every registered counter (the historical
    /// counters-only surface: trace footers, `FederationReport`,
    /// [`merge_into`](MetricsRegistry::merge_into) consume this).
    pub fn snapshot(&self) -> BTreeMap<String, u64> {
        self.counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.to_string(), v.get()))
            .collect()
    }

    /// Point-in-time view of every registered metric, all types. Each
    /// histogram is copied under its own lock, so its internal fields
    /// (bucket counts vs. total count vs. max) are mutually consistent.
    pub fn full_snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            counters: self.snapshot(),
            gauges: self
                .gauges
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.to_string(), v.get()))
                .collect(),
            histograms: self
                .histograms
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.to_string(), v.get()))
                .collect(),
        }
    }

    /// Sum this registry's counter snapshot into an accumulating map
    /// (report merging across controller + learners).
    pub fn merge_into(&self, acc: &mut BTreeMap<String, u64>) {
        for (k, v) in self.snapshot() {
            *acc.entry(k).or_insert(0) += v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn typed_handles_share_state_by_name() {
        let reg = MetricsRegistry::new();
        reg.gauge("open_streams").set(5);
        reg.gauge("open_streams").add(2);
        assert_eq!(reg.gauge("open_streams").get(), 7);
        reg.gauge("open_streams").sub(10);
        assert_eq!(reg.gauge("open_streams").get(), -3);

        reg.histogram("phase").record(Duration::from_millis(5));
        reg.histogram("phase").record(Duration::from_millis(7));
        assert_eq!(reg.histogram("phase").get().count(), 2);
    }

    #[test]
    fn full_snapshot_carries_all_three_types() {
        let reg = MetricsRegistry::new();
        reg.counter("c").add(3);
        reg.gauge("g").set(-4);
        reg.histogram("h").record(Duration::from_micros(10));
        let snap = reg.full_snapshot();
        assert_eq!(snap.counters["c"], 3);
        assert_eq!(snap.gauges["g"], -4);
        assert_eq!(snap.histograms["h"].count(), 1);
        // The counters-only surface matches the typed one.
        assert_eq!(reg.snapshot(), snap.counters);
    }

    #[test]
    fn concurrent_hammer_yields_consistent_snapshots() {
        // N threads bump one counter and record into one histogram in
        // lockstep pairs; every observed snapshot must be internally
        // consistent (histogram count == sum of its buckets — a torn
        // read would break that) and sequential snapshots must be
        // monotone for counters.
        let reg = MetricsRegistry::new();
        const THREADS: usize = 8;
        const OPS: u64 = 2_000;
        let mut handles = Vec::new();
        for t in 0..THREADS {
            let c = reg.counter("hits");
            let h = reg.histogram("lat");
            handles.push(std::thread::spawn(move || {
                for i in 0..OPS {
                    c.incr();
                    h.record_ns(1 + (t as u64 * OPS + i) % 1_000_000);
                }
            }));
        }
        let observer = {
            let reg = Arc::clone(&reg);
            std::thread::spawn(move || {
                let mut last_hits = 0u64;
                for _ in 0..200 {
                    let snap = reg.full_snapshot();
                    let hits = snap.counters["hits"];
                    assert!(hits >= last_hits, "counter went backwards: {last_hits} -> {hits}");
                    last_hits = hits;
                    let h = &snap.histograms["lat"];
                    assert!(h.count() <= THREADS as u64 * OPS);
                    assert_eq!(
                        h.quantile(1.0).map(|_| ()).is_some(),
                        !h.is_empty(),
                        "quantile/emptiness disagree"
                    );
                    std::thread::yield_now();
                }
            })
        };
        for hnd in handles {
            hnd.join().unwrap();
        }
        observer.join().unwrap();
        let snap = reg.full_snapshot();
        assert_eq!(snap.counters["hits"], THREADS as u64 * OPS);
        assert_eq!(snap.histograms["lat"].count(), THREADS as u64 * OPS);
    }
}
