//! Named monotonic counters behind one registry.
//!
//! The degradation/wire counters used to be scattered as ad-hoc
//! `AtomicU64` fields across `Controller`, `StreamIngest`, and the
//! driver, and every new counter meant five-file plumbing (field,
//! increment site, accessor, report field, report fill). A
//! [`CounterRegistry`] collapses that: components register a counter by
//! name once (`registry.counter("streams_gced")`), bump the returned
//! handle on the hot path (one relaxed atomic add — no registry lock),
//! and [`snapshot`](CounterRegistry::snapshot) hands the whole set to
//! `FederationReport` / the trace recorder in a single call.
//!
//! Counter names are `&'static str` by design: the set of counters is a
//! closed, code-defined vocabulary (see the `names` module), not
//! user data.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// The unified registry (PR-10) under its historical name: every
/// construction/threading site written against the counters-only
/// registry keeps compiling, and gains gauge/histogram handles.
pub use super::registry::MetricsRegistry as CounterRegistry;

/// Stable counter names shared by components, reports, and traces.
pub mod names {
    /// Framed-upload streams refused at admission (per-learner cap).
    pub const STREAMS_REFUSED: &str = "streams_refused";
    /// Open streams reclaimed by idle/lifetime GC.
    pub const STREAMS_GCED: &str = "streams_gced";
    /// Dispatch RPCs abandoned after exhausting the retry budget.
    pub const RETRY_GIVE_UPS: &str = "retry_give_ups";
    /// Deltas that fell back to full-f32 sends (missing base).
    pub const FALLBACK_SENDS: &str = "fallback_sends";
    /// Encoded bytes received on the upload path.
    pub const WIRE_BYTES_IN: &str = "wire_bytes_in";
    /// Raw (decoded) bytes the upload path expanded to.
    pub const WIRE_BYTES_RAW: &str = "wire_bytes_raw";
    /// Encoded bytes sent on the dispatch path.
    pub const DISPATCH_WIRE_SENT: &str = "dispatch_wire_sent";
    /// Raw bytes the dispatch path would have sent uncoded.
    pub const DISPATCH_WIRE_RAW: &str = "dispatch_wire_raw";
    /// Dispatch-side encode operations (encode-once fan-out ⇒ per
    /// round, not per learner).
    pub const DISPATCH_ENCODES: &str = "dispatch_encodes";
    /// Completions that missed their round barrier and were folded in
    /// with staleness discounting.
    pub const LATE_FOLDS: &str = "late_folds";
    /// Upload frames dropped by seq/decode validation.
    pub const FRAMES_REJECTED: &str = "frames_rejected";
}

/// A cheap cloneable handle to one named counter. Increments are
/// relaxed atomics; no lock is taken after registration.
#[derive(Clone, Debug, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn incr(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Raise the counter to `n` if below it (peak-style counters).
    pub fn fetch_max(&self, n: u64) {
        self.0.fetch_max(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    #[test]
    fn counter_handles_share_state() {
        let reg = CounterRegistry::new();
        let a = reg.counter(names::LATE_FOLDS);
        let b = reg.counter(names::LATE_FOLDS);
        a.add(3);
        b.incr();
        assert_eq!(reg.counter(names::LATE_FOLDS).get(), 4);
    }

    #[test]
    fn snapshot_sees_all_registered_counters() {
        let reg = CounterRegistry::new();
        reg.counter(names::STREAMS_GCED).add(2);
        reg.counter(names::RETRY_GIVE_UPS);
        let snap = reg.snapshot();
        assert_eq!(snap.get(names::STREAMS_GCED), Some(&2));
        assert_eq!(snap.get(names::RETRY_GIVE_UPS), Some(&0));
        assert!(!snap.contains_key(names::FALLBACK_SENDS));
    }

    #[test]
    fn merge_into_sums_by_name() {
        let a = CounterRegistry::new();
        let b = CounterRegistry::new();
        a.counter(names::WIRE_BYTES_IN).add(10);
        b.counter(names::WIRE_BYTES_IN).add(5);
        b.counter(names::FRAMES_REJECTED).incr();
        let mut acc = BTreeMap::new();
        a.merge_into(&mut acc);
        b.merge_into(&mut acc);
        assert_eq!(acc[names::WIRE_BYTES_IN], 15);
        assert_eq!(acc[names::FRAMES_REJECTED], 1);
    }

    #[test]
    fn fetch_max_keeps_peak() {
        let reg = CounterRegistry::new();
        let c = reg.counter("peak_streams");
        c.fetch_max(3);
        c.fetch_max(1);
        assert_eq!(c.get(), 3);
    }
}
