//! Fixed-size thread pool — the OpenMP analog.
//!
//! The paper parallelizes model aggregation with "one thread per model
//! tensor ... thread parallelism is enabled using OpenMP" (§3, Fig. 4). In
//! Rust we use a long-lived pool of workers fed through a shared injector
//! queue plus a scoped `parallel_for` that blocks until every task in the
//! batch has completed, which is exactly the `#pragma omp parallel for`
//! execution shape.
//!
//! The pool is intentionally simple (single global `Mutex<VecDeque>`): the
//! tasks it runs — per-tensor weighted sums over megabytes of `f32` — are
//! large enough that queue contention is unmeasurable (see
//! `benches/agg_ablation.rs`), and simplicity keeps the scheduler easy to
//! reason about under panics.

use crate::util::clock::Clock;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Task = Box<dyn FnOnce() + Send + 'static>;

struct Queue {
    tasks: Mutex<(VecDeque<Task>, bool)>, // (queue, shutting_down)
    available: Condvar,
}

/// A fixed-size worker pool with scoped fork/join semantics.
pub struct ThreadPool {
    queue: Arc<Queue>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
    clock: Clock,
}

impl ThreadPool {
    /// Create a pool with `size` workers (`size >= 1`) on the system clock.
    pub fn new(size: usize) -> Self {
        Self::with_clock(size, Clock::system())
    }

    /// Create a pool whose workers register as busy with `clock` while
    /// executing a task, so simulated time cannot jump past a deadline
    /// while in-flight work (e.g. a completion being processed) could
    /// still produce events.
    pub fn with_clock(size: usize, clock: Clock) -> Self {
        let size = size.max(1);
        let queue = Arc::new(Queue {
            tasks: Mutex::new((VecDeque::new(), false)),
            available: Condvar::new(),
        });
        let workers = (0..size)
            .map(|i| {
                let q = Arc::clone(&queue);
                let c = clock.clone();
                std::thread::Builder::new()
                    .name(format!("metisfl-pool-{i}"))
                    .spawn(move || worker_loop(q, c))
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { queue, workers, size, clock }
    }

    /// Pool with one worker per available hardware thread.
    pub fn with_hardware_threads() -> Self {
        Self::new(hardware_threads())
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Fire-and-forget task submission.
    pub fn spawn<F: FnOnce() + Send + 'static>(&self, f: F) {
        let mut guard = self.queue.tasks.lock().unwrap();
        guard.0.push_back(Box::new(f));
        drop(guard);
        self.queue.available.notify_one();
    }

    /// Run `f(i)` for every `i in 0..n`, distributing over the pool, and
    /// block until all iterations are done — `#pragma omp parallel for`.
    ///
    /// `f` only needs to live for the duration of the call; internally the
    /// closure is smuggled across the `'static` boundary and the scope
    /// guard guarantees it is not used after return (panics in tasks are
    /// propagated to the caller as a panic here).
    pub fn parallel_for<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Send + Sync,
    {
        if n == 0 {
            return;
        }
        if n == 1 {
            f(0);
            return;
        }
        let done = Arc::new(Barrier::new(n));
        // SAFETY: we block on `done.wait()` before returning, so no task
        // can observe `f` after the borrow expires.
        let f_static: &(dyn Fn(usize) + Send + Sync) = &f;
        let f_static: &'static (dyn Fn(usize) + Send + Sync) =
            unsafe { std::mem::transmute(f_static) };
        for i in 0..n {
            let d = Arc::clone(&done);
            self.spawn(move || {
                let guard = PanicGuard(&d);
                f_static(i);
                std::mem::forget(guard);
                d.task_done(false);
            });
        }
        // A busy caller parked on the barrier is not runnable: shed its
        // registration so simulated time can serve the workers' sleeps.
        let _parked = self.clock.suspended();
        done.wait();
    }

    /// Map `f` over `0..n` in parallel, collecting results in index order.
    pub fn parallel_map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Send + Sync,
    {
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        {
            let slots = SyncSlots(out.as_mut_ptr());
            let slots_ref = &slots;
            self.parallel_for(n, move |i| {
                // SAFETY: each index is written exactly once by one task.
                unsafe { *slots_ref.0.add(i) = Some(f(i)) };
            });
        }
        out.into_iter().map(|t| t.expect("slot filled")).collect()
    }

    /// Shared partition arithmetic for [`ThreadPool::parallel_chunks`]
    /// and [`ThreadPool::reduce_chunks`]: `(chunk_count, chunk_size)`
    /// such that chunk `c` covers `c*size .. min((c+1)*size, n)`.
    fn chunk_layout(&self, n: usize) -> (usize, usize) {
        let chunks = self.size.min(n.max(1));
        (chunks, n.div_ceil(chunks))
    }

    /// Split `0..n` into `chunks ≈ size()` contiguous ranges and run `f`
    /// on each range in parallel. Better than `parallel_for` when the
    /// per-index work is tiny.
    pub fn parallel_chunks<F>(&self, n: usize, f: F)
    where
        F: Fn(std::ops::Range<usize>) + Send + Sync,
    {
        let (chunks, chunk) = self.chunk_layout(n);
        self.parallel_for(chunks, |c| {
            let lo = c * chunk;
            let hi = ((c + 1) * chunk).min(n);
            if lo < hi {
                f(lo..hi);
            }
        });
    }

    /// Chunk-local partial-sum reduction: evaluate `f` over the same
    /// contiguous ranges as [`ThreadPool::parallel_chunks`] (shared
    /// [`ThreadPool::chunk_layout`] arithmetic) and sum the per-chunk
    /// partials **in chunk order**, so the result is deterministic for a
    /// fixed pool size regardless of which worker finishes first. Used
    /// for norm bookkeeping on the aggregation hot path (the per-chunk
    /// `f` typically wraps [`crate::tensor::ops::dot`]) and by
    /// server-optimizer / metrics diagnostics.
    pub fn reduce_chunks<F>(&self, n: usize, f: F) -> f64
    where
        F: Fn(std::ops::Range<usize>) -> f64 + Send + Sync,
    {
        if n == 0 {
            return 0.0;
        }
        let (chunks, chunk) = self.chunk_layout(n);
        let partials = self.parallel_map(chunks, |c| {
            let lo = c * chunk;
            let hi = ((c + 1) * chunk).min(n);
            if lo < hi {
                f(lo..hi)
            } else {
                0.0
            }
        });
        partials.iter().sum()
    }
}

struct SyncSlots<T>(*mut Option<T>);
// SAFETY: disjoint-index writes only (see parallel_map).
unsafe impl<T: Send> Send for SyncSlots<T> {}
unsafe impl<T: Send> Sync for SyncSlots<T> {}

/// Counts completed tasks; `wait` blocks until all have finished and
/// re-raises if any task panicked.
struct Barrier {
    remaining: AtomicUsize,
    panicked: AtomicUsize,
    mutex: Mutex<()>,
    cv: Condvar,
}

impl Barrier {
    fn new(n: usize) -> Self {
        Barrier {
            remaining: AtomicUsize::new(n),
            panicked: AtomicUsize::new(0),
            mutex: Mutex::new(()),
            cv: Condvar::new(),
        }
    }

    fn task_done(&self, panicked: bool) {
        if panicked {
            self.panicked.fetch_add(1, Ordering::SeqCst);
        }
        if self.remaining.fetch_sub(1, Ordering::SeqCst) == 1 {
            let _g = self.mutex.lock().unwrap();
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut g = self.mutex.lock().unwrap();
        while self.remaining.load(Ordering::SeqCst) != 0 {
            g = self.cv.wait(g).unwrap();
        }
        if self.panicked.load(Ordering::SeqCst) != 0 {
            panic!("a parallel_for task panicked");
        }
    }
}

/// Marks the barrier done-with-panic if the task unwinds.
struct PanicGuard<'a>(&'a Barrier);
impl Drop for PanicGuard<'_> {
    fn drop(&mut self) {
        self.0.task_done(true);
    }
}

fn worker_loop(q: Arc<Queue>, clock: Clock) {
    loop {
        let task = {
            let mut guard = q.tasks.lock().unwrap();
            loop {
                if let Some(t) = guard.0.pop_front() {
                    break Some(t);
                }
                if guard.1 {
                    break None;
                }
                guard = q.available.wait(guard).unwrap();
            }
        };
        match task {
            Some(t) => {
                // Busy for the task's duration: simulated time must not
                // jump while this work could still produce clock events.
                let _busy = clock.busy();
                // Worker survives task panics; the barrier's PanicGuard
                // reports them to the waiting caller.
                let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(t));
            }
            None => return,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut guard = self.queue.tasks.lock().unwrap();
            guard.1 = true;
        }
        self.queue.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Detected hardware parallelism (≥1).
pub fn hardware_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_runs_every_index_exactly_once() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
        pool.parallel_for(64, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for h in &hits {
            assert_eq!(h.load(Ordering::SeqCst), 1);
        }
    }

    #[test]
    fn parallel_map_preserves_index_order() {
        let pool = ThreadPool::new(3);
        let v = pool.parallel_map(100, |i| i * i);
        assert_eq!(v, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_chunks_covers_range_without_overlap() {
        let pool = ThreadPool::new(4);
        let sum = AtomicU64::new(0);
        pool.parallel_chunks(1000, |r| {
            let local: u64 = r.map(|i| i as u64).sum();
            sum.fetch_add(local, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 999 * 1000 / 2);
    }

    #[test]
    fn reduce_chunks_matches_serial_sum_deterministically() {
        let data: Vec<f64> = (0..10_000).map(|i| (i as f64) * 0.25 - 7.0).collect();
        let serial: f64 = data.iter().sum();
        let pool = ThreadPool::new(4);
        let reduce = || pool.reduce_chunks(data.len(), |r| data[r].iter().sum());
        let first = reduce();
        // Chunk-ordered summation ⇒ bitwise identical across runs.
        for _ in 0..5 {
            assert_eq!(reduce().to_bits(), first.to_bits());
        }
        assert!((first - serial).abs() < 1e-6, "{first} vs {serial}");
        // Edge cases: empty input and fewer items than workers.
        assert_eq!(pool.reduce_chunks(0, |_| panic!("must not run")), 0.0);
        assert_eq!(pool.reduce_chunks(2, |r| r.len() as f64), 2.0);
    }

    #[test]
    fn zero_and_one_iteration_edge_cases() {
        let pool = ThreadPool::new(2);
        pool.parallel_for(0, |_| panic!("must not run"));
        // n == 1 runs inline on the caller thread.
        pool.parallel_for(1, |i| {
            assert_eq!(i, 0);
        });
        let v = pool.parallel_map(1, |_| 7);
        assert_eq!(v, vec![7]);
    }

    #[test]
    fn task_panic_propagates_to_caller() {
        let pool = ThreadPool::new(2);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.parallel_for(8, |i| {
                if i == 3 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err());
        // Pool must still be usable afterwards.
        let v = pool.parallel_map(4, |i| i + 1);
        assert_eq!(v, vec![1, 2, 3, 4]);
    }

    #[test]
    fn spawn_fire_and_forget_completes() {
        let pool = ThreadPool::new(2);
        let done = Arc::new(AtomicUsize::new(0));
        for _ in 0..16 {
            let d = Arc::clone(&done);
            pool.spawn(move || {
                d.fetch_add(1, Ordering::SeqCst);
            });
        }
        let sw = crate::util::Stopwatch::start();
        while done.load(Ordering::SeqCst) != 16 {
            assert!(sw.elapsed() < std::time::Duration::from_secs(5), "tasks did not finish");
            std::thread::yield_now();
        }
    }

    #[test]
    fn sim_pool_workers_register_busy() {
        // A worker sleeping on the sim clock suspends its own busy
        // registration, so the sleep completes via a jump even though
        // the worker is "executing" the task.
        let sim = Clock::sim();
        let pool = ThreadPool::with_clock(2, sim.clone());
        let done = Arc::new(AtomicUsize::new(0));
        let d = Arc::clone(&done);
        let c = sim.clone();
        pool.spawn(move || {
            c.sleep(std::time::Duration::from_secs(30));
            d.fetch_add(1, Ordering::SeqCst);
        });
        let sw = crate::util::Stopwatch::start();
        while done.load(Ordering::SeqCst) != 1 {
            assert!(sw.elapsed() < std::time::Duration::from_secs(5), "sim sleep wedged");
            std::thread::yield_now();
        }
        assert!(sim.now() >= std::time::Duration::from_secs(30));
    }
}
