//! Minimal leveled stderr logger (`log` crate replacement).
//!
//! Level is read once from `METISFL_LOG` (`debug`, `info` (default),
//! `warn`, `error`, `off`). Timestamps are milliseconds since process
//! start so interleaved controller/learner logs are easy to correlate —
//! unless a simulated [`Clock`] is registered ([`set_clock`]), in which
//! case they are *virtual* milliseconds, so log lines line up with
//! MFTR1 trace ticks and span intervals from the same run. Log lines
//! also carry the currently open federation round ([`set_round`]) so a
//! grep for `r12` isolates one round's story across components.

use crate::util::clock::Clock;
use once_cell::sync::Lazy;
use std::io::Write;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LogLevel {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
    Off = 4,
}

static LEVEL: Lazy<LogLevel> = Lazy::new(|| {
    match std::env::var("METISFL_LOG").unwrap_or_default().to_ascii_lowercase().as_str() {
        "debug" => LogLevel::Debug,
        "warn" => LogLevel::Warn,
        "error" => LogLevel::Error,
        "off" | "none" => LogLevel::Off,
        _ => LogLevel::Info,
    }
});
static SINK: Lazy<Mutex<()>> = Lazy::new(|| Mutex::new(()));

/// The clock log timestamps derive from. `None` (the default) falls
/// back to real process uptime; a registered sim clock switches the
/// whole process's log timeline to virtual time.
static LOG_CLOCK: Lazy<Mutex<Option<Clock>>> = Lazy::new(|| Mutex::new(None));

/// Currently open federation round + 1 (0 = no round open), so round 0
/// is representable.
static CURRENT_ROUND: AtomicU64 = AtomicU64::new(0);

/// Route log timestamps through `clock`. Registering a sim clock makes
/// timestamps virtual milliseconds (correlating with trace ticks);
/// registering a system clock keeps process-uptime millis (the two
/// timelines coincide). Call once per process, from whoever owns the
/// run's clock (driver, loadtest harness).
pub fn set_clock(clock: Clock) {
    *LOG_CLOCK.lock().unwrap() = Some(clock);
}

/// Tag subsequent log lines with the open round.
pub fn set_round(round: u64) {
    CURRENT_ROUND.store(round.wrapping_add(1), Ordering::Relaxed);
}

/// Drop the round tag (barrier closed / between rounds).
pub fn clear_round() {
    CURRENT_ROUND.store(0, Ordering::Relaxed);
}

fn timestamp_ms() -> u128 {
    match LOG_CLOCK.lock().unwrap().as_ref() {
        Some(c) => c.now().as_millis(),
        None => crate::util::clock::uptime_ms(),
    }
}

/// Current minimum level.
pub fn level() -> LogLevel {
    *LEVEL
}

pub fn enabled(l: LogLevel) -> bool {
    l >= *LEVEL && *LEVEL != LogLevel::Off
}

pub fn log_at(l: LogLevel, component: &str, msg: &str) {
    if !enabled(l) {
        return;
    }
    let ms = timestamp_ms();
    let tag = match l {
        LogLevel::Debug => "DEBUG",
        LogLevel::Info => "INFO ",
        LogLevel::Warn => "WARN ",
        LogLevel::Error => "ERROR",
        LogLevel::Off => return,
    };
    let round = CURRENT_ROUND.load(Ordering::Relaxed);
    let _g = SINK.lock().unwrap();
    let _ = if round == 0 {
        writeln!(std::io::stderr(), "[{ms:>8}ms {tag} {component}] {msg}")
    } else {
        writeln!(std::io::stderr(), "[{ms:>8}ms {tag} {component} r{}] {msg}", round - 1)
    };
}

pub fn log_debug(component: &str, msg: &str) {
    log_at(LogLevel::Debug, component, msg);
}

pub fn log_info(component: &str, msg: &str) {
    log_at(LogLevel::Info, component, msg);
}

pub fn log_warn(component: &str, msg: &str) {
    log_at(LogLevel::Warn, component, msg);
}

pub fn log_error(component: &str, msg: &str) {
    log_at(LogLevel::Error, component, msg);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_are_ordered() {
        assert!(LogLevel::Debug < LogLevel::Info);
        assert!(LogLevel::Info < LogLevel::Warn);
        assert!(LogLevel::Warn < LogLevel::Error);
        assert!(LogLevel::Error < LogLevel::Off);
    }

    #[test]
    fn logging_does_not_panic() {
        log_debug("test", "debug message");
        log_info("test", "info message");
        log_warn("test", "warn message");
        log_error("test", "error message");
    }

    #[test]
    fn round_tag_and_clock_registration_do_not_panic() {
        set_round(0);
        log_info("test", "round-0 tagged");
        set_round(12);
        log_info("test", "round-12 tagged");
        clear_round();
        log_info("test", "untagged again");
        // The clock registry is process-global and other tests (driver,
        // loadtest harness) re-register concurrently, so this only
        // exercises the seam — no assertion on the racy timestamp value.
        set_clock(Clock::sim());
        let _ = timestamp_ms();
        log_info("test", "virtual timestamp");
        set_clock(Clock::system());
    }
}
