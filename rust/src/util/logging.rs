//! Minimal leveled stderr logger (`log` crate replacement).
//!
//! Level is read once from `METISFL_LOG` (`debug`, `info` (default),
//! `warn`, `error`, `off`). Timestamps are milliseconds since process
//! start so interleaved controller/learner logs are easy to correlate.

use once_cell::sync::Lazy;
use std::io::Write;
use std::sync::Mutex;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LogLevel {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
    Off = 4,
}

static LEVEL: Lazy<LogLevel> = Lazy::new(|| {
    match std::env::var("METISFL_LOG").unwrap_or_default().to_ascii_lowercase().as_str() {
        "debug" => LogLevel::Debug,
        "warn" => LogLevel::Warn,
        "error" => LogLevel::Error,
        "off" | "none" => LogLevel::Off,
        _ => LogLevel::Info,
    }
});
static SINK: Lazy<Mutex<()>> = Lazy::new(|| Mutex::new(()));

/// Current minimum level.
pub fn level() -> LogLevel {
    *LEVEL
}

pub fn enabled(l: LogLevel) -> bool {
    l >= *LEVEL && *LEVEL != LogLevel::Off
}

pub fn log_at(l: LogLevel, component: &str, msg: &str) {
    if !enabled(l) {
        return;
    }
    let ms = crate::util::clock::uptime_ms();
    let tag = match l {
        LogLevel::Debug => "DEBUG",
        LogLevel::Info => "INFO ",
        LogLevel::Warn => "WARN ",
        LogLevel::Error => "ERROR",
        LogLevel::Off => return,
    };
    let _g = SINK.lock().unwrap();
    let _ = writeln!(std::io::stderr(), "[{ms:>8}ms {tag} {component}] {msg}");
}

pub fn log_debug(component: &str, msg: &str) {
    log_at(LogLevel::Debug, component, msg);
}

pub fn log_info(component: &str, msg: &str) {
    log_at(LogLevel::Info, component, msg);
}

pub fn log_warn(component: &str, msg: &str) {
    log_at(LogLevel::Warn, component, msg);
}

pub fn log_error(component: &str, msg: &str) {
    log_at(LogLevel::Error, component, msg);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_are_ordered() {
        assert!(LogLevel::Debug < LogLevel::Info);
        assert!(LogLevel::Info < LogLevel::Warn);
        assert!(LogLevel::Warn < LogLevel::Error);
        assert!(LogLevel::Error < LogLevel::Off);
    }

    #[test]
    fn logging_does_not_panic() {
        log_debug("test", "debug message");
        log_info("test", "info message");
        log_warn("test", "warn message");
        log_error("test", "error message");
    }
}
