//! Summary statistics for the bench harness (criterion replacement).

use std::time::Duration;

/// Summary of a sample of measurements (durations stored as seconds).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Summarize raw f64 samples (any unit; benches use seconds).
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of(empty)");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: percentile(&sorted, 0.50),
            p90: percentile(&sorted, 0.90),
            p99: percentile(&sorted, 0.99),
            max: sorted[n - 1],
        }
    }

    /// Summarize durations, in seconds.
    pub fn of_durations(samples: &[Duration]) -> Summary {
        let secs: Vec<f64> = samples.iter().map(|d| d.as_secs_f64()).collect();
        Summary::of(&secs)
    }

    pub fn mean_duration(&self) -> Duration {
        Duration::from_secs_f64(self.mean.max(0.0))
    }
}

/// Linear-interpolated percentile of a pre-sorted slice, q in [0,1].
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant_samples() {
        let s = Summary::of(&[2.0; 10]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 2.0);
        assert_eq!(s.p50, 2.0);
    }

    #[test]
    fn summary_basic_moments() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert_eq!(percentile(&sorted, 0.5), 5.0);
        assert_eq!(percentile(&sorted, 0.0), 0.0);
        assert_eq!(percentile(&sorted, 1.0), 10.0);
    }

    #[test]
    fn of_durations_roundtrip() {
        let s = Summary::of_durations(&[Duration::from_millis(10), Duration::from_millis(20)]);
        assert!((s.mean - 0.015).abs() < 1e-9);
        assert_eq!(s.mean_duration(), Duration::from_secs_f64(s.mean));
    }

    #[test]
    #[should_panic]
    fn empty_sample_panics() {
        let _ = Summary::of(&[]);
    }
}
