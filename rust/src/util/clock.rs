//! Unified virtual-time API: the one seam through which the whole crate
//! reads the clock, sleeps, and waits on deadlines.
//!
//! Every component takes a [`Clock`] handle at construction instead of
//! calling `Instant::now()` / `thread::sleep` directly (those calls live
//! only in this module). Two implementations share the handle:
//!
//! * [`Clock::system`] — real wall clock. `now()` is monotonic time
//!   since a process-wide epoch; `sleep` and `wait_timeout` are the std
//!   primitives. Zero-cost: no allocation, no extra synchronization.
//! * [`Clock::sim`] — a discrete-event simulated clock. Sleepers park
//!   on a binary heap of wake deadlines; when every registered-busy
//!   thread is blocked waiting on the clock, time *jumps* to the next
//!   waiter's deadline instead of passing in real time. A 1k-learner
//!   federation whose learners "train" for simulated seconds per round
//!   completes in real milliseconds per round (`metisfl loadtest
//!   --sim`), and timeout/GC/backoff paths become deterministic and
//!   fast to exercise.
//!
//! Timestamps are [`Duration`]s since the clock's epoch (not
//! `Instant`s, which cannot be fabricated for simulated time). They are
//! only meaningful relative to the clock that produced them.
//!
//! ## Simulated-time liveness model
//!
//! The sim clock cannot see threads the way a kernel scheduler can, so
//! it combines two signals to decide when jumping is safe:
//!
//! * **Busy registration.** Threads doing work that may produce clock
//!   events (thread-pool workers executing tasks, harness arrival
//!   threads) hold a [`BusyGuard`]. While any registered thread is
//!   busy, time never jumps — a quorum deadline cannot fire while a
//!   completion is being processed. Entering a clock wait suspends the
//!   current thread's own registration (a busy thread that sleeps is
//!   not busy).
//! * **Quiescence grace.** Unregistered compute (scoped encoder
//!   threads, transport internals) is covered by a short real-time
//!   grace window: a waiter only jumps after observing no clock
//!   activity for two consecutive grace periods. In a discrete-event
//!   model compute takes zero virtual time, so a rare premature jump
//!   during untracked compute is a modeling choice, not a correctness
//!   bug — the guard + grace combination just keeps event ordering
//!   stable on the paths that matter (completions vs. deadlines).

use once_cell::sync::Lazy;
use std::cell::Cell;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashSet};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// A point on a [`Clock`]'s timeline: the time elapsed since that
/// clock's epoch. Only comparable to timestamps from the same clock.
pub type Timestamp = Duration;

// The process-wide monotonic anchor. Every system-clock reading in the
// crate derives from this single `Instant` — keeping the only
// `Instant::now()` call sites in this module is what makes wall time an
// injected dependency everywhere else.
static EPOCH: Lazy<Instant> = Lazy::new(Instant::now);

/// Milliseconds since process start (log timestamps).
pub fn uptime_ms() -> u128 {
    EPOCH.elapsed().as_millis()
}

/// Real-time grace a sim waiter observes before concluding the system
/// is quiescent (two consecutive windows with no clock activity).
const SIM_GRACE: Duration = Duration::from_micros(500);

/// Real-time slice for simulated condvar waits: short enough that a
/// virtual-deadline check happens promptly, long enough not to spin.
const SIM_CV_SLICE: Duration = Duration::from_micros(300);

thread_local! {
    // How many [`BusyGuard`]s the current thread holds. The global busy
    // count tracks *threads* (0→1 / 1→0 transitions), so nested guards
    // are free and a clock wait can suspend the whole thread's
    // registration with one decrement.
    static BUSY_DEPTH: Cell<u32> = const { Cell::new(0) };
}

#[derive(Default)]
struct SimInner {
    now: Duration,
    /// Registered threads currently runnable (not blocked on the clock).
    busy: u64,
    /// Token source for heap entries.
    seq: u64,
    /// Bumped on every clock event (new sleeper, jump, busy
    /// transition); waiters use it to detect quiescence.
    activity: u64,
    /// Pending wake deadlines, earliest first. Lazy deletion: entries
    /// whose waiter already left are parked in `cancelled` and skipped
    /// when the heap is pruned.
    heap: BinaryHeap<Reverse<(Duration, u64)>>,
    cancelled: HashSet<u64>,
}

impl SimInner {
    /// Drop cancelled and already-served entries off the top.
    fn prune(&mut self) {
        while let Some(&Reverse((t, tok))) = self.heap.peek() {
            if self.cancelled.remove(&tok) || t <= self.now {
                self.heap.pop();
            } else {
                break;
            }
        }
    }

    fn bump(&mut self) {
        self.activity = self.activity.wrapping_add(1);
    }

    /// Jump to the earliest pending deadline (caller established
    /// quiescence). Returns true if time moved.
    fn advance_to_next(&mut self) -> bool {
        self.prune();
        match self.heap.peek() {
            Some(&Reverse((t, _))) => {
                self.now = t;
                self.bump();
                self.prune();
                true
            }
            None => false,
        }
    }

    /// One quiescence-detection step for a waiter that just saw a real
    /// grace period elapse: jump only on the second consecutive
    /// no-activity observation.
    fn poll_advance(&mut self, last_seen: &mut Option<u64>) -> bool {
        if self.busy != 0 {
            *last_seen = None;
            return false;
        }
        if *last_seen == Some(self.activity) {
            self.advance_to_next()
        } else {
            *last_seen = Some(self.activity);
            false
        }
    }
}

struct SimState {
    m: Mutex<SimInner>,
    cv: Condvar,
}

impl SimState {
    fn new() -> SimState {
        SimState { m: Mutex::new(SimInner::default()), cv: Condvar::new() }
    }

    /// Temporarily drop this thread's busy registration (entering a
    /// clock wait). Returns whether a resume is owed.
    fn suspend_busy(self: &Arc<Self>) -> bool {
        if BUSY_DEPTH.with(|c| c.get()) == 0 {
            return false;
        }
        let mut g = self.m.lock().unwrap();
        g.busy = g.busy.saturating_sub(1);
        g.bump();
        self.cv.notify_all();
        true
    }

    fn resume_busy(self: &Arc<Self>) {
        let mut g = self.m.lock().unwrap();
        g.busy += 1;
        g.bump();
    }

    fn sleep(self: &Arc<Self>, d: Duration) {
        if d.is_zero() {
            return;
        }
        let suspended = self.suspend_busy();
        let mut g = self.m.lock().unwrap();
        let wake = g.now + d;
        let token = g.seq;
        g.seq += 1;
        g.heap.push(Reverse((wake, token)));
        g.bump();
        // A new earliest deadline changes every waiter's jump target.
        self.cv.notify_all();
        let mut last_seen: Option<u64> = None;
        while g.now < wake {
            let (g2, timeout) = self.cv.wait_timeout(g, SIM_GRACE).unwrap();
            g = g2;
            if g.now >= wake {
                break;
            }
            if timeout.timed_out() {
                if g.poll_advance(&mut last_seen) {
                    self.cv.notify_all();
                }
            } else {
                last_seen = None;
            }
        }
        drop(g);
        if suspended {
            self.resume_busy();
        }
    }

    /// Wait on the caller's condvar under simulated time: register the
    /// virtual deadline, then wait in short real slices so a real
    /// notify still wakes promptly. Returns `(guard, timed_out)`;
    /// `timed_out == false` means a notify arrived (the caller's
    /// predicate loop re-checks, exactly like std's condvar contract).
    fn cv_wait<'a, T>(
        self: &Arc<Self>,
        cv: &Condvar,
        mut guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> (MutexGuard<'a, T>, bool) {
        let suspended = self.suspend_busy();
        let (wake, token) = {
            let mut g = self.m.lock().unwrap();
            let wake = g.now + dur;
            let token = g.seq;
            g.seq += 1;
            g.heap.push(Reverse((wake, token)));
            g.bump();
            self.cv.notify_all();
            (wake, token)
        };
        let mut last_seen: Option<u64> = None;
        loop {
            let (g2, timeout) = cv.wait_timeout(guard, SIM_CV_SLICE).unwrap();
            guard = g2;
            let mut g = self.m.lock().unwrap();
            if g.now >= wake {
                drop(g);
                if suspended {
                    self.resume_busy();
                }
                return (guard, true);
            }
            if !timeout.timed_out() {
                // Real notify: unregister our deadline and hand control
                // back to the caller's predicate loop.
                g.cancelled.insert(token);
                drop(g);
                if suspended {
                    self.resume_busy();
                }
                return (guard, false);
            }
            if g.poll_advance(&mut last_seen) {
                self.cv.notify_all();
            }
            drop(g);
        }
    }

    fn advance_to(self: &Arc<Self>, t: Timestamp) {
        let mut g = self.m.lock().unwrap();
        if t > g.now {
            g.now = t;
            g.bump();
            g.prune();
            self.cv.notify_all();
        }
    }
}

/// A cloneable clock handle: real wall time or discrete-event simulated
/// time behind one API. See the module docs for the model.
#[derive(Clone)]
pub struct Clock {
    inner: ClockInner,
}

#[derive(Clone)]
enum ClockInner {
    System,
    Sim(Arc<SimState>),
}

impl std::fmt::Debug for Clock {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.inner {
            ClockInner::System => write!(f, "Clock::system"),
            ClockInner::Sim(_) => write!(f, "Clock::sim(t={:?})", self.now()),
        }
    }
}

impl Default for Clock {
    fn default() -> Clock {
        Clock::system()
    }
}

impl Clock {
    /// The real wall clock (process-wide monotonic epoch).
    pub fn system() -> Clock {
        Clock { inner: ClockInner::System }
    }

    /// A fresh simulated clock starting at `t = 0`.
    pub fn sim() -> Clock {
        Clock { inner: ClockInner::Sim(Arc::new(SimState::new())) }
    }

    pub fn is_sim(&self) -> bool {
        matches!(self.inner, ClockInner::Sim(_))
    }

    /// Current time on this clock's timeline.
    pub fn now(&self) -> Timestamp {
        match &self.inner {
            ClockInner::System => EPOCH.elapsed(),
            ClockInner::Sim(s) => s.m.lock().unwrap().now,
        }
    }

    /// Time elapsed since `earlier` (zero if `earlier` is in the
    /// future — mirrors `Instant::elapsed`'s monotonic saturation).
    pub fn since(&self, earlier: Timestamp) -> Duration {
        self.now().saturating_sub(earlier)
    }

    /// Sleep for `d` on this clock's timeline. Simulated sleeps park on
    /// the wake heap and return when virtual time reaches the deadline
    /// (jumping there if the system is otherwise idle).
    pub fn sleep(&self, d: Duration) {
        match &self.inner {
            ClockInner::System => std::thread::sleep(d),
            ClockInner::Sim(s) => s.sleep(d),
        }
    }

    /// Condvar wait with a deadline on this clock's timeline. Returns
    /// `(guard, timed_out)`. Callers keep their standard predicate
    /// loop: `timed_out == false` only promises that a notify (or a
    /// spurious wake) happened, not that the predicate holds.
    pub fn wait_timeout<'a, T>(
        &self,
        cv: &Condvar,
        guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> (MutexGuard<'a, T>, bool) {
        match &self.inner {
            ClockInner::System => {
                let (g, timeout) = cv.wait_timeout(guard, dur).unwrap();
                (g, timeout.timed_out())
            }
            ClockInner::Sim(s) => s.cv_wait(cv, guard, dur),
        }
    }

    /// Register the current thread as busy (runnable) for simulated-time
    /// accounting; a no-op on the system clock. While any busy thread
    /// exists, simulated time never jumps.
    pub fn busy(&self) -> BusyGuard {
        match &self.inner {
            ClockInner::System => BusyGuard { state: None },
            ClockInner::Sim(s) => {
                let depth = BUSY_DEPTH.with(|c| {
                    let v = c.get();
                    c.set(v + 1);
                    v
                });
                if depth == 0 {
                    let mut g = s.m.lock().unwrap();
                    g.busy += 1;
                    g.bump();
                }
                BusyGuard { state: Some(Arc::clone(s)) }
            }
        }
    }

    /// Temporarily shed the current thread's busy registration around a
    /// non-clock blocking wait (e.g. a pool barrier) so a blocked
    /// caller cannot wedge simulated time. No-op on the system clock or
    /// when the thread holds no [`BusyGuard`].
    pub fn suspended(&self) -> SuspendGuard {
        match &self.inner {
            ClockInner::System => SuspendGuard { state: None },
            ClockInner::Sim(s) => {
                if s.suspend_busy() {
                    SuspendGuard { state: Some(Arc::clone(s)) }
                } else {
                    SuspendGuard { state: None }
                }
            }
        }
    }

    /// Move simulated time forward to `t` (replay driving; no-op on the
    /// system clock and for past timestamps).
    pub fn advance_to(&self, t: Timestamp) {
        if let ClockInner::Sim(s) = &self.inner {
            s.advance_to(t);
        }
    }
}

/// RAII busy registration (see [`Clock::busy`]).
pub struct BusyGuard {
    state: Option<Arc<SimState>>,
}

impl Drop for BusyGuard {
    fn drop(&mut self) {
        if let Some(s) = &self.state {
            let depth = BUSY_DEPTH.with(|c| {
                let v = c.get() - 1;
                c.set(v);
                v
            });
            if depth == 0 {
                let mut g = s.m.lock().unwrap();
                g.busy = g.busy.saturating_sub(1);
                g.bump();
                s.cv.notify_all();
            }
        }
    }
}

/// RAII busy suspension (see [`Clock::suspended`]).
pub struct SuspendGuard {
    state: Option<Arc<SimState>>,
}

impl Drop for SuspendGuard {
    fn drop(&mut self) {
        if let Some(s) = &self.state {
            s.resume_busy();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, Ordering};

    #[test]
    fn system_clock_is_monotonic() {
        let c = Clock::system();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
        assert!(!c.is_sim());
    }

    #[test]
    fn sim_sleep_jumps_instead_of_waiting() {
        // An hour of virtual sleep must complete in (well under) a
        // second of real time, via a single heap jump — this is also
        // the no-busy-wait property: 3600 s / grace would be millions
        // of iterations if the waiter spun.
        let real = Clock::system();
        let sim = Clock::sim();
        let t0 = real.now();
        sim.sleep(Duration::from_secs(3600));
        assert!(sim.now() >= Duration::from_secs(3600));
        assert!(
            real.since(t0) < Duration::from_secs(2),
            "sim sleep took {:?} real",
            real.since(t0)
        );
    }

    #[test]
    fn sleepers_wake_in_heap_deadline_order() {
        let sim = Clock::sim();
        let order = Arc::new(Mutex::new(Vec::new()));
        let mut handles = Vec::new();
        for secs in [30u64, 10, 20] {
            let c = sim.clone();
            let order = Arc::clone(&order);
            handles.push(std::thread::spawn(move || {
                c.sleep(Duration::from_secs(secs));
                order.lock().unwrap().push(secs);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(*order.lock().unwrap(), vec![10, 20, 30]);
        assert!(sim.now() >= Duration::from_secs(30));
    }

    #[test]
    fn busy_guard_blocks_time_jumps() {
        let sim = Clock::sim();
        let woke = Arc::new(AtomicBool::new(false));
        let guard = sim.busy();
        let sleeper = {
            let c = sim.clone();
            let woke = Arc::clone(&woke);
            std::thread::spawn(move || {
                c.sleep(Duration::from_secs(5));
                woke.store(true, Ordering::SeqCst);
            })
        };
        // With a busy thread registered, the sleeper cannot jump.
        std::thread::sleep(Duration::from_millis(30));
        assert!(!woke.load(Ordering::SeqCst), "time jumped while a thread was busy");
        drop(guard);
        sleeper.join().unwrap();
        assert!(woke.load(Ordering::SeqCst));
    }

    #[test]
    fn nested_busy_guards_count_one_thread() {
        let sim = Clock::sim();
        let g1 = sim.busy();
        let g2 = sim.busy();
        drop(g1);
        // Still busy: the outer guard remains.
        let woke = Arc::new(AtomicBool::new(false));
        let sleeper = {
            let c = sim.clone();
            let woke = Arc::clone(&woke);
            std::thread::spawn(move || {
                c.sleep(Duration::from_secs(1));
                woke.store(true, Ordering::SeqCst);
            })
        };
        std::thread::sleep(Duration::from_millis(20));
        assert!(!woke.load(Ordering::SeqCst));
        drop(g2);
        sleeper.join().unwrap();
    }

    #[test]
    fn cv_wait_times_out_on_virtual_deadline() {
        let sim = Clock::sim();
        let real = Clock::system();
        let m = Mutex::new(());
        let cv = Condvar::new();
        let t0 = real.now();
        let (_g, timed_out) = sim.wait_timeout(&cv, m.lock().unwrap(), Duration::from_secs(600));
        assert!(timed_out);
        assert!(sim.now() >= Duration::from_secs(600));
        assert!(real.since(t0) < Duration::from_secs(2));
    }

    #[test]
    fn cv_wait_returns_on_real_notify() {
        let sim = Clock::sim();
        let shared = Arc::new((Mutex::new(false), Condvar::new()));
        let notifier = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(10));
                *shared.0.lock().unwrap() = true;
                shared.1.notify_all();
            })
        };
        let mut guard = shared.0.lock().unwrap();
        let mut timed_out = false;
        while !*guard && !timed_out {
            let (g, to) = sim.wait_timeout(&shared.1, guard, Duration::from_secs(3600));
            guard = g;
            timed_out = to;
        }
        assert!(*guard, "notify lost");
        // The virtual deadline never needed to fire.
        assert!(sim.now() < Duration::from_secs(3600));
        drop(guard);
        notifier.join().unwrap();
    }

    #[test]
    fn suspended_guard_allows_jumps_while_parked() {
        let sim = Clock::sim();
        let woke = Arc::new(AtomicBool::new(false));
        let c = sim.clone();
        let woke2 = Arc::clone(&woke);
        let sleeper = std::thread::spawn(move || {
            c.sleep(Duration::from_secs(2));
            woke2.store(true, Ordering::SeqCst);
        });
        // A busy thread that parks on non-clock work suspends its
        // registration, so the sleeper can jump.
        let _busy = sim.busy();
        {
            let _parked = sim.suspended();
            sleeper.join().unwrap();
        }
        assert!(woke.load(Ordering::SeqCst));
    }

    #[test]
    fn advance_to_is_monotonic_and_sim_only() {
        let sim = Clock::sim();
        sim.advance_to(Duration::from_secs(10));
        assert_eq!(sim.now(), Duration::from_secs(10));
        sim.advance_to(Duration::from_secs(5));
        assert_eq!(sim.now(), Duration::from_secs(10), "advance_to went backwards");
        let sys = Clock::system();
        let before = sys.now();
        sys.advance_to(before + Duration::from_secs(3600));
        assert!(sys.now() < before + Duration::from_secs(1800));
    }

    #[test]
    fn timestamps_and_since_saturate() {
        let c = Clock::system();
        let now = c.now();
        assert_eq!(c.since(now + Duration::from_secs(100)), Duration::ZERO);
    }
}
