//! Low-level substrates used across the crate.
//!
//! Everything here exists because the build is fully offline: no `rayon`,
//! `rand`, `log`, `criterion` or `proptest` crates are available, so the
//! crate ships its own (small, well-tested) equivalents:
//!
//! * [`threadpool`] — fixed-size pool + scoped `parallel_for`, the OpenMP
//!   analog used by the parallel aggregator (paper Fig. 4).
//! * [`rng`] — deterministic xoshiro256** PRNG (seedable, splittable).
//! * [`clock`] — the unified time seam: real or discrete-event simulated
//!   time behind one injectable [`Clock`] handle.
//! * [`stopwatch`] — clock-based timers for the T1–T9 operation metrics.
//! * [`logging`] — leveled stderr logger (`METISFL_LOG=debug|info|warn`).
//! * [`stats`] — mean / std / percentile summaries for the bench harness.
//! * [`prop`] — miniature property-based testing runner.

pub mod clock;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod stopwatch;
pub mod threadpool;

pub use clock::{Clock, Timestamp};
pub use logging::{log_debug, log_info, log_warn, LogLevel};
pub use rng::Rng;
pub use stats::Summary;
pub use stopwatch::Stopwatch;
pub use threadpool::ThreadPool;

/// Format a `std::time::Duration` as engineering-friendly text (ns/µs/ms/s).
pub fn fmt_duration(d: std::time::Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.2}s", ns as f64 / 1e9)
    }
}

/// Format a byte count as human-readable text.
pub fn fmt_bytes(b: usize) -> String {
    if b < 1024 {
        format!("{b}B")
    } else if b < 1024 * 1024 {
        format!("{:.1}KiB", b as f64 / 1024.0)
    } else if b < 1024 * 1024 * 1024 {
        format!("{:.1}MiB", b as f64 / (1024.0 * 1024.0))
    } else {
        format!("{:.2}GiB", b as f64 / (1024.0 * 1024.0 * 1024.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn duration_formatting_picks_sane_units() {
        assert_eq!(fmt_duration(Duration::from_nanos(12)), "12ns");
        assert_eq!(fmt_duration(Duration::from_micros(12)), "12.00µs");
        assert_eq!(fmt_duration(Duration::from_millis(12)), "12.00ms");
        assert_eq!(fmt_duration(Duration::from_secs(12)), "12.00s");
    }

    #[test]
    fn byte_formatting_picks_sane_units() {
        assert_eq!(fmt_bytes(12), "12B");
        assert_eq!(fmt_bytes(12 * 1024), "12.0KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.0MiB");
        assert_eq!(fmt_bytes(5 * 1024 * 1024 * 1024), "5.00GiB");
    }
}
