//! Miniature property-based testing runner (proptest replacement).
//!
//! Usage (`no_run`: doctest binaries lack the xla rpath on this image):
//!
//! ```no_run
//! use metisfl::util::prop::{prop_check, Gen};
//! prop_check("vec reverse twice is identity", 200, |g| {
//!     let v = g.vec_f32(0..64);
//!     let mut w = v.clone();
//!     w.reverse();
//!     w.reverse();
//!     assert_eq!(v, w);
//! });
//! ```
//!
//! Each case gets a fresh deterministic generator derived from a base seed
//! (`METISFL_PROP_SEED`, default 0xC0FFEE) and the case index; on failure
//! the panic message names the case seed so the exact input can be
//! replayed with `METISFL_PROP_SEED=<seed> METISFL_PROP_CASES=1`.

use super::rng::Rng;

/// Random input generator handed to each property case.
pub struct Gen {
    rng: Rng,
    pub case_seed: u64,
}

impl Gen {
    pub fn from_seed(seed: u64) -> Gen {
        Gen { rng: Rng::new(seed), case_seed: seed }
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    pub fn usize_in(&mut self, r: std::ops::Range<usize>) -> usize {
        assert!(r.start < r.end);
        r.start + self.rng.gen_range(r.end - r.start)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.gen_range_f64(lo as f64, hi as f64) as f32
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.gen_range_f64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.gen_bool(0.5)
    }

    /// Vec of f32 with length drawn from `len`, values N(0,1)-ish plus
    /// occasional exact zeros and large magnitudes to probe edge cases.
    pub fn vec_f32(&mut self, len: std::ops::Range<usize>) -> Vec<f32> {
        let n = self.usize_in(len.start..len.end.max(len.start + 1));
        (0..n)
            .map(|_| match self.rng.gen_range(10) {
                0 => 0.0,
                1 => 1e6 * self.rng.next_gaussian() as f32,
                _ => self.rng.next_gaussian() as f32,
            })
            .collect()
    }

    /// Vec of f64 analogous to [`Gen::vec_f32`].
    pub fn vec_f64(&mut self, len: std::ops::Range<usize>) -> Vec<f64> {
        let n = self.usize_in(len.start..len.end.max(len.start + 1));
        (0..n).map(|_| self.rng.next_gaussian()).collect()
    }

    /// Random tensor shape with `rank in 1..=max_rank` and bounded element
    /// count.
    pub fn shape(&mut self, max_rank: usize, max_elems: usize) -> Vec<usize> {
        let rank = self.usize_in(1..max_rank + 1);
        let mut dims = vec![1usize; rank];
        let mut elems = 1usize;
        for d in dims.iter_mut() {
            let cap = (max_elems / elems).max(1).min(16);
            *d = self.usize_in(1..cap + 1);
            elems *= *d;
        }
        dims
    }

    /// Random byte vector.
    pub fn bytes(&mut self, len: std::ops::Range<usize>) -> Vec<u8> {
        let n = self.usize_in(len.start..len.end.max(len.start + 1));
        (0..n).map(|_| (self.rng.next_u64() & 0xFF) as u8).collect()
    }
}

fn base_seed() -> u64 {
    std::env::var("METISFL_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

fn case_count(default_cases: usize) -> usize {
    std::env::var("METISFL_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default_cases)
}

/// Run `property` against `cases` random generators. Panics (with the
/// failing case seed) on the first failure.
pub fn prop_check(name: &str, cases: usize, property: impl Fn(&mut Gen)) {
    let base = base_seed();
    let cases = case_count(cases);
    for i in 0..cases {
        let seed = base.wrapping_add(i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen::from_seed(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| property(&mut g)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed on case {i} (replay with \
                 METISFL_PROP_SEED={seed} METISFL_PROP_CASES=1): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        prop_check("sum commutes", 50, |g| {
            let a = g.f64_in(-10.0, 10.0);
            let b = g.f64_in(-10.0, 10.0);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            prop_check("always fails", 5, |_| panic!("nope"));
        });
        let msg = match r {
            Err(e) => e.downcast_ref::<String>().cloned().unwrap_or_default(),
            Ok(()) => panic!("property should have failed"),
        };
        assert!(msg.contains("METISFL_PROP_SEED="), "{msg}");
        assert!(msg.contains("always fails"), "{msg}");
    }

    #[test]
    fn shapes_respect_bounds() {
        prop_check("shape bounds", 100, |g| {
            let s = g.shape(4, 256);
            assert!(!s.is_empty() && s.len() <= 4);
            assert!(s.iter().product::<usize>() <= 256);
            assert!(s.iter().all(|&d| d >= 1));
        });
    }

    #[test]
    fn gen_is_deterministic_per_seed() {
        let mut a = Gen::from_seed(99);
        let mut b = Gen::from_seed(99);
        assert_eq!(a.vec_f32(1..32), b.vec_f32(1..32));
        assert_eq!(a.bytes(1..32), b.bytes(1..32));
    }
}
