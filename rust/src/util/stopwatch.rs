//! Clock-based instrumentation used by the T1–T9 operation metrics
//! (paper Fig. 1) and the bench harness. Timers read whatever [`Clock`]
//! they were started on, so the same instrumentation works under real
//! and simulated time.

use crate::util::clock::{Clock, Timestamp};
use std::time::Duration;

/// A restartable stopwatch over a [`Clock`].
#[derive(Debug, Clone)]
pub struct Stopwatch {
    clock: Clock,
    started: Timestamp,
}

impl Stopwatch {
    /// Start on the system clock.
    pub fn start() -> Self {
        Self::start_with(&Clock::system())
    }

    /// Start on an explicit clock (use this inside clocked components).
    pub fn start_with(clock: &Clock) -> Self {
        Stopwatch { clock: clock.clone(), started: clock.now() }
    }

    /// Elapsed time since `start`/`lap`.
    pub fn elapsed(&self) -> Duration {
        self.clock.since(self.started)
    }

    /// Elapsed seconds as f64.
    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Reset the origin and return the time elapsed until now.
    pub fn lap(&mut self) -> Duration {
        let now = self.clock.now();
        let d = now.saturating_sub(self.started);
        self.started = now;
        d
    }
}

/// Accumulates durations of repeated occurrences of one operation.
#[derive(Debug, Default, Clone)]
pub struct OpTimer {
    total: Duration,
    count: u64,
    max: Duration,
}

impl OpTimer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, d: Duration) {
        self.total += d;
        self.count += 1;
        if d > self.max {
            self.max = d;
        }
    }

    /// Time a closure and record its duration; returns the closure result.
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let sw = Stopwatch::start();
        let r = f();
        self.record(sw.elapsed());
        r
    }

    pub fn total(&self) -> Duration {
        self.total
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn max(&self) -> Duration {
        self.max
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            Duration::ZERO
        } else {
            self.total / self.count as u32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_measures_forward_time() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(sw.elapsed() >= Duration::from_millis(4));
    }

    #[test]
    fn lap_resets_origin() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(3));
        let first = sw.lap();
        assert!(first >= Duration::from_millis(2));
        assert!(sw.elapsed() < first);
    }

    #[test]
    fn stopwatch_follows_sim_clock() {
        let sim = Clock::sim();
        let sw = Stopwatch::start_with(&sim);
        sim.advance_to(Duration::from_secs(90));
        assert_eq!(sw.elapsed(), Duration::from_secs(90));
    }

    #[test]
    fn op_timer_accumulates() {
        let mut t = OpTimer::new();
        t.record(Duration::from_millis(10));
        t.record(Duration::from_millis(30));
        assert_eq!(t.count(), 2);
        assert_eq!(t.total(), Duration::from_millis(40));
        assert_eq!(t.mean(), Duration::from_millis(20));
        assert_eq!(t.max(), Duration::from_millis(30));
    }

    #[test]
    fn op_timer_time_closure() {
        let mut t = OpTimer::new();
        let v = t.time(|| 42);
        assert_eq!(v, 42);
        assert_eq!(t.count(), 1);
    }

    #[test]
    fn empty_timer_mean_is_zero() {
        assert_eq!(OpTimer::new().mean(), Duration::ZERO);
    }
}
