//! Deterministic PRNG: xoshiro256** seeded through SplitMix64.
//!
//! Used everywhere randomness is needed (synthetic data, learner
//! selection, property tests, masking secure aggregation) so every run is
//! reproducible from a single `u64` seed.

/// xoshiro256** 1.0 (Blackman & Vigna). Not cryptographically secure; the
/// crypto module derives keystream material from SHA-256 instead.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that small/sequential seeds give
    /// well-distributed states.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (e.g. per learner) from this one.
    pub fn split(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free for our
    /// purposes; modulo bias is negligible at u64 width for n << 2^64).
    pub fn gen_range(&mut self, n: usize) -> usize {
        assert!(n > 0, "gen_range(0)");
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in `[lo, hi)`.
    pub fn gen_range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn next_gaussian(&mut self) -> f64 {
        let mut u1 = self.next_f64();
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli(p).
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.gen_range(i + 1);
            v.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (k <= n), order randomized.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Fill a slice with i.i.d. N(0, scale²) f32 values.
    pub fn fill_gaussian_f32(&mut self, out: &mut [f32], scale: f32) {
        for v in out.iter_mut() {
            *v = self.next_gaussian() as f32 * scale;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 10_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.gen_range(10) < 10);
        }
        assert_eq!(r.gen_range(1), 0);
    }

    #[test]
    fn sample_indices_distinct_and_bounded() {
        let mut r = Rng::new(11);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Rng::new(123);
        let mut a = root.split(1);
        let mut b = root.split(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
