//! Message schema for controller ⇄ learner ⇄ driver communication.
//!
//! Mirrors the RPCs in the paper's Appendix B flow diagrams (Figs. 8–10):
//! registration, `RunTask` (async train dispatch, acked immediately),
//! `MarkTaskCompleted` (learner-initiated completion callback),
//! `EvaluateModel` (synchronous eval call), heartbeats, and shutdown.
//! Models travel as sequences of byte tensors (§3).
//!
//! The surface is split into two planes (see `net` for the transport
//! view):
//!
//! * **Control plane** — small typed request/response messages, issued
//!   through the stubs in [`client`] ([`client::ControllerClient`],
//!   [`client::LearnerClient`]). Sessions open with a versioned
//!   [`Message::Hello`] handshake, and failures carry a structured
//!   [`ErrorCode`] instead of a bare string.
//! * **Data plane** — bulk model payloads move as a chunked stream
//!   ([`Message::ModelStreamBegin`] → [`Message::ModelChunk`]* →
//!   [`Message::ModelStreamEnd`]), so neither side ever materializes a
//!   whole-model wire buffer and the receiver can decode/ingest while
//!   the network is still delivering. One-shot `ShipModel` /
//!   `MarkTaskCompleted` remain for small models; both paths produce
//!   bitwise-identical results (property-tested).

pub mod client;
pub mod ingest;
pub mod wire;

/// Protocol version spoken by this build, negotiated via
/// [`Message::Hello`]. v1 = the pre-split single-plane protocol; v2 adds
/// the typed control plane + streaming data plane; v3 makes the data
/// plane symmetric (controller→learner streamed dispatch) and
/// codec-aware (`Hello` carries an offered codec set, `HelloAck` the
/// accepted intersection, and every `ModelStreamBegin` names the codec
/// and delta base it encodes against); v4 adds the framed `delta-rle`
/// entropy-coded wire (each `ModelChunk` of a framed stream carries
/// exactly one self-delimiting compressed frame) and opens every
/// dispatch connection with the `Hello` handshake, so mixed fleets
/// degrade the fan-out codec to the accepted intersection instead of
/// failing at `Begin`; v5 adds completion telemetry to `TaskMeta`
/// (measured steps-per-second + training wall time, feeding the
/// controller's pacing subsystem) and the `Deregister` control message
/// for graceful learner departure. The telemetry fields are encoded
/// last and decoded tolerantly **where meta is the trailing wire
/// field** (`MarkTaskCompleted`, the on-disk store record) — not in
/// `ModelStreamBegin`, where `spec` follows meta; cross-version
/// sessions are still refused outright at `Hello` (exact version
/// equality), so the tolerance is a decode-robustness property, not a
/// v4-interop mode. v6 adds the hierarchical aggregation tier: the
/// `PartialAggregate` stream purpose carries one shard's partial
/// weighted sum upstream from an aggregator to the root controller
/// (shard total weight rides `TaskMeta::num_samples`), reusing the
/// existing data-plane framing unchanged. The [`HealthProbe`] payload
/// in `HeartbeatAck` is a trailing field decoded tolerantly (absent →
/// zeros), so it rides v6 without a version bump. The span trace
/// context (`TaskMeta::trace_id` + `TaskMeta::parent_span`) rides v6
/// the same way: two varints appended after the telemetry tail,
/// decoded tolerantly (absent → 0 = "no trace"), so instrumented and
/// uninstrumented frames coexist within the version.
pub const PROTO_VERSION: u32 = 6;

use crate::tensor::{ByteOrder, CodecId, DType, Tensor, TensorModel};
use anyhow::{bail, Result};
use wire::{WireReader, WireWriter};

/// Structured error taxonomy carried by [`Message::Error`] replies.
///
/// Callers branch on the code (retry? reconnect? give up?); `detail` is
/// for humans and logs only.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// Unclassified server-side failure.
    Internal,
    /// Component is shut down or not serving.
    Unavailable,
    /// Model payload failed decoding or validation.
    InvalidModel,
    /// Message kind not handled by this component.
    Unsupported,
    /// Request was understood but refused (e.g. negative ack).
    Rejected,
    /// Requested entity does not exist (e.g. no community model yet).
    NotFound,
    /// Data-plane stream protocol violation (bad seq, size, digest).
    StreamProtocol,
    /// Peer speaks an incompatible protocol version.
    VersionMismatch,
}

impl ErrorCode {
    pub fn code(self) -> u8 {
        match self {
            ErrorCode::Internal => 0,
            ErrorCode::Unavailable => 1,
            ErrorCode::InvalidModel => 2,
            ErrorCode::Unsupported => 3,
            ErrorCode::Rejected => 4,
            ErrorCode::NotFound => 5,
            ErrorCode::StreamProtocol => 6,
            ErrorCode::VersionMismatch => 7,
        }
    }

    pub fn from_code(c: u8) -> Result<ErrorCode> {
        Ok(match c {
            0 => ErrorCode::Internal,
            1 => ErrorCode::Unavailable,
            2 => ErrorCode::InvalidModel,
            3 => ErrorCode::Unsupported,
            4 => ErrorCode::Rejected,
            5 => ErrorCode::NotFound,
            6 => ErrorCode::StreamProtocol,
            7 => ErrorCode::VersionMismatch,
            _ => bail!("unknown error code {c}"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            ErrorCode::Internal => "internal",
            ErrorCode::Unavailable => "unavailable",
            ErrorCode::InvalidModel => "invalid_model",
            ErrorCode::Unsupported => "unsupported",
            ErrorCode::Rejected => "rejected",
            ErrorCode::NotFound => "not_found",
            ErrorCode::StreamProtocol => "stream_protocol",
            ErrorCode::VersionMismatch => "version_mismatch",
        }
    }
}

impl std::fmt::Display for ErrorCode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// What a model stream delivers once complete.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamPurpose {
    /// Driver → controller community-model initialization (`ShipModel`).
    ShipModel,
    /// Learner → controller training completion (`MarkTaskCompleted`).
    TaskCompletion,
    /// Controller → learner training dispatch (`RunTask`): the `End`
    /// ack queues local training against the streamed model.
    RunTask,
    /// Controller → learner evaluation dispatch (`EvaluateModel`): the
    /// `End` reply is the in-call `EvaluateModelReply`.
    Evaluate,
    /// Aggregator → root controller: one shard's partial weighted sum
    /// (un-normalized) for the round, computed over the shard's arrived
    /// learners in sorted-id order. `TaskMeta::num_samples` carries the
    /// shard's total weight so the root can fold shards with the exact
    /// arithmetic of a flat fleet.
    PartialAggregate,
}

impl StreamPurpose {
    pub fn code(self) -> u8 {
        match self {
            StreamPurpose::ShipModel => 0,
            StreamPurpose::TaskCompletion => 1,
            StreamPurpose::RunTask => 2,
            StreamPurpose::Evaluate => 3,
            StreamPurpose::PartialAggregate => 4,
        }
    }

    pub fn from_code(c: u8) -> Result<StreamPurpose> {
        Ok(match c {
            0 => StreamPurpose::ShipModel,
            1 => StreamPurpose::TaskCompletion,
            2 => StreamPurpose::RunTask,
            3 => StreamPurpose::Evaluate,
            4 => StreamPurpose::PartialAggregate,
            _ => bail!("unknown stream purpose {c}"),
        })
    }
}

/// Per-tensor structure metadata announced by `ModelStreamBegin`: the
/// receiver pre-sizes its decode buffers from this, before any payload
/// byte arrives.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorLayoutProto {
    pub name: String,
    pub dtype: DType,
    pub byte_order: ByteOrder,
    pub shape: Vec<usize>,
}

impl TensorLayoutProto {
    /// The stream layout the sender announces for `model` under `codec`:
    /// one entry per tensor, the codec's wire dtype, little-endian.
    /// Single source of truth shared by the client stub, the controller
    /// dispatch fan-out, and the tests that mirror them.
    pub fn codec_layout_of(model: &TensorModel, codec: CodecId) -> Vec<TensorLayoutProto> {
        let dtype = codec.wire_dtype();
        model
            .tensors
            .iter()
            .map(|t| TensorLayoutProto {
                name: t.name.clone(),
                dtype,
                byte_order: ByteOrder::Little,
                shape: t.shape.clone(),
            })
            .collect()
    }

    /// [`TensorLayoutProto::codec_layout_of`] for the f32 codec.
    pub fn f32_layout_of(model: &TensorModel) -> Vec<TensorLayoutProto> {
        Self::codec_layout_of(model, CodecId::F32)
    }

    /// Element count, guarding against shape-product overflow from a
    /// hostile peer.
    pub fn elem_count_checked(&self) -> Result<usize> {
        self.shape
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .ok_or_else(|| anyhow::anyhow!("tensor '{}' shape overflows usize", self.name))
    }

    /// Encoded payload bytes this tensor contributes to the stream.
    pub fn byte_len_checked(&self) -> Result<usize> {
        self.elem_count_checked()?
            .checked_mul(self.dtype.size_bytes())
            .ok_or_else(|| anyhow::anyhow!("tensor '{}' byte size overflows usize", self.name))
    }

    fn write(&self, w: &mut WireWriter) {
        w.put_str(&self.name);
        w.put_u8(self.dtype.code());
        w.put_u8(self.byte_order.code());
        w.put_usize_list(&self.shape);
    }

    fn read(r: &mut WireReader) -> Result<TensorLayoutProto> {
        Ok(TensorLayoutProto {
            name: r.get_str()?,
            dtype: DType::from_code(r.get_u8()?)?,
            byte_order: ByteOrder::from_code(r.get_u8()?)?,
            shape: r.get_usize_list()?,
        })
    }
}

/// Wire form of one tensor: structure metadata + raw bytes (paper §3).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorProto {
    pub name: String,
    pub dtype: DType,
    pub byte_order: ByteOrder,
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl TensorProto {
    /// Encode an in-memory tensor (f32) into wire form.
    pub fn from_tensor(t: &Tensor, dtype: DType, order: ByteOrder) -> TensorProto {
        TensorProto {
            name: t.name.clone(),
            dtype,
            byte_order: order,
            shape: t.shape.clone(),
            data: t.encode_data(dtype, order),
        }
    }

    /// Decode back into an in-memory f32 tensor.
    pub fn to_tensor(&self) -> Result<Tensor> {
        Tensor::decode_data(
            self.name.clone(),
            self.shape.clone(),
            self.dtype,
            self.byte_order,
            &self.data,
        )
    }

    fn write(&self, w: &mut WireWriter) {
        w.put_str(&self.name);
        w.put_u8(self.dtype.code());
        w.put_u8(self.byte_order.code());
        w.put_usize_list(&self.shape);
        w.put_bytes(&self.data);
    }

    fn read(r: &mut WireReader) -> Result<TensorProto> {
        let name = r.get_str()?;
        let dtype = DType::from_code(r.get_u8()?)?;
        let byte_order = ByteOrder::from_code(r.get_u8()?)?;
        let shape = r.get_usize_list()?;
        let data = r.get_bytes()?.to_vec();
        let expected: usize = shape.iter().product::<usize>() * dtype.size_bytes();
        if data.len() != expected {
            bail!("tensor '{name}': payload {} != expected {expected}", data.len());
        }
        Ok(TensorProto { name, dtype, byte_order, shape, data })
    }
}

/// Wire form of a whole model.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ModelProto {
    pub tensors: Vec<TensorProto>,
}

impl ModelProto {
    pub fn from_model(m: &TensorModel, dtype: DType, order: ByteOrder) -> ModelProto {
        ModelProto {
            tensors: m.tensors.iter().map(|t| TensorProto::from_tensor(t, dtype, order)).collect(),
        }
    }

    pub fn to_model(&self) -> Result<TensorModel> {
        Ok(TensorModel::new(
            self.tensors.iter().map(|t| t.to_tensor()).collect::<Result<Vec<_>>>()?,
        ))
    }

    pub fn byte_size(&self) -> usize {
        self.tensors.iter().map(|t| t.data.len()).sum()
    }

    fn write(&self, w: &mut WireWriter) {
        w.put_varint(self.tensors.len() as u64);
        for t in &self.tensors {
            t.write(w);
        }
    }

    fn read(r: &mut WireReader) -> Result<ModelProto> {
        let n = r.get_varint()? as usize;
        if n > 1_000_000 {
            bail!("implausible tensor count {n}");
        }
        let tensors = (0..n).map(|_| TensorProto::read(r)).collect::<Result<Vec<_>>>()?;
        Ok(ModelProto { tensors })
    }
}

/// Local-training hyperparameters carried by a train task.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TaskSpec {
    pub epochs: usize,
    pub batch_size: usize,
    pub learning_rate: f64,
    /// Semi-sync step budget: max local SGD steps this round (0 = by epochs).
    pub step_budget: usize,
}

/// Execution metadata returned with a completed train task (App. B:
/// "training time per batch, number of completed steps and epochs").
///
/// The v5 telemetry fields (`steps_per_sec`, `train_wall_time_us`)
/// feed the controller's per-learner pacing profiles; they are encoded
/// last and decoded tolerantly (absent → 0) in messages where meta is
/// the trailing field, so a pre-v5 `MarkTaskCompleted` (or on-disk
/// store record) still parses.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TaskMeta {
    pub train_time_per_batch_us: u64,
    pub completed_steps: usize,
    pub completed_epochs: usize,
    pub num_samples: usize,
    pub train_loss: f64,
    /// Measured local-training throughput (SGD steps per second) over
    /// the whole task, as observed by the learner. 0 = not reported.
    pub steps_per_sec: f64,
    /// Wall-clock microseconds the local training took end to end
    /// (sleeps and data loading included). 0 = not reported.
    pub train_wall_time_us: u64,
    /// Span trace correlation id: every span caused by the same root
    /// operation (a round dispatch, a shard fold) shares one trace_id
    /// across processes. 0 = no trace context attached.
    pub trace_id: u64,
    /// span_id of the sender-side span that caused this message, so the
    /// receiver can parent its own spans under it. 0 = no parent.
    pub parent_span: u64,
}

impl TaskMeta {
    /// The trace context this meta carries, if any.
    pub fn span_ctx(&self) -> crate::obs::SpanCtx {
        crate::obs::SpanCtx { trace_id: self.trace_id, parent_span: self.parent_span }
    }

    /// Attach a trace context (no-op fields when `ctx` is unset).
    pub fn with_span_ctx(mut self, ctx: crate::obs::SpanCtx) -> TaskMeta {
        self.trace_id = ctx.trace_id;
        self.parent_span = ctx.parent_span;
        self
    }
}

/// Evaluation result.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct EvalResult {
    pub loss: f64,
    pub num_samples: usize,
    pub eval_time_us: u64,
}

/// Component state snapshot carried by [`Message::HeartbeatAck`]: what
/// "healthy" actually means, in numbers. Encoded as a trailing field
/// and decoded tolerantly (absent → all zeros), so an ack from a peer
/// that predates the payload still parses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct HealthProbe {
    /// Rounds currently open (barrier not yet satisfied).
    pub open_rounds: u64,
    /// Data-plane streams mid-flight right now (after idle GC).
    pub open_streams: u64,
    /// Sends abandoned after exhausting their retry budget — the
    /// component's "I gave up on a peer" counter.
    pub retry_give_ups: u64,
}

impl HealthProbe {
    /// The health verdict the ack's `healthy` bit reports: a component
    /// is degraded once it has abandoned sends (open rounds and live
    /// streams are normal mid-round states, give-ups are not).
    pub fn is_healthy(&self) -> bool {
        self.retry_give_ups == 0
    }
}

/// All protocol messages. Request/response pairing is handled by the
/// transport; `Ack` is the generic fast reply.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Learner → controller: join the federation.
    Register { learner_id: String, host: String, port: u16, num_samples: usize },
    /// Learner (or driver, on a learner's behalf) → controller: leave
    /// the federation. The controller drops the learner's handle and
    /// every per-learner map entry (participation history, pacing
    /// profile, pinned delta base).
    Deregister { learner_id: String },
    /// Controller → learner reply.
    RegisterAck { accepted: bool, assigned_index: usize },
    /// Driver → controller: initial community model state.
    ShipModel { model: ModelProto },
    /// Controller → learner: asynchronous training dispatch (Fig. 9).
    RunTask { task_id: u64, round: u64, model: ModelProto, spec: TaskSpec },
    /// Immediate acknowledgment (false = submission failed).
    Ack { task_id: u64, ok: bool },
    /// Learner → controller: local training finished (Fig. 9).
    MarkTaskCompleted { task_id: u64, learner_id: String, model: ModelProto, meta: TaskMeta },
    /// Controller → learner: synchronous evaluation call (Fig. 10).
    EvaluateModel { task_id: u64, round: u64, model: ModelProto },
    /// Learner → controller eval reply (carried in the same call).
    EvaluateModelReply { task_id: u64, learner_id: String, result: EvalResult },
    /// Driver → any: liveness probe (Fig. 8 "Monitoring").
    Heartbeat { from: String },
    /// Reply to `Heartbeat`: `healthy` is the component's own verdict
    /// ([`HealthProbe::is_healthy`]), `health` the numbers behind it.
    HeartbeatAck { component: String, healthy: bool, health: HealthProbe },
    /// Driver → any: orderly shutdown (learners first, then controller).
    Shutdown,
    /// Structured error reply (see [`ErrorCode`]).
    Error { code: ErrorCode, detail: String },
    /// Driver → controller: fetch current community model.
    GetModel,
    ModelReply { model: ModelProto, round: u64 },
    /// Control-plane session opener: announce our protocol version and
    /// the wire codecs we can speak (offered set).
    Hello { proto_version: u32, codecs: Vec<CodecId> },
    /// Accepting reply to `Hello` (versions matched); `codecs` is the
    /// accepted intersection of the offered set with the responder's.
    HelloAck { proto_version: u32, component: String, codecs: Vec<CodecId> },
    /// Data plane: open a model stream. Carries everything *except* the
    /// payload — stream identity, routing fields, the wire codec the
    /// chunks are encoded with (plus the delta base's identity when the
    /// codec needs one), per-tensor layout (so the receiver can pre-size
    /// decode buffers), the task metadata that `MarkTaskCompleted` would
    /// have carried inline, and the `TaskSpec` a streamed `RunTask`
    /// dispatch would have carried inline.
    ModelStreamBegin {
        stream_id: u64,
        task_id: u64,
        round: u64,
        purpose: StreamPurpose,
        learner_id: String,
        codec: CodecId,
        /// Identity (community round) of the shared base model a
        /// delta-coded stream XORs against; 0 when the codec needs none.
        base_round: u64,
        layout: Vec<TensorLayoutProto>,
        meta: TaskMeta,
        spec: TaskSpec,
    },
    /// Data plane: one contiguous slice of the stream's flat payload
    /// (tensor byte blobs concatenated in layout order). `seq` starts at
    /// 0 and increments by 1. For element-size-stable codecs, chunks
    /// need not align to element or tensor boundaries; for framed codecs
    /// (delta-rle) every chunk is exactly one self-delimiting frame,
    /// never split, and never spanning a tensor boundary.
    ModelChunk { stream_id: u64, seq: u64, bytes: Vec<u8> },
    /// Data plane: close a stream. `digest` is the FNV-1a 64 hash of all
    /// payload bytes in stream order ([`wire::fnv1a64`]).
    ModelStreamEnd { stream_id: u64, digest: u64 },
}

impl Message {
    /// Convenience constructor for structured error replies.
    pub fn error(code: ErrorCode, detail: impl Into<String>) -> Message {
        Message::Error { code, detail: detail.into() }
    }
}

// Message discriminants on the wire.
const T_REGISTER: u8 = 1;
const T_REGISTER_ACK: u8 = 2;
const T_SHIP_MODEL: u8 = 3;
const T_RUN_TASK: u8 = 4;
const T_ACK: u8 = 5;
const T_MARK_COMPLETED: u8 = 6;
const T_EVALUATE: u8 = 7;
const T_EVALUATE_REPLY: u8 = 8;
const T_HEARTBEAT: u8 = 9;
const T_HEARTBEAT_ACK: u8 = 10;
const T_SHUTDOWN: u8 = 11;
const T_ERROR: u8 = 12;
const T_GET_MODEL: u8 = 13;
const T_MODEL_REPLY: u8 = 14;
const T_HELLO: u8 = 15;
const T_HELLO_ACK: u8 = 16;
const T_STREAM_BEGIN: u8 = 17;
const T_CHUNK: u8 = 18;
const T_STREAM_END: u8 = 19;
const T_DEREGISTER: u8 = 20;

fn write_codecs(w: &mut WireWriter, codecs: &[CodecId]) {
    let codes: Vec<u8> = codecs.iter().map(|c| c.code()).collect();
    w.put_bytes(&codes);
}

/// Codec-set field of `Hello`/`HelloAck`. Tolerates the field being
/// absent (empty set): a v2 peer's handshake must still *decode* so the
/// handler can answer with a structured `VersionMismatch` instead of
/// the connection dying on a wire error.
fn read_codecs(r: &mut WireReader) -> Result<Vec<CodecId>> {
    if r.is_done() {
        return Ok(Vec::new());
    }
    r.get_bytes()?.iter().map(|&c| CodecId::from_code(c)).collect()
}

fn write_spec(w: &mut WireWriter, spec: &TaskSpec) {
    w.put_varint(spec.epochs as u64);
    w.put_varint(spec.batch_size as u64);
    w.put_f64(spec.learning_rate);
    w.put_varint(spec.step_budget as u64);
}

fn read_spec(r: &mut WireReader) -> Result<TaskSpec> {
    Ok(TaskSpec {
        epochs: r.get_varint()? as usize,
        batch_size: r.get_varint()? as usize,
        learning_rate: r.get_f64()?,
        step_budget: r.get_varint()? as usize,
    })
}

fn write_meta(w: &mut WireWriter, meta: &TaskMeta) {
    w.put_varint(meta.train_time_per_batch_us);
    w.put_varint(meta.completed_steps as u64);
    w.put_varint(meta.completed_epochs as u64);
    w.put_varint(meta.num_samples as u64);
    w.put_f64(meta.train_loss);
    w.put_f64(meta.steps_per_sec);
    w.put_varint(meta.train_wall_time_us);
    w.put_varint(meta.trace_id);
    w.put_varint(meta.parent_span);
}

fn read_meta(r: &mut WireReader) -> Result<TaskMeta> {
    let train_time_per_batch_us = r.get_varint()?;
    let completed_steps = r.get_varint()? as usize;
    let completed_epochs = r.get_varint()? as usize;
    let num_samples = r.get_varint()? as usize;
    let train_loss = r.get_f64()?;
    // v5 telemetry tail: tolerate a pre-v5 meta that ends here. Only
    // effective where meta is the message's trailing field ("absent" is
    // observable as end-of-buffer) — i.e. `MarkTaskCompleted`; in
    // `ModelStreamBegin` the spec follows meta, but that message can
    // only come from a same-version peer (Hello requires equality).
    let (steps_per_sec, train_wall_time_us) =
        if r.is_done() { (0.0, 0) } else { (r.get_f64()?, r.get_varint()?) };
    // Span trace-context tail (PR-10): same tolerance, one layer
    // further out — a meta that ends at the telemetry tail carries no
    // trace context (0 = unset), so pre-span frames still parse.
    let (trace_id, parent_span) =
        if r.is_done() { (0, 0) } else { (r.get_varint()?, r.get_varint()?) };
    Ok(TaskMeta {
        train_time_per_batch_us,
        completed_steps,
        completed_epochs,
        num_samples,
        train_loss,
        steps_per_sec,
        train_wall_time_us,
        trace_id,
        parent_span,
    })
}

impl Message {
    /// Serialize to wire bytes (discriminant + positional fields).
    pub fn encode(&self) -> Vec<u8> {
        let mut w = WireWriter::with_capacity(self.encoded_size_hint());
        match self {
            Message::Register { learner_id, host, port, num_samples } => {
                w.put_u8(T_REGISTER);
                w.put_str(learner_id);
                w.put_str(host);
                w.put_varint(*port as u64);
                w.put_varint(*num_samples as u64);
            }
            Message::Deregister { learner_id } => {
                w.put_u8(T_DEREGISTER);
                w.put_str(learner_id);
            }
            Message::RegisterAck { accepted, assigned_index } => {
                w.put_u8(T_REGISTER_ACK);
                w.put_bool(*accepted);
                w.put_varint(*assigned_index as u64);
            }
            Message::ShipModel { model } => {
                w.put_u8(T_SHIP_MODEL);
                model.write(&mut w);
            }
            Message::RunTask { task_id, round, model, spec } => {
                w.put_u8(T_RUN_TASK);
                w.put_varint(*task_id);
                w.put_varint(*round);
                model.write(&mut w);
                write_spec(&mut w, spec);
            }
            Message::Ack { task_id, ok } => {
                w.put_u8(T_ACK);
                w.put_varint(*task_id);
                w.put_bool(*ok);
            }
            Message::MarkTaskCompleted { task_id, learner_id, model, meta } => {
                w.put_u8(T_MARK_COMPLETED);
                w.put_varint(*task_id);
                w.put_str(learner_id);
                model.write(&mut w);
                write_meta(&mut w, meta);
            }
            Message::EvaluateModel { task_id, round, model } => {
                w.put_u8(T_EVALUATE);
                w.put_varint(*task_id);
                w.put_varint(*round);
                model.write(&mut w);
            }
            Message::EvaluateModelReply { task_id, learner_id, result } => {
                w.put_u8(T_EVALUATE_REPLY);
                w.put_varint(*task_id);
                w.put_str(learner_id);
                w.put_f64(result.loss);
                w.put_varint(result.num_samples as u64);
                w.put_varint(result.eval_time_us);
            }
            Message::Heartbeat { from } => {
                w.put_u8(T_HEARTBEAT);
                w.put_str(from);
            }
            Message::HeartbeatAck { component, healthy, health } => {
                w.put_u8(T_HEARTBEAT_ACK);
                w.put_str(component);
                w.put_bool(*healthy);
                w.put_varint(health.open_rounds);
                w.put_varint(health.open_streams);
                w.put_varint(health.retry_give_ups);
            }
            Message::Shutdown => w.put_u8(T_SHUTDOWN),
            Message::Error { code, detail } => {
                w.put_u8(T_ERROR);
                w.put_u8(code.code());
                w.put_str(detail);
            }
            Message::GetModel => w.put_u8(T_GET_MODEL),
            Message::ModelReply { model, round } => {
                w.put_u8(T_MODEL_REPLY);
                model.write(&mut w);
                w.put_varint(*round);
            }
            Message::Hello { proto_version, codecs } => {
                w.put_u8(T_HELLO);
                w.put_varint(*proto_version as u64);
                write_codecs(&mut w, codecs);
            }
            Message::HelloAck { proto_version, component, codecs } => {
                w.put_u8(T_HELLO_ACK);
                w.put_varint(*proto_version as u64);
                w.put_str(component);
                write_codecs(&mut w, codecs);
            }
            Message::ModelStreamBegin {
                stream_id,
                task_id,
                round,
                purpose,
                learner_id,
                codec,
                base_round,
                layout,
                meta,
                spec,
            } => {
                w.put_u8(T_STREAM_BEGIN);
                w.put_varint(*stream_id);
                w.put_varint(*task_id);
                w.put_varint(*round);
                w.put_u8(purpose.code());
                w.put_str(learner_id);
                w.put_u8(codec.code());
                w.put_varint(*base_round);
                w.put_varint(layout.len() as u64);
                for t in layout {
                    t.write(&mut w);
                }
                write_meta(&mut w, meta);
                write_spec(&mut w, spec);
            }
            Message::ModelChunk { stream_id, seq, bytes } => {
                w.put_u8(T_CHUNK);
                w.put_varint(*stream_id);
                w.put_varint(*seq);
                w.put_bytes(bytes);
            }
            Message::ModelStreamEnd { stream_id, digest } => {
                w.put_u8(T_STREAM_END);
                w.put_varint(*stream_id);
                w.put_varint(*digest);
            }
        }
        w.into_bytes()
    }

    /// Parse from wire bytes.
    pub fn decode(buf: &[u8]) -> Result<Message> {
        let mut r = WireReader::new(buf);
        let tag = r.get_u8()?;
        let msg = match tag {
            T_REGISTER => Message::Register {
                learner_id: r.get_str()?,
                host: r.get_str()?,
                port: r.get_varint()? as u16,
                num_samples: r.get_varint()? as usize,
            },
            T_DEREGISTER => Message::Deregister { learner_id: r.get_str()? },
            T_REGISTER_ACK => Message::RegisterAck {
                accepted: r.get_bool()?,
                assigned_index: r.get_varint()? as usize,
            },
            T_SHIP_MODEL => Message::ShipModel { model: ModelProto::read(&mut r)? },
            T_RUN_TASK => Message::RunTask {
                task_id: r.get_varint()?,
                round: r.get_varint()?,
                model: ModelProto::read(&mut r)?,
                spec: read_spec(&mut r)?,
            },
            T_ACK => Message::Ack { task_id: r.get_varint()?, ok: r.get_bool()? },
            T_MARK_COMPLETED => Message::MarkTaskCompleted {
                task_id: r.get_varint()?,
                learner_id: r.get_str()?,
                model: ModelProto::read(&mut r)?,
                meta: read_meta(&mut r)?,
            },
            T_EVALUATE => Message::EvaluateModel {
                task_id: r.get_varint()?,
                round: r.get_varint()?,
                model: ModelProto::read(&mut r)?,
            },
            T_EVALUATE_REPLY => Message::EvaluateModelReply {
                task_id: r.get_varint()?,
                learner_id: r.get_str()?,
                result: EvalResult {
                    loss: r.get_f64()?,
                    num_samples: r.get_varint()? as usize,
                    eval_time_us: r.get_varint()?,
                },
            },
            T_HEARTBEAT => Message::Heartbeat { from: r.get_str()? },
            T_HEARTBEAT_ACK => {
                let component = r.get_str()?;
                let healthy = r.get_bool()?;
                // Health payload is the trailing field; tolerate an ack
                // that ends at `healthy` (pre-payload peers, stubs).
                let health = if r.is_done() {
                    HealthProbe::default()
                } else {
                    HealthProbe {
                        open_rounds: r.get_varint()?,
                        open_streams: r.get_varint()?,
                        retry_give_ups: r.get_varint()?,
                    }
                };
                Message::HeartbeatAck { component, healthy, health }
            }
            T_SHUTDOWN => Message::Shutdown,
            T_ERROR => Message::Error {
                code: ErrorCode::from_code(r.get_u8()?)?,
                detail: r.get_str()?,
            },
            T_GET_MODEL => Message::GetModel,
            T_MODEL_REPLY => {
                let model = ModelProto::read(&mut r)?;
                Message::ModelReply { model, round: r.get_varint()? }
            }
            T_HELLO => Message::Hello {
                proto_version: r.get_varint()? as u32,
                codecs: read_codecs(&mut r)?,
            },
            T_HELLO_ACK => Message::HelloAck {
                proto_version: r.get_varint()? as u32,
                component: r.get_str()?,
                codecs: read_codecs(&mut r)?,
            },
            T_STREAM_BEGIN => {
                let stream_id = r.get_varint()?;
                let task_id = r.get_varint()?;
                let round = r.get_varint()?;
                let purpose = StreamPurpose::from_code(r.get_u8()?)?;
                let learner_id = r.get_str()?;
                let codec = CodecId::from_code(r.get_u8()?)?;
                let base_round = r.get_varint()?;
                let n = r.get_varint()? as usize;
                if n > 1_000_000 {
                    bail!("implausible stream layout tensor count {n}");
                }
                let layout = (0..n)
                    .map(|_| TensorLayoutProto::read(&mut r))
                    .collect::<Result<Vec<_>>>()?;
                let meta = read_meta(&mut r)?;
                let spec = read_spec(&mut r)?;
                Message::ModelStreamBegin {
                    stream_id,
                    task_id,
                    round,
                    purpose,
                    learner_id,
                    codec,
                    base_round,
                    layout,
                    meta,
                    spec,
                }
            }
            T_CHUNK => Message::ModelChunk {
                stream_id: r.get_varint()?,
                seq: r.get_varint()?,
                bytes: r.get_bytes()?.to_vec(),
            },
            T_STREAM_END => Message::ModelStreamEnd {
                stream_id: r.get_varint()?,
                digest: r.get_varint()?,
            },
            t => bail!("unknown message tag {t}"),
        };
        if !r.is_done() {
            bail!("trailing bytes after message (tag {tag})");
        }
        Ok(msg)
    }

    /// Encode a batch of `RunTask`s that share `(task_id, round,
    /// model)` but differ per target in their `TaskSpec`, as one shared
    /// prefix (the model bytes, serialized ONCE) plus one small spec
    /// suffix per entry: `prefix ‖ suffixes[i]` is byte-identical to
    /// `Message::RunTask { .., spec: specs[i] }.encode()` (`TaskSpec`
    /// is deliberately the last field of `RunTask` on the wire). This
    /// is how pacing-aware semi-sync hands every learner its own step
    /// budget on the one-shot path without per-learner model encodes —
    /// and, because callers assemble the full frame only at send time,
    /// without holding O(learners × model) frame copies alive.
    pub fn encode_run_task_parts(
        task_id: u64,
        round: u64,
        model: &ModelProto,
        specs: &[TaskSpec],
    ) -> (Vec<u8>, Vec<Vec<u8>>) {
        let hint = Message::RunTask {
            task_id,
            round,
            model: ModelProto::default(),
            spec: TaskSpec::default(),
        }
        .encoded_size_hint();
        let mut w = WireWriter::with_capacity(
            hint + model.byte_size()
                + model.tensors.iter().map(|t| t.name.len() + 24).sum::<usize>(),
        );
        w.put_u8(T_RUN_TASK);
        w.put_varint(task_id);
        w.put_varint(round);
        model.write(&mut w);
        let prefix = w.into_bytes();
        let suffixes = specs
            .iter()
            .map(|spec| {
                let mut sw = WireWriter::with_capacity(40);
                write_spec(&mut sw, spec);
                sw.into_bytes()
            })
            .collect();
        (prefix, suffixes)
    }

    /// Rough encoded size, to pre-size buffers (exact for tensor payloads).
    pub fn encoded_size_hint(&self) -> usize {
        let model_size = |m: &ModelProto| {
            m.byte_size() + m.tensors.iter().map(|t| t.name.len() + 24).sum::<usize>() + 16
        };
        match self {
            Message::ShipModel { model }
            | Message::EvaluateModel { model, .. }
            | Message::ModelReply { model, .. } => model_size(model) + 32,
            Message::RunTask { model, .. } => model_size(model) + 64,
            Message::MarkTaskCompleted { model, .. } => model_size(model) + 96,
            Message::ModelChunk { bytes, .. } => bytes.len() + 48,
            Message::ModelStreamBegin { layout, learner_id, .. } => {
                layout
                    .iter()
                    .map(|t| t.name.len() + 8 * t.shape.len() + 16)
                    .sum::<usize>()
                    + learner_id.len()
                    + 192
            }
            _ => 128,
        }
    }

    /// Short human-readable name for logs/metrics.
    pub fn kind(&self) -> &'static str {
        match self {
            Message::Register { .. } => "Register",
            Message::Deregister { .. } => "Deregister",
            Message::RegisterAck { .. } => "RegisterAck",
            Message::ShipModel { .. } => "ShipModel",
            Message::RunTask { .. } => "RunTask",
            Message::Ack { .. } => "Ack",
            Message::MarkTaskCompleted { .. } => "MarkTaskCompleted",
            Message::EvaluateModel { .. } => "EvaluateModel",
            Message::EvaluateModelReply { .. } => "EvaluateModelReply",
            Message::Heartbeat { .. } => "Heartbeat",
            Message::HeartbeatAck { .. } => "HeartbeatAck",
            Message::Shutdown => "Shutdown",
            Message::Error { .. } => "Error",
            Message::GetModel => "GetModel",
            Message::ModelReply { .. } => "ModelReply",
            Message::Hello { .. } => "Hello",
            Message::HelloAck { .. } => "HelloAck",
            Message::ModelStreamBegin { .. } => "ModelStreamBegin",
            Message::ModelChunk { .. } => "ModelChunk",
            Message::ModelStreamEnd { .. } => "ModelStreamEnd",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;
    use crate::util::Rng;

    fn sample_model() -> TensorModel {
        let layout = ModelSpec::mlp(4, 2, 8).tensor_layout();
        let mut rng = Rng::new(3);
        TensorModel::random_init(&layout, &mut rng)
    }

    #[test]
    fn tensor_proto_roundtrip() {
        let m = sample_model();
        let p = TensorProto::from_tensor(&m.tensors[0], DType::F32, ByteOrder::Little);
        let t = p.to_tensor().unwrap();
        assert_eq!(t, m.tensors[0]);
    }

    #[test]
    fn model_proto_roundtrip_all_dtypes() {
        let m = sample_model();
        for dtype in [DType::F32, DType::F64] {
            for order in [ByteOrder::Little, ByteOrder::Big] {
                let p = ModelProto::from_model(&m, dtype, order);
                let back = p.to_model().unwrap();
                assert_eq!(back.param_count(), m.param_count());
                assert!(m.max_abs_diff(&back) == 0.0, "{dtype:?} {order:?}");
            }
        }
    }

    #[test]
    fn every_message_roundtrips() {
        let model = ModelProto::from_model(&sample_model(), DType::F32, ByteOrder::Little);
        let msgs = vec![
            Message::Register {
                learner_id: "l1".into(),
                host: "127.0.0.1".into(),
                port: 9000,
                num_samples: 100,
            },
            Message::Deregister { learner_id: "l1".into() },
            Message::RegisterAck { accepted: true, assigned_index: 3 },
            Message::ShipModel { model: model.clone() },
            Message::RunTask {
                task_id: 7,
                round: 2,
                model: model.clone(),
                spec: TaskSpec {
                    epochs: 1,
                    batch_size: 100,
                    learning_rate: 0.01,
                    step_budget: 0,
                },
            },
            Message::Ack { task_id: 7, ok: true },
            Message::MarkTaskCompleted {
                task_id: 7,
                learner_id: "l1".into(),
                model: model.clone(),
                meta: TaskMeta {
                    train_time_per_batch_us: 1500,
                    completed_steps: 10,
                    completed_epochs: 1,
                    num_samples: 100,
                    train_loss: 0.5,
                    steps_per_sec: 666.25,
                    train_wall_time_us: 15_000,
                    trace_id: 0xABCD_EF01_2345_6789,
                    parent_span: 42,
                },
            },
            Message::EvaluateModel { task_id: 8, round: 2, model: model.clone() },
            Message::EvaluateModelReply {
                task_id: 8,
                learner_id: "l1".into(),
                result: EvalResult { loss: 0.25, num_samples: 100, eval_time_us: 800 },
            },
            Message::Heartbeat { from: "driver".into() },
            Message::HeartbeatAck {
                component: "controller".into(),
                healthy: true,
                health: HealthProbe::default(),
            },
            Message::HeartbeatAck {
                component: "aggregator/1".into(),
                healthy: false,
                health: HealthProbe { open_rounds: 1, open_streams: 4, retry_give_ups: 2 },
            },
            Message::Shutdown,
            Message::Error { code: ErrorCode::Rejected, detail: "nope".into() },
            Message::GetModel,
            Message::Hello { proto_version: PROTO_VERSION, codecs: CodecId::ALL.to_vec() },
            Message::Hello { proto_version: PROTO_VERSION, codecs: Vec::new() },
            Message::HelloAck {
                proto_version: PROTO_VERSION,
                component: "controller".into(),
                codecs: vec![CodecId::F32, CodecId::Delta],
            },
            Message::ModelStreamBegin {
                stream_id: 0xDEAD_BEEF,
                task_id: 7,
                round: 2,
                purpose: StreamPurpose::TaskCompletion,
                learner_id: "l1".into(),
                codec: CodecId::Delta,
                base_round: 41,
                layout: model
                    .tensors
                    .iter()
                    .map(|t| TensorLayoutProto {
                        name: t.name.clone(),
                        dtype: t.dtype,
                        byte_order: t.byte_order,
                        shape: t.shape.clone(),
                    })
                    .collect(),
                meta: TaskMeta { num_samples: 100, train_loss: 0.25, ..Default::default() },
                spec: TaskSpec { epochs: 2, batch_size: 10, learning_rate: 0.5, step_budget: 3 },
            },
            Message::ModelStreamBegin {
                stream_id: 1,
                task_id: 9,
                round: 3,
                purpose: StreamPurpose::RunTask,
                learner_id: String::new(),
                codec: CodecId::Bf16,
                base_round: 0,
                layout: Vec::new(),
                meta: TaskMeta::default(),
                spec: TaskSpec::default(),
            },
            Message::ModelStreamBegin {
                stream_id: 2,
                task_id: 10,
                round: 4,
                purpose: StreamPurpose::PartialAggregate,
                learner_id: "agg-0".into(),
                codec: CodecId::DeltaRle,
                base_round: 3,
                layout: Vec::new(),
                // For partial-sum uploads num_samples carries the
                // shard's total weight.
                meta: TaskMeta { num_samples: 75, ..Default::default() },
                spec: TaskSpec::default(),
            },
            Message::ModelChunk { stream_id: 0xDEAD_BEEF, seq: 3, bytes: vec![1, 2, 3, 4, 5] },
            Message::ModelChunk { stream_id: 1, seq: 0, bytes: Vec::new() },
            Message::ModelStreamEnd { stream_id: 0xDEAD_BEEF, digest: u64::MAX },
            Message::ModelReply { model, round: 5 },
        ];
        for m in msgs {
            let bytes = m.encode();
            let back = Message::decode(&bytes).unwrap();
            assert_eq!(back, m, "roundtrip failed for {}", m.kind());
        }
    }

    #[test]
    fn every_error_code_roundtrips() {
        for code in [
            ErrorCode::Internal,
            ErrorCode::Unavailable,
            ErrorCode::InvalidModel,
            ErrorCode::Unsupported,
            ErrorCode::Rejected,
            ErrorCode::NotFound,
            ErrorCode::StreamProtocol,
            ErrorCode::VersionMismatch,
        ] {
            assert_eq!(ErrorCode::from_code(code.code()).unwrap(), code);
            let m = Message::error(code, "d");
            assert_eq!(Message::decode(&m.encode()).unwrap(), m);
        }
        assert!(ErrorCode::from_code(200).is_err());
    }

    #[test]
    fn stream_layout_overflow_guards() {
        let t = TensorLayoutProto {
            name: "huge".into(),
            dtype: DType::F32,
            byte_order: ByteOrder::Little,
            shape: vec![usize::MAX, 2],
        };
        assert!(t.elem_count_checked().is_err());
        let t = TensorLayoutProto {
            name: "edge".into(),
            dtype: DType::F64,
            byte_order: ByteOrder::Little,
            shape: vec![usize::MAX / 4],
        };
        assert!(t.elem_count_checked().is_ok());
        assert!(t.byte_len_checked().is_err());
    }

    #[test]
    fn v2_hello_without_codecs_still_decodes() {
        // A pre-v3 peer's Hello/HelloAck carry no codec set. They must
        // still decode (as an empty set) so the version check can answer
        // with a typed VersionMismatch instead of a dropped connection.
        let mut w = WireWriter::new();
        w.put_u8(super::T_HELLO);
        w.put_varint(2);
        assert_eq!(
            Message::decode(&w.into_bytes()).unwrap(),
            Message::Hello { proto_version: 2, codecs: Vec::new() }
        );
        let mut w = WireWriter::new();
        w.put_u8(super::T_HELLO_ACK);
        w.put_varint(2);
        w.put_str("controller");
        assert_eq!(
            Message::decode(&w.into_bytes()).unwrap(),
            Message::HelloAck {
                proto_version: 2,
                component: "controller".into(),
                codecs: Vec::new()
            }
        );
    }

    #[test]
    fn heartbeat_ack_without_health_tail_still_decodes() {
        // A pre-PR-9 ack ends at the `healthy` bool. The tolerant
        // reader must fill the health payload with zeros instead of
        // erroring at end-of-buffer.
        let mut w = WireWriter::new();
        w.put_u8(super::T_HEARTBEAT_ACK);
        w.put_str("learner/l1");
        w.put_bool(true);
        assert_eq!(
            Message::decode(&w.into_bytes()).unwrap(),
            Message::HeartbeatAck {
                component: "learner/l1".into(),
                healthy: true,
                health: HealthProbe::default(),
            }
        );
        assert!(HealthProbe::default().is_healthy());
        assert!(!HealthProbe { retry_give_ups: 1, ..Default::default() }.is_healthy());
        assert!(HealthProbe { open_rounds: 3, open_streams: 9, ..Default::default() }
            .is_healthy());
    }

    #[test]
    fn v4_meta_without_telemetry_tail_still_decodes() {
        // A pre-v5 `MarkTaskCompleted` ends its meta at `train_loss`.
        // The tolerant reader must fill the telemetry tail with zeros
        // instead of erroring at end-of-buffer.
        let model = ModelProto::from_model(&sample_model(), DType::F32, ByteOrder::Little);
        let mut w = WireWriter::new();
        w.put_u8(super::T_MARK_COMPLETED);
        w.put_varint(7);
        w.put_str("l1");
        model.write(&mut w);
        w.put_varint(1500); // train_time_per_batch_us
        w.put_varint(10); // completed_steps
        w.put_varint(1); // completed_epochs
        w.put_varint(100); // num_samples
        w.put_f64(0.5); // train_loss — v4 meta ends here
        match Message::decode(&w.into_bytes()).unwrap() {
            Message::MarkTaskCompleted { meta, .. } => {
                assert_eq!(meta.train_time_per_batch_us, 1500);
                assert_eq!(meta.train_loss, 0.5);
                assert_eq!(meta.steps_per_sec, 0.0);
                assert_eq!(meta.train_wall_time_us, 0);
            }
            other => panic!("unexpected {}", other.kind()),
        }
    }

    #[test]
    fn meta_without_trace_ctx_tail_still_decodes() {
        // A pre-PR-10 v6 `MarkTaskCompleted` ends its meta at the v5
        // telemetry tail. The tolerant reader must leave the trace
        // context unset (0) instead of erroring at end-of-buffer.
        let model = ModelProto::from_model(&sample_model(), DType::F32, ByteOrder::Little);
        let mut w = WireWriter::new();
        w.put_u8(super::T_MARK_COMPLETED);
        w.put_varint(7);
        w.put_str("l1");
        model.write(&mut w);
        w.put_varint(1500); // train_time_per_batch_us
        w.put_varint(10); // completed_steps
        w.put_varint(1); // completed_epochs
        w.put_varint(100); // num_samples
        w.put_f64(0.5); // train_loss
        w.put_f64(666.25); // steps_per_sec
        w.put_varint(15_000); // train_wall_time_us — pre-span meta ends here
        match Message::decode(&w.into_bytes()).unwrap() {
            Message::MarkTaskCompleted { meta, .. } => {
                assert_eq!(meta.steps_per_sec, 666.25);
                assert_eq!(meta.train_wall_time_us, 15_000);
                assert_eq!(meta.trace_id, 0);
                assert_eq!(meta.parent_span, 0);
                assert!(!meta.span_ctx().is_set());
            }
            other => panic!("unexpected {}", other.kind()),
        }
    }

    #[test]
    fn run_task_parts_share_the_model_and_differ_per_spec() {
        let model = ModelProto::from_model(&sample_model(), DType::F32, ByteOrder::Little);
        let specs: Vec<TaskSpec> = (1..=3)
            .map(|b| TaskSpec {
                epochs: 1,
                batch_size: 10,
                learning_rate: 0.01,
                step_budget: b * 7,
            })
            .collect();
        let (prefix, suffixes) = Message::encode_run_task_parts(4, 2, &model, &specs);
        assert_eq!(suffixes.len(), 3);
        for (suffix, spec) in suffixes.iter().zip(&specs) {
            let mut frame = prefix.clone();
            frame.extend_from_slice(suffix);
            // Each assembled frame decodes to a full RunTask carrying
            // that spec.
            match Message::decode(&frame).unwrap() {
                Message::RunTask { task_id, round, model: m, spec: s } => {
                    assert_eq!((task_id, round), (4, 2));
                    assert_eq!(m, model);
                    assert_eq!(&s, spec);
                }
                other => panic!("unexpected {}", other.kind()),
            }
            // And matches the monolithic encoder byte for byte.
            let direct = Message::RunTask {
                task_id: 4,
                round: 2,
                model: model.clone(),
                spec: spec.clone(),
            }
            .encode();
            assert_eq!(frame, direct);
        }
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Message::decode(&[]).is_err());
        assert!(Message::decode(&[200]).is_err());
        // Valid tag but truncated body.
        let mut bytes = Message::Heartbeat { from: "x".into() }.encode();
        bytes.truncate(bytes.len() - 1);
        assert!(Message::decode(&bytes).is_err());
        // Trailing bytes rejected.
        let mut bytes = Message::Shutdown.encode();
        bytes.push(0);
        assert!(Message::decode(&bytes).is_err());
    }

    #[test]
    fn tensor_payload_length_validated() {
        let m = sample_model();
        let mut p = TensorProto::from_tensor(&m.tensors[0], DType::F32, ByteOrder::Little);
        p.data.truncate(p.data.len() - 4);
        let mut w = WireWriter::new();
        w.put_u8(super::T_SHIP_MODEL);
        w.put_varint(1);
        p.write(&mut w);
        assert!(Message::decode(&w.into_bytes()).is_err());
    }

    #[test]
    fn size_hint_covers_encoded_size() {
        let model = ModelProto::from_model(&sample_model(), DType::F32, ByteOrder::Little);
        let m = Message::RunTask {
            task_id: 1,
            round: 1,
            model,
            spec: TaskSpec { epochs: 1, batch_size: 10, learning_rate: 0.1, step_budget: 0 },
        };
        assert!(m.encoded_size_hint() >= m.encode().len());
    }
}
