//! Binary wire primitives (protobuf replacement).
//!
//! Positional encoding with varint lengths: each message type writes its
//! fields in a fixed order, so no per-field tags are needed. Tensor
//! payloads are raw byte blobs (bulk `memcpy`), which is the property the
//! paper credits for MetisFL's low (de)serialization overhead (§3).

use anyhow::{bail, Result};

/// FNV-1a 64-bit offset basis — the initial state for [`fnv1a64`].
pub const FNV64_INIT: u64 = 0xcbf2_9ce4_8422_2325;

/// Incremental FNV-1a 64-bit hash: fold `bytes` into `state`.
///
/// This is the data-plane stream digest: cheap enough to run at wire
/// speed on every `ModelChunk`, stateful so the sender never needs the
/// whole payload in memory, and byte-order-independent of the tensor
/// contents (it hashes the encoded wire bytes, not the decoded floats).
/// It detects corruption/reordering, not adversaries — the secure
/// channel's HMAC covers integrity against tampering.
pub fn fnv1a64(state: u64, bytes: &[u8]) -> u64 {
    let mut h = state;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Append-only wire writer.
#[derive(Default)]
pub struct WireWriter {
    buf: Vec<u8>,
}

impl WireWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(n: usize) -> Self {
        WireWriter { buf: Vec::with_capacity(n) }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// LEB128 unsigned varint.
    pub fn put_varint(&mut self, mut v: u64) {
        loop {
            let byte = (v & 0x7F) as u8;
            v >>= 7;
            if v == 0 {
                self.buf.push(byte);
                return;
            }
            self.buf.push(byte | 0x80);
        }
    }

    /// Zig-zag signed varint.
    pub fn put_signed(&mut self, v: i64) {
        self.put_varint(((v << 1) ^ (v >> 63)) as u64);
    }

    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_bool(&mut self, v: bool) {
        self.put_u8(v as u8);
    }

    /// Length-prefixed byte blob.
    pub fn put_bytes(&mut self, v: &[u8]) {
        self.put_varint(v.len() as u64);
        self.buf.extend_from_slice(v);
    }

    /// Length-prefixed UTF-8 string.
    pub fn put_str(&mut self, v: &str) {
        self.put_bytes(v.as_bytes());
    }

    /// Length-prefixed list of usize (shapes etc.).
    pub fn put_usize_list(&mut self, v: &[usize]) {
        self.put_varint(v.len() as u64);
        for &x in v {
            self.put_varint(x as u64);
        }
    }
}

/// Cursor-based wire reader.
pub struct WireReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> WireReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        WireReader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_done(&self) -> bool {
        self.remaining() == 0
    }

    pub fn get_u8(&mut self) -> Result<u8> {
        if self.pos >= self.buf.len() {
            bail!("wire underrun at {}", self.pos);
        }
        let v = self.buf[self.pos];
        self.pos += 1;
        Ok(v)
    }

    pub fn get_varint(&mut self) -> Result<u64> {
        let mut v: u64 = 0;
        let mut shift = 0u32;
        loop {
            let byte = self.get_u8()?;
            if shift >= 64 {
                bail!("varint overflow");
            }
            v |= ((byte & 0x7F) as u64) << shift;
            if byte & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
        }
    }

    pub fn get_signed(&mut self) -> Result<i64> {
        let u = self.get_varint()?;
        Ok(((u >> 1) as i64) ^ -((u & 1) as i64))
    }

    pub fn get_f32(&mut self) -> Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn get_f64(&mut self) -> Result<f64> {
        let b = self.take(8)?;
        Ok(f64::from_le_bytes(b.try_into().unwrap()))
    }

    pub fn get_bool(&mut self) -> Result<bool> {
        Ok(self.get_u8()? != 0)
    }

    pub fn get_bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.get_varint()? as usize;
        self.take(n)
    }

    pub fn get_str(&mut self) -> Result<String> {
        let b = self.get_bytes()?;
        Ok(std::str::from_utf8(b)
            .map_err(|_| anyhow::anyhow!("invalid utf-8 string on wire"))?
            .to_string())
    }

    pub fn get_usize_list(&mut self) -> Result<Vec<usize>> {
        let n = self.get_varint()? as usize;
        if n > self.remaining() {
            bail!("list length {n} exceeds remaining {}", self.remaining());
        }
        (0..n).map(|_| self.get_varint().map(|v| v as usize)).collect()
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!("wire underrun: need {n}, have {}", self.remaining());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn varint_boundaries() {
        for v in [0u64, 1, 127, 128, 300, 16383, 16384, u32::MAX as u64, u64::MAX] {
            let mut w = WireWriter::new();
            w.put_varint(v);
            let bytes = w.into_bytes();
            let mut r = WireReader::new(&bytes);
            assert_eq!(r.get_varint().unwrap(), v);
            assert!(r.is_done());
        }
    }

    #[test]
    fn signed_zigzag_roundtrip() {
        for v in [0i64, 1, -1, 63, -64, i64::MAX, i64::MIN] {
            let mut w = WireWriter::new();
            w.put_signed(v);
            let bytes = w.into_bytes();
            assert_eq!(WireReader::new(&bytes).get_signed().unwrap(), v);
        }
    }

    #[test]
    fn mixed_fields_roundtrip() {
        let mut w = WireWriter::new();
        w.put_str("hello");
        w.put_f32(1.5);
        w.put_f64(-2.25);
        w.put_bool(true);
        w.put_bytes(&[1, 2, 3]);
        w.put_usize_list(&[10, 0, 999]);
        let bytes = w.into_bytes();
        let mut r = WireReader::new(&bytes);
        assert_eq!(r.get_str().unwrap(), "hello");
        assert_eq!(r.get_f32().unwrap(), 1.5);
        assert_eq!(r.get_f64().unwrap(), -2.25);
        assert!(r.get_bool().unwrap());
        assert_eq!(r.get_bytes().unwrap(), &[1, 2, 3]);
        assert_eq!(r.get_usize_list().unwrap(), vec![10, 0, 999]);
        assert!(r.is_done());
    }

    #[test]
    fn underrun_is_an_error_not_a_panic() {
        let mut r = WireReader::new(&[0x80]); // unterminated varint
        assert!(r.get_varint().is_err());
        let mut r = WireReader::new(&[5, 1, 2]); // bytes blob longer than buffer
        assert!(r.get_bytes().is_err());
        let mut r = WireReader::new(&[]);
        assert!(r.get_f32().is_err());
    }

    #[test]
    fn malicious_list_length_rejected() {
        let mut w = WireWriter::new();
        w.put_varint(u64::MAX); // claims a huge list
        let bytes = w.into_bytes();
        let mut r = WireReader::new(&bytes);
        assert!(r.get_usize_list().is_err());
    }

    #[test]
    fn fnv1a64_matches_reference_vectors_and_chunks_freely() {
        // Reference FNV-1a 64 vectors.
        assert_eq!(fnv1a64(FNV64_INIT, b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(FNV64_INIT, b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(FNV64_INIT, b"foobar"), 0x85944171f73967e8);
        // Incremental folding is split-point independent.
        let data = b"the quick brown fox";
        let whole = fnv1a64(FNV64_INIT, data);
        for split in 0..data.len() {
            let part = fnv1a64(fnv1a64(FNV64_INIT, &data[..split]), &data[split..]);
            assert_eq!(part, whole, "split at {split}");
        }
    }

    #[test]
    fn prop_random_field_sequences_roundtrip() {
        prop_check("wire roundtrip", 100, |g| {
            let blob = g.bytes(0..300);
            let s_len = g.usize_in(0..20);
            let s: String = (0..s_len).map(|_| 'x').collect();
            let v = g.rng().next_u64();
            let mut w = WireWriter::new();
            w.put_varint(v);
            w.put_bytes(&blob);
            w.put_str(&s);
            let bytes = w.into_bytes();
            let mut r = WireReader::new(&bytes);
            assert_eq!(r.get_varint().unwrap(), v);
            assert_eq!(r.get_bytes().unwrap(), &blob[..]);
            assert_eq!(r.get_str().unwrap(), s);
            assert!(r.is_done());
        });
    }
}
