//! Typed control-plane stubs + data-plane streaming sender.
//!
//! The raw transport ([`crate::net::ClientConn`]) moves opaque
//! [`Message`]s; everything above it used to hand-roll `match msg`
//! blocks and stringly errors. This module is the typed facade:
//!
//! * [`ControllerClient`] / [`LearnerClient`] — one method per RPC,
//!   returning domain values or a structured [`RpcError`]. Both open
//!   their session with the versioned [`hello`] handshake, which also
//!   negotiates the wire codec set ([`hello_negotiate`] /
//!   [`SUPPORTED_CODECS`]).
//! * [`stream_model_send`] — the data-plane sender: walks a model
//!   tensor by tensor through a [`StreamSend`]'s codec and ships it as
//!   `ModelStreamBegin` → `ModelChunk`* → `ModelStreamEnd`. Sender-side
//!   peak extra memory is one encoded tensor plus one chunk, regardless
//!   of model size. Delta sends fall back to full f32 when the receiver
//!   lacks the base ([`stream_model_with_fallback`]).
//! * Reply interpreters ([`ack_of`], [`eval_reply_of`]) shared with the
//!   schedulers' broadcast paths, which keep the encode-once
//!   `send_raw` fan-out but no longer parse replies by hand.
//!
//! Free functions take `&mut dyn ClientConn` so components that own a
//! long-lived connection (the learner's completion-callback channel, a
//! `LearnerHandle`) can borrow it to the stub layer without giving up
//! ownership.

use super::wire::{fnv1a64, FNV64_INIT};
use super::{
    ErrorCode, EvalResult, HealthProbe, Message, ModelProto, StreamPurpose, TaskMeta, TaskSpec,
    TensorLayoutProto, PROTO_VERSION,
};
use crate::net::{ClientConn, Psk};
use crate::tensor::{CodecId, TensorModel};
use std::sync::atomic::{AtomicU64, Ordering};

/// Wire codecs this build offers in the `Hello` handshake, in `auto`
/// preference order (see [`crate::tensor::codec`]).
pub const SUPPORTED_CODECS: [CodecId; 4] = CodecId::ALL;

/// Default data-plane chunk size (256 KiB): large enough to amortize
/// per-chunk framing/ack overhead, small enough that in-flight receive
/// memory stays negligible next to any model worth streaming.
pub const DEFAULT_CHUNK_BYTES: usize = 256 * 1024;

/// Smallest permitted chunk (guards against pathological 1-byte chunk
/// configs turning one model into millions of RPCs).
pub const MIN_CHUNK_BYTES: usize = 1024;

/// Typed RPC failure taxonomy.
#[derive(Debug)]
pub enum RpcError {
    /// Transport-level failure: connect, send, recv, or codec. The
    /// connection is suspect — callers should drop and re-dial.
    Transport(anyhow::Error),
    /// The peer replied with a structured [`Message::Error`]. The
    /// connection itself is healthy.
    Remote { code: ErrorCode, detail: String },
    /// The peer replied with a well-formed message of the wrong kind.
    Unexpected { expected: &'static str, got: String },
}

impl RpcError {
    /// The remote error code, when this is a remote failure.
    pub fn remote_code(&self) -> Option<ErrorCode> {
        match self {
            RpcError::Remote { code, .. } => Some(*code),
            _ => None,
        }
    }

    /// Should the caller tear down and re-establish the connection?
    pub fn is_transport(&self) -> bool {
        matches!(self, RpcError::Transport(_))
    }
}

impl std::fmt::Display for RpcError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RpcError::Transport(e) => write!(f, "transport error: {e:#}"),
            RpcError::Remote { code, detail } => write!(f, "remote error [{code}]: {detail}"),
            RpcError::Unexpected { expected, got } => {
                write!(f, "unexpected reply: wanted {expected}, got {got}")
            }
        }
    }
}

impl std::error::Error for RpcError {}

impl From<anyhow::Error> for RpcError {
    fn from(e: anyhow::Error) -> Self {
        RpcError::Transport(e)
    }
}

pub type RpcResult<T> = Result<T, RpcError>;

/// One blocking RPC; `Error` replies surface as [`RpcError::Remote`].
pub fn rpc(conn: &mut dyn ClientConn, msg: &Message) -> RpcResult<Message> {
    match conn.rpc(msg) {
        Ok(Message::Error { code, detail }) => Err(RpcError::Remote { code, detail }),
        Ok(reply) => Ok(reply),
        Err(e) => Err(RpcError::Transport(e)),
    }
}

/// Interpret any reply as a positive `Ack`, returning its task id.
pub fn ack_of(reply: &Message) -> RpcResult<u64> {
    match reply {
        Message::Ack { task_id, ok: true } => Ok(*task_id),
        Message::Ack { task_id, ok: false } => Err(RpcError::Remote {
            code: ErrorCode::Rejected,
            detail: format!("task {task_id} refused"),
        }),
        Message::Error { code, detail } => {
            Err(RpcError::Remote { code: *code, detail: detail.clone() })
        }
        other => Err(RpcError::Unexpected { expected: "Ack", got: other.kind().to_string() }),
    }
}

/// Interpret a reply as an `EvaluateModelReply`.
pub fn eval_reply_of(reply: &Message) -> RpcResult<(&str, &EvalResult)> {
    match reply {
        Message::EvaluateModelReply { learner_id, result, .. } => {
            Ok((learner_id.as_str(), result))
        }
        Message::Error { code, detail } => {
            Err(RpcError::Remote { code: *code, detail: detail.clone() })
        }
        other => Err(RpcError::Unexpected {
            expected: "EvaluateModelReply",
            got: other.kind().to_string(),
        }),
    }
}

fn expect_ack(reply: Message) -> RpcResult<u64> {
    ack_of(&reply)
}

/// Versioned session opener: announce [`PROTO_VERSION`] and our codec
/// set, return the peer's version. Mismatches come back as
/// `RpcError::Remote { code: VersionMismatch, .. }` from the peer.
pub fn hello(conn: &mut dyn ClientConn) -> RpcResult<u32> {
    hello_negotiate(conn).map(|(v, _)| v)
}

/// [`hello`] that also returns the codec set the peer accepted (the
/// intersection of [`SUPPORTED_CODECS`] with the peer's own set).
pub fn hello_negotiate(conn: &mut dyn ClientConn) -> RpcResult<(u32, Vec<CodecId>)> {
    let msg = Message::Hello {
        proto_version: PROTO_VERSION,
        codecs: SUPPORTED_CODECS.to_vec(),
    };
    match rpc(conn, &msg)? {
        Message::HelloAck { proto_version, codecs, .. } => Ok((proto_version, codecs)),
        other => Err(RpcError::Unexpected { expected: "HelloAck", got: other.kind().to_string() }),
    }
}

/// Liveness probe; returns `(component, healthy)`.
pub fn heartbeat(conn: &mut dyn ClientConn, from: &str) -> RpcResult<(String, bool)> {
    heartbeat_probe(conn, from).map(|(component, healthy, _)| (component, healthy))
}

/// [`heartbeat`] that also returns the component's [`HealthProbe`]
/// payload (zeros when the peer predates it), for probers that feed a
/// failure detector.
pub fn heartbeat_probe(
    conn: &mut dyn ClientConn,
    from: &str,
) -> RpcResult<(String, bool, HealthProbe)> {
    match rpc(conn, &Message::Heartbeat { from: from.to_string() })? {
        Message::HeartbeatAck { component, healthy, health } => Ok((component, healthy, health)),
        other => Err(RpcError::Unexpected {
            expected: "HeartbeatAck",
            got: other.kind().to_string(),
        }),
    }
}

/// Orderly shutdown request.
pub fn shutdown(conn: &mut dyn ClientConn) -> RpcResult<()> {
    expect_ack(rpc(conn, &Message::Shutdown)?)?;
    Ok(())
}

/// Learner → controller registration; returns the assigned index.
pub fn register(
    conn: &mut dyn ClientConn,
    learner_id: &str,
    endpoint: &str,
    num_samples: usize,
) -> RpcResult<usize> {
    let msg = Message::Register {
        learner_id: learner_id.to_string(),
        host: endpoint.to_string(),
        port: 0,
        num_samples,
    };
    match rpc(conn, &msg)? {
        Message::RegisterAck { accepted: true, assigned_index } => Ok(assigned_index),
        Message::RegisterAck { accepted: false, .. } => Err(RpcError::Remote {
            code: ErrorCode::Rejected,
            detail: "registration rejected".into(),
        }),
        other => Err(RpcError::Unexpected {
            expected: "RegisterAck",
            got: other.kind().to_string(),
        }),
    }
}

/// Graceful departure: drop the learner's registration and every
/// per-learner map the controller keeps (pacing profile, pinned delta
/// base, participation history).
pub fn deregister(conn: &mut dyn ClientConn, learner_id: &str) -> RpcResult<()> {
    expect_ack(rpc(conn, &Message::Deregister { learner_id: learner_id.to_string() })?)?;
    Ok(())
}

/// One-shot completion callback (small models / compatibility path).
pub fn mark_task_completed(
    conn: &mut dyn ClientConn,
    task_id: u64,
    learner_id: &str,
    model: ModelProto,
    meta: TaskMeta,
) -> RpcResult<()> {
    let msg = Message::MarkTaskCompleted {
        task_id,
        learner_id: learner_id.to_string(),
        model,
        meta,
    };
    expect_ack(rpc(conn, &msg)?)?;
    Ok(())
}

/// Process-unique stream id: a per-process random-ish salt (boot time)
/// plus an odd-multiplier counter walk, so concurrent senders — in this
/// process or another — practically never collide at the receiver.
pub fn next_stream_id() -> u64 {
    static SALT: once_cell::sync::Lazy<u64> = once_cell::sync::Lazy::new(|| {
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0x5EED)
            ^ (std::process::id() as u64).rotate_left(32)
    });
    static CTR: AtomicU64 = AtomicU64::new(1);
    SALT.wrapping_add(CTR.fetch_add(1, Ordering::Relaxed).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Everything one data-plane stream send needs: routing, payload,
/// codec, and the delta base (when the codec requires one).
#[derive(Clone)]
pub struct StreamSend<'a> {
    pub purpose: StreamPurpose,
    pub task_id: u64,
    /// Purpose-dependent round field: scheduler round for uploads,
    /// community round of the carried model for dispatch streams (the
    /// identity the receiver records as its future delta base).
    pub round: u64,
    pub learner_id: &'a str,
    pub model: &'a TensorModel,
    pub meta: &'a TaskMeta,
    /// Training hyperparameters for `RunTask` dispatch streams
    /// (default for every other purpose).
    pub spec: &'a TaskSpec,
    pub codec: CodecId,
    /// The shared base model for delta encoding; must be `Some` with a
    /// matching layout when `codec.needs_base()`.
    pub base: Option<&'a TensorModel>,
    /// Identity (community round) of `base`.
    pub base_round: u64,
    pub chunk_bytes: usize,
}

impl<'a> StreamSend<'a> {
    /// An f32 (no-base) send — the compatibility path every purpose can
    /// fall back to.
    #[allow(clippy::too_many_arguments)]
    pub fn f32(
        purpose: StreamPurpose,
        task_id: u64,
        round: u64,
        learner_id: &'a str,
        model: &'a TensorModel,
        meta: &'a TaskMeta,
        spec: &'a TaskSpec,
        chunk_bytes: usize,
    ) -> StreamSend<'a> {
        StreamSend {
            purpose,
            task_id,
            round,
            learner_id,
            model,
            meta,
            spec,
            codec: CodecId::F32,
            base: None,
            base_round: 0,
            chunk_bytes,
        }
    }
}

/// Stream one model over the data plane: `Begin` (layout + codec +
/// routing + metadata) → element-ordered `Chunk`s → `End` (running
/// FNV-1a digest). Returns the peer's `End` reply (an `Ack`, or the
/// in-call reply for [`StreamPurpose::Evaluate`] streams).
///
/// Tensors are encoded one at a time through the send's codec and
/// sliced into `chunk_bytes` chunks (clamped to [`MIN_CHUNK_BYTES`]),
/// so the sender never holds a whole-model wire buffer. Each step is a
/// request/response RPC on `conn`, which keeps the data plane working
/// over every transport (tcp, secure, inproc) with strict send/recv
/// pairing.
pub fn stream_model_send(conn: &mut dyn ClientConn, send: &StreamSend<'_>) -> RpcResult<Message> {
    let send = StreamSend { chunk_bytes: send.chunk_bytes.max(MIN_CHUNK_BYTES), ..send.clone() };
    stream_model_with(&mut |msg| rpc(&mut *conn, &msg), &send)
}

/// Compatibility wrapper: f32 send with an `Ack`-only `End` reply.
#[allow(clippy::too_many_arguments)]
pub fn stream_model(
    conn: &mut dyn ClientConn,
    purpose: StreamPurpose,
    task_id: u64,
    round: u64,
    learner_id: &str,
    model: &TensorModel,
    meta: &TaskMeta,
    chunk_bytes: usize,
) -> RpcResult<()> {
    let spec = TaskSpec::default();
    let send =
        StreamSend::f32(purpose, task_id, round, learner_id, model, meta, &spec, chunk_bytes);
    ack_of(&stream_model_send(conn, &send)?)?;
    Ok(())
}

/// The data-plane send walk itself — `Begin` → `Chunk`s → `End` with
/// the running digest — shared by [`stream_model_send`], the controller
/// dispatch fallback path, and the tests that must mirror the real
/// sender byte for byte (including adversarial sub-minimum chunk sizes,
/// which is why this layer does NOT clamp). `rpc_fn` delivers one
/// request and returns the peer's reply; the final `End` reply is
/// returned with remote `Error`s surfaced as [`RpcError::Remote`].
#[doc(hidden)]
pub fn stream_model_with<F>(rpc_fn: &mut F, send: &StreamSend<'_>) -> RpcResult<Message>
where
    F: FnMut(Message) -> RpcResult<Message>,
{
    let chunk_bytes = send.chunk_bytes.max(1);
    let codec = send.codec.codec();
    let base = if send.codec.needs_base() {
        let base = send.base.ok_or_else(|| {
            RpcError::Transport(anyhow::anyhow!("{} codec requires a base model", send.codec))
        })?;
        let aligned = base.tensors.len() == send.model.tensors.len()
            && base
                .tensors
                .iter()
                .zip(&send.model.tensors)
                .all(|(b, m)| b.elem_count() == m.elem_count());
        if !aligned {
            return Err(RpcError::Transport(anyhow::anyhow!(
                "delta base layout does not match the model being sent"
            )));
        }
        Some(base)
    } else {
        None
    };
    let stream_id = next_stream_id();
    let begin = Message::ModelStreamBegin {
        stream_id,
        task_id: send.task_id,
        round: send.round,
        purpose: send.purpose,
        learner_id: send.learner_id.to_string(),
        codec: send.codec,
        base_round: send.base_round,
        layout: TensorLayoutProto::codec_layout_of(send.model, send.codec),
        meta: send.meta.clone(),
        spec: send.spec.clone(),
    };
    expect_ack(rpc_fn(begin)?)?;
    let mut seq = 0u64;
    let mut digest = FNV64_INIT;
    if codec.is_framed() {
        // Framed codecs (delta-rle): one self-delimiting compressed
        // frame per chunk, each covering a whole element block within a
        // single tensor — the receiver decompresses every chunk
        // independently, overlapped with the next chunk's transfer.
        // The controller's pipelined fan-out
        // (`Controller::stream_broadcast`) mirrors this walk (same
        // block formula, same digest fold) — keep the two in lockstep.
        let block = (chunk_bytes / 4).max(1);
        for (i, t) in send.model.tensors.iter().enumerate() {
            let mut lo = 0usize;
            while lo < t.data.len() {
                let hi = (lo + block).min(t.data.len());
                let mut frame = Vec::with_capacity((hi - lo) + 16);
                codec.encode_frame_into(
                    &t.data[lo..hi],
                    base.map(|b| &b.tensors[i].data[lo..hi]),
                    &mut frame,
                );
                digest = fnv1a64(digest, &frame);
                expect_ack(rpc_fn(Message::ModelChunk { stream_id, seq, bytes: frame })?)?;
                seq += 1;
                lo = hi;
            }
        }
    } else {
        for (i, t) in send.model.tensors.iter().enumerate() {
            let bytes = codec.encode(&t.data, base.map(|b| &b.tensors[i].data[..]));
            for part in bytes.chunks(chunk_bytes) {
                digest = fnv1a64(digest, part);
                expect_ack(rpc_fn(Message::ModelChunk { stream_id, seq, bytes: part.to_vec() })?)?;
                seq += 1;
            }
        }
    }
    match rpc_fn(Message::ModelStreamEnd { stream_id, digest })? {
        Message::Error { code, detail } => Err(RpcError::Remote { code, detail }),
        reply => Ok(reply),
    }
}

/// [`stream_model_with`] that retries once with the full f32 codec when
/// a base-needing codec is refused with `NotFound` (the receiver does
/// not hold the announced base — new peer, stale round, async skew).
#[doc(hidden)]
pub fn stream_model_with_fallback<F>(rpc_fn: &mut F, send: &StreamSend<'_>) -> RpcResult<Message>
where
    F: FnMut(Message) -> RpcResult<Message>,
{
    stream_model_with_fallback_counted(rpc_fn, send).map(|(reply, _)| reply)
}

/// [`stream_model_with_fallback`] that also reports whether the f32
/// fallback path fired, so callers can tick the degradation counter
/// (`FederationReport::fallback_sends`) without re-deriving it from the
/// error flow.
#[doc(hidden)]
pub fn stream_model_with_fallback_counted<F>(
    rpc_fn: &mut F,
    send: &StreamSend<'_>,
) -> RpcResult<(Message, bool)>
where
    F: FnMut(Message) -> RpcResult<Message>,
{
    match stream_model_with(rpc_fn, send) {
        Err(RpcError::Remote { code: ErrorCode::NotFound, .. }) if send.codec.needs_base() => {
            let full =
                StreamSend { codec: CodecId::F32, base: None, base_round: 0, ..send.clone() };
            stream_model_with(rpc_fn, &full).map(|reply| (reply, true))
        }
        other => other.map(|reply| (reply, false)),
    }
}

/// Typed stub for driver/learner → controller RPCs.
pub struct ControllerClient {
    conn: Box<dyn ClientConn>,
    /// Protocol version the controller reported in the handshake.
    pub peer_version: u32,
    /// Codec set the controller accepted in the handshake.
    pub peer_codecs: Vec<CodecId>,
}

impl ControllerClient {
    /// Dial and perform the versioned handshake.
    pub fn connect(endpoint: &str, psk: Psk) -> RpcResult<ControllerClient> {
        Self::from_conn(crate::net::connect(endpoint, psk).map_err(RpcError::Transport)?)
    }

    /// Wrap an existing connection, performing the handshake on it.
    pub fn from_conn(mut conn: Box<dyn ClientConn>) -> RpcResult<ControllerClient> {
        let (peer_version, peer_codecs) = hello_negotiate(conn.as_mut())?;
        Ok(ControllerClient { conn, peer_version, peer_codecs })
    }

    pub fn register(
        &mut self,
        learner_id: &str,
        endpoint: &str,
        num_samples: usize,
    ) -> RpcResult<usize> {
        register(self.conn.as_mut(), learner_id, endpoint, num_samples)
    }

    /// Graceful learner departure.
    pub fn deregister(&mut self, learner_id: &str) -> RpcResult<()> {
        deregister(self.conn.as_mut(), learner_id)
    }

    /// One-shot community-model initialization.
    pub fn ship_model(&mut self, model: ModelProto) -> RpcResult<()> {
        expect_ack(rpc(self.conn.as_mut(), &Message::ShipModel { model })?)?;
        Ok(())
    }

    /// Streamed community-model initialization (large models).
    pub fn ship_model_streamed(&mut self, model: &TensorModel, chunk_bytes: usize) -> RpcResult<()> {
        stream_model(
            self.conn.as_mut(),
            StreamPurpose::ShipModel,
            0,
            0,
            "",
            model,
            &TaskMeta::default(),
            chunk_bytes,
        )
    }

    pub fn mark_task_completed(
        &mut self,
        task_id: u64,
        learner_id: &str,
        model: ModelProto,
        meta: TaskMeta,
    ) -> RpcResult<()> {
        mark_task_completed(self.conn.as_mut(), task_id, learner_id, model, meta)
    }

    /// Streamed completion callback (large models).
    #[allow(clippy::too_many_arguments)]
    pub fn mark_task_completed_streamed(
        &mut self,
        task_id: u64,
        round: u64,
        learner_id: &str,
        model: &TensorModel,
        meta: &TaskMeta,
        chunk_bytes: usize,
    ) -> RpcResult<()> {
        stream_model(
            self.conn.as_mut(),
            StreamPurpose::TaskCompletion,
            task_id,
            round,
            learner_id,
            model,
            meta,
            chunk_bytes,
        )
    }

    /// Fetch the current community model and its round.
    pub fn get_model(&mut self) -> RpcResult<(ModelProto, u64)> {
        match rpc(self.conn.as_mut(), &Message::GetModel)? {
            Message::ModelReply { model, round } => Ok((model, round)),
            other => Err(RpcError::Unexpected {
                expected: "ModelReply",
                got: other.kind().to_string(),
            }),
        }
    }

    pub fn heartbeat(&mut self, from: &str) -> RpcResult<(String, bool)> {
        heartbeat(self.conn.as_mut(), from)
    }

    pub fn shutdown(&mut self) -> RpcResult<()> {
        shutdown(self.conn.as_mut())
    }

    /// Surrender the underlying connection.
    pub fn into_inner(self) -> Box<dyn ClientConn> {
        self.conn
    }
}

/// Typed stub for controller/driver → learner RPCs.
pub struct LearnerClient {
    conn: Box<dyn ClientConn>,
    pub peer_version: u32,
    pub peer_codecs: Vec<CodecId>,
}

impl LearnerClient {
    pub fn connect(endpoint: &str, psk: Psk) -> RpcResult<LearnerClient> {
        Self::from_conn(crate::net::connect(endpoint, psk).map_err(RpcError::Transport)?)
    }

    pub fn from_conn(mut conn: Box<dyn ClientConn>) -> RpcResult<LearnerClient> {
        let (peer_version, peer_codecs) = hello_negotiate(conn.as_mut())?;
        Ok(LearnerClient { conn, peer_version, peer_codecs })
    }

    /// Fire-and-forget train dispatch; Ok(()) once the learner acked.
    pub fn run_task(
        &mut self,
        task_id: u64,
        round: u64,
        model: ModelProto,
        spec: TaskSpec,
    ) -> RpcResult<()> {
        let msg = Message::RunTask { task_id, round, model, spec };
        expect_ack(rpc(self.conn.as_mut(), &msg)?)?;
        Ok(())
    }

    /// Synchronous evaluation call.
    pub fn evaluate(
        &mut self,
        task_id: u64,
        round: u64,
        model: ModelProto,
    ) -> RpcResult<EvalResult> {
        let msg = Message::EvaluateModel { task_id, round, model };
        let reply = rpc(self.conn.as_mut(), &msg)?;
        eval_reply_of(&reply).map(|(_, r)| r.clone())
    }

    pub fn heartbeat(&mut self, from: &str) -> RpcResult<(String, bool)> {
        heartbeat(self.conn.as_mut(), from)
    }

    pub fn shutdown(&mut self) -> RpcResult<()> {
        shutdown(self.conn.as_mut())
    }

    pub fn into_inner(self) -> Box<dyn ClientConn> {
        self.conn
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{serve, Service};
    use std::sync::Arc;

    /// Minimal control-plane peer: handshake + heartbeat + ack.
    struct Peer;
    impl Service for Peer {
        fn handle(&self, msg: Message) -> Message {
            match msg {
                Message::Hello { proto_version, codecs } if proto_version == PROTO_VERSION => {
                    Message::HelloAck {
                        proto_version: PROTO_VERSION,
                        component: "peer".into(),
                        codecs: crate::tensor::codec::negotiate(&codecs, &SUPPORTED_CODECS),
                    }
                }
                Message::Hello { proto_version, .. } => Message::error(
                    ErrorCode::VersionMismatch,
                    format!("we speak v{PROTO_VERSION}, peer v{proto_version}"),
                ),
                Message::Heartbeat { from } => Message::HeartbeatAck {
                    component: from,
                    healthy: true,
                    health: HealthProbe::default(),
                },
                Message::Shutdown => Message::Ack { task_id: 0, ok: true },
                other => Message::error(ErrorCode::Unsupported, other.kind()),
            }
        }
    }

    #[test]
    fn stub_handshake_and_typed_calls() {
        let server = serve("inproc://client-stub-test", Arc::new(Peer), None).unwrap();
        let mut c = ControllerClient::connect(&server.endpoint(), None).unwrap();
        assert_eq!(c.peer_version, PROTO_VERSION);
        assert_eq!(c.peer_codecs, SUPPORTED_CODECS.to_vec());
        let (component, healthy) = c.heartbeat("t").unwrap();
        assert_eq!(component, "t");
        assert!(healthy);
        c.shutdown().unwrap();
    }

    #[test]
    fn remote_errors_carry_codes() {
        let server = serve("inproc://client-err-test", Arc::new(Peer), None).unwrap();
        let mut conn = crate::net::connect(&server.endpoint(), None).unwrap();
        // Peer answers GetModel with Unsupported — the stub surfaces it
        // as a typed remote error, not a string.
        let err = rpc(conn.as_mut(), &Message::GetModel).unwrap_err();
        assert_eq!(err.remote_code(), Some(ErrorCode::Unsupported));
        assert!(!err.is_transport());
        drop(server);
    }

    #[test]
    fn ack_interpreters_cover_the_reply_space() {
        assert_eq!(ack_of(&Message::Ack { task_id: 9, ok: true }).unwrap(), 9);
        let e = ack_of(&Message::Ack { task_id: 9, ok: false }).unwrap_err();
        assert_eq!(e.remote_code(), Some(ErrorCode::Rejected));
        let e = ack_of(&Message::error(ErrorCode::Unavailable, "down")).unwrap_err();
        assert_eq!(e.remote_code(), Some(ErrorCode::Unavailable));
        let e = ack_of(&Message::GetModel).unwrap_err();
        assert!(matches!(e, RpcError::Unexpected { expected: "Ack", .. }));
    }

    #[test]
    fn stream_ids_are_unique_under_concurrency() {
        let mut joins = Vec::new();
        for _ in 0..4 {
            joins.push(std::thread::spawn(|| {
                (0..256).map(|_| next_stream_id()).collect::<Vec<u64>>()
            }));
        }
        let mut all: Vec<u64> = joins.into_iter().flat_map(|j| j.join().unwrap()).collect();
        let n = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n, "stream id collision");
    }
}
