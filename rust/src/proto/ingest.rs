//! Inbound data-plane stream engine, shared by the controller (model
//! uploads) and the learner (streamed dispatch) — the receiving half of
//! the symmetric data plane.
//!
//! A [`StreamIngest`] owns the registry of in-flight inbound streams.
//! Chunks decode **on arrival**, directly into pre-sized per-tensor f32
//! buffers drawn from an optional [`BufferPool`] — the receiver never
//! materializes a whole-model wire buffer, and receive overlaps decode.
//! Framed codecs (delta-rle) go one stage further: the connection
//! handler validates + digests a chunk and acks immediately, while a
//! small deferred-decode worker pool decompresses it — decode of chunk
//! N overlaps chunk N+1's encode and wire transfer (the receive half
//! of the data plane's double-buffered pipeline). Pending frames live
//! in per-stream FIFO queues served round-robin by every worker (work
//! conservation: a burst of hot framed uploads spreads across the whole
//! pool instead of hashing onto one worker while others idle).
//! Decode failures surface as typed `StreamProtocol` errors on the
//! next chunk or at `End`.
//! The component embedding the ingest decides what a finished stream
//! *means* (store a contribution, install a community model, start a
//! training task, run an evaluation) via the [`FinishedStream`] returned
//! by [`StreamIngest::end`].
//!
//! Hostile-peer hardening (admission control before any buffer
//! allocation, per-stream and aggregate announced-byte budgets, idle
//! GC, the dead-flag chunk-race guard) lives here once instead of per
//! component. Time is injected through the crate-wide
//! [`Clock`](crate::util::Clock) handle, so the idle-GC timeout path is
//! deterministic under test and in simulated runs; degradation counters
//! live in the embedding component's
//! [`CounterRegistry`](crate::metrics::CounterRegistry).

use super::{ErrorCode, Message, StreamPurpose, TaskMeta, TaskSpec, TensorLayoutProto};
use crate::metrics::counters::{names, Counter, CounterRegistry};
use crate::proto::wire::{fnv1a64, FNV64_INIT};
use crate::tensor::{ByteOrder, CodecId, DType, Tensor, TensorModel};
use crate::util::clock::Timestamp;
use crate::util::{log_debug, Clock};
use anyhow::{bail, Result};
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Source of decode buffers: the controller plugs its aggregation
/// [`ScratchArena`](crate::controller::aggregation::ScratchArena) in, so
/// a steady-state streamed round re-fills the buffers the previous
/// community model (and the store's evicted contributions) vacated.
pub trait BufferPool: Send + Sync {
    /// Check out a zero-extended buffer of exactly `len` elements.
    fn take(&self, len: usize) -> Vec<f32>;
    /// Hand a buffer back for reuse.
    fn recycle(&self, buf: Vec<f32>);
}

/// Wire-payload gauge + byte totals, shared between the ingest front
/// end (connection handlers) and the deferred-decode worker. The byte
/// totals are registry [`Counter`]s, so `FederationReport` and the
/// trace recorder read them through the same snapshot as every other
/// degradation counter.
struct WireStats {
    /// Wire-payload bytes currently held for model ingest (one-shot
    /// protos being decoded + stream chunks in flight or queued for the
    /// decode worker), plus the high-water mark.
    in_flight: AtomicUsize,
    peak: AtomicUsize,
    /// Total data-plane payload bytes received over streams (wire form,
    /// i.e. compressed for framed codecs, half-size for bf16).
    recv_wire: Counter,
    /// f32-equivalent bytes those stream payloads decoded into — the
    /// raw volume the wire codec avoided moving.
    recv_raw: Counter,
}

impl WireStats {
    fn new(counters: &CounterRegistry) -> WireStats {
        WireStats {
            in_flight: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
            recv_wire: counters.counter(names::WIRE_BYTES_IN),
            recv_raw: counters.counter(names::WIRE_BYTES_RAW),
        }
    }

    fn hold(&self, bytes: usize) {
        let now = self.in_flight.fetch_add(bytes, Ordering::SeqCst) + bytes;
        self.peak.fetch_max(now, Ordering::SeqCst);
    }

    fn release(&self, bytes: usize) {
        self.in_flight.fetch_sub(bytes, Ordering::SeqCst);
    }

    fn note_recv(&self, wire: usize, raw_equiv: usize) {
        self.recv_wire.add(wire as u64);
        self.recv_raw.add(raw_equiv as u64);
    }
}

/// Destination span reserved for one framed chunk — fixed under the
/// same stream lock that validated its `seq`, so frames land at the
/// right offsets no matter what order the decode worker receives them
/// in (two handlers racing between lock release and channel enqueue
/// must not be able to transpose blocks).
struct FrameSpan {
    tensor: usize,
    lo: usize,
    elems: usize,
}

/// One frame awaiting deferred decode (framed streams only).
struct PendingFrame {
    stream: Arc<Mutex<ModelStream>>,
    bytes: Vec<u8>,
    span: FrameSpan,
}

/// Shared state of the deferred-decode pool: per-stream FIFO queues of
/// pending frames plus a round-robin service order. Every worker pulls
/// from the front stream and rotates it to the back, so a burst of hot
/// framed uploads spreads across the whole pool (work conservation)
/// while each stream's own frames stay FIFO-queued. Frames *may*
/// decode out of order or concurrently — their destination spans were
/// fixed at seq validation, so arrival order at a worker is irrelevant.
struct DecodeQueues {
    /// Streams with pending frames, service order. Invariant: a stream
    /// id appears here exactly once iff it has an entry in `jobs`.
    order: VecDeque<u64>,
    jobs: HashMap<u64, VecDeque<PendingFrame>>,
    /// Frames currently being decoded, per stream (flush barrier).
    active: HashMap<u64, usize>,
    /// Total queued frames (backpressure against `DecodePool::cap`).
    queued: usize,
    shutdown: bool,
}

/// The deferred-decode worker pool's shared half (workers hold an
/// `Arc`; the [`StreamIngest`] keeps the join handles).
struct DecodePool {
    m: Mutex<DecodeQueues>,
    /// Signals workers: a frame was queued (or shutdown).
    work: Condvar,
    /// Signals flushers: a stream's last pending/active frame finished.
    done: Condvar,
    /// Signals enqueuers: queue depth dropped below `cap`.
    space: Condvar,
    /// Max frames queued across all streams — the pool-wide double
    /// buffer that bounds receiver memory and provides the chunk-ack
    /// backpressure a slow decode is supposed to exert.
    cap: usize,
}

impl DecodePool {
    fn new(cap: usize) -> DecodePool {
        DecodePool {
            m: Mutex::new(DecodeQueues {
                order: VecDeque::new(),
                jobs: HashMap::new(),
                active: HashMap::new(),
                queued: 0,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
            space: Condvar::new(),
            cap,
        }
    }

    /// Queue one frame for `stream_id`, blocking while the pool is at
    /// capacity (the backpressure that stalls the sender's next chunk
    /// ack). Returns false if the pool is shutting down (the frame was
    /// not queued).
    fn enqueue(&self, stream_id: u64, frame: PendingFrame) -> bool {
        let mut g = self.m.lock().unwrap();
        while g.queued >= self.cap && !g.shutdown {
            g = self.space.wait(g).unwrap();
        }
        if g.shutdown {
            return false;
        }
        let q = g.jobs.entry(stream_id).or_default();
        let newly = q.is_empty();
        q.push_back(frame);
        g.queued += 1;
        if newly {
            g.order.push_back(stream_id);
        }
        drop(g);
        self.work.notify_one();
        true
    }

    /// Drop every *queued* frame for `stream_id` (kill path), releasing
    /// its wire-gauge bytes. Frames already mid-decode finish against
    /// the dead flag.
    fn prune(&self, stream_id: u64, stats: &WireStats) {
        let mut g = self.m.lock().unwrap();
        if let Some(q) = g.jobs.remove(&stream_id) {
            g.queued -= q.len();
            g.order.retain(|id| *id != stream_id);
            for f in q {
                stats.release(f.bytes.len());
            }
            drop(g);
            self.space.notify_all();
            self.done.notify_all();
        }
    }

    /// Wait until `stream_id` has no queued or in-flight frames (every
    /// failure it will ever defer has landed) — End's barrier before
    /// the completeness/digest verdict. Unlike a pool-wide barrier,
    /// this never waits on *other* streams' backlogs.
    fn flush_stream(&self, stream_id: u64) {
        let mut g = self.m.lock().unwrap();
        while !g.shutdown
            && (g.jobs.contains_key(&stream_id) || g.active.contains_key(&stream_id))
        {
            g = self.done.wait(g).unwrap();
        }
    }

    fn worker_loop(self: &Arc<Self>, stats: &WireStats, clock: &Clock) {
        loop {
            let (id, frame) = {
                let mut g = self.m.lock().unwrap();
                loop {
                    if g.shutdown {
                        return;
                    }
                    if let Some(id) = g.order.pop_front() {
                        let q = g.jobs.get_mut(&id).expect("queued stream has jobs");
                        let frame = q.pop_front().expect("queued stream has a frame");
                        if q.is_empty() {
                            g.jobs.remove(&id);
                        } else {
                            // Rotate: the next worker serves the next
                            // stream before this one's next frame.
                            g.order.push_back(id);
                        }
                        g.queued -= 1;
                        *g.active.entry(id).or_insert(0) += 1;
                        self.space.notify_all();
                        break (id, frame);
                    }
                    g = self.work.wait(g).unwrap();
                }
            };
            {
                // Busy for the decode: simulated time must not jump
                // past a deadline while a completion's frames are
                // still decompressing.
                let _busy = clock.busy();
                let mut s = frame.stream.lock().unwrap();
                if !s.dead && s.deferred.is_none() {
                    if let Err(e) = s.decode_reserved(&frame.span, &frame.bytes) {
                        s.deferred = Some(e);
                    }
                }
            }
            stats.release(frame.bytes.len());
            let mut g = self.m.lock().unwrap();
            let a = g.active.get_mut(&id).expect("active entry");
            *a -= 1;
            if *a == 0 {
                g.active.remove(&id);
            }
            drop(g);
            self.done.notify_all();
        }
    }

    fn shutdown(&self) {
        self.m.lock().unwrap().shutdown = true;
        self.work.notify_all();
        self.space.notify_all();
        self.done.notify_all();
    }
}

/// Caps on the inbound data plane, so a buggy or hostile peer cannot
/// grow receiver memory without bound: concurrent open streams, the
/// wire payload one stream may announce, the *aggregate* wire payload
/// announced across all open streams (decoded f32 buffers can be up to
/// 2× the wire size for bf16 payloads), how long an idle stream may
/// sit before being reclaimed (a peer that dies between `Begin` and
/// `End` must not pin its buffers — or a registry slot — forever), and
/// how long a stream may live in *total*. The lifetime cap closes the
/// slow-loris hole: a peer trickling one chunk per idle interval keeps
/// `last_activity` forever fresh, so idle GC alone would let it pin its
/// admission budget indefinitely.
#[derive(Debug, Clone)]
pub struct IngestLimits {
    pub max_open_streams: usize,
    pub max_stream_bytes: usize,
    pub max_total_stream_bytes: usize,
    pub idle_timeout: Duration,
    pub max_stream_lifetime: Duration,
}

impl Default for IngestLimits {
    fn default() -> IngestLimits {
        IngestLimits {
            max_open_streams: 256,
            max_stream_bytes: 1 << 30,       // 1 GiB wire payload per stream
            max_total_stream_bytes: 4 << 30, // 4 GiB announced across streams
            idle_timeout: Duration::from_secs(300),
            max_stream_lifetime: Duration::from_secs(900),
        }
    }
}

/// Decoded `ModelStreamBegin` fields, as the embedding component's
/// message handler received them.
pub struct StreamBegin {
    pub stream_id: u64,
    pub task_id: u64,
    pub round: u64,
    pub purpose: StreamPurpose,
    pub learner_id: String,
    pub codec: CodecId,
    pub base_round: u64,
    pub layout: Vec<TensorLayoutProto>,
    pub meta: TaskMeta,
    pub spec: TaskSpec,
}

/// A completed, digest-verified, fully decoded stream.
pub struct FinishedStream {
    pub purpose: StreamPurpose,
    pub task_id: u64,
    pub round: u64,
    pub learner_id: String,
    pub codec: CodecId,
    pub meta: TaskMeta,
    pub spec: TaskSpec,
    pub model: TensorModel,
}

/// Announced structure of one in-flight tensor.
struct StreamTensor {
    name: String,
    shape: Vec<usize>,
    dtype: DType,
    elems: usize,
}

/// An in-flight inbound model stream: the accumulator that becomes a
/// [`FinishedStream`] at `End`.
///
/// Buffers are pre-sized from the `Begin` layout and drawn from the
/// ingest's [`BufferPool`] when it has one. Chunks decode **on
/// arrival** through the stream's codec, directly into the partially
/// filled tensors; delta streams XOR against the resolved base as they
/// decode, so no second pass over the model is ever needed.
pub struct ModelStream {
    purpose: StreamPurpose,
    task_id: u64,
    round: u64,
    learner_id: String,
    codec: CodecId,
    meta: TaskMeta,
    spec: TaskSpec,
    /// Announced structure, one entry per tensor.
    layout: Vec<StreamTensor>,
    /// Delta base resolved by the embedding component at `Begin`.
    base: Option<Arc<TensorModel>>,
    /// Decoded output buffers, pool-drawn when available.
    bufs: Vec<Vec<f32>>,
    /// Elements decoded so far, per tensor.
    filled: Vec<usize>,
    /// Tensor currently being filled.
    cur_tensor: usize,
    /// Payload bytes consumed so far / expected in total. Element-stable
    /// codecs count wire bytes; framed codecs count the f32-equivalent
    /// bytes each frame decoded into (wire bytes vary with compression,
    /// the decoded volume is what the announced layout fixes).
    received: usize,
    expected: usize,
    next_seq: u64,
    /// Partial-element bytes straddling a chunk boundary (< element
    /// size; element-stable codecs only — frames are never split).
    carry: Vec<u8>,
    /// Running FNV-1a 64 over the payload bytes as they crossed the wire.
    digest: u64,
    /// Framed codec: chunks are self-delimiting frames, decoded by the
    /// deferred-decode worker instead of in the connection handler.
    framed: bool,
    /// First failure hit by the deferred-decode worker; surfaced as a
    /// typed StreamProtocol error on the next chunk or at `End`.
    deferred: Option<anyhow::Error>,
    /// Shared byte totals (compressed vs f32-equivalent received).
    stats: Arc<WireStats>,
    /// Pool to return `bufs` to if the stream dies.
    pool: Option<Arc<dyn BufferPool>>,
    /// Last `Begin`/`Chunk` arrival (on the ingest's clock); idle
    /// streams past the limit are garbage-collected.
    last_activity: Timestamp,
    /// When `Begin` was admitted; streams alive past
    /// `max_stream_lifetime` are reclaimed even if chunks keep
    /// trickling in (the slow-loris guard).
    opened_at: Timestamp,
    /// Set by [`ModelStream::recycle`]: the buffers are gone. A chunk
    /// handler that raced the close (it cloned the registry `Arc`
    /// before removal) must fail gracefully instead of indexing the
    /// drained `bufs`.
    dead: bool,
}

impl ModelStream {
    /// Fold one chunk's bytes into the partial model (element-stable
    /// codecs; the digest was already folded by the front end).
    fn ingest(&mut self, mut bytes: &[u8]) -> Result<()> {
        if self.received + bytes.len() > self.expected {
            bail!(
                "stream overrun: {} + {} > expected {}",
                self.received,
                bytes.len(),
                self.expected
            );
        }
        self.received += bytes.len();
        let esz = self.codec.wire_dtype().size_bytes();
        self.stats.note_recv(bytes.len(), bytes.len() * 4 / esz);
        let codec = self.codec.codec();
        let base = self.base.clone();
        while !bytes.is_empty() {
            // Advance past tensors that are already full (zero-element
            // tensors fall through immediately).
            while self.cur_tensor < self.layout.len()
                && self.filled[self.cur_tensor] == self.layout[self.cur_tensor].elems
            {
                self.cur_tensor += 1;
            }
            let t = self.cur_tensor;
            if t >= self.layout.len() {
                bail!("stream bytes beyond announced layout");
            }
            let elems = self.layout[t].elems;
            let esz = self.layout[t].dtype.size_bytes();
            let base_span = |lo: usize, hi: usize| {
                base.as_ref().map(|b| &b.tensors[t].data[lo..hi])
            };
            // Complete a partial element left over from the last chunk.
            if !self.carry.is_empty() {
                let need = esz - self.carry.len();
                let take = need.min(bytes.len());
                self.carry.extend_from_slice(&bytes[..take]);
                bytes = &bytes[take..];
                if self.carry.len() == esz {
                    let idx = self.filled[t];
                    let carry = std::mem::take(&mut self.carry);
                    codec.decode_into(
                        &carry,
                        base_span(idx, idx + 1),
                        &mut self.bufs[t][idx..idx + 1],
                    );
                    self.filled[t] += 1;
                }
                continue;
            }
            // Bulk-decode whole elements into this tensor's buffer.
            let max_bytes = (elems - self.filled[t]) * esz;
            let take = bytes.len().min(max_bytes);
            let whole = (take / esz) * esz;
            if whole > 0 {
                let lo = self.filled[t];
                let n = whole / esz;
                codec.decode_into(
                    &bytes[..whole],
                    base_span(lo, lo + n),
                    &mut self.bufs[t][lo..lo + n],
                );
                self.filled[t] += n;
            }
            self.carry.extend_from_slice(&bytes[whole..take]);
            bytes = &bytes[take..];
        }
        Ok(())
    }

    /// Reserve the destination span for one self-delimiting frame —
    /// the ordering-sensitive half of framed ingest, run in the
    /// connection handler under the same lock that validated `seq`.
    /// Parses only the cheap frame header; malformed headers surface
    /// immediately as chunk errors.
    fn reserve_frame_span(&mut self, bytes: &[u8]) -> Result<FrameSpan> {
        let n = self.codec.codec().frame_elems(bytes)?;
        if n == 0 {
            bail!("empty frame");
        }
        while self.cur_tensor < self.layout.len()
            && self.filled[self.cur_tensor] == self.layout[self.cur_tensor].elems
        {
            self.cur_tensor += 1;
        }
        let t = self.cur_tensor;
        if t >= self.layout.len() {
            bail!("frame beyond announced layout");
        }
        let lo = self.filled[t];
        let remaining = self.layout[t].elems - lo;
        if n > remaining {
            bail!(
                "frame covers {n} elements but tensor '{}' has {remaining} remaining \
                 (frames must not span tensors)",
                self.layout[t].name
            );
        }
        if self.received + n * 4 > self.expected {
            bail!("stream overrun: {} + {} > expected {}", self.received, n * 4, self.expected);
        }
        self.filled[t] += n;
        self.received += n * 4;
        self.stats.note_recv(bytes.len(), n * 4);
        Ok(FrameSpan { tensor: t, lo, elems: n })
    }

    /// Decompress one frame into its pre-reserved span (the deferred
    /// half, run on the decode worker — span reservation already fixed
    /// the destination, so arrival order at the worker is irrelevant).
    fn decode_reserved(&mut self, span: &FrameSpan, bytes: &[u8]) -> Result<()> {
        let base = self.base.clone();
        let (t, lo, n) = (span.tensor, span.lo, span.elems);
        self.codec.codec().decode_frame(
            bytes,
            base.as_ref().map(|b| &b.tensors[t].data[lo..lo + n]),
            &mut self.bufs[t][lo..lo + n],
        )
    }

    /// Finish the stream, returning the decoded model.
    fn finish(mut self, digest: u64) -> std::result::Result<TensorModel, (Self, anyhow::Error)> {
        if self.received != self.expected {
            let e = anyhow::anyhow!(
                "stream truncated: got {} of {} payload bytes",
                self.received,
                self.expected
            );
            return Err((self, e));
        }
        if !self.carry.is_empty() {
            let e = anyhow::anyhow!("stream ends mid-element ({} carry bytes)", self.carry.len());
            return Err((self, e));
        }
        if digest != self.digest {
            let e = anyhow::anyhow!(
                "stream digest mismatch: sender {:#018x}, receiver {:#018x}",
                digest,
                self.digest
            );
            return Err((self, e));
        }
        let bufs = std::mem::take(&mut self.bufs);
        let tensors = self
            .layout
            .iter()
            .zip(bufs)
            .map(|(t, data)| Tensor::new(t.name.clone(), t.shape.clone(), data))
            .collect();
        Ok(TensorModel::new(tensors))
    }

    /// Hand every buffer back to the pool (stream abandoned or failed)
    /// and mark the stream dead for any handler still holding its `Arc`.
    fn recycle(&mut self) {
        self.dead = true;
        self.base = None;
        if let Some(pool) = &self.pool {
            for buf in self.bufs.drain(..) {
                pool.recycle(buf);
            }
        } else {
            self.bufs.clear();
        }
    }
}

/// Test-only handle keeping a stream's `Arc` alive across a close, to
/// drive the dead-flag chunk-race path deterministically.
#[doc(hidden)]
pub struct StreamHold(Arc<Mutex<ModelStream>>);

/// The inbound stream registry + admission control + wire-memory gauge.
///
/// Everything here stays off the embedding component's state mutex;
/// per-stream locks sit below the registry lock, so chunk ingest for
/// one peer never contends with another peer's stream.
pub struct StreamIngest {
    limits: IngestLimits,
    streams: Mutex<HashMap<u64, Arc<Mutex<ModelStream>>>>,
    /// Wire bytes announced by currently-open streams (admission budget
    /// against `limits.max_total_stream_bytes`).
    open_stream_bytes: AtomicUsize,
    /// Wire gauge + received-byte totals. The gauge covers wire payload
    /// held for ingest (one-shot protos being decoded + stream chunks in
    /// flight or queued for the decode worker) — the "second whole-model
    /// buffer" the data plane eliminates; tests assert the streamed
    /// bound.
    stats: Arc<WireStats>,
    /// Deferred-decode worker pool (framed streams): per-stream FIFO
    /// queues served round-robin by every worker, with a pool-wide
    /// queue-depth cap for backpressure — one slow decompression never
    /// idles the other workers, and a burst of hot framed uploads
    /// spreads across the whole pool. Spawned lazily on the first
    /// framed chunk.
    decode_pool: Mutex<Option<Arc<DecodePool>>>,
    decode_workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// Injected time source: idle/lifetime GC deadlines run on this
    /// clock (real or simulated).
    clock: Clock,
    /// Shared degradation counters (the embedding component's registry,
    /// which `FederationReport` and the trace recorder snapshot).
    counters: Arc<CounterRegistry>,
    /// Streams turned away by admission control (slot cap, aggregate
    /// announced-byte budget, raced slot) — the degradation signal a
    /// chaos run reads back through `FederationReport`.
    streams_refused: Counter,
    /// Streams reclaimed by the idle/lifetime GC.
    streams_gced: Counter,
}

/// Size of the deferred-decode worker pool: a few threads cover any
/// realistic number of simultaneously-bursting framed uploads without
/// turning every `StreamIngest` into a thread farm.
fn decode_pool_size() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).clamp(1, 4)
}

impl Default for StreamIngest {
    fn default() -> StreamIngest {
        StreamIngest::new(IngestLimits::default())
    }
}

impl StreamIngest {
    /// System clock, private counter registry. Components embedding an
    /// ingest in a clocked/reported context use
    /// [`StreamIngest::with_clock`] instead.
    pub fn new(limits: IngestLimits) -> StreamIngest {
        StreamIngest::with_clock(limits, Clock::system(), CounterRegistry::new())
    }

    /// The single injection point: the embedding component hands the
    /// ingest its [`Clock`] (GC deadlines follow real or simulated
    /// time) and its [`CounterRegistry`] (refused/GC'd-stream and wire
    /// byte counters land in the same snapshot as everything else).
    /// This replaces the old per-module `set_clock` fake-clock seam.
    pub fn with_clock(
        limits: IngestLimits,
        clock: Clock,
        counters: Arc<CounterRegistry>,
    ) -> StreamIngest {
        StreamIngest {
            limits,
            streams: Mutex::new(HashMap::new()),
            open_stream_bytes: AtomicUsize::new(0),
            stats: Arc::new(WireStats::new(&counters)),
            decode_pool: Mutex::new(None),
            decode_workers: Mutex::new(Vec::new()),
            clock,
            streams_refused: counters.counter(names::STREAMS_REFUSED),
            streams_gced: counters.counter(names::STREAMS_GCED),
            counters,
        }
    }

    /// The registry this ingest reports into.
    pub fn counters(&self) -> &Arc<CounterRegistry> {
        &self.counters
    }

    fn now(&self) -> Timestamp {
        self.clock.now()
    }

    // ---- wire-memory gauge -------------------------------------------

    /// Account `bytes` of wire payload held for ingest (also used by
    /// the embedding component's one-shot decode path, so streamed and
    /// one-shot runs share one gauge).
    pub fn wire_hold(&self, bytes: usize) {
        self.stats.hold(bytes);
    }

    pub fn wire_release(&self, bytes: usize) {
        self.stats.release(bytes);
    }

    /// High-water mark of wire-payload bytes held for model ingest.
    pub fn peak_wire_bytes(&self) -> usize {
        self.stats.peak.load(Ordering::SeqCst)
    }

    /// Total stream payload bytes received so far, in wire form
    /// (compressed for framed codecs, half-size for bf16).
    pub fn recv_wire_bytes(&self) -> u64 {
        self.stats.recv_wire.get()
    }

    /// f32-equivalent bytes the received stream payloads decoded into —
    /// `recv_raw_bytes - recv_wire_bytes` is what the wire codec kept
    /// off the network.
    pub fn recv_raw_bytes(&self) -> u64 {
        self.stats.recv_raw.get()
    }

    /// Streams currently open.
    pub fn open_streams(&self) -> usize {
        self.streams.lock().unwrap().len()
    }

    /// Wire-payload bytes currently held for model ingest (chunks in
    /// flight or queued for the decode worker). Must drain to zero once
    /// every stream has finished or been reclaimed — the no-leak gauge
    /// the chaos tests assert on.
    pub fn wire_in_flight_bytes(&self) -> usize {
        self.stats.in_flight.load(Ordering::SeqCst)
    }

    /// Streams refused by admission control (slot cap, announced-byte
    /// budget, raced slot).
    pub fn streams_refused(&self) -> u64 {
        self.streams_refused.get()
    }

    /// Streams reclaimed by the idle/lifetime GC.
    pub fn streams_gced(&self) -> u64 {
        self.streams_gced.get()
    }

    // ---- deferred-decode pipeline (framed codecs) --------------------

    /// Handle on the deferred-decode pool, spawning it (and its
    /// workers) on first use. The workers own the back half of the
    /// two-stage receive pipeline: a connection handler validates /
    /// digests chunk N+1 and acks while a worker is still
    /// decompressing chunk N. Per-stream FIFO queues are served
    /// round-robin by *every* worker, so a burst of hot framed uploads
    /// spreads across the whole pool instead of hashing onto one
    /// worker while the others idle.
    fn pool(&self) -> Arc<DecodePool> {
        let mut guard = self.decode_pool.lock().unwrap();
        if let Some(pool) = guard.as_ref() {
            return Arc::clone(pool);
        }
        let pool = Arc::new(DecodePool::new(decode_pool_size() * 2));
        let mut workers = self.decode_workers.lock().unwrap();
        for i in 0..decode_pool_size() {
            let p = Arc::clone(&pool);
            let stats = Arc::clone(&self.stats);
            let clock = self.clock.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("metisfl-ingest-decode-{i}"))
                    .spawn(move || p.worker_loop(&stats, &clock))
                    .expect("spawn ingest decode worker"),
            );
        }
        *guard = Some(Arc::clone(&pool));
        pool
    }

    // ---- protocol steps ----------------------------------------------

    /// Open a stream. `pool` supplies decode buffers (or `None` for
    /// plain allocation); `base` is the delta base the component
    /// resolved from `args.base_round` — `None` when it holds no such
    /// model, which refuses base-needing codecs with `NotFound` so the
    /// sender can fall back to a full send.
    pub fn begin(
        &self,
        args: StreamBegin,
        pool: Option<Arc<dyn BufferPool>>,
        base: Option<Arc<TensorModel>>,
    ) -> Message {
        if args.layout.is_empty() {
            return Message::error(ErrorCode::StreamProtocol, "empty stream layout");
        }
        if args.codec.needs_base() && base.is_none() {
            return Message::error(
                ErrorCode::NotFound,
                format!(
                    "no shared {} base for round {} (send full instead)",
                    args.codec, args.base_round
                ),
            );
        }
        let wire_dtype = args.codec.wire_dtype();
        let mut parsed = Vec::with_capacity(args.layout.len());
        let mut expected = 0usize;
        for t in &args.layout {
            if t.dtype != wire_dtype {
                return Message::error(
                    ErrorCode::StreamProtocol,
                    format!(
                        "layout dtype {:?} does not match codec {} ({:?})",
                        t.dtype, args.codec, wire_dtype
                    ),
                );
            }
            if t.byte_order != ByteOrder::Little {
                return Message::error(
                    ErrorCode::StreamProtocol,
                    "stream payloads are little-endian",
                );
            }
            let elems = match t.elem_count_checked() {
                Ok(n) => n,
                Err(e) => return Message::error(ErrorCode::StreamProtocol, format!("{e:#}")),
            };
            let bytes = match t.byte_len_checked() {
                Ok(n) => n,
                Err(e) => return Message::error(ErrorCode::StreamProtocol, format!("{e:#}")),
            };
            expected = match expected.checked_add(bytes) {
                Some(n) if n <= self.limits.max_stream_bytes => n,
                _ => {
                    return Message::error(
                        ErrorCode::StreamProtocol,
                        format!("stream exceeds {} payload bytes", self.limits.max_stream_bytes),
                    )
                }
            };
            parsed.push(StreamTensor {
                name: t.name.clone(),
                shape: t.shape.clone(),
                dtype: t.dtype,
                elems,
            });
        }
        // A delta base must align elementwise with the announced layout.
        if let Some(b) = &base {
            let aligned = b.tensors.len() == parsed.len()
                && b.tensors.iter().zip(&parsed).all(|(bt, lt)| bt.elem_count() == lt.elems);
            if !aligned {
                return Message::error(
                    ErrorCode::StreamProtocol,
                    format!("{} base layout does not match the stream layout", args.codec),
                );
            }
        }
        // Admission control runs BEFORE any buffer is allocated, so an
        // unauthenticated `Begin` flood cannot commit memory: reclaim
        // idle streams, then check slot, duplicate id, and the aggregate
        // announced-bytes budget.
        self.gc_idle();
        {
            let streams = self.streams.lock().unwrap();
            if streams.len() >= self.limits.max_open_streams {
                self.streams_refused.incr();
                return Message::error(
                    ErrorCode::StreamProtocol,
                    format!("too many open streams (max {})", self.limits.max_open_streams),
                );
            }
            if streams.contains_key(&args.stream_id) {
                return Message::error(
                    ErrorCode::StreamProtocol,
                    format!("stream id {:#x} already open", args.stream_id),
                );
            }
        }
        let budget = self.open_stream_bytes.fetch_add(expected, Ordering::SeqCst) + expected;
        if budget > self.limits.max_total_stream_bytes {
            self.open_stream_bytes.fetch_sub(expected, Ordering::SeqCst);
            self.streams_refused.incr();
            return Message::error(
                ErrorCode::StreamProtocol,
                format!(
                    "open streams would exceed {} announced bytes",
                    self.limits.max_total_stream_bytes
                ),
            );
        }
        // Pre-size the decode buffers from the pool (when the component
        // owns one): a steady-state streamed round re-fills the buffers
        // the previous community model vacated.
        let now = self.now();
        let bufs: Vec<Vec<f32>> = parsed
            .iter()
            .map(|t| match &pool {
                Some(p) => p.take(t.elems),
                None => vec![0.0; t.elems],
            })
            .collect();
        let filled = vec![0usize; parsed.len()];
        let mut stream = ModelStream {
            purpose: args.purpose,
            task_id: args.task_id,
            round: args.round,
            learner_id: args.learner_id,
            codec: args.codec,
            meta: args.meta,
            spec: args.spec,
            layout: parsed,
            base,
            bufs,
            filled,
            cur_tensor: 0,
            received: 0,
            expected,
            next_seq: 0,
            carry: Vec::new(),
            digest: FNV64_INIT,
            framed: args.codec.is_framed(),
            deferred: None,
            stats: Arc::clone(&self.stats),
            pool,
            last_activity: now,
            opened_at: now,
            dead: false,
        };
        let mut streams = self.streams.lock().unwrap();
        // Re-check under the lock: a racing Begin may have taken the id
        // or the last slot while we were allocating.
        if streams.len() >= self.limits.max_open_streams
            || streams.contains_key(&args.stream_id)
        {
            drop(streams);
            stream.recycle();
            self.open_stream_bytes.fetch_sub(expected, Ordering::SeqCst);
            self.streams_refused.incr();
            return Message::error(
                ErrorCode::StreamProtocol,
                format!("stream id {:#x} rejected (slot raced away)", args.stream_id),
            );
        }
        streams.insert(args.stream_id, Arc::new(Mutex::new(stream)));
        Message::Ack { task_id: args.stream_id, ok: true }
    }

    /// Fold one chunk into its stream. Returns the ack (or a typed
    /// error, after which the stream is gone). Framed streams ack as
    /// soon as the chunk is validated and queued — decompression runs on
    /// the decode worker while the sender's next chunk is already on the
    /// wire; a decode failure surfaces on the next chunk or at `End`.
    pub fn chunk(&self, stream_id: u64, seq: u64, bytes: Vec<u8>) -> Message {
        let Some(stream) = self.streams.lock().unwrap().get(&stream_id).cloned() else {
            return Message::error(
                ErrorCode::StreamProtocol,
                format!("chunk for unknown stream {stream_id:#x}"),
            );
        };
        self.chunk_into(&stream, stream_id, seq, bytes)
    }

    fn chunk_into(
        &self,
        stream: &Arc<Mutex<ModelStream>>,
        stream_id: u64,
        seq: u64,
        bytes: Vec<u8>,
    ) -> Message {
        self.wire_hold(bytes.len());
        // Front-end validation under the stream lock: seq ordering, the
        // dead-flag race guard, any failure the decode worker deferred,
        // the running digest, and — for framed streams — the frame's
        // destination-span reservation. Everything ordering-sensitive
        // happens here, so the worker can apply frames in whatever
        // order they reach its queue.
        let result = {
            let mut s = stream.lock().unwrap();
            if s.dead {
                // We raced a close: the registry entry is already gone
                // and the buffers were recycled.
                Err(anyhow::anyhow!("chunk for a closed stream"))
            } else if let Some(e) = s.deferred.take() {
                Err(anyhow::anyhow!("deferred decode failure: {e:#}"))
            } else if seq != s.next_seq {
                Err(anyhow::anyhow!("chunk seq {seq}, expected {}", s.next_seq))
            } else {
                s.last_activity = self.now();
                s.next_seq += 1;
                s.digest = fnv1a64(s.digest, &bytes);
                if s.framed {
                    s.reserve_frame_span(&bytes).map(Some)
                } else {
                    s.ingest(&bytes).map(|()| None)
                }
            }
        };
        match result {
            Ok(Some(span)) => {
                // The pool releases the gauge once the frame is
                // decoded; a blocked enqueue here (pool at its
                // queue-depth cap) is the pipeline's backpressure —
                // the stall a slow decode is supposed to exert on the
                // sender's next chunk ack.
                let held = bytes.len();
                let frame = PendingFrame { stream: Arc::clone(stream), bytes, span };
                if !self.pool().enqueue(stream_id, frame) {
                    self.wire_release(held);
                    self.kill(stream_id);
                    return Message::error(ErrorCode::Internal, "ingest decode pool gone");
                }
                Message::Ack { task_id: stream_id, ok: true }
            }
            Ok(None) => {
                self.wire_release(bytes.len());
                Message::Ack { task_id: stream_id, ok: true }
            }
            Err(e) => {
                self.wire_release(bytes.len());
                self.kill(stream_id);
                Message::error(ErrorCode::StreamProtocol, format!("{e:#}"))
            }
        }
    }

    /// Close a stream: verify completeness + digest and hand the decoded
    /// model back to the embedding component. `Err` carries the reply to
    /// send the peer (the stream is already torn down).
    pub fn end(&self, stream_id: u64, digest: u64) -> std::result::Result<FinishedStream, Message> {
        // Framed streams decode through the pool: drain THIS stream's
        // queue first so every queued frame (and any failure it
        // deferred) has landed before the completeness/digest verdict
        // below. The barrier is per-stream — End never waits on some
        // other upload's decode backlog.
        let framed = self
            .streams
            .lock()
            .unwrap()
            .get(&stream_id)
            .map(|s| s.lock().unwrap().framed);
        match framed {
            Some(true) => {
                let pool = self.decode_pool.lock().unwrap().clone();
                if let Some(pool) = pool {
                    pool.flush_stream(stream_id);
                }
            }
            Some(false) => {}
            None => {
                return Err(Message::error(
                    ErrorCode::StreamProtocol,
                    format!("end for unknown stream {stream_id:#x}"),
                ))
            }
        }
        let Some(stream) = self.streams.lock().unwrap().remove(&stream_id) else {
            return Err(Message::error(
                ErrorCode::StreamProtocol,
                format!("end for unknown stream {stream_id:#x}"),
            ));
        };
        // Sole holder now (the registry entry is gone; chunk handlers
        // clone the Arc only while the entry exists and hold it briefly,
        // and the decode worker was drained above).
        let mut stream = match Arc::try_unwrap(stream) {
            Ok(m) => m.into_inner().unwrap(),
            Err(arc) => {
                // A racing chunk still holds the Arc: a protocol
                // violation (chunks after End); drop the stream.
                let mut s = arc.lock().unwrap();
                self.open_stream_bytes.fetch_sub(s.expected, Ordering::SeqCst);
                s.recycle();
                return Err(Message::error(
                    ErrorCode::StreamProtocol,
                    "stream closed while chunks were in flight",
                ));
            }
        };
        self.open_stream_bytes.fetch_sub(stream.expected, Ordering::SeqCst);
        if let Some(e) = stream.deferred.take() {
            stream.recycle();
            return Err(Message::error(
                ErrorCode::StreamProtocol,
                format!("deferred decode failure: {e:#}"),
            ));
        }
        let (purpose, task_id, round, learner_id, codec, meta, spec) = (
            stream.purpose,
            stream.task_id,
            stream.round,
            stream.learner_id.clone(),
            stream.codec,
            stream.meta.clone(),
            stream.spec.clone(),
        );
        match stream.finish(digest) {
            Ok(model) => Ok(FinishedStream {
                purpose,
                task_id,
                round,
                learner_id,
                codec,
                meta,
                spec,
                model,
            }),
            Err((mut s, e)) => {
                s.recycle();
                Err(Message::error(ErrorCode::StreamProtocol, format!("{e:#}")))
            }
        }
    }

    /// Reclaim streams with no activity past the idle timeout OR alive
    /// past the total-lifetime cap: a peer that died mid-stream must not
    /// pin its buffers or leak a registry slot until the cap locks
    /// streaming out entirely, and a slow-loris peer trickling just
    /// often enough to stay "active" must not hold its admission budget
    /// forever. Returns how many streams were reclaimed.
    pub fn gc_idle(&self) -> usize {
        let now = self.now();
        let expired: Vec<u64> = {
            let streams = self.streams.lock().unwrap();
            streams
                .iter()
                .filter(|(_, s)| {
                    let s = s.lock().unwrap();
                    now.saturating_sub(s.last_activity) > self.limits.idle_timeout
                        || now.saturating_sub(s.opened_at) > self.limits.max_stream_lifetime
                })
                .map(|(id, _)| *id)
                .collect()
        };
        let n = expired.len();
        for id in expired {
            log_debug("ingest", &format!("reclaiming idle/expired stream {id:#x}"));
            self.kill(id);
        }
        self.streams_gced.add(n as u64);
        n
    }

    /// Forcibly reclaim every open stream regardless of its deadlines —
    /// the harness's end-of-run wedge gate for fleets that finish with
    /// half-open streams (peers that died mid-upload), without faking
    /// time past the idle window. Returns how many were reclaimed.
    pub fn gc_force(&self) -> usize {
        let ids: Vec<u64> = self.streams.lock().unwrap().keys().copied().collect();
        let n = ids.len();
        for id in ids {
            log_debug("ingest", &format!("force-reclaiming stream {id:#x}"));
            self.kill(id);
        }
        self.streams_gced.add(n as u64);
        n
    }

    /// Drop a failed/abandoned stream, recycle its buffers, and return
    /// its announced bytes to the admission budget. Frames it still has
    /// queued on the decode pool are pruned (their gauge bytes
    /// released); a frame already mid-decode finishes against the dead
    /// flag and releases its own bytes.
    pub fn kill(&self, stream_id: u64) {
        let pool = self.decode_pool.lock().unwrap().clone();
        if let Some(pool) = pool {
            pool.prune(stream_id, &self.stats);
        }
        if let Some(stream) = self.streams.lock().unwrap().remove(&stream_id) {
            let mut s = stream.lock().unwrap();
            self.open_stream_bytes.fetch_sub(s.expected, Ordering::SeqCst);
            s.recycle();
        }
    }

    /// Keep a stream's `Arc` alive outside the registry — the handle a
    /// racing chunk handler would hold. Test hook for the dead-flag
    /// path; never used in production code.
    #[doc(hidden)]
    pub fn hold_for_test(&self, stream_id: u64) -> Option<StreamHold> {
        self.streams.lock().unwrap().get(&stream_id).cloned().map(StreamHold)
    }

    /// Deliver a chunk through a held handle, exactly as a handler that
    /// cloned the `Arc` before a racing close would.
    #[doc(hidden)]
    pub fn chunk_into_held(&self, hold: &StreamHold, seq: u64, bytes: Vec<u8>) -> Message {
        // The stream id is only used for registry teardown + ack text;
        // recover it from the registry if still present, else 0.
        let id = {
            let streams = self.streams.lock().unwrap();
            streams
                .iter()
                .find(|(_, s)| Arc::ptr_eq(s, &hold.0))
                .map(|(id, _)| *id)
                .unwrap_or(0)
        };
        self.chunk_into(&hold.0, id, seq, bytes)
    }
}

impl Drop for StreamIngest {
    fn drop(&mut self) {
        let pool = self.decode_pool.lock().unwrap().take();
        if let Some(pool) = pool {
            pool.shutdown();
        }
        for h in self.decode_workers.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;
    use crate::proto::client::{stream_model_with, StreamSend};
    use crate::proto::client::RpcResult;
    use crate::util::Rng;

    fn model(seed: u64) -> TensorModel {
        let layout = ModelSpec::mlp(4, 2, 8).tensor_layout();
        TensorModel::random_init(&layout, &mut Rng::new(seed))
    }

    /// Drive a full stream against an ingest through the REAL sender
    /// walk, dispatching Begin/Chunk/End to the right ingest calls.
    fn drive(
        ingest: &StreamIngest,
        send: &StreamSend<'_>,
        base: Option<Arc<TensorModel>>,
    ) -> RpcResult<FinishedStream> {
        let finished: Mutex<Option<FinishedStream>> = Mutex::new(None);
        let reply = stream_model_with(
            &mut |msg| {
                Ok(match msg {
                    Message::ModelStreamBegin {
                        stream_id,
                        task_id,
                        round,
                        purpose,
                        learner_id,
                        codec,
                        base_round,
                        layout,
                        meta,
                        spec,
                    } => ingest.begin(
                        StreamBegin {
                            stream_id,
                            task_id,
                            round,
                            purpose,
                            learner_id,
                            codec,
                            base_round,
                            layout,
                            meta,
                            spec,
                        },
                        None,
                        base.clone(),
                    ),
                    Message::ModelChunk { stream_id, seq, bytes } => {
                        ingest.chunk(stream_id, seq, bytes)
                    }
                    Message::ModelStreamEnd { stream_id, digest } => {
                        match ingest.end(stream_id, digest) {
                            Ok(f) => {
                                let id = f.task_id;
                                *finished.lock().unwrap() = Some(f);
                                Message::Ack { task_id: id, ok: true }
                            }
                            Err(reply) => reply,
                        }
                    }
                    other => Message::error(ErrorCode::Unsupported, other.kind()),
                })
            },
            send,
        )?;
        let _ = reply;
        Ok(finished.lock().unwrap().take().expect("stream did not finish"))
    }

    fn send_args<'a>(
        m: &'a TensorModel,
        meta: &'a TaskMeta,
        spec: &'a TaskSpec,
        codec: CodecId,
        base: Option<&'a TensorModel>,
        chunk: usize,
    ) -> StreamSend<'a> {
        StreamSend {
            purpose: StreamPurpose::TaskCompletion,
            task_id: 7,
            round: 1,
            learner_id: "l0",
            model: m,
            meta,
            spec,
            codec,
            base,
            base_round: 1,
            chunk_bytes: chunk,
        }
    }

    #[test]
    fn every_codec_roundtrips_through_ingest() {
        let m = model(3);
        let base = Arc::new(model(4));
        let meta = TaskMeta { num_samples: 9, ..Default::default() };
        let spec = TaskSpec::default();
        for codec in CodecId::ALL {
            // 13-byte chunks split elements and tensors arbitrarily.
            for chunk in [13usize, 64, 1 << 20] {
                let ingest = StreamIngest::default();
                let b = codec.needs_base().then(|| Arc::clone(&base));
                let send = send_args(&m, &meta, &spec, codec, b.as_deref(), chunk);
                let f = drive(&ingest, &send, b.clone()).unwrap();
                assert_eq!(f.codec, codec);
                assert_eq!(f.meta.num_samples, 9);
                assert_eq!(ingest.open_streams(), 0);
                if codec.is_lossless() {
                    assert_eq!(f.model, m, "{codec} chunk {chunk}");
                } else {
                    // bf16: bounded error, structure preserved.
                    assert_eq!(f.model.layout(), m.layout());
                    for (a, b) in m.tensors.iter().zip(&f.model.tensors) {
                        for (x, y) in a.data.iter().zip(&b.data) {
                            let bound = x.abs() * (1.0 / 256.0) + f32::MIN_POSITIVE;
                            assert!((x - y).abs() <= bound, "{x} vs {y}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn delta_without_base_is_not_found() {
        let m = model(5);
        let meta = TaskMeta::default();
        let spec = TaskSpec::default();
        let ingest = StreamIngest::default();
        // The sender believes it has a base; the receiver does not.
        let base = model(6);
        let send = send_args(&m, &meta, &spec, CodecId::Delta, Some(&base), 64);
        let err = drive(&ingest, &send, None).unwrap_err();
        match err {
            crate::proto::client::RpcError::Remote { code, .. } => {
                assert_eq!(code, ErrorCode::NotFound)
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(ingest.open_streams(), 0);
    }

    #[test]
    fn idle_gc_uses_injected_clock() {
        let clock = Clock::sim();
        let ingest =
            StreamIngest::with_clock(IngestLimits::default(), clock.clone(), CounterRegistry::new());
        let t0 = clock.now();

        let m = model(1);
        let begin = StreamBegin {
            stream_id: 9,
            task_id: 1,
            round: 0,
            purpose: StreamPurpose::TaskCompletion,
            learner_id: "a".into(),
            codec: CodecId::F32,
            base_round: 0,
            layout: TensorLayoutProto::f32_layout_of(&m),
            meta: TaskMeta::default(),
            spec: TaskSpec::default(),
        };
        assert!(matches!(ingest.begin(begin, None, None), Message::Ack { ok: true, .. }));
        assert_eq!(ingest.open_streams(), 1);
        // Just inside the timeout: survives.
        clock.advance_to(t0 + IngestLimits::default().idle_timeout);
        assert_eq!(ingest.gc_idle(), 0);
        assert_eq!(ingest.open_streams(), 1);
        // One nanosecond past: reclaimed.
        clock.advance_to(t0 + IngestLimits::default().idle_timeout + Duration::from_nanos(1));
        assert_eq!(ingest.gc_idle(), 1);
        assert_eq!(ingest.open_streams(), 0);
        // Budget returned: the same announced bytes admit again.
        assert_eq!(ingest.open_stream_bytes.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn chunk_racing_a_close_errors_instead_of_panicking() {
        let ingest = StreamIngest::default();
        let m = model(2);
        let begin = StreamBegin {
            stream_id: 11,
            task_id: 1,
            round: 0,
            purpose: StreamPurpose::TaskCompletion,
            learner_id: "a".into(),
            codec: CodecId::F32,
            base_round: 0,
            layout: TensorLayoutProto::f32_layout_of(&m),
            meta: TaskMeta::default(),
            spec: TaskSpec::default(),
        };
        assert!(matches!(ingest.begin(begin, None, None), Message::Ack { ok: true, .. }));
        // A handler clones the Arc (it is mid-chunk)…
        let hold = ingest.hold_for_test(11).unwrap();
        // …while End arrives: the close sees the shared Arc, recycles
        // the buffers, and marks the stream dead.
        match ingest.end(11, FNV64_INIT) {
            Err(Message::Error { code, detail }) => {
                assert_eq!(code, ErrorCode::StreamProtocol);
                assert!(detail.contains("in flight"), "{detail}");
            }
            other => panic!("unexpected {:?}", other.err()),
        }
        assert_eq!(ingest.open_streams(), 0);
        // The racing chunk now lands on the dead stream: a typed error,
        // not a panic on the drained buffers.
        match ingest.chunk_into_held(&hold, 0, vec![0u8; 4]) {
            Message::Error { code, detail } => {
                assert_eq!(code, ErrorCode::StreamProtocol);
                assert!(detail.contains("closed stream"), "{detail}");
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn begin_rejects_codec_layout_mismatch() {
        let ingest = StreamIngest::default();
        let m = model(8);
        // bf16 codec but an f32 layout: refused before any allocation.
        let begin = StreamBegin {
            stream_id: 21,
            task_id: 1,
            round: 0,
            purpose: StreamPurpose::TaskCompletion,
            learner_id: "a".into(),
            codec: CodecId::Bf16,
            base_round: 0,
            layout: TensorLayoutProto::f32_layout_of(&m),
            meta: TaskMeta::default(),
            spec: TaskSpec::default(),
        };
        match ingest.begin(begin, None, None) {
            Message::Error { code, .. } => assert_eq!(code, ErrorCode::StreamProtocol),
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(ingest.open_streams(), 0);
    }

    #[test]
    fn framed_ingest_counts_compressed_vs_raw_bytes() {
        // A delta-rle stream whose model barely moved: the wire total
        // must come in far below the f32-equivalent total, and the
        // decoded model must still be bit-exact.
        let base = Arc::new(model(21));
        let mut m = (*base).clone();
        for t in &mut m.tensors {
            for v in t.data.iter_mut().step_by(13) {
                *v *= 1.0 + 1e-6;
            }
        }
        let meta = TaskMeta::default();
        let spec = TaskSpec::default();
        let ingest = StreamIngest::default();
        let send = send_args(&m, &meta, &spec, CodecId::DeltaRle, Some(&*base), 256);
        let f = drive(&ingest, &send, Some(Arc::clone(&base))).unwrap();
        assert_eq!(f.model, m);
        let wire = ingest.recv_wire_bytes();
        let raw = ingest.recv_raw_bytes();
        assert_eq!(raw as usize, m.byte_size_f32());
        assert!(wire * 4 < raw, "delta-rle moved {wire} of {raw} raw bytes");
        assert_eq!(ingest.open_streams(), 0);
    }

    #[test]
    fn framed_decode_failure_is_deferred_to_end() {
        // A frame with a valid header but corrupt payload is acked (its
        // span is reserved in the handler; decompression is deferred),
        // and the failure surfaces as a typed StreamProtocol error at
        // End. A frame with a corrupt *header* is refused immediately.
        let m = model(22);
        let base = Arc::new(model(22));
        let ingest = StreamIngest::default();
        let begin = |stream_id: u64| StreamBegin {
            stream_id,
            task_id: 1,
            round: 0,
            purpose: StreamPurpose::TaskCompletion,
            learner_id: "a".into(),
            codec: CodecId::DeltaRle,
            base_round: 1,
            layout: TensorLayoutProto::codec_layout_of(&m, CodecId::DeltaRle),
            meta: TaskMeta::default(),
            spec: TaskSpec::default(),
        };
        assert!(matches!(
            ingest.begin(begin(31), None, Some(Arc::clone(&base))),
            Message::Ack { ok: true, .. }
        ));
        // Valid header (RLE flag, 4 elements) but a truncated payload:
        // the chunk acks, decompression fails on the worker…
        let bad = vec![1u8, 4, 0];
        let digest = fnv1a64(FNV64_INIT, &bad);
        assert!(matches!(ingest.chunk(31, 0, bad), Message::Ack { ok: true, .. }));
        // …and the deferred failure lands at End.
        match ingest.end(31, digest) {
            Err(Message::Error { code, detail }) => {
                assert_eq!(code, ErrorCode::StreamProtocol);
                assert!(detail.contains("deferred decode"), "{detail}");
            }
            other => panic!("unexpected {:?}", other.err()),
        }
        assert_eq!(ingest.open_streams(), 0);
        // A malformed frame *header* never reaches the worker: refused
        // at the chunk, stream torn down.
        assert!(matches!(
            ingest.begin(begin(32), None, Some(base)),
            Message::Ack { ok: true, .. }
        ));
        match ingest.chunk(32, 0, vec![9u8, 4, 0, 0]) {
            Message::Error { code, .. } => assert_eq!(code, ErrorCode::StreamProtocol),
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(ingest.open_streams(), 0);
        // Budget returned: nothing leaks.
        assert_eq!(ingest.open_stream_bytes.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn concurrent_framed_streams_decode_on_the_worker_pool() {
        // Two framed uploads interleaved chunk by chunk on one ingest:
        // the pool serves their per-stream queues round-robin across
        // all workers, and both must decode bit-exactly — the span
        // reservation done at seq-validation time keeps each stream's
        // frames at the right offsets no matter which worker
        // decompresses them, in whatever order.
        let base = Arc::new(model(31));
        let mut m1 = (*base).clone();
        let mut m2 = (*base).clone();
        for t in &mut m1.tensors {
            for v in t.data.iter_mut().step_by(7) {
                *v += 0.25;
            }
        }
        for t in &mut m2.tensors {
            for v in t.data.iter_mut().step_by(5) {
                *v -= 0.5;
            }
        }
        let ingest = StreamIngest::default();
        let codec = CodecId::DeltaRle;
        let begin = |stream_id: u64, m: &TensorModel| StreamBegin {
            stream_id,
            task_id: stream_id,
            round: 1,
            purpose: StreamPurpose::TaskCompletion,
            learner_id: format!("l{stream_id}"),
            codec,
            base_round: 1,
            layout: TensorLayoutProto::codec_layout_of(m, codec),
            meta: TaskMeta::default(),
            spec: TaskSpec::default(),
        };
        // Pre-encode both streams' frames with the real sender walk.
        let frames_of = |m: &TensorModel| {
            let impl_ = codec.codec();
            let block = 64usize;
            let mut frames = Vec::new();
            for (i, t) in m.tensors.iter().enumerate() {
                let mut lo = 0usize;
                while lo < t.data.len() {
                    let hi = (lo + block).min(t.data.len());
                    let mut f = Vec::new();
                    impl_.encode_frame_into(
                        &t.data[lo..hi],
                        Some(&base.tensors[i].data[lo..hi]),
                        &mut f,
                    );
                    frames.push(f);
                    lo = hi;
                }
            }
            frames
        };
        let (f1, f2) = (frames_of(&m1), frames_of(&m2));
        assert!(matches!(
            ingest.begin(begin(1000, &m1), None, Some(Arc::clone(&base))),
            Message::Ack { ok: true, .. }
        ));
        assert!(matches!(
            ingest.begin(begin(1001, &m2), None, Some(Arc::clone(&base))),
            Message::Ack { ok: true, .. }
        ));
        let (mut d1, mut d2) = (FNV64_INIT, FNV64_INIT);
        let n = f1.len().max(f2.len());
        for seq in 0..n {
            if let Some(f) = f1.get(seq) {
                d1 = fnv1a64(d1, f);
                assert!(matches!(
                    ingest.chunk(1000, seq as u64, f.clone()),
                    Message::Ack { ok: true, .. }
                ));
            }
            if let Some(f) = f2.get(seq) {
                d2 = fnv1a64(d2, f);
                assert!(matches!(
                    ingest.chunk(1001, seq as u64, f.clone()),
                    Message::Ack { ok: true, .. }
                ));
            }
        }
        let out1 = ingest.end(1000, d1).map_err(|e| format!("{e:?}")).unwrap();
        let out2 = ingest.end(1001, d2).map_err(|e| format!("{e:?}")).unwrap();
        assert_eq!(out1.model, m1);
        assert_eq!(out2.model, m2);
        assert_eq!(ingest.open_streams(), 0);
    }

    #[test]
    fn lifetime_gc_reclaims_a_trickling_slow_loris() {
        // A peer sending one chunk per idle interval keeps
        // `last_activity` forever fresh, so the idle check alone never
        // fires — the total-lifetime deadline must reclaim it anyway.
        let clock = Clock::sim();
        let ingest =
            StreamIngest::with_clock(IngestLimits::default(), clock.clone(), CounterRegistry::new());
        let t0 = clock.now();
        let limits = IngestLimits::default();
        assert!(limits.max_stream_lifetime >= limits.idle_timeout);

        let m = model(41);
        let begin = StreamBegin {
            stream_id: 51,
            task_id: 1,
            round: 0,
            purpose: StreamPurpose::TaskCompletion,
            learner_id: "loris".into(),
            codec: CodecId::F32,
            base_round: 0,
            layout: TensorLayoutProto::f32_layout_of(&m),
            meta: TaskMeta::default(),
            spec: TaskSpec::default(),
        };
        assert!(matches!(ingest.begin(begin, None, None), Message::Ack { ok: true, .. }));
        // Trickle one tiny chunk exactly at each idle deadline: always
        // inside the idle window, so idle GC never fires…
        let mut seq = 0u64;
        let mut elapsed = Duration::ZERO;
        while elapsed < limits.max_stream_lifetime {
            elapsed += limits.idle_timeout;
            clock.advance_to(t0 + elapsed);
            assert!(matches!(
                ingest.chunk(51, seq, vec![0u8; 4]),
                Message::Ack { ok: true, .. }
            ));
            seq += 1;
            if elapsed <= limits.max_stream_lifetime {
                assert_eq!(ingest.gc_idle(), 0, "not yet past the lifetime cap");
            }
        }
        // …but one nanosecond past the lifetime cap the stream is
        // reclaimed even though its last chunk just arrived.
        clock.advance_to(t0 + limits.max_stream_lifetime + Duration::from_nanos(1));
        assert!(matches!(ingest.chunk(51, seq, vec![0u8; 4]), Message::Ack { ok: true, .. }));
        assert_eq!(ingest.gc_idle(), 1);
        assert_eq!(ingest.open_streams(), 0);
        assert_eq!(ingest.streams_gced(), 1);
        assert_eq!(ingest.open_stream_bytes.load(Ordering::SeqCst), 0);
        assert_eq!(ingest.wire_in_flight_bytes(), 0);
        // The loris's next trickle gets a typed error, not a slot.
        assert!(matches!(
            ingest.chunk(51, seq + 1, vec![0u8; 4]),
            Message::Error { code: ErrorCode::StreamProtocol, .. }
        ));
    }

    /// Pool that counts checkouts/returns, so tests can assert every
    /// reserved arena buffer came back after a failure.
    struct CountingPool {
        taken: AtomicUsize,
        recycled: AtomicUsize,
    }

    impl BufferPool for CountingPool {
        fn take(&self, len: usize) -> Vec<f32> {
            self.taken.fetch_add(1, Ordering::SeqCst);
            vec![0.0; len]
        }

        fn recycle(&self, _buf: Vec<f32>) {
            self.recycled.fetch_add(1, Ordering::SeqCst);
        }
    }

    #[test]
    fn mid_stream_disconnect_during_delta_rle_releases_everything() {
        // A framed delta-rle upload dies mid-stream: valid frames are
        // already queued on (or through) the decode worker, then the
        // peer vanishes — no more chunks, no End. The gauge must drain,
        // the forced GC must reclaim the half-open stream (returning
        // every pool buffer and the admission budget), and a zombie
        // chunk racing the teardown must get a typed StreamProtocol
        // error, not a panic.
        let base = Arc::new(model(42));
        let mut m = (*base).clone();
        for t in &mut m.tensors {
            for v in t.data.iter_mut().step_by(3) {
                *v += 0.125;
            }
        }
        let clock = Clock::sim();
        let ingest =
            StreamIngest::with_clock(IngestLimits::default(), clock.clone(), CounterRegistry::new());
        let t0 = clock.now();
        let pool = Arc::new(CountingPool {
            taken: AtomicUsize::new(0),
            recycled: AtomicUsize::new(0),
        });
        let codec = CodecId::DeltaRle;
        let begin = StreamBegin {
            stream_id: 61,
            task_id: 1,
            round: 1,
            purpose: StreamPurpose::TaskCompletion,
            learner_id: "gone".into(),
            codec,
            base_round: 1,
            layout: TensorLayoutProto::codec_layout_of(&m, codec),
            meta: TaskMeta::default(),
            spec: TaskSpec::default(),
        };
        assert!(matches!(
            ingest.begin(
                begin,
                Some(Arc::clone(&pool) as Arc<dyn BufferPool>),
                Some(Arc::clone(&base))
            ),
            Message::Ack { ok: true, .. }
        ));
        let n_bufs = pool.taken.load(Ordering::SeqCst);
        assert!(n_bufs > 0);
        // First two frames of the real encoding arrive, then silence.
        let impl_ = codec.codec();
        for seq in 0..2u64 {
            let lo = seq as usize * 16;
            let mut frame = Vec::new();
            impl_.encode_frame_into(
                &m.tensors[0].data[lo..lo + 16],
                Some(&base.tensors[0].data[lo..lo + 16]),
                &mut frame,
            );
            assert!(matches!(
                ingest.chunk(61, seq, frame),
                Message::Ack { ok: true, .. }
            ));
        }
        // The deferred pool finishes the queued frames: the wire
        // gauge drains to zero even though the stream never closed.
        // (Real-time deadline — the pool workers run on OS threads
        // regardless of the ingest's virtual clock.)
        let sw = crate::util::Stopwatch::start();
        while ingest.wire_in_flight_bytes() != 0 {
            assert!(sw.elapsed() < Duration::from_secs(10), "wire gauge never drained");
            std::thread::yield_now();
        }
        assert!(ingest.peak_wire_bytes() > 0, "frames were held at some point");
        // A handler clones the Arc just before the GC wins the race…
        let hold = ingest.hold_for_test(61).unwrap();
        clock.advance_to(t0 + IngestLimits::default().idle_timeout + Duration::from_nanos(1));
        assert_eq!(ingest.gc_idle(), 1, "half-open stream must be reclaimed");
        // …and its late chunk gets the typed error.
        match ingest.chunk_into_held(&hold, 2, vec![1u8, 4, 0]) {
            Message::Error { code, detail } => {
                assert_eq!(code, ErrorCode::StreamProtocol);
                assert!(detail.contains("closed stream"), "{detail}");
            }
            other => panic!("unexpected {other:?}"),
        }
        // No leak: every pool buffer returned, budget and gauge at zero.
        assert_eq!(pool.recycled.load(Ordering::SeqCst), n_bufs);
        assert_eq!(ingest.open_streams(), 0);
        assert_eq!(ingest.streams_gced(), 1);
        assert_eq!(ingest.open_stream_bytes.load(Ordering::SeqCst), 0);
        assert_eq!(ingest.wire_in_flight_bytes(), 0);
    }

    #[test]
    fn begin_rejects_misaligned_delta_base() {
        let ingest = StreamIngest::default();
        let m = model(8);
        let wrong_base = Arc::new(TensorModel::new(vec![Tensor::zeros("x", vec![3])]));
        let begin = StreamBegin {
            stream_id: 22,
            task_id: 1,
            round: 0,
            purpose: StreamPurpose::TaskCompletion,
            learner_id: "a".into(),
            codec: CodecId::Delta,
            base_round: 0,
            layout: TensorLayoutProto::codec_layout_of(&m, CodecId::Delta),
            meta: TaskMeta::default(),
            spec: TaskSpec::default(),
        };
        match ingest.begin(begin, None, Some(wrong_base)) {
            Message::Error { code, .. } => assert_eq!(code, ErrorCode::StreamProtocol),
            other => panic!("unexpected {other:?}"),
        }
    }
}
