//! # MetisFL (reproduction)
//!
//! A federated-learning framework whose **federation controller is the
//! first-class citizen**, reproducing *"MetisFL: An Embarrassingly
//! Parallelized Controller for Scalable & Efficient Federated Learning
//! Workflows"* (Stripelis et al., 2023).
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//!
//! * **L3 (this crate)** — controller (parallel per-tensor aggregation,
//!   model store, sync/semi-sync/async schedulers), learner runtime,
//!   federation driver, wire protocol, metrics, and the baseline framework
//!   behavioural models used by the paper's evaluation.
//! * **L2 (`python/compile/model.py`)** — the HousingMLP model as JAX
//!   `train_step` / `eval_step`, AOT-lowered to HLO text artifacts.
//! * **L1 (`python/compile/kernels/`)** — Pallas kernels (fused dense,
//!   weighted FedAvg, SGD update) called from L2.
//!
//! Python runs only at build time (`make artifacts`); the request path is
//! pure Rust + PJRT.
//!
//! ## Quickstart
//!
//! ```no_run
//! use metisfl::prelude::*;
//!
//! let env = FederationEnv::builder("quickstart")
//!     .learners(4)
//!     .rounds(3)
//!     .model(ModelSpec::mlp(10, 4, 8))
//!     .build();
//! let report = metisfl::driver::run_simulated(&env).unwrap();
//! println!("final loss: {:?}", report.round_metrics.last());
//! ```

pub mod baselines;
pub mod cli;
pub mod config;
pub mod controller;
pub mod crypto;
pub mod driver;
pub mod harness;
pub mod json;
pub mod learner;
pub mod metrics;
pub mod net;
pub mod obs;
pub mod proto;
pub mod runtime;
pub mod tensor;
pub mod util;

/// Convenience re-exports for the common API surface.
pub mod prelude {
    pub use crate::config::{FederationEnv, ModelSpec, Protocol};
    pub use crate::controller::aggregation::{AggregationRule, FedAvg};
    pub use crate::controller::Controller;
    pub use crate::driver::{run_simulated, FederationReport};
    pub use crate::learner::Learner;
    pub use crate::metrics::FedOp;
    pub use crate::config::WireCodecChoice;
    pub use crate::proto::client::{ControllerClient, LearnerClient, RpcError};
    pub use crate::proto::ErrorCode;
    pub use crate::tensor::{CodecId, DType, Tensor, TensorModel};
}

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
