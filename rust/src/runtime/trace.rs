//! Deterministic trace record/replay for the controller.
//!
//! A recording controller appends every event that can influence its
//! state to a compact binary trace: raw inbound frames (registrations,
//! completions, stream chunks — byte-exact), plus the scheduler-side
//! decisions that do not arrive over the wire (round open/close,
//! aggregation, async task marks, delta-base installs). The trace embeds
//! the run's full environment (via [`FederationEnv::to_yaml_source`])
//! and ends with a footer holding the final community-model digest and a
//! whole-registry counter snapshot.
//!
//! [`replay`] re-drives a fresh controller from the trace on a
//! [`Clock::sim`] virtual clock: each event's recorded tick advances the
//! clock, inbound frames go through the ordinary [`Service::handle`]
//! path, and scheduler events call the same internal entry points the
//! live schedulers used. Because the recorder lock serializes the live
//! timeline (see `Controller::handle`), applying the same events in the
//! same order MUST reproduce the same state — the replay asserts the
//! community digest bitwise and cross-checks round membership, making
//! any nondeterminism in the control or data plane a loud, diffable
//! failure instead of a heisenbug.
//!
//! ## Wire format (`MFTR1`)
//!
//! ```text
//! "MFTR1\n"                                 magic
//! u32 env_len, env_len bytes                env YAML source
//! repeated events:
//!   u8 kind, u64 tick_nanos, u32 payload_len, payload
//! footer (kind 0xFF, must be last):
//!   u64 community_digest
//!   u32 n, n × { u32 key_len, key, u64 value }
//! ```
//!
//! All integers are little-endian. Id lists inside payloads are
//! `u32 count` followed by `count` length-prefixed strings.

use crate::config::FederationEnv;
use crate::controller::Controller;
use crate::metrics::counters::names;
use crate::net::Service;
use crate::proto::wire::{fnv1a64, FNV64_INIT};
use crate::proto::Message;
use crate::tensor::TensorModel;
use crate::util::clock::{Clock, Timestamp};
use crate::util::log_debug;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

/// Trace file magic (format version 1).
pub const TRACE_MAGIC: &[u8; 6] = b"MFTR1\n";

const EV_INBOUND: u8 = 0;
const EV_ROUND_OPEN: u8 = 2;
const EV_ROUND_CLOSE: u8 = 3;
const EV_AGGREGATE: u8 = 4;
const EV_MARK_OUTSTANDING: u8 = 5;
const EV_BASE_SET: u8 = 6;
const EV_SPANS: u8 = 7;
const EV_FOOTER: u8 = 0xFF;

/// Community snapshots kept during replay for `BaseSet` resolution: the
/// live base inserts always reference a model that *was* the community
/// at the recorded round, so a short history suffices (a synchronous
/// run only ever needs the latest one).
const BASE_HISTORY_CAP: usize = 32;

/// Counters a replay is expected to reproduce exactly: everything
/// driven purely by the recorded event order. Dispatch-side counters
/// (`dispatch_*`, retry give-ups, fallback sends) are excluded — a
/// replay applies the *effects* of dispatch, it never redials the
/// network that produced them.
pub const REPLAYABLE_COUNTERS: &[&str] = &[
    names::STREAMS_REFUSED,
    names::STREAMS_GCED,
    names::LATE_FOLDS,
    names::WIRE_BYTES_IN,
    names::WIRE_BYTES_RAW,
    names::FRAMES_REJECTED,
];

/// Bitwise-comparable digest of a model: tensor names + f32 bit
/// patterns, folded through FNV-1a. This is the identity the trace
/// footer records and the chaos-equivalence / replay gates compare.
pub fn model_digest(m: &TensorModel) -> u64 {
    let mut d = FNV64_INIT;
    for t in &m.tensors {
        d = fnv1a64(d, t.name.as_bytes());
        let mut bytes = Vec::with_capacity(t.data.len() * 4);
        for v in &t.data {
            bytes.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        d = fnv1a64(d, &bytes);
    }
    d
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

fn put_ids(buf: &mut Vec<u8>, ids: &[String]) {
    put_u32(buf, ids.len() as u32);
    for id in ids {
        put_str(buf, id);
    }
}

/// Append-only event recorder. The controller owns one behind a mutex
/// whose guard is held across each recorded event *and* the state
/// mutation it describes, so the buffer order is the controller's
/// observed timeline.
pub struct TraceRecorder {
    buf: Vec<u8>,
    events: u64,
}

impl TraceRecorder {
    pub fn new(env_source: &str) -> TraceRecorder {
        let mut buf = Vec::with_capacity(env_source.len() + 4096);
        buf.extend_from_slice(TRACE_MAGIC);
        put_u32(&mut buf, env_source.len() as u32);
        buf.extend_from_slice(env_source.as_bytes());
        TraceRecorder { buf, events: 0 }
    }

    fn event(&mut self, kind: u8, tick: Timestamp, payload: &[u8]) {
        self.buf.push(kind);
        put_u64(&mut self.buf, tick.as_nanos() as u64);
        put_u32(&mut self.buf, payload.len() as u32);
        self.buf.extend_from_slice(payload);
        self.events += 1;
    }

    /// Events recorded so far (footer excluded).
    pub fn events(&self) -> u64 {
        self.events
    }

    /// One raw inbound frame, byte-exact as it arrived on the wire.
    pub fn inbound(&mut self, tick: Timestamp, wire: &[u8]) {
        self.event(EV_INBOUND, tick, wire);
    }

    /// Scheduler opened `round` expecting `ids`.
    pub fn round_open(&mut self, tick: Timestamp, round: u64, ids: &[String]) {
        let mut p = Vec::with_capacity(12 + ids.len() * 16);
        put_u64(&mut p, round);
        put_ids(&mut p, ids);
        self.event(EV_ROUND_OPEN, tick, &p);
    }

    /// Round barrier closed; `arrived` (sorted) made the cut.
    pub fn round_close(&mut self, tick: Timestamp, round: u64, arrived: &[String]) {
        let mut p = Vec::with_capacity(12 + arrived.len() * 16);
        put_u64(&mut p, round);
        put_ids(&mut p, arrived);
        self.event(EV_ROUND_CLOSE, tick, &p);
    }

    /// Scheduler aggregated `ids`' stored models into round `round`.
    pub fn aggregate(&mut self, tick: Timestamp, round: u64, ids: &[String]) {
        let mut p = Vec::with_capacity(12 + ids.len() * 16);
        put_u64(&mut p, round);
        put_ids(&mut p, ids);
        self.event(EV_AGGREGATE, tick, &p);
    }

    /// Async scheduler marked a task outstanding for `id`.
    pub fn mark_outstanding(&mut self, tick: Timestamp, id: &str) {
        let mut p = Vec::with_capacity(id.len() + 4);
        put_str(&mut p, id);
        self.event(EV_MARK_OUTSTANDING, tick, &p);
    }

    /// Dispatch installed the community-at-`round` model as `id`'s
    /// delta base (the model itself is reconstructed from the replay's
    /// own community history — see [`replay`]).
    pub fn base_set(&mut self, tick: Timestamp, id: &str, round: u64) {
        let mut p = Vec::with_capacity(id.len() + 12);
        put_str(&mut p, id);
        put_u64(&mut p, round);
        self.event(EV_BASE_SET, tick, &p);
    }

    /// Controller-side spans, batched (kind 7). Spans are observability
    /// payload only: replay ignores them; `metisfl trace dump` renders
    /// them as a per-trace timeline.
    pub fn spans(&mut self, tick: Timestamp, spans: &[crate::obs::Span]) {
        if spans.is_empty() {
            return;
        }
        let mut p = Vec::with_capacity(4 + spans.len() * 72);
        put_u32(&mut p, spans.len() as u32);
        for s in spans {
            put_u64(&mut p, s.trace_id);
            put_u64(&mut p, s.span_id);
            put_u64(&mut p, s.parent);
            put_str(&mut p, s.op);
            put_str(&mut p, &s.peer);
            put_u64(&mut p, s.round);
            put_u64(&mut p, s.task_id);
            put_u64(&mut p, s.stream_id);
            put_u64(&mut p, s.t_start.as_nanos() as u64);
            put_u64(&mut p, s.t_end.as_nanos() as u64);
        }
        self.event(EV_SPANS, tick, &p);
    }

    /// Seal the trace: append the footer (final community digest +
    /// counter snapshot) and hand back the finished bytes.
    pub fn finish(mut self, community_digest: u64, counters: &BTreeMap<String, u64>) -> Vec<u8> {
        let mut p = Vec::with_capacity(16 + counters.len() * 32);
        put_u64(&mut p, community_digest);
        put_u32(&mut p, counters.len() as u32);
        for (k, v) in counters {
            put_str(&mut p, k);
            put_u64(&mut p, *v);
        }
        // The footer is a summary, not a timeline entry: tick 0.
        self.event(EV_FOOTER, Duration::ZERO, &p);
        self.buf
    }
}

/// One span as recorded in a trace. Mirrors [`crate::obs::Span`] with
/// an owned `op` (the in-memory span uses a static vocabulary).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpanRecord {
    pub trace_id: u64,
    pub span_id: u64,
    pub parent: u64,
    pub op: String,
    pub peer: String,
    pub round: u64,
    pub task_id: u64,
    pub stream_id: u64,
    pub t_start: Timestamp,
    pub t_end: Timestamp,
}

/// One decoded trace event (tick carried alongside in [`Trace`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TraceEvent {
    Inbound { wire: Vec<u8> },
    RoundOpen { round: u64, ids: Vec<String> },
    RoundClose { round: u64, arrived: Vec<String> },
    Aggregate { round: u64, ids: Vec<String> },
    MarkOutstanding { id: String },
    BaseSet { id: String, round: u64 },
    Spans { spans: Vec<SpanRecord> },
}

/// A fully parsed trace: environment + timeline + footer.
#[derive(Debug, Clone)]
pub struct Trace {
    pub env_source: String,
    pub events: Vec<(Timestamp, TraceEvent)>,
    pub community_digest: u64,
    pub counters: BTreeMap<String, u64>,
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            bail!("trace truncated at byte {} (wanted {n} more)", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str_block(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        Ok(String::from_utf8(self.take(n)?.to_vec()).context("non-UTF-8 string in trace")?)
    }

    fn ids(&mut self) -> Result<Vec<String>> {
        let n = self.u32()?;
        (0..n).map(|_| self.str_block()).collect()
    }

    fn done(&self) -> bool {
        self.pos >= self.buf.len()
    }
}

impl Trace {
    /// Parse a finished trace. Fails on bad magic, truncation, or a
    /// missing footer (an unfinished recording is not replayable — its
    /// expected digest was never sealed).
    pub fn decode(bytes: &[u8]) -> Result<Trace> {
        let mut c = Cursor { buf: bytes, pos: 0 };
        if c.take(TRACE_MAGIC.len()).map(|m| m != TRACE_MAGIC).unwrap_or(true) {
            bail!("not a MetisFL trace (bad magic; expected {:?})", TRACE_MAGIC);
        }
        let env_len = c.u32()? as usize;
        let env_source =
            String::from_utf8(c.take(env_len)?.to_vec()).context("non-UTF-8 trace env")?;
        let mut events = Vec::new();
        let mut footer: Option<(u64, BTreeMap<String, u64>)> = None;
        while !c.done() {
            let kind = c.u8()?;
            let tick = Duration::from_nanos(c.u64()?);
            let len = c.u32()? as usize;
            let mut p = Cursor { buf: c.take(len)?, pos: 0 };
            match kind {
                EV_INBOUND => {
                    events.push((tick, TraceEvent::Inbound { wire: p.buf.to_vec() }));
                }
                EV_ROUND_OPEN => {
                    events.push((tick, TraceEvent::RoundOpen { round: p.u64()?, ids: p.ids()? }));
                }
                EV_ROUND_CLOSE => {
                    events.push((
                        tick,
                        TraceEvent::RoundClose { round: p.u64()?, arrived: p.ids()? },
                    ));
                }
                EV_AGGREGATE => {
                    events.push((tick, TraceEvent::Aggregate { round: p.u64()?, ids: p.ids()? }));
                }
                EV_MARK_OUTSTANDING => {
                    events.push((tick, TraceEvent::MarkOutstanding { id: p.str_block()? }));
                }
                EV_BASE_SET => {
                    events.push((
                        tick,
                        TraceEvent::BaseSet { id: p.str_block()?, round: p.u64()? },
                    ));
                }
                EV_SPANS => {
                    let n = p.u32()?;
                    let mut spans = Vec::with_capacity(n as usize);
                    for _ in 0..n {
                        spans.push(SpanRecord {
                            trace_id: p.u64()?,
                            span_id: p.u64()?,
                            parent: p.u64()?,
                            op: p.str_block()?,
                            peer: p.str_block()?,
                            round: p.u64()?,
                            task_id: p.u64()?,
                            stream_id: p.u64()?,
                            t_start: Duration::from_nanos(p.u64()?),
                            t_end: Duration::from_nanos(p.u64()?),
                        });
                    }
                    events.push((tick, TraceEvent::Spans { spans }));
                }
                EV_FOOTER => {
                    let digest = p.u64()?;
                    let n = p.u32()?;
                    let mut counters = BTreeMap::new();
                    for _ in 0..n {
                        let k = p.str_block()?;
                        let v = p.u64()?;
                        counters.insert(k, v);
                    }
                    footer = Some((digest, counters));
                    if !c.done() {
                        bail!("trace has {} trailing bytes after the footer", c.buf.len() - c.pos);
                    }
                }
                other => bail!("unknown trace event kind {other} at byte {}", c.pos),
            }
        }
        let (community_digest, counters) =
            footer.context("trace has no footer (recording was never finished)")?;
        Ok(Trace { env_source, events, community_digest, counters })
    }
}

/// What a replay produced, against what the recording promised.
#[derive(Debug)]
pub struct ReplayOutcome {
    /// Timeline events applied (footer excluded).
    pub events: usize,
    pub recorded_digest: u64,
    pub replayed_digest: u64,
    pub recorded_counters: BTreeMap<String, u64>,
    pub replayed_counters: BTreeMap<String, u64>,
    /// First detected divergence; `None` means the replay reproduced
    /// the recorded community model bitwise (and every round closed on
    /// the recorded membership).
    pub divergence: Option<String>,
}

impl ReplayOutcome {
    pub fn matches(&self) -> bool {
        self.divergence.is_none()
    }

    /// Mismatches among [`REPLAYABLE_COUNTERS`] as
    /// `(name, recorded, replayed)`. Informational alongside the digest
    /// gate: a chaos run sealed while abandoned streams still had
    /// decode work in flight can legitimately differ by a few wire
    /// bytes without the math diverging.
    pub fn counter_diffs(&self) -> Vec<(String, u64, u64)> {
        REPLAYABLE_COUNTERS
            .iter()
            .filter_map(|name| {
                let rec = self.recorded_counters.get(*name).copied().unwrap_or(0);
                let rep = self.replayed_counters.get(*name).copied().unwrap_or(0);
                (rec != rep).then(|| (name.to_string(), rec, rep))
            })
            .collect()
    }
}

/// Decode `bytes` and [`replay`] the trace.
pub fn replay_trace(bytes: &[u8]) -> Result<ReplayOutcome> {
    let trace = Trace::decode(bytes)?;
    replay(&trace)
}

/// Re-drive a fresh controller from a recorded trace on a simulated
/// clock and compare the outcome against the footer. Structural
/// failures (undecodable frame, aggregation error) return `Err`;
/// behavioral divergence lands in [`ReplayOutcome::divergence`] so the
/// caller can print both digests.
pub fn replay(trace: &Trace) -> Result<ReplayOutcome> {
    let env = FederationEnv::from_yaml(&trace.env_source)
        .context("parsing the trace's embedded environment")?;
    let clock = Clock::sim();
    let controller = Controller::with_clock(env, None, clock.clone())?;
    // Community snapshots by round, for BaseSet reconstruction: the
    // live insert always stored a pointer to the model that was the
    // community at `round`, which this replay has just as well — it
    // built it from the same events.
    let mut history: BTreeMap<u64, Arc<TensorModel>> = BTreeMap::new();
    let mut divergence: Option<String> = None;
    for (i, (tick, ev)) in trace.events.iter().enumerate() {
        clock.advance_to(*tick);
        match ev {
            TraceEvent::Inbound { wire } => {
                let msg = Message::decode(wire)
                    .with_context(|| format!("trace event {i}: undecodable inbound frame"))?;
                let reply = controller.handle(msg);
                // Refusals are part of the recorded behavior (delta-base
                // misses, duplicate-completion gates): they must re-occur
                // identically, never abort the replay.
                if matches!(reply, Message::Error { .. }) {
                    log_debug("replay", &format!("event {i}: inbound refused: {reply:?}"));
                }
            }
            TraceEvent::RoundOpen { round, ids } => controller.replay_open_round(*round, ids),
            TraceEvent::RoundClose { round, arrived } => {
                let got = controller.replay_close_round();
                if got != *arrived && divergence.is_none() {
                    divergence = Some(format!(
                        "round {round} closed on {got:?}; the recording closed on {arrived:?}"
                    ));
                }
            }
            TraceEvent::Aggregate { round, ids } => {
                controller
                    .replay_aggregate(ids, *round)
                    .with_context(|| format!("trace event {i}: aggregate for round {round}"))?;
            }
            TraceEvent::MarkOutstanding { id } => controller.replay_mark_outstanding(id),
            TraceEvent::BaseSet { id, round } => match history.get(round) {
                Some(m) => controller.replay_set_base(id, *round, Arc::clone(m)),
                None if divergence.is_none() => {
                    divergence = Some(format!(
                        "trace event {i}: no community snapshot for round {round} \
                         (history cap {BASE_HISTORY_CAP})"
                    ));
                }
                None => {}
            },
            // Spans are observability payload: they never influenced the
            // recorded controller's state, so replay skips them.
            TraceEvent::Spans { .. } => {}
        }
        if let Some((m, r)) = controller.community() {
            history.insert(r, m);
            while history.len() > BASE_HISTORY_CAP {
                let oldest = *history.keys().next().expect("non-empty history");
                history.remove(&oldest);
            }
        }
    }
    let replayed_digest = controller.community().map(|(m, _)| model_digest(&m)).unwrap_or(0);
    if divergence.is_none() && replayed_digest != trace.community_digest {
        divergence = Some(format!(
            "community digest {replayed_digest:#018x} != recorded {:#018x}",
            trace.community_digest
        ));
    }
    Ok(ReplayOutcome {
        events: trace.events.len(),
        recorded_digest: trace.community_digest,
        replayed_digest,
        recorded_counters: trace.counters.clone(),
        replayed_counters: controller.counters().snapshot(),
        divergence,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(ms: u64) -> Timestamp {
        Duration::from_millis(ms)
    }

    #[test]
    fn recorder_roundtrips_through_the_decoder() {
        let mut rec = TraceRecorder::new("learners: 2\n");
        rec.inbound(t(1), &[1, 2, 3]);
        rec.round_open(t(2), 1, &["a".into(), "b".into()]);
        rec.mark_outstanding(t(3), "a");
        rec.base_set(t(4), "b", 7);
        rec.round_close(t(5), 1, &["a".into()]);
        rec.aggregate(t(6), 1, &["a".into()]);
        assert_eq!(rec.events(), 6);
        let mut counters = BTreeMap::new();
        counters.insert("late_folds".to_string(), 3u64);
        counters.insert("wire_bytes_in".to_string(), 1024u64);
        let bytes = rec.finish(0xDEAD_BEEF, &counters);

        let trace = Trace::decode(&bytes).unwrap();
        assert_eq!(trace.env_source, "learners: 2\n");
        assert_eq!(trace.community_digest, 0xDEAD_BEEF);
        assert_eq!(trace.counters, counters);
        assert_eq!(trace.events.len(), 6);
        assert_eq!(trace.events[0], (t(1), TraceEvent::Inbound { wire: vec![1, 2, 3] }));
        assert_eq!(
            trace.events[1],
            (t(2), TraceEvent::RoundOpen { round: 1, ids: vec!["a".into(), "b".into()] })
        );
        assert_eq!(trace.events[2], (t(3), TraceEvent::MarkOutstanding { id: "a".into() }));
        assert_eq!(trace.events[3], (t(4), TraceEvent::BaseSet { id: "b".into(), round: 7 }));
        assert_eq!(
            trace.events[4],
            (t(5), TraceEvent::RoundClose { round: 1, arrived: vec!["a".into()] })
        );
        assert_eq!(
            trace.events[5],
            (t(6), TraceEvent::Aggregate { round: 1, ids: vec!["a".into()] })
        );
    }

    #[test]
    fn span_batches_roundtrip_and_replay_ignores_them() {
        use crate::obs::SpanSink;
        use crate::util::clock::Clock;
        let clock = Clock::sim();
        let sink = SpanSink::new("controller", clock.clone());
        sink.enable();
        let root = sink.begin("round", crate::obs::SpanCtx::UNSET).round(1);
        clock.advance_to(Duration::from_millis(5));
        let child = sink.begin("dispatch", root.ctx()).peer("l0").round(1).task(1);
        clock.advance_to(Duration::from_millis(8));
        child.end();
        root.end();
        let spans = sink.drain();
        assert_eq!(spans.len(), 2);

        let mut rec = TraceRecorder::new("learners: 1\n");
        rec.spans(t(9), &spans);
        // An empty batch records nothing.
        rec.spans(t(10), &[]);
        assert_eq!(rec.events(), 1);
        let bytes = rec.finish(0, &BTreeMap::new());
        let trace = Trace::decode(&bytes).unwrap();
        assert_eq!(trace.events.len(), 1);
        match &trace.events[0].1 {
            TraceEvent::Spans { spans: got } => {
                assert_eq!(got.len(), 2);
                let dispatch = got.iter().find(|s| s.op == "dispatch").unwrap();
                let round = got.iter().find(|s| s.op == "round").unwrap();
                assert_eq!(dispatch.parent, round.span_id);
                assert_eq!(dispatch.trace_id, round.trace_id);
                assert_eq!(dispatch.peer, "l0");
                assert_eq!(dispatch.t_start, Duration::from_millis(5));
                assert_eq!(dispatch.t_end, Duration::from_millis(8));
            }
            other => panic!("unexpected event {other:?}"),
        }
    }

    #[test]
    fn decoder_rejects_bad_magic_truncation_and_unfinished_traces() {
        assert!(Trace::decode(b"not a trace at all").is_err());
        let bytes = TraceRecorder::new("x: 1\n").finish(7, &BTreeMap::new());
        assert!(Trace::decode(&bytes).is_ok());
        assert!(Trace::decode(&bytes[..bytes.len() - 3]).is_err(), "truncated footer");
        // An unfinished recording (no footer) is not replayable.
        let mut rec = TraceRecorder::new("x: 1\n");
        rec.inbound(t(1), &[9]);
        let unfinished = rec.buf.clone();
        let err = format!("{:#}", Trace::decode(&unfinished).unwrap_err());
        assert!(err.contains("footer"), "{err}");
    }

    #[test]
    fn model_digest_separates_name_and_bit_changes() {
        use crate::config::ModelSpec;
        use crate::util::Rng;
        let layout = ModelSpec::mlp(4, 1, 4).tensor_layout();
        let a = TensorModel::random_init(&layout, &mut Rng::new(1));
        let b = TensorModel::random_init(&layout, &mut Rng::new(2));
        assert_eq!(model_digest(&a), model_digest(&a));
        assert_ne!(model_digest(&a), model_digest(&b));
    }
}
