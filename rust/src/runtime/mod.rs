//! PJRT runtime: load and execute the AOT-compiled L2/L1 artifacts.
//!
//! `make artifacts` lowers the JAX model (`python/compile/`) to HLO text
//! files plus a `manifest.json`. This module loads them through the `xla`
//! crate (`PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `compile` → `execute`) and exposes:
//!
//! * [`XlaTrainer`] — a [`Trainer`] running the compiled
//!   `train_step`/`eval_step` (real local training on the request path,
//!   no Python),
//! * [`xla_fedavg_backend`] — the compiled Pallas lincomb kernel as an
//!   aggregation [`Backend`](crate::controller::aggregation::Backend)
//!   for the XLA-aggregation ablation.
//!
//! The `xla` crate's types are `Rc`-based (thread-confined), so a single
//! [`XlaService`] thread owns the PJRT client and all compiled
//! executables; callers talk to it over channels with plain `Vec<f32>`
//! payloads. One compile per artifact per process (cached), shared by all
//! simulated learners.

pub mod trace;

use crate::config::ModelSpec;
use crate::json::{self, Value};
use crate::learner::{Dataset, Trainer};
use crate::proto::{EvalResult, TaskMeta, TaskSpec};
use crate::tensor::TensorModel;
use crate::util::{log_info, Stopwatch};
use anyhow::{bail, Context, Result};
use once_cell::sync::Lazy;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::Mutex;

/// A tensor crossing the service channel: data + shape.
pub type HostTensor = (Vec<f32>, Vec<i64>);

enum XlaReq {
    Compile { path: PathBuf, reply: mpsc::Sender<Result<usize>> },
    Execute { exe: usize, inputs: Vec<HostTensor>, reply: mpsc::Sender<Result<Vec<Vec<f32>>>> },
}

/// Handle to the process-wide XLA service thread.
pub struct XlaService {
    tx: Mutex<mpsc::Sender<XlaReq>>,
}

static SERVICE: Lazy<XlaService> = Lazy::new(XlaService::spawn);

impl XlaService {
    /// The process-wide service (PJRT client created on first use).
    pub fn global() -> &'static XlaService {
        &SERVICE
    }

    fn spawn() -> XlaService {
        let (tx, rx) = mpsc::channel::<XlaReq>();
        std::thread::Builder::new()
            .name("metisfl-xla".into())
            .spawn(move || Self::serve(rx))
            .expect("spawn xla service");
        XlaService { tx: Mutex::new(tx) }
    }

    fn serve(rx: mpsc::Receiver<XlaReq>) {
        let client = match xla::PjRtClient::cpu() {
            Ok(c) => c,
            Err(e) => {
                // Fail every request with a clear error.
                while let Ok(req) = rx.recv() {
                    let msg = format!("PJRT CPU client unavailable: {e}");
                    match req {
                        XlaReq::Compile { reply, .. } => {
                            let _ = reply.send(Err(anyhow::anyhow!(msg)));
                        }
                        XlaReq::Execute { reply, .. } => {
                            let _ = reply.send(Err(anyhow::anyhow!(msg)));
                        }
                    }
                }
                return;
            }
        };
        log_info("runtime", &format!("PJRT client up: {}", client.platform_name()));
        let mut exes: Vec<xla::PjRtLoadedExecutable> = Vec::new();
        let mut cache: HashMap<PathBuf, usize> = HashMap::new();
        while let Ok(req) = rx.recv() {
            match req {
                XlaReq::Compile { path, reply } => {
                    let result = (|| -> Result<usize> {
                        if let Some(&id) = cache.get(&path) {
                            return Ok(id);
                        }
                        let sw = Stopwatch::start();
                        let proto = xla::HloModuleProto::from_text_file(&path)
                            .map_err(|e| anyhow::anyhow!("parse {path:?}: {e}"))?;
                        let comp = xla::XlaComputation::from_proto(&proto);
                        let exe = client
                            .compile(&comp)
                            .map_err(|e| anyhow::anyhow!("compile {path:?}: {e}"))?;
                        let id = exes.len();
                        exes.push(exe);
                        cache.insert(path.clone(), id);
                        log_info(
                            "runtime",
                            &format!("compiled {path:?} in {:?} (exe #{id})", sw.elapsed()),
                        );
                        Ok(id)
                    })();
                    let _ = reply.send(result);
                }
                XlaReq::Execute { exe, inputs, reply } => {
                    let result = (|| -> Result<Vec<Vec<f32>>> {
                        let e = exes
                            .get(exe)
                            .ok_or_else(|| anyhow::anyhow!("bad exe id {exe}"))?;
                        let literals: Vec<xla::Literal> = inputs
                            .iter()
                            .map(|(data, shape)| -> Result<xla::Literal> {
                                let lit = xla::Literal::vec1(data);
                                if shape.len() == 1 && shape[0] as usize == data.len() {
                                    Ok(lit)
                                } else {
                                    lit.reshape(shape)
                                        .map_err(|er| anyhow::anyhow!("reshape: {er}"))
                                }
                            })
                            .collect::<Result<_>>()?;
                        let out = e
                            .execute::<xla::Literal>(&literals)
                            .map_err(|er| anyhow::anyhow!("execute: {er}"))?;
                        let root = out[0][0]
                            .to_literal_sync()
                            .map_err(|er| anyhow::anyhow!("fetch: {er}"))?;
                        // Artifacts are lowered with return_tuple=True.
                        let parts = root
                            .to_tuple()
                            .map_err(|er| anyhow::anyhow!("untuple: {er}"))?;
                        parts
                            .into_iter()
                            .map(|p| {
                                p.to_vec::<f32>().map_err(|er| anyhow::anyhow!("to_vec: {er}"))
                            })
                            .collect()
                    })();
                    let _ = reply.send(result);
                }
            }
        }
    }

    /// Compile (or fetch from cache) an HLO text file.
    pub fn compile(&self, path: &Path) -> Result<usize> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(XlaReq::Compile { path: path.to_path_buf(), reply })
            .map_err(|_| anyhow::anyhow!("xla service down"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("xla service dropped reply"))?
    }

    /// Execute a compiled module; returns the decomposed output tuple.
    pub fn execute(&self, exe: usize, inputs: Vec<HostTensor>) -> Result<Vec<Vec<f32>>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(XlaReq::Execute { exe, inputs, reply })
            .map_err(|_| anyhow::anyhow!("xla service down"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("xla service dropped reply"))?
    }
}

/// One model variant's artifact set, per `manifest.json`.
#[derive(Debug, Clone)]
pub struct VariantInfo {
    pub name: String,
    pub train_file: String,
    pub eval_file: String,
    pub lincomb_file: String,
    pub param_count: usize,
    pub input_dim: usize,
    pub hidden_layers: usize,
    pub hidden_units: usize,
    pub batch: usize,
}

/// Loaded artifact manifest.
pub struct Artifacts {
    pub dir: PathBuf,
    variants: HashMap<String, VariantInfo>,
}

impl Artifacts {
    pub fn load(dir: impl Into<PathBuf>) -> Result<Artifacts> {
        let dir = dir.into();
        let manifest_path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts`"))?;
        let v = json::parse(&src).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let mut variants = HashMap::new();
        let vmap = v
            .get("variants")
            .and_then(Value::as_object)
            .ok_or_else(|| anyhow::anyhow!("manifest missing 'variants'"))?;
        for (name, info) in vmap {
            let get_str = |k: &str| -> Result<String> {
                Ok(info
                    .get(k)
                    .and_then(Value::as_str)
                    .ok_or_else(|| anyhow::anyhow!("variant {name}: missing {k}"))?
                    .to_string())
            };
            let get_n = |k: &str| -> Result<usize> {
                info.get(k)
                    .and_then(Value::as_usize)
                    .ok_or_else(|| anyhow::anyhow!("variant {name}: missing {k}"))
            };
            variants.insert(
                name.clone(),
                VariantInfo {
                    name: name.clone(),
                    train_file: get_str("train")?,
                    eval_file: get_str("eval")?,
                    lincomb_file: get_str("lincomb")?,
                    param_count: get_n("param_count")?,
                    input_dim: get_n("input_dim")?,
                    hidden_layers: get_n("hidden_layers")?,
                    hidden_units: get_n("hidden_units")?,
                    batch: get_n("batch")?,
                },
            );
        }
        Ok(Artifacts { dir, variants })
    }

    pub fn variant(&self, name: &str) -> Option<&VariantInfo> {
        self.variants.get(name)
    }

    pub fn variant_names(&self) -> Vec<&str> {
        self.variants.keys().map(|s| s.as_str()).collect()
    }

    /// Find the variant matching a model spec.
    pub fn for_spec(&self, spec: &ModelSpec) -> Result<&VariantInfo> {
        self.variant(&spec.variant_name()).ok_or_else(|| {
            anyhow::anyhow!(
                "no artifact variant '{}' (have: {:?}) — run `make artifacts`",
                spec.variant_name(),
                self.variants.keys().collect::<Vec<_>>()
            )
        })
    }

    fn file(&self, name: &str) -> PathBuf {
        self.dir.join(name)
    }
}

/// Real local training via the AOT-compiled JAX steps.
pub struct XlaTrainer {
    train_exe: usize,
    eval_exe: usize,
    batch: usize,
    features: usize,
    layout: Vec<(String, Vec<usize>)>,
    param_count: usize,
}

impl XlaTrainer {
    /// Load + compile the artifacts for `spec` (cached per process).
    pub fn load(artifacts_dir: &str, spec: &ModelSpec) -> Result<XlaTrainer> {
        let arts = Artifacts::load(artifacts_dir)?;
        let info = arts.for_spec(spec)?;
        if info.param_count != spec.param_count() {
            bail!(
                "artifact param count {} != spec {} — stale artifacts?",
                info.param_count,
                spec.param_count()
            );
        }
        let svc = XlaService::global();
        let train_exe = svc.compile(&arts.file(&info.train_file))?;
        let eval_exe = svc.compile(&arts.file(&info.eval_file))?;
        Ok(XlaTrainer {
            train_exe,
            eval_exe,
            batch: info.batch,
            features: info.input_dim,
            layout: spec.tensor_layout(),
            param_count: info.param_count,
        })
    }

    /// Pad/repeat a short batch to the compiled static batch size.
    fn pad_batch(&self, x: &[f32], y: &[f32]) -> (Vec<f32>, Vec<f32>) {
        let rows = y.len();
        if rows == self.batch {
            return (x.to_vec(), y.to_vec());
        }
        let mut xp = Vec::with_capacity(self.batch * self.features);
        let mut yp = Vec::with_capacity(self.batch);
        for r in 0..self.batch {
            let src = r % rows;
            xp.extend_from_slice(&x[src * self.features..(src + 1) * self.features]);
            yp.push(y[src]);
        }
        (xp, yp)
    }
}

impl Trainer for XlaTrainer {
    fn train(
        &self,
        model: &TensorModel,
        data: &Dataset,
        spec: &TaskSpec,
    ) -> Result<(TensorModel, TaskMeta)> {
        if data.features != self.features {
            bail!("dataset features {} != compiled {}", data.features, self.features);
        }
        let sw = Stopwatch::start();
        let svc = XlaService::global();
        let mut flat = model.to_flat();
        if flat.len() != self.param_count {
            bail!("model params {} != compiled {}", flat.len(), self.param_count);
        }
        let mut steps = 0usize;
        let mut last_loss = 0.0f64;
        let budget = if spec.step_budget > 0 { spec.step_budget } else { usize::MAX };
        let lr = spec.learning_rate as f32;
        'outer: for _ in 0..spec.epochs.max(1) {
            for (xb, yb) in data.train_batches(self.batch) {
                let (xp, yp) = self.pad_batch(xb, yb);
                let out = svc.execute(
                    self.train_exe,
                    vec![
                        (std::mem::take(&mut flat), vec![self.param_count as i64]),
                        (xp, vec![self.batch as i64, self.features as i64]),
                        (yp, vec![self.batch as i64]),
                        (vec![lr], vec![]),
                    ],
                )?;
                let mut it = out.into_iter();
                flat = it.next().ok_or_else(|| anyhow::anyhow!("train_step: no params out"))?;
                last_loss = it
                    .next()
                    .and_then(|l| l.first().copied())
                    .ok_or_else(|| anyhow::anyhow!("train_step: no loss out"))?
                    as f64;
                steps += 1;
                if steps >= budget {
                    break 'outer;
                }
            }
        }
        let trained = TensorModel::from_flat(&self.layout, &flat)?;
        let elapsed = sw.elapsed();
        Ok((
            trained,
            TaskMeta {
                train_time_per_batch_us: (elapsed.as_micros() as u64 / steps.max(1) as u64)
                    .max(1),
                completed_steps: steps,
                completed_epochs: spec.epochs.max(1),
                num_samples: data.train_len(),
                train_loss: last_loss,
                steps_per_sec: steps.max(1) as f64 / elapsed.as_secs_f64().max(1e-9),
                train_wall_time_us: (elapsed.as_micros() as u64).max(1),
                ..TaskMeta::default()
            },
        ))
    }

    fn evaluate(&self, model: &TensorModel, data: &Dataset) -> Result<EvalResult> {
        let sw = Stopwatch::start();
        let svc = XlaService::global();
        let flat = model.to_flat();
        let mut total = 0.0f64;
        let mut batches = 0usize;
        for (xb, yb) in data.test_batches(self.batch) {
            let (xp, yp) = self.pad_batch(xb, yb);
            let out = svc.execute(
                self.eval_exe,
                vec![
                    (flat.clone(), vec![self.param_count as i64]),
                    (xp, vec![self.batch as i64, self.features as i64]),
                    (yp, vec![self.batch as i64]),
                ],
            )?;
            total += out
                .first()
                .and_then(|l| l.first().copied())
                .ok_or_else(|| anyhow::anyhow!("eval_step: no loss out"))? as f64;
            batches += 1;
        }
        Ok(EvalResult {
            loss: total / batches.max(1) as f64,
            num_samples: data.test_len(),
            eval_time_us: sw.elapsed().as_micros() as u64,
        })
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

/// Build the XLA aggregation backend from the compiled Pallas lincomb
/// kernel: `lincomb(a, b, wa, wb) = wa·a + wb·b` over flat params.
/// The weighted sum over N models is a left fold of N−1 lincomb calls.
/// Models arrive as `Arc`s (the controller's zero-copy plumbing); the
/// only copies made here are the flat staging buffers PJRT consumes.
pub fn xla_fedavg_backend(
    artifacts_dir: &str,
    spec: &ModelSpec,
) -> Result<crate::controller::aggregation::XlaAggFn> {
    let arts = Artifacts::load(artifacts_dir)?;
    let info = arts.for_spec(spec)?;
    let exe = XlaService::global().compile(&arts.file(&info.lincomb_file))?;
    let param_count = info.param_count;
    let layout = spec.tensor_layout();
    Ok(std::sync::Arc::new(move |models: &[std::sync::Arc<TensorModel>], coeffs: &[f64]| {
        if models.is_empty() {
            bail!("xla aggregation with zero models");
        }
        let svc = XlaService::global();
        let dims = vec![param_count as i64];
        let mut acc = models[0].to_flat();
        let mut acc_w = coeffs[0] as f32;
        for (m, &c) in models.iter().zip(coeffs).skip(1) {
            let out = svc.execute(
                exe,
                vec![
                    (acc, dims.clone()),
                    (m.to_flat(), dims.clone()),
                    (vec![acc_w], vec![]),
                    (vec![c as f32], vec![]),
                ],
            )?;
            acc = out.into_iter().next().ok_or_else(|| anyhow::anyhow!("lincomb: no out"))?;
            acc_w = 1.0; // coefficients already applied into acc
        }
        if acc_w != 1.0 {
            for v in acc.iter_mut() {
                *v *= acc_w;
            }
        }
        TensorModel::from_flat(&layout, &acc)
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_missing_dir_is_helpful_error() {
        let e = Artifacts::load("/nonexistent-metisfl").err().unwrap();
        assert!(format!("{e:#}").contains("make artifacts"));
    }

    #[test]
    fn manifest_parsing() {
        let dir = std::env::temp_dir().join(format!("metisfl-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"variants":{"mlp_l2_u8_in4_out1":{"train":"t.hlo.txt","eval":"e.hlo.txt",
                "lincomb":"l.hlo.txt","param_count":121,"input_dim":4,"hidden_layers":2,
                "hidden_units":8,"batch":16}}}"#,
        )
        .unwrap();
        let arts = Artifacts::load(&dir).unwrap();
        let spec = ModelSpec::mlp(4, 2, 8);
        let info = arts.for_spec(&spec).unwrap();
        assert_eq!(info.param_count, 121);
        assert_eq!(info.batch, 16);
        assert!(arts.variant("nope").is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    // Real execution tests live in rust/tests/runtime_xla.rs (they need
    // `make artifacts` to have run).
}
