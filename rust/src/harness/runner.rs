//! Measurement loops and report emission.

use crate::util::{fmt_duration, Stopwatch, Summary};
use std::io::Write;
use std::path::PathBuf;
use std::time::Duration;

/// Warmup + sample loop (criterion's core loop, simplified).
pub struct BenchRunner {
    pub warmup: usize,
    pub samples: usize,
}

impl BenchRunner {
    pub fn new() -> BenchRunner {
        // Keep CI cheap; benches override with FULL=1.
        if full_scale() {
            BenchRunner { warmup: 2, samples: 7 }
        } else {
            BenchRunner { warmup: 1, samples: 3 }
        }
    }

    /// Measure `f` (seconds per call).
    pub fn run(&self, mut f: impl FnMut()) -> Summary {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let sw = Stopwatch::start();
            f();
            samples.push(sw.elapsed_secs());
        }
        Summary::of(&samples)
    }
}

impl Default for BenchRunner {
    fn default() -> Self {
        Self::new()
    }
}

/// `FULL=1` switches every bench to the paper's full sweep.
pub fn full_scale() -> bool {
    std::env::var("FULL").map(|v| v == "1").unwrap_or(false)
}

/// Collects rows and writes aligned markdown to stdout + CSV and JSON
/// to `bench_out/<name>.{csv,json}`. The JSON form is what the CI
/// `bench-regression` job merges into `BENCH_<sha>.json` and diffs
/// against the checked-in `BENCH_baseline.json` (see `metisfl
/// bench-check`).
pub struct ReportWriter {
    name: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl ReportWriter {
    pub fn new(name: &str, headers: &[&str]) -> ReportWriter {
        ReportWriter {
            name: name.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, values: Vec<String>) {
        assert_eq!(values.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(values);
    }

    /// Render the aligned markdown table.
    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, v) in widths.iter_mut().zip(row) {
                *w = (*w).max(v.len());
            }
        }
        let mut out = String::new();
        out.push('|');
        for (h, w) in self.headers.iter().zip(&widths) {
            out.push_str(&format!(" {h:<w$} |"));
        }
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push('|');
            for (v, w) in row.iter().zip(&widths) {
                out.push_str(&format!(" {v:<w$} |"));
            }
            out.push('\n');
        }
        out
    }

    /// Machine-readable form: `{name, headers, rows}`.
    pub fn to_json(&self) -> crate::json::Value {
        use crate::json::Value;
        let headers =
            Value::Array(self.headers.iter().map(|h| Value::String(h.clone())).collect());
        let rows = Value::Array(
            self.rows
                .iter()
                .map(|r| Value::Array(r.iter().map(|v| Value::String(v.clone())).collect()))
                .collect(),
        );
        Value::object(vec![
            ("name", Value::String(self.name.clone())),
            ("headers", headers),
            ("rows", rows),
        ])
    }

    /// Print markdown to stdout and persist CSV + JSON to `bench_out/`.
    pub fn emit(&self) -> std::io::Result<PathBuf> {
        println!("\n### {}\n", self.name);
        println!("{}", self.to_markdown());
        let dir = PathBuf::from("bench_out");
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("{}.csv", self.name));
        let mut f = std::fs::File::create(&path)?;
        writeln!(f, "{}", self.headers.join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        std::fs::write(
            dir.join(format!("{}.json", self.name)),
            crate::json::to_string_pretty(&self.to_json()),
        )?;
        Ok(path)
    }
}

/// Format a duration in seconds for table cells (paper reports seconds).
pub fn fmt_secs(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 100.0 {
        format!("{s:.0}")
    } else if s >= 1.0 {
        format!("{s:.2}")
    } else {
        fmt_duration(d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runner_collects_samples() {
        let r = BenchRunner { warmup: 1, samples: 4 };
        let mut calls = 0;
        let s = r.run(|| calls += 1);
        assert_eq!(calls, 5);
        assert_eq!(s.n, 4);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn report_markdown_is_aligned_and_csv_written() {
        let mut w = ReportWriter::new("test-report", &["learners", "a", "b"]);
        w.row(vec!["10".into(), "1.5".into(), "2.0".into()]);
        w.row(vec!["200".into(), "10.25".into(), "x".into()]);
        let md = w.to_markdown();
        assert!(md.contains("| learners |"));
        assert!(md.lines().count() == 4);
        let path = w.emit().unwrap();
        let csv = std::fs::read_to_string(&path).unwrap();
        assert!(csv.starts_with("learners,a,b\n"));
        assert!(csv.contains("200,10.25,x"));
        // The machine-readable twin for the CI bench-regression gate.
        let json_path = path.with_extension("json");
        let v = crate::json::parse(&std::fs::read_to_string(&json_path).unwrap()).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("test-report"));
        assert_eq!(v.get("rows").unwrap().as_array().unwrap().len(), 2);
        std::fs::remove_file(path).ok();
        std::fs::remove_file(json_path).ok();
    }

    #[test]
    fn fmt_secs_scales() {
        assert_eq!(fmt_secs(Duration::from_secs(120)), "120");
        assert_eq!(fmt_secs(Duration::from_millis(2500)), "2.50");
        assert_eq!(fmt_secs(Duration::from_millis(12)), "12.00ms");
    }
}
