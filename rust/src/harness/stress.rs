//! One stress-test federation round under a framework profile.
//!
//! Executes the controller-side operations of Fig. 1 in isolation —
//! exactly what the paper's quantitative evaluation measures (§4.2):
//! FedAvg, all learners participating, 100 samples/learner, batch 100,
//! learner compute held constant across frameworks so the differences
//! isolate the controller implementation.

use crate::baselines::{pyserde, CodecKind, DispatchKind, FrameworkProfile};
use crate::baselines::calibration::{Calibration, ParallelModel};
use crate::config::ModelSpec;
use crate::proto::{Message, ModelProto, TaskSpec};
use crate::tensor::{ByteOrder, DType, TensorModel};
use crate::util::{Rng, Stopwatch, ThreadPool};
use std::time::Duration;

/// The six per-round timings of Figs. 5–7 (panels a–f).
#[derive(Debug, Clone)]
pub struct StressTimings {
    pub train_dispatch: Duration,
    pub train_round: Duration,
    pub aggregation: Duration,
    /// Modelled parallel aggregation at the paper's 32 cores (only set
    /// for the ParallelTensor profile when real cores < tensors).
    pub aggregation_modeled: Option<Duration>,
    pub eval_dispatch: Duration,
    pub eval_round: Duration,
    pub federation_round: Duration,
}

/// Pre-built workload for one (model, learners) cell so repeated bench
/// samples don't re-generate models.
pub struct StressWorkload {
    pub spec: ModelSpec,
    pub learners: usize,
    community: TensorModel,
    updates: Vec<TensorModel>,
    weights: Vec<f64>,
    /// Constant modelled learner compute per round (same for every
    /// framework; the paper's learners are CPU-bound equals).
    pub learner_compute: Duration,
}

impl StressWorkload {
    pub fn new(spec: ModelSpec, learners: usize, seed: u64) -> StressWorkload {
        let mut rng = Rng::new(seed);
        let layout = spec.tensor_layout();
        let community = TensorModel::random_init(&layout, &mut rng);
        // Learner updates: community + small noise (cheap to generate,
        // realistic payload entropy).
        let updates: Vec<TensorModel> = (0..learners)
            .map(|_| {
                let mut m = community.clone();
                // Perturb one tensor per update; payload size is what
                // matters for codec/aggregation costs.
                let t = rng.gen_range(m.tensors.len());
                for v in m.tensors[t].data.iter_mut() {
                    *v += 0.01 * (rng.next_f32() - 0.5);
                }
                m
            })
            .collect();
        let weights = vec![100.0; learners]; // 100 samples each (§4.2)
        StressWorkload { spec, learners, community, updates, weights, learner_compute: Duration::ZERO }
    }
}

/// Encode a model under the profile's codec (dispatch path).
fn encode_model(profile: &FrameworkProfile, model: &TensorModel) -> Vec<u8> {
    match profile.codec {
        CodecKind::BytesTensor => {
            // The production path: tensor-as-bytes proto message.
            let proto = ModelProto::from_model(model, DType::F32, ByteOrder::Little);
            Message::RunTask {
                task_id: 0,
                round: 0,
                model: proto,
                spec: TaskSpec { epochs: 1, batch_size: 100, learning_rate: 0.01, step_budget: 0 },
            }
            .encode()
        }
        CodecKind::Pickle => pyserde::pickle_encode(model, profile.serde_tax),
        CodecKind::PickleBase64 => {
            let p = pyserde::pickle_encode(model, profile.serde_tax);
            pyserde::base64_encode(&p)
        }
    }
}

/// Decode under the profile's codec (reception path).
fn decode_model(profile: &FrameworkProfile, bytes: &[u8], reference: &TensorModel) -> TensorModel {
    match profile.codec {
        CodecKind::BytesTensor => match Message::decode(bytes).expect("decode") {
            Message::RunTask { model, .. } => model.to_model().expect("to_model"),
            _ => unreachable!(),
        },
        CodecKind::Pickle => pyserde::pickle_decode(bytes, profile.serde_tax).expect("unpickle"),
        CodecKind::PickleBase64 => {
            let raw = pyserde::base64_decode(bytes).expect("b64");
            pyserde::pickle_decode(&raw, profile.serde_tax).expect("unpickle")
        }
    }
    .clone_layout_check(reference)
}

trait LayoutCheck {
    fn clone_layout_check(self, reference: &TensorModel) -> TensorModel;
}

impl LayoutCheck for TensorModel {
    fn clone_layout_check(self, reference: &TensorModel) -> TensorModel {
        debug_assert_eq!(self.tensor_count(), reference.tensor_count());
        self
    }
}

/// A small control message (the workflow-engine chatter NVFlare-style
/// dispatchers pay per task).
fn control_message_roundtrip() {
    let msg = Message::Heartbeat { from: "workflow-engine".into() };
    let bytes = msg.encode();
    let _ = Message::decode(&bytes).expect("control msg");
}

/// Run one federation round under `profile`, timing each operation.
pub fn stress_round(
    profile: &FrameworkProfile,
    w: &StressWorkload,
    pool: &ThreadPool,
    cal: &Calibration,
) -> StressTimings {
    let round_sw = Stopwatch::start();

    // --- (a) training task dispatch -----------------------------------
    let sw = Stopwatch::start();
    let train_payloads: Vec<Vec<u8>> = match profile.dispatch {
        DispatchKind::AsyncPooled => {
            // MetisFL: encode once, submit through the pool (async acks).
            let encoded = encode_model(profile, &w.community);
            pool.parallel_map(w.learners, |_i| encoded.clone())
        }
        DispatchKind::SequentialPerLearner { control_msgs } => {
            // GIL frameworks: one serialize + send per learner, plus the
            // workflow engine's control chatter.
            (0..w.learners)
                .map(|_| {
                    for _ in 0..control_msgs {
                        control_message_roundtrip();
                    }
                    encode_model(profile, &w.community)
                })
                .collect()
        }
    };
    let train_dispatch = sw.elapsed();

    // --- (b) training round: learner decode + compute + upload encode --
    // Learner-side work is identical across frameworks except for the
    // codec each one forces on its clients.
    let sw = Stopwatch::start();
    let uploads: Vec<Vec<u8>> = w
        .updates
        .iter()
        .zip(&train_payloads)
        .map(|(update, payload)| {
            let _downloaded = decode_model(profile, payload, &w.community);
            if !w.learner_compute.is_zero() {
                crate::util::Clock::system().sleep(w.learner_compute);
            }
            encode_model(profile, update)
        })
        .collect();
    // Controller receives + stores every local model (shared from here
    // on — the production store/aggregation path passes `Arc`s).
    let received: Vec<std::sync::Arc<TensorModel>> = uploads
        .iter()
        .map(|u| std::sync::Arc::new(decode_model(profile, u, &w.community)))
        .collect();
    let train_round = train_dispatch + sw.elapsed();

    // --- (c) aggregation ------------------------------------------------
    let total: f64 = w.weights.iter().sum();
    let coeffs: Vec<f64> = w.weights.iter().map(|x| x / total).collect();
    let sw = Stopwatch::start();
    let new_community = profile.aggregate(&received, &coeffs, pool);
    let aggregation = sw.elapsed();

    // 1-core substitution: model the 32-core OpenMP time from the
    // measured sequential time (DESIGN.md §Substitutions).
    let aggregation_modeled = if matches!(
        profile.agg,
        crate::baselines::AggKind::ParallelTensor
    ) && cal.hardware_threads < w.spec.tensor_count()
    {
        // Measure the sequential time once on the same inputs.
        let sw = Stopwatch::start();
        let _ = crate::controller::aggregation::WeightedSum::compute(
            &received,
            &coeffs,
            &crate::controller::aggregation::Backend::Sequential,
        );
        let seq = sw.elapsed();
        Some(ParallelModel::paper_machine(cal).parallel_time(seq, w.spec.tensor_count()))
    } else {
        None
    };

    // --- (d)/(e) evaluation dispatch + round ----------------------------
    let sw = Stopwatch::start();
    let eval_payloads: Vec<Vec<u8>> = match profile.dispatch {
        _ if profile.eval_fast => {
            // IBM FL: eval reuses a cached serialized model (fast path).
            let encoded = encode_model(profile, &new_community);
            (0..w.learners).map(|_| encoded.clone()).collect()
        }
        DispatchKind::AsyncPooled => {
            let encoded = encode_model(profile, &new_community);
            pool.parallel_map(w.learners, |_i| encoded.clone())
        }
        DispatchKind::SequentialPerLearner { control_msgs } => (0..w.learners)
            .map(|_| {
                for _ in 0..control_msgs {
                    control_message_roundtrip();
                }
                encode_model(profile, &new_community)
            })
            .collect(),
    };
    let eval_dispatch = sw.elapsed();

    let sw = Stopwatch::start();
    for payload in &eval_payloads {
        let m = decode_model(profile, payload, &w.community);
        // Cheap deterministic eval (same for all frameworks).
        let mut acc = 0.0f64;
        for v in &m.tensors[0].data {
            acc += *v as f64;
        }
        std::hint::black_box(acc);
    }
    let eval_round = eval_dispatch + sw.elapsed();

    let federation_round = round_sw.elapsed();
    StressTimings {
        train_dispatch,
        train_round,
        aggregation,
        aggregation_modeled,
        eval_dispatch,
        eval_round,
        federation_round,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{calibration, Framework, FrameworkProfile};

    fn run(fw: Framework, learners: usize) -> StressTimings {
        let spec = ModelSpec::mlp(8, 4, 16);
        let w = StressWorkload::new(spec, learners, 3);
        let pool = ThreadPool::new(2);
        let cal = calibration::measure();
        stress_round(&FrameworkProfile::of(fw), &w, &pool, &cal)
    }

    #[test]
    fn timings_are_ordered_and_positive() {
        let t = run(Framework::MetisFLOmp, 4);
        assert!(t.federation_round >= t.aggregation);
        assert!(t.train_round >= t.train_dispatch);
        assert!(t.eval_round >= t.eval_dispatch);
        assert!(t.aggregation > Duration::ZERO);
    }

    #[test]
    fn pickle_frameworks_pay_more_for_serialization() {
        let metis = run(Framework::MetisFL, 6);
        let flower = run(Framework::Flower, 6);
        // Train round is dominated by codec work in the stress setup.
        assert!(
            flower.train_round > metis.train_round,
            "flower {:?} !> metis {:?}",
            flower.train_round,
            metis.train_round
        );
    }

    #[test]
    fn ibm_eval_dispatch_is_fast_relative_to_train_dispatch() {
        let t = run(Framework::IbmFL, 6);
        assert!(
            t.eval_dispatch < t.train_dispatch,
            "eval {:?} !< train {:?}",
            t.eval_dispatch,
            t.train_dispatch
        );
    }

    #[test]
    fn parallel_profile_reports_modeled_aggregation_on_small_machines() {
        let cal = calibration::measure();
        let t = run(Framework::MetisFLOmp, 4);
        if cal.hardware_threads < 10 {
            let modeled = t.aggregation_modeled.expect("modeled time on 1-core box");
            assert!(modeled > Duration::ZERO);
        }
        let t2 = run(Framework::MetisFL, 4);
        assert!(t2.aggregation_modeled.is_none());
    }

    #[test]
    fn workload_updates_share_layout_with_community() {
        let w = StressWorkload::new(ModelSpec::mlp(4, 2, 8), 3, 1);
        for u in &w.updates {
            assert_eq!(u.layout(), w.community.layout());
        }
        assert_eq!(w.weights.len(), 3);
    }
}
