//! Open-loop loadtest: Poisson learner arrivals, per-phase latency
//! histograms, chaos profiles, and graceful-degradation gates.
//!
//! Unlike [`stress`](super::stress), which times one round's controller
//! operations in isolation, the loadtest drives a *whole federation*
//! (controller + fleet over the in-process transport) under an open-loop
//! arrival schedule: learners register at a configured rate whether or
//! not the controller keeps up, so admission-control behavior is
//! measured rather than masked by back-pressure. Each phase — dial,
//! dispatch, train, upload, aggregate, and the whole round — lands in a
//! log-bucketed [`LatencyHistogram`], reported as p50/p99/p999.
//!
//! With a [`ChaosSpec`] the run doubles as a robustness gate:
//! [`run_loadtest`] hard-asserts that every round's quorum fired and
//! that no ingest stream stays wedged after a forced GC sweep, and
//! [`verify_chaos_equivalence`] re-runs the surviving fleet without
//! chaos and requires the community model to match **bitwise** — faults
//! may shrink participation, but they must never corrupt the math.

use crate::config::{
    FederationEnv, HeteroFleetSpec, ModelSpec, ObservabilitySpec, TrainerKind, WireCodecChoice,
};
use crate::controller::{scheduling, Controller};
use crate::harness::runner::ReportWriter;
use crate::learner::{Dataset, Learner, LearnerServicer, SyntheticTrainer, Trainer};
use crate::metrics::histogram::LatencyHistogram;
use crate::net::chaos::ChaosSpec;
use crate::net::{Psk, ServerHandle};
use crate::tensor::TensorModel;
use crate::util::{log_debug, log_info, Clock, Rng, Stopwatch};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Loadtest knobs. `quick()` is the CI smoke preset; the CLI maps
/// `metisfl loadtest` flags onto these fields.
#[derive(Debug, Clone)]
pub struct LoadtestConfig {
    /// Fleet size (chaos fractions apply to this count).
    pub learners: usize,
    /// Open-loop arrival rate, learners per second (exponential
    /// interarrivals; `<= 0` means all-at-once).
    pub rate: f64,
    pub rounds: usize,
    pub model: ModelSpec,
    pub chaos: ChaosSpec,
    /// Deadline-quorum fraction (1.0 = classic full barrier).
    pub quorum_fraction: f64,
    /// Streamed data-plane chunk size; chaos faults that act on chunks
    /// (sever / corrupt / slow-loris) require `> 0`.
    pub stream_chunk_bytes: usize,
    pub task_timeout_ms: u64,
    pub seed: u64,
    /// Synthetic trainer step time (uniform fleet).
    pub step_time_us: u64,
    /// Data-plane wire codec for the run (`Auto` resolves per the env's
    /// rules; the replay property test sweeps f32 / delta / delta-rle).
    pub wire_codec: WireCodecChoice,
    /// Run the whole federation on a [`Clock::sim`] discrete-event
    /// clock: arrival gaps, modeled compute, timeouts, and backoffs all
    /// elapse in virtual time, so a 1k-learner fleet over simulated
    /// minutes completes in real seconds (`metisfl loadtest --sim`).
    pub sim: bool,
    /// Record a deterministic trace of the controller's timeline
    /// (`metisfl loadtest --record <file>`): every inbound frame and
    /// scheduler decision, sealed with the final community digest, so
    /// `metisfl replay` can re-drive the run and assert it bitwise.
    pub record: bool,
    /// Enable span tracing on the controller and every learner for the
    /// run (`metisfl loadtest --spans`). The report is then published
    /// under the `loadtest_spans` name so the CI regression gate can
    /// hold the instrumented run to its own ceiling without clobbering
    /// the spans-off baseline.
    pub spans: bool,
}

impl LoadtestConfig {
    /// CI smoke preset: small fleet, no chaos, sub-second wall clock.
    pub fn quick() -> LoadtestConfig {
        LoadtestConfig {
            learners: 8,
            rate: 200.0,
            rounds: 2,
            model: ModelSpec::mlp(4, 2, 8),
            chaos: ChaosSpec::default(),
            quorum_fraction: 1.0,
            stream_chunk_bytes: 2048,
            task_timeout_ms: 10_000,
            seed: 42,
            step_time_us: 200,
            wire_codec: WireCodecChoice::Auto,
            sim: false,
            record: false,
            spans: false,
        }
    }

    fn env_for(&self, name: &str, active: usize) -> FederationEnv {
        FederationEnv::builder(name)
            .learners(active)
            .rounds(self.rounds)
            .model(self.model.clone())
            .samples_per_learner(20)
            .batch_size(10)
            .seed(self.seed)
            .quorum_fraction(self.quorum_fraction)
            .task_timeout_ms(self.task_timeout_ms)
            .stream_chunk_bytes(self.stream_chunk_bytes)
            .trainer(TrainerKind::Synthetic {
                step_time_us: self.step_time_us,
                hetero: HeteroFleetSpec::default(),
            })
            .chaos(self.chaos.clone())
            .wire_codec(self.wire_codec)
            .observability(ObservabilitySpec { listen_addr: String::new(), spans: self.spans })
            .build()
    }
}

/// Phase names in report order.
pub const PHASES: [&str; 6] = ["dial", "dispatch", "train", "upload", "aggregate", "round"];

/// What one loadtest run measured and survived.
#[derive(Debug, Clone)]
pub struct LoadtestReport {
    /// Report name the gated table publishes under: `loadtest`, or
    /// `loadtest_spans` when the run was traced (`cfg.spans`).
    pub name: &'static str,
    /// `(phase, histogram)` in [`PHASES`] order.
    pub phases: Vec<(&'static str, LatencyHistogram)>,
    /// Configured fleet size for this run (after any survivor filter).
    pub fleet: usize,
    pub registered: usize,
    /// Learners whose every dial was chaos-refused.
    pub refused_dials: usize,
    pub rounds_completed: usize,
    /// Completions counted per round (quorum evidence).
    pub completed_per_round: Vec<usize>,
    /// FNV-1a over the final community model's tensor names + f32 bits.
    pub community_digest: u64,
    pub community_round: u64,
    pub streams_refused: u64,
    pub streams_gced: u64,
    pub retry_give_ups: u64,
    pub fallback_sends: u64,
    pub late_folds: u64,
    pub peak_wire_ingest_bytes: usize,
    /// One-call snapshot of the controller's [`CounterRegistry`] with
    /// every learner registry merged in — the degradation evidence the
    /// trace recorder and replay gate compare wholesale.
    pub counters: BTreeMap<String, u64>,
    /// The sealed trace bytes when the run was recorded (`cfg.record`),
    /// sealed *before* the post-round drain sweep so the footer's
    /// counters cover exactly the recorded timeline.
    pub trace: Option<Vec<u8>>,
}

impl LoadtestReport {
    pub fn phase(&self, name: &str) -> &LatencyHistogram {
        &self.phases.iter().find(|(n, _)| *n == name).expect("unknown phase").1
    }

    /// The `bench_out/<name>.{csv,json}` table the CI regression gate
    /// diffs (keys `loadtest/<phase>/p99_ms`, or `loadtest_spans/...`
    /// for a traced run).
    pub fn table(&self) -> ReportWriter {
        let mut w = ReportWriter::new(
            self.name,
            &["phase", "p50_ms", "p99_ms", "p999_ms", "max_ms", "samples"],
        );
        for (name, h) in &self.phases {
            w.row(vec![
                name.to_string(),
                fmt_ms(h.p50()),
                fmt_ms(h.p99()),
                fmt_ms(h.p999()),
                fmt_ms(Some(h.max())),
                h.count().to_string(),
            ]);
        }
        w
    }
}

/// Empty histograms have no quantiles; render them as `-` rather than
/// a fake zero the regression gate would happily "pass".
fn fmt_ms(d: Option<Duration>) -> String {
    match d {
        Some(d) => format!("{:.3}", d.as_secs_f64() * 1e3),
        None => "-".to_string(),
    }
}

/// Bitwise-comparable digest of a model (canonical implementation lives
/// with the trace format it seals).
pub use crate::runtime::trace::model_digest;

fn next_loadtest_id() -> u64 {
    static RUN: AtomicU64 = AtomicU64::new(0);
    RUN.fetch_add(1, Ordering::SeqCst)
}

/// Run the full configured fleet.
pub fn run_loadtest(cfg: &LoadtestConfig) -> Result<LoadtestReport> {
    run_filtered(cfg, None)
}

/// Core loop; `fleet` restricts the run to a subset of the *original*
/// learner indices (the chaos-equivalence clean twin) while preserving
/// every per-learner seed: learner `i` keeps the same id, dataset, and
/// trainer stream whether or not its siblings exist.
fn run_filtered(cfg: &LoadtestConfig, fleet: Option<&[usize]>) -> Result<LoadtestReport> {
    if cfg.learners == 0 || cfg.rounds == 0 {
        bail!("loadtest needs at least one learner and one round");
    }
    let indices: Vec<usize> = match fleet {
        Some(f) => f.to_vec(),
        None => (0..cfg.learners).collect(),
    };
    let run = next_loadtest_id();
    let env = cfg.env_for(&format!("loadtest-{run}"), indices.len());
    env.validate()?;
    let psk: Psk = None;
    let clock = if cfg.sim { Clock::sim() } else { Clock::system() };
    // Log timestamps follow the run's clock: a sim run logs virtual
    // millis that line up with its trace ticks and span intervals.
    crate::util::logging::set_clock(clock.clone());

    let controller = Controller::with_clock(env.clone(), psk, clock.clone())?;
    if cfg.spans {
        controller.span_sink().enable();
    }
    if cfg.record {
        // Before any learner dials in: registrations are part of the
        // recorded timeline.
        controller.start_recording();
    }
    let ctrl_ep = format!("inproc://loadtest-ctrl-{run}");
    let _ctrl_server =
        crate::net::serve(&ctrl_ep, Arc::clone(&controller) as Arc<dyn crate::net::Service>, psk)?;

    // Chaos plans are always drawn over the FULL configured fleet so
    // victim assignment is invariant under the survivor filter.
    let plans = env.chaos.plan_fleet(cfg.learners, cfg.seed);

    // Per-learner seeds must not depend on which indices run: walk every
    // index, instantiating only the active ones.
    let mut data_rng = Rng::new(cfg.seed);
    let mut learners: Vec<Arc<Learner>> = Vec::with_capacity(indices.len());
    let mut servers: Vec<Box<dyn ServerHandle>> = Vec::new();
    let mut endpoints: Vec<String> = Vec::new();
    let mut refused = 0usize;
    for i in 0..cfg.learners {
        let ds_seed = data_rng.split(i as u64).next_u64();
        if !indices.contains(&i) {
            continue;
        }
        let dataset = Dataset::synthetic_housing(
            env.model.input_dim,
            env.samples_per_learner,
            env.samples_per_learner,
            ds_seed,
        );
        let trainer: Arc<dyn Trainer> = Arc::new(
            SyntheticTrainer::for_fleet(cfg.step_time_us, &HeteroFleetSpec::default(), cfg.seed, i)
                .on_clock(clock.clone()),
        );
        let learner = Learner::with_clock(
            &format!("learner-{i}"),
            &ctrl_ep,
            psk,
            trainer,
            dataset,
            clock.clone(),
        );
        learner.set_stream_chunk(env.effective_stream_chunk());
        learner.set_upload_codec(env.upload_codec());
        learner.set_delta_fallback(env.delta_fallback);
        if cfg.spans {
            learner.span_sink().enable();
        }
        let plan = &plans[i];
        if !plan.is_noop() {
            learner.set_chaos(plan.clone());
        }
        if plan.refuse_dial {
            refused += 1;
        }
        let ep = format!("inproc://loadtest-{run}-l{i}");
        let server = crate::net::serve(
            &ep,
            Arc::new(LearnerServicer(Arc::clone(&learner))) as Arc<dyn crate::net::Service>,
            psk,
        )?;
        endpoints.push(ep);
        servers.push(server);
        learners.push(learner);
    }

    // --- Open-loop arrivals: exponential interarrival schedule --------
    let mut arrival_rng = Rng::new(cfg.seed ^ 0xA881);
    let mut offsets: Vec<Duration> = Vec::with_capacity(learners.len());
    let mut at = Duration::ZERO;
    for _ in &learners {
        if cfg.rate > 0.0 {
            let u = arrival_rng.next_f64();
            at += Duration::from_secs_f64(-(1.0 - u).ln() / cfg.rate);
        }
        offsets.push(at);
    }
    let horizon = at;

    let start = clock.now();
    let mut joins = Vec::with_capacity(learners.len());
    for (k, learner) in learners.iter().enumerate() {
        let learner = Arc::clone(learner);
        let ep = endpoints[k].clone();
        let due = start + offsets[k];
        let clock = clock.clone();
        joins.push(
            std::thread::Builder::new()
                .name(format!("loadtest-arrival-{k}"))
                .spawn(move || {
                    // Register as busy so simulated time cannot jump past
                    // an arrival mid-dial; the sleep below suspends the
                    // registration while this thread is parked.
                    let _busy = clock.busy();
                    let wait = due.saturating_sub(clock.now());
                    if !wait.is_zero() {
                        clock.sleep(wait);
                    }
                    let sw = Stopwatch::start_with(&clock);
                    match learner.register(&ep) {
                        Ok(_) => Some(sw.elapsed()),
                        Err(e) => {
                            log_debug("loadtest", &format!("arrival failed: {e:#}"));
                            None
                        }
                    }
                })
                .expect("spawn arrival thread"),
        );
    }
    let mut dial = LatencyHistogram::new();
    let mut registered = 0usize;
    for j in joins {
        if let Some(d) = j.join().expect("arrival thread panicked") {
            dial.record(d);
            registered += 1;
        }
    }
    if registered == 0 {
        bail!("loadtest: no learner survived registration");
    }
    controller
        .wait_for_learners(registered, horizon + Duration::from_secs(30))
        .context("loadtest: waiting for registrations")?;
    log_info(
        "loadtest",
        &format!(
            "{registered}/{} registered over {:?} ({refused} chaos-refused)",
            learners.len(),
            horizon
        ),
    );

    let mut init_rng = Rng::new(cfg.seed ^ 0x5EED_0F_0E715); // driver's salt
    controller.ship_model(TensorModel::random_init(&env.model.tensor_layout(), &mut init_rng));

    // --- Rounds, with the quorum-fires hard gate -----------------------
    let mut dispatch = LatencyHistogram::new();
    let mut train = LatencyHistogram::new();
    let mut aggregate = LatencyHistogram::new();
    let mut round_hist = LatencyHistogram::new();
    let mut completed_per_round = Vec::with_capacity(cfg.rounds);
    let mut round_rng = Rng::new(cfg.seed ^ 0xD157);
    for round in 1..=cfg.rounds as u64 {
        let report = scheduling::run_round(&controller, round, &mut round_rng)
            .with_context(|| format!("loadtest round {round}"))?;
        let target = (cfg.quorum_fraction * report.participants as f64).ceil().max(1.0) as usize;
        if report.completed < target {
            bail!(
                "loadtest round {round}: quorum never fired \
                 ({}/{} completed, target {target})",
                report.completed,
                report.participants
            );
        }
        dispatch.record(report.train_dispatch);
        train.record(report.train_round);
        aggregate.record(report.aggregation);
        round_hist.record(report.federation_round);
        completed_per_round.push(report.completed);
    }

    // Seal the trace BEFORE the drain sweep below: `gc_force` reclaims
    // from the harness thread, outside any recorded event, so counters
    // it bumps (streams_gced) must land after the footer or a faithful
    // replay would come up short.
    let trace = if cfg.record { controller.finish_recording() } else { None };

    // --- No-wedged-streams gate ---------------------------------------
    // Chaos victims may still be dripping their doomed uploads; every
    // round has closed, so any stream still open is abandoned by
    // construction. Force-reclaim them, then poll (real time — this
    // gates on real handler threads finishing mid-decode frames, not on
    // the run's timeline) until the wire accounting drains; re-force
    // each pass in case a victim trickled in a late chunk between
    // sweeps.
    let drain = Stopwatch::start();
    loop {
        let _ = controller.ingest().gc_force();
        if controller.ingest().open_streams() == 0
            && controller.ingest().wire_in_flight_bytes() == 0
        {
            break;
        }
        if drain.elapsed() >= Duration::from_secs(20) {
            bail!(
                "loadtest: {} stream(s) still wedged ({} wire bytes in flight) \
                 after forced GC",
                controller.ingest().open_streams(),
                controller.ingest().wire_in_flight_bytes()
            );
        }
        Clock::system().sleep(Duration::from_millis(10));
    }

    let (community, community_round) =
        controller.community().context("loadtest: community model vanished")?;
    let mut upload = LatencyHistogram::new();
    let mut learner_give_ups = 0u64;
    let mut learner_fallbacks = 0u64;
    let mut counters = controller.counters().snapshot();
    for l in &learners {
        for d in l.take_upload_timings() {
            upload.record(d);
        }
        learner_give_ups += l.retry_give_ups();
        learner_fallbacks += l.fallback_sends();
        l.counters().merge_into(&mut counters);
    }

    let report = LoadtestReport {
        name: if cfg.spans { "loadtest_spans" } else { "loadtest" },
        phases: vec![
            ("dial", dial),
            ("dispatch", dispatch),
            ("train", train),
            ("upload", upload),
            ("aggregate", aggregate),
            ("round", round_hist),
        ],
        fleet: learners.len(),
        registered,
        refused_dials: refused,
        rounds_completed: completed_per_round.len(),
        completed_per_round,
        community_digest: model_digest(&community),
        community_round,
        streams_refused: controller.ingest().streams_refused(),
        streams_gced: controller.ingest().streams_gced(),
        retry_give_ups: controller.retry_give_ups() + learner_give_ups,
        fallback_sends: controller.fallback_sends() + learner_fallbacks,
        late_folds: controller.late_folds(),
        peak_wire_ingest_bytes: controller.peak_wire_ingest_bytes(),
        counters,
        trace,
    };
    for mut s in servers {
        s.shutdown();
    }
    Ok(report)
}

/// Chaos-vs-clean comparison.
#[derive(Debug)]
pub struct EquivalenceReport {
    pub chaos: LoadtestReport,
    pub clean: LoadtestReport,
    /// Original fleet indices untouched by any chaos fault.
    pub survivors: Vec<usize>,
}

/// The graceful-degradation acceptance gate: run the chaos scenario,
/// then re-run ONLY the surviving learners with chaos off and a full
/// quorum, and require the community models to be bitwise identical.
/// Also asserts the chaos run closed every round at its quorum (no
/// late-fold contamination of the aggregate).
pub fn verify_chaos_equivalence(cfg: &LoadtestConfig) -> Result<EquivalenceReport> {
    if cfg.chaos.is_off() {
        bail!("chaos equivalence needs a chaos profile (cfg.chaos is off)");
    }
    if cfg.stream_chunk_bytes == 0 {
        bail!(
            "chaos equivalence requires the streamed data plane: sever / corrupt / \
             slow-loris act on model chunks (set stream_chunk_bytes > 0)"
        );
    }
    let chaos = run_loadtest(cfg)?;
    if chaos.late_folds != 0 {
        bail!(
            "chaos run folded {} completion(s) through the late/staleness path — \
             the aggregate is no longer the plain quorum set",
            chaos.late_folds
        );
    }
    let plans = cfg.chaos.plan_fleet(cfg.learners, cfg.seed);
    let survivors: Vec<usize> = (0..cfg.learners).filter(|&i| plans[i].is_noop()).collect();
    if survivors.is_empty() {
        bail!("chaos profile leaves no survivors to compare against");
    }
    let mut clean_cfg = cfg.clone();
    clean_cfg.chaos = ChaosSpec::default();
    clean_cfg.quorum_fraction = 1.0;
    let clean = run_filtered(&clean_cfg, Some(&survivors))?;
    if chaos.community_digest != clean.community_digest {
        bail!(
            "community model diverged under chaos: {:#018x} (chaos, round {}) vs \
             {:#018x} (clean survivors, round {})",
            chaos.community_digest,
            chaos.community_round,
            clean.community_digest,
            clean.community_round
        );
    }
    Ok(EquivalenceReport { chaos, clean, survivors })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_loadtest_completes_with_full_phase_coverage() {
        let mut cfg = LoadtestConfig::quick();
        cfg.learners = 4;
        cfg.rate = 500.0;
        let report = run_loadtest(&cfg).unwrap();
        assert_eq!(report.fleet, 4);
        assert_eq!(report.registered, 4);
        assert_eq!(report.refused_dials, 0);
        assert_eq!(report.rounds_completed, 2);
        assert_eq!(report.completed_per_round, vec![4, 4]);
        assert_eq!(report.phase("dial").count(), 4);
        assert_eq!(report.phase("round").count(), 2);
        assert_eq!(report.phase("upload").count(), 8, "4 learners × 2 rounds");
        assert!(report.phase("round").p99().unwrap() > Duration::ZERO);
        assert_ne!(report.community_digest, 0);
        assert_eq!(report.retry_give_ups, 0);
        assert_eq!(report.streams_gced, 0);
        // The gated table renders one row per phase.
        let md = report.table().to_markdown();
        for phase in PHASES {
            assert!(md.contains(phase), "missing {phase} in:\n{md}");
        }
    }

    #[test]
    fn loadtest_is_deterministic_in_outcome() {
        let mut cfg = LoadtestConfig::quick();
        cfg.learners = 3;
        cfg.rate = 1000.0;
        let a = run_loadtest(&cfg).unwrap();
        let b = run_loadtest(&cfg).unwrap();
        // Latencies differ run to run; the *math* must not.
        assert_eq!(a.community_digest, b.community_digest);
        assert_eq!(a.completed_per_round, b.completed_per_round);
    }

    #[test]
    fn sim_loadtest_compresses_virtual_time_and_preserves_the_math() {
        let mut cfg = LoadtestConfig::quick();
        cfg.learners = 4;
        cfg.rate = 2.0; // ~2 virtual seconds of arrivals
        cfg.step_time_us = 100_000; // heavy virtual compute per step
        cfg.sim = true;
        let real = Stopwatch::start();
        let sim_report = run_loadtest(&cfg).unwrap();
        // Virtual seconds of arrivals + compute must not cost
        // proportional real time.
        assert!(
            real.elapsed() < Duration::from_secs(20),
            "sim run took {:?} real",
            real.elapsed()
        );
        assert_eq!(sim_report.rounds_completed, 2);
        assert_eq!(sim_report.completed_per_round, vec![4, 4]);
        // Train latencies are virtual: the modeled compute shows up in
        // the phase histogram even though it never elapsed for real.
        assert!(sim_report.phase("train").max() >= Duration::from_millis(100));

        // Same math as a wall-clock run of the same seed.
        let mut wall_cfg = cfg.clone();
        wall_cfg.sim = false;
        wall_cfg.rate = 1000.0;
        wall_cfg.step_time_us = 100;
        let wall = run_loadtest(&wall_cfg).unwrap();
        assert_eq!(
            sim_report.community_digest, wall.community_digest,
            "sim timing leaked into the math"
        );
    }

    #[test]
    fn spans_run_publishes_under_its_own_report_name() {
        let mut cfg = LoadtestConfig::quick();
        cfg.learners = 3;
        cfg.rate = 1000.0;
        cfg.spans = true;
        let traced = run_loadtest(&cfg).unwrap();
        assert_eq!(traced.name, "loadtest_spans");
        assert_eq!(traced.rounds_completed, 2);
        // Tracing must never perturb the math.
        let mut off = cfg.clone();
        off.spans = false;
        let base = run_loadtest(&off).unwrap();
        assert_eq!(base.name, "loadtest");
        assert_eq!(traced.community_digest, base.community_digest);
    }

    #[test]
    fn chaos_equivalence_holds_on_a_small_fleet() {
        let mut cfg = LoadtestConfig::quick();
        cfg.learners = 6;
        cfg.rate = 1000.0;
        // 1 severed + 1 slow-loris → 4 survivors; quorum 4/6.
        cfg.chaos = ChaosSpec {
            seed: 7,
            sever_fraction: 0.2,
            slow_loris: 1,
            drip_ms: 5,
            ..ChaosSpec::default()
        };
        cfg.quorum_fraction = 0.66;
        let eq = verify_chaos_equivalence(&cfg).unwrap();
        assert_eq!(eq.survivors.len(), 4);
        assert_eq!(eq.chaos.completed_per_round, vec![4, 4]);
        assert_eq!(eq.clean.completed_per_round, vec![4, 4]);
        // Victims left evidence: give-ups from both victims' retries and
        // GC'd streams from their abandoned uploads.
        assert!(eq.chaos.retry_give_ups > 0);
        assert!(eq.chaos.streams_gced > 0);
        assert_eq!(eq.clean.retry_give_ups, 0);
    }
}
