//! Figure/table sweeps: framework × learner-count grids per model size.
//!
//! Each of Figs. 5/6/7 is one model size ({100k, 1M, 10M} params) with
//! six panels (train dispatch, train round, aggregation, eval dispatch,
//! eval round, federation round) over learners {10, 25, 50, 100, 200}.
//! Table 2 is the federation-round column of Fig. 7.

use super::runner::{fmt_secs, full_scale, BenchRunner, ReportWriter};
use super::stress::{stress_round, StressTimings, StressWorkload};
use crate::baselines::calibration::{self, Calibration};
use crate::baselines::{Framework, FrameworkProfile};
use crate::config::ModelSpec;
use crate::metrics::FedOp;
use crate::util::ThreadPool;
use std::collections::BTreeMap;
use std::time::Duration;

/// Sweep configuration for one figure.
#[derive(Debug, Clone)]
pub struct FigureConfig {
    /// Figure id in the paper ("fig5" | "fig6" | "fig7").
    pub name: &'static str,
    pub spec: ModelSpec,
    pub learner_counts: Vec<usize>,
    pub frameworks: Vec<Framework>,
    pub seed: u64,
}

impl FigureConfig {
    /// Default sweep; `FULL=1` uses the paper's grid and model sizes.
    pub fn paper(name: &'static str, spec: ModelSpec, reduced_spec: ModelSpec) -> FigureConfig {
        let (spec, learner_counts) = if full_scale() {
            (spec, vec![10, 25, 50, 100, 200])
        } else {
            (reduced_spec, vec![10, 25, 50])
        };
        FigureConfig {
            name,
            spec,
            learner_counts,
            frameworks: Framework::ALL.to_vec(),
            seed: 42,
        }
    }
}

/// One (framework, learners) measurement cell.
#[derive(Debug, Clone)]
pub struct FigureCell {
    pub framework: Framework,
    pub learners: usize,
    pub timings: StressTimings,
}

/// A completed figure sweep.
pub struct FigureResult {
    pub config: FigureConfig,
    pub cells: Vec<FigureCell>,
    pub calibration: Calibration,
}

/// Run the sweep for one figure.
pub fn figure_sweep(config: FigureConfig) -> FigureResult {
    let cal = calibration::measure();
    let pool = ThreadPool::with_hardware_threads();
    let runner = BenchRunner::new();
    let mut cells = Vec::new();
    for &n in &config.learner_counts {
        // One workload per learner count, shared across frameworks so
        // every row sees identical payloads.
        let w = StressWorkload::new(config.spec.clone(), n, config.seed);
        for &fw in &config.frameworks {
            let profile = FrameworkProfile::of(fw);
            let mut last: Option<StressTimings> = None;
            // BenchRunner drives repetitions; keep the median-ish last.
            let _summary = runner.run(|| {
                last = Some(stress_round(&profile, &w, &pool, &cal));
            });
            cells.push(FigureCell { framework: fw, learners: n, timings: last.unwrap() });
        }
    }
    FigureResult { config, cells, calibration: cal }
}

impl FigureResult {
    fn cell(&self, fw: Framework, n: usize) -> Option<&FigureCell> {
        self.cells.iter().find(|c| c.framework == fw && c.learners == n)
    }

    /// Value of one op for a cell. For MetisFL-OMP aggregation (and the
    /// rounds containing it) the modelled 32-core time is used when the
    /// real machine cannot express the parallelism; columns carrying
    /// modelled values are marked in the panel title.
    fn op_value(&self, c: &FigureCell, op: FedOp) -> Duration {
        let t = &c.timings;
        let agg = t.aggregation_modeled.unwrap_or(t.aggregation);
        match op {
            FedOp::TrainDispatch => t.train_dispatch,
            FedOp::TrainRound => t.train_round,
            FedOp::Aggregation => agg,
            FedOp::EvalDispatch => t.eval_dispatch,
            FedOp::EvalRound => t.eval_round,
            FedOp::FederationRound => {
                // Replace the measured aggregation slice with the modelled
                // one so the round total is consistent.
                t.federation_round - t.aggregation + agg
            }
            _ => Duration::ZERO,
        }
    }

    /// Emit all six panels as tables (markdown + CSV).
    pub fn emit_panels(&self) -> std::io::Result<()> {
        let modeled = self
            .cells
            .iter()
            .any(|c| c.timings.aggregation_modeled.is_some());
        println!(
            "\n## {} — {} params ({} tensors){}",
            self.config.name,
            self.config.spec.param_count(),
            self.config.spec.tensor_count(),
            if modeled {
                format!(
                    " [MetisFL gRPC+OMP aggregation modelled at {} cores; measured {} threads]",
                    calibration::PAPER_CORES,
                    self.calibration.hardware_threads
                )
            } else {
                String::new()
            }
        );
        for (panel, op) in ["a", "b", "c", "d", "e", "f"].iter().zip(FedOp::figure_panels()) {
            let mut headers = vec!["learners".to_string()];
            headers.extend(self.config.frameworks.iter().map(|f| f.label().to_string()));
            let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
            let mut w = ReportWriter::new(
                &format!("{}_{panel}_{}", self.config.name, op.name()),
                &hdr_refs,
            );
            for &n in &self.config.learner_counts {
                let mut row = vec![n.to_string()];
                for &fw in &self.config.frameworks {
                    row.push(match self.cell(fw, n) {
                        Some(c) => fmt_secs(self.op_value(c, op)),
                        None => "N/A".into(),
                    });
                }
                w.row(row);
            }
            w.emit()?;
        }
        Ok(())
    }

    /// Emit the Table-2 shape: federation round seconds per framework ×
    /// learner count.
    pub fn emit_table2(&self) -> std::io::Result<()> {
        let mut headers = vec!["#Learners".to_string()];
        headers.extend(self.config.frameworks.iter().map(|f| f.label().to_string()));
        let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut w = ReportWriter::new("table2_federation_round", &hdr_refs);
        for &n in &self.config.learner_counts {
            let mut row = vec![n.to_string()];
            for &fw in &self.config.frameworks {
                row.push(match self.cell(fw, n) {
                    Some(c) => fmt_secs(self.op_value(c, FedOp::FederationRound)),
                    None => "N/A".into(),
                });
            }
            w.row(row);
        }
        w.emit()?;
        Ok(())
    }

    /// Cross-framework ratios for the shape checks (speedup of
    /// MetisFL-OMP over each framework on an op, at the largest N).
    pub fn speedups(&self, op: FedOp) -> BTreeMap<&'static str, f64> {
        let n = *self.config.learner_counts.last().unwrap();
        let base = self
            .cell(Framework::MetisFLOmp, n)
            .map(|c| self.op_value(c, op).as_secs_f64())
            .unwrap_or(f64::NAN);
        let mut out = BTreeMap::new();
        for &fw in &self.config.frameworks {
            if fw == Framework::MetisFLOmp {
                continue;
            }
            if let Some(c) = self.cell(fw, n) {
                out.insert(fw.label(), self.op_value(c, op).as_secs_f64() / base);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_sweep() -> FigureResult {
        figure_sweep(FigureConfig {
            name: "figtest",
            spec: ModelSpec::mlp(8, 3, 16),
            learner_counts: vec![4, 8],
            frameworks: vec![Framework::MetisFLOmp, Framework::MetisFL, Framework::Flower],
            seed: 7,
        })
    }

    #[test]
    fn sweep_produces_all_cells() {
        let r = tiny_sweep();
        assert_eq!(r.cells.len(), 6);
        assert!(r.cell(Framework::Flower, 8).is_some());
        assert!(r.cell(Framework::IbmFL, 8).is_none());
    }

    #[test]
    fn metisfl_beats_python_style_controller() {
        let r = tiny_sweep();
        let speedups = r.speedups(FedOp::FederationRound);
        let flower = speedups["Flower"];
        assert!(flower > 1.0, "expected Flower slower, ratio {flower}");
    }

    #[test]
    fn round_times_grow_with_learner_count() {
        let r = tiny_sweep();
        for fw in [Framework::MetisFL, Framework::Flower] {
            let t4 = r.cell(fw, 4).unwrap().timings.federation_round;
            let t8 = r.cell(fw, 8).unwrap().timings.federation_round;
            assert!(t8 > t4 / 2, "{}: {t4:?} -> {t8:?}", fw.label());
        }
    }
}
