//! Benchmark harness (criterion replacement) + the cross-framework
//! stress-round simulator that regenerates the paper's figures.
//!
//! * [`runner`] — warmup/iteration loops producing [`Summary`] stats and
//!   aligned markdown / CSV emitters under `bench_out/`.
//! * [`stress`] — executes one federation round's controller operations
//!   under a [`FrameworkProfile`](crate::baselines::FrameworkProfile),
//!   timing the six panels of Figs. 5–7 in isolation.
//! * [`figures`] — the learner-count × framework sweeps for Figs. 5/6/7
//!   and Table 2 (scaled-down by default; `FULL=1` for the paper's grid).
//! * [`loadtest`] — the open-loop arrival harness: per-phase latency
//!   histograms, chaos profiles, and graceful-degradation gates.

pub mod figures;
pub mod loadtest;
pub mod runner;
pub mod stress;

pub use figures::{figure_sweep, FigureConfig, FigureResult};
pub use loadtest::{run_loadtest, verify_chaos_equivalence, LoadtestConfig, LoadtestReport};
pub use runner::{BenchRunner, ReportWriter};
pub use stress::{stress_round, StressTimings};
