//! Local training backends.
//!
//! [`Trainer`] abstracts what happens between `RunTask` and
//! `MarkTaskCompleted`. Two implementations ship:
//!
//! * [`SyntheticTrainer`] (here) — stress-test trainer: produces a
//!   deterministic parameter-shaped update and models compute time with a
//!   configurable per-step cost. The paper's quantitative evaluation
//!   measures controller operations, not learning quality, and randomly
//!   samples data per learner — this is the equivalent workload source.
//! * `runtime::XlaTrainer` — real local training: executes the
//!   AOT-compiled JAX `train_step`/`eval_step` artifacts via PJRT.

use super::data::Dataset;
use crate::proto::{EvalResult, TaskMeta, TaskSpec};
use crate::tensor::TensorModel;
use crate::util::{Clock, Rng, Stopwatch};
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};

/// Local training/evaluation backend.
pub trait Trainer: Send + Sync {
    /// Train `model` on `data` per `spec`; return the updated model and
    /// execution metadata.
    fn train(&self, model: &TensorModel, data: &Dataset, spec: &TaskSpec)
        -> Result<(TensorModel, TaskMeta)>;

    /// Evaluate `model` on the local test split.
    fn evaluate(&self, model: &TensorModel, data: &Dataset) -> Result<EvalResult>;

    fn name(&self) -> &'static str;
}

/// Stress-test trainer with modeled compute time. A per-learner
/// profile (speed multiplier folded into `step_time_us`, jitter,
/// dropout) turns a uniform fleet into the heterogeneous, flaky
/// deployments the pacing subsystem schedules around.
pub struct SyntheticTrainer {
    /// Modeled per-step compute time in microseconds (0 = no sleep).
    pub step_time_us: u64,
    /// Update magnitude relative to parameter scale.
    pub update_scale: f32,
    /// Uniform ± fraction applied to each task's modeled compute time.
    jitter_frac: f64,
    /// Probability a training task fails outright (no completion
    /// callback reaches the controller — the timeout/quorum path
    /// handles it).
    dropout: f64,
    /// Differentiates per-learner trainer instances so their updates
    /// (and jitter/dropout draws) are independent yet deterministic.
    seed: u64,
    invocation: AtomicU64,
    /// Clock the modeled compute sleep runs on. Under [`Clock::sim`]
    /// the sleep parks on virtual time, so a simulated fleet's compute
    /// phase costs no wall clock.
    clock: Clock,
}

impl SyntheticTrainer {
    pub fn new(step_time_us: u64, update_scale: f32) -> SyntheticTrainer {
        SyntheticTrainer::with_profile(step_time_us, update_scale, 0.0, 0.0, 0)
    }

    /// Per-learner trainer for a (possibly heterogeneous) synthetic
    /// fleet: learner `index` runs at `step_time_us × factor(index)`
    /// with the fleet's jitter/dropout, seeded deterministically from
    /// the env seed + index. Single source of truth shared by the
    /// in-process driver and the standalone `metisfl learner` process,
    /// so both deployment modes model bit-identical fleets.
    pub fn for_fleet(
        step_time_us: u64,
        hetero: &crate::config::HeteroFleetSpec,
        env_seed: u64,
        index: usize,
    ) -> SyntheticTrainer {
        let step = (step_time_us as f64 * hetero.factor(index)).round() as u64;
        SyntheticTrainer::with_profile(
            step,
            0.01,
            hetero.jitter_frac,
            hetero.dropout,
            env_seed ^ ((index as u64) << 32) ^ index as u64,
        )
    }

    /// Trainer with a heterogeneity profile (see
    /// [`crate::config::HeteroFleetSpec`]).
    pub fn with_profile(
        step_time_us: u64,
        update_scale: f32,
        jitter_frac: f64,
        dropout: f64,
        seed: u64,
    ) -> SyntheticTrainer {
        SyntheticTrainer {
            step_time_us,
            update_scale,
            jitter_frac,
            dropout,
            seed,
            invocation: AtomicU64::new(0),
            clock: Clock::system(),
        }
    }

    /// Rebind the modeled-compute sleep (and reported timings) to
    /// `clock`. Builder-style so fleet construction reads as
    /// `SyntheticTrainer::for_fleet(..).on_clock(clock)`.
    pub fn on_clock(mut self, clock: Clock) -> SyntheticTrainer {
        self.clock = clock;
        self
    }

    fn steps_for(&self, data: &Dataset, spec: &TaskSpec) -> usize {
        let per_epoch = data.train_len().div_ceil(spec.batch_size.max(1)).max(1);
        if spec.step_budget > 0 {
            spec.step_budget
        } else {
            per_epoch * spec.epochs.max(1)
        }
    }
}

impl Trainer for SyntheticTrainer {
    fn train(
        &self,
        model: &TensorModel,
        data: &Dataset,
        spec: &TaskSpec,
    ) -> Result<(TensorModel, TaskMeta)> {
        let sw = Stopwatch::start_with(&self.clock);
        let steps = self.steps_for(data, spec);
        let invocation = self.invocation.fetch_add(1, Ordering::SeqCst);
        // Deterministic, parameter-shaped pseudo-update: the workload a
        // learner would ship, without the FLOPs. Touch every parameter so
        // memory traffic is realistic.
        let mut rng = Rng::new(
            0x7EA4 ^ self.seed.rotate_left(17) ^ invocation.wrapping_mul(0x9E3779B97F4A7C15),
        );
        // Dropout draw comes first (and only when configured, so the
        // default profile's update stream is unchanged): a dropped task
        // produces no completion callback at all.
        if self.dropout > 0.0 && rng.gen_bool(self.dropout) {
            anyhow::bail!("synthetic dropout (invocation {invocation})");
        }
        let mut out = model.clone();
        for t in &mut out.tensors {
            for v in t.data.iter_mut() {
                *v += self.update_scale * (rng.next_f32() - 0.5);
            }
        }
        if self.step_time_us > 0 {
            let mut sleep_us = self.step_time_us.saturating_mul(steps as u64);
            if self.jitter_frac > 0.0 {
                let j = 1.0 + self.jitter_frac * (2.0 * rng.next_f64() - 1.0);
                sleep_us = (sleep_us as f64 * j.max(0.0)) as u64;
            }
            self.clock.sleep(std::time::Duration::from_micros(sleep_us));
        }
        let elapsed = sw.elapsed();
        let meta = TaskMeta {
            train_time_per_batch_us: (elapsed.as_micros() as u64 / steps as u64).max(1),
            completed_steps: steps,
            completed_epochs: spec.epochs.max(1),
            num_samples: data.train_len(),
            train_loss: 1.0 / (1.0 + invocation as f64).sqrt(), // plausibly decreasing
            steps_per_sec: steps as f64 / elapsed.as_secs_f64().max(1e-9),
            train_wall_time_us: (elapsed.as_micros() as u64).max(1),
            ..TaskMeta::default()
        };
        Ok((out, meta))
    }

    fn evaluate(&self, model: &TensorModel, data: &Dataset) -> Result<EvalResult> {
        let sw = Stopwatch::start();
        // A cheap deterministic pseudo-loss that depends on the model so
        // different community models evaluate differently.
        let norm = model.l2_norm();
        let loss = (norm / (1.0 + norm)) + 0.1;
        Ok(EvalResult {
            loss,
            num_samples: data.test_len(),
            eval_time_us: sw.elapsed().as_micros() as u64,
        })
    }

    fn name(&self) -> &'static str {
        "synthetic"
    }
}

/// Pure-rust reference trainer: actual SGD on the MLP, implemented with
/// naive loops. Used by tests to validate the XLA trainer's numerics and
/// by examples when artifacts are unavailable. Slow — test-scale only.
pub struct RustSgdTrainer;

impl RustSgdTrainer {
    /// Forward pass returning per-layer activations. Model layout must be
    /// the `ModelSpec::tensor_layout()` order: (w, b)* then head (w, b).
    fn forward(model: &TensorModel, x: &[f32], features: usize) -> (Vec<Vec<f32>>, Vec<f32>) {
        let rows = x.len() / features;
        let mut acts: Vec<Vec<f32>> = Vec::new();
        let mut cur = x.to_vec();
        let mut cur_dim = features;
        let pairs = model.tensors.len() / 2;
        for p in 0..pairs {
            let w = &model.tensors[2 * p];
            let b = &model.tensors[2 * p + 1];
            let out_dim = w.shape[1];
            let mut next = vec![0.0f32; rows * out_dim];
            for r in 0..rows {
                for o in 0..out_dim {
                    let mut acc = b.data[o];
                    for i in 0..cur_dim {
                        acc += cur[r * cur_dim + i] * w.data[i * out_dim + o];
                    }
                    // ReLU on hidden layers, identity on the head.
                    next[r * out_dim + o] = if p + 1 < pairs { acc.max(0.0) } else { acc };
                }
            }
            acts.push(cur);
            cur = next;
            cur_dim = out_dim;
        }
        (acts, cur)
    }

    /// MSE loss over predictions (output dim 1).
    fn mse(pred: &[f32], y: &[f32]) -> f64 {
        pred.iter()
            .zip(y)
            .map(|(p, t)| {
                let d = (*p - *t) as f64;
                d * d
            })
            .sum::<f64>()
            / y.len() as f64
    }

    /// One SGD step on a batch (full backprop).
    fn sgd_step(model: &mut TensorModel, x: &[f32], y: &[f32], features: usize, lr: f32) -> f64 {
        let rows = y.len();
        let (acts, pred) = Self::forward(model, x, features);
        let loss = Self::mse(&pred, y);
        // Backward.
        let pairs = model.tensors.len() / 2;
        // dL/dpred = 2 (pred - y) / n
        let mut grad: Vec<f32> =
            pred.iter().zip(y).map(|(p, t)| 2.0 * (p - t) / rows as f32).collect();
        for p in (0..pairs).rev() {
            let in_dim = model.tensors[2 * p].shape[0];
            let out_dim = model.tensors[2 * p].shape[1];
            let input = &acts[p];
            // Recompute this layer's pre-activation output to mask ReLU.
            // (acts[p] is the layer input; for hidden layers the forward
            // output was ReLU(z) which we can recover from the next
            // input, acts[p+1], except for the head.)
            let output: &[f32] = if p + 1 < pairs { &acts[p + 1] } else { &pred };
            let mut gw = vec![0.0f32; in_dim * out_dim];
            let mut gb = vec![0.0f32; out_dim];
            let mut gin = vec![0.0f32; rows * in_dim];
            for r in 0..rows {
                for o in 0..out_dim {
                    let mut g = grad[r * out_dim + o];
                    if p + 1 < pairs && output[r * out_dim + o] <= 0.0 {
                        g = 0.0; // ReLU mask
                    }
                    if g == 0.0 {
                        continue;
                    }
                    gb[o] += g;
                    for i in 0..in_dim {
                        gw[i * out_dim + o] += input[r * in_dim + i] * g;
                        gin[r * in_dim + i] += model.tensors[2 * p].data[i * out_dim + o] * g;
                    }
                }
            }
            for (wv, g) in model.tensors[2 * p].data.iter_mut().zip(&gw) {
                *wv -= lr * g;
            }
            for (bv, g) in model.tensors[2 * p + 1].data.iter_mut().zip(&gb) {
                *bv -= lr * g;
            }
            grad = gin;
        }
        loss
    }
}

impl Trainer for RustSgdTrainer {
    fn train(
        &self,
        model: &TensorModel,
        data: &Dataset,
        spec: &TaskSpec,
    ) -> Result<(TensorModel, TaskMeta)> {
        let sw = Stopwatch::start();
        let mut m = model.clone();
        let mut steps = 0usize;
        let mut last_loss = 0.0f64;
        let budget = if spec.step_budget > 0 { spec.step_budget } else { usize::MAX };
        'outer: for _ in 0..spec.epochs.max(1) {
            for (xb, yb) in data.train_batches(spec.batch_size.max(1)) {
                last_loss = Self::sgd_step(
                    &mut m,
                    xb,
                    yb,
                    data.features,
                    spec.learning_rate as f32,
                );
                steps += 1;
                if steps >= budget {
                    break 'outer;
                }
            }
        }
        let elapsed = sw.elapsed();
        let meta = TaskMeta {
            train_time_per_batch_us: (elapsed.as_micros() as u64 / steps.max(1) as u64).max(1),
            completed_steps: steps,
            completed_epochs: spec.epochs.max(1),
            num_samples: data.train_len(),
            train_loss: last_loss,
            steps_per_sec: steps.max(1) as f64 / elapsed.as_secs_f64().max(1e-9),
            train_wall_time_us: (elapsed.as_micros() as u64).max(1),
            ..TaskMeta::default()
        };
        Ok((m, meta))
    }

    fn evaluate(&self, model: &TensorModel, data: &Dataset) -> Result<EvalResult> {
        let sw = Stopwatch::start();
        let (_, pred) = Self::forward(model, &data.x_test, data.features);
        let loss = Self::mse(&pred, &data.y_test);
        Ok(EvalResult {
            loss,
            num_samples: data.test_len(),
            eval_time_us: sw.elapsed().as_micros() as u64,
        })
    }

    fn name(&self) -> &'static str {
        "rust_sgd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;
    use crate::tensor::TensorModel;

    fn setup() -> (TensorModel, Dataset) {
        let layout = ModelSpec::mlp(4, 2, 8).tensor_layout();
        let model = TensorModel::random_init(&layout, &mut Rng::new(3));
        let data = Dataset::synthetic_housing(4, 64, 32, 5);
        (model, data)
    }

    fn spec() -> TaskSpec {
        TaskSpec { epochs: 1, batch_size: 16, learning_rate: 0.01, step_budget: 0 }
    }

    #[test]
    fn synthetic_trainer_changes_every_tensor() {
        let (model, data) = setup();
        let t = SyntheticTrainer::new(0, 0.1);
        let (out, meta) = t.train(&model, &data, &spec()).unwrap();
        assert_eq!(meta.completed_steps, 4); // 64/16
        assert_eq!(meta.num_samples, 64);
        for (a, b) in out.tensors.iter().zip(&model.tensors) {
            assert_ne!(a.data, b.data, "tensor {} unchanged", a.name);
        }
    }

    #[test]
    fn synthetic_trainer_reports_throughput_telemetry() {
        let (model, data) = setup();
        let t = SyntheticTrainer::new(0, 0.1);
        let (_, meta) = t.train(&model, &data, &spec()).unwrap();
        assert!(meta.steps_per_sec > 0.0);
        assert!(meta.train_wall_time_us >= 1);
        // Telemetry is self-consistent within rounding.
        let derived = meta.completed_steps as f64 / (meta.train_wall_time_us as f64 / 1e6);
        assert!(
            (derived - meta.steps_per_sec).abs() / meta.steps_per_sec < 0.5,
            "{derived} vs {}",
            meta.steps_per_sec
        );
    }

    #[test]
    fn dropout_profile_fails_tasks_deterministically() {
        let (model, data) = setup();
        // dropout = 1 − ε fails essentially every task; two trainers
        // with the same seed behave identically.
        let a = SyntheticTrainer::with_profile(0, 0.1, 0.0, 0.99, 7);
        let b = SyntheticTrainer::with_profile(0, 0.1, 0.0, 0.99, 7);
        let ra: Vec<bool> = (0..20).map(|_| a.train(&model, &data, &spec()).is_ok()).collect();
        let rb: Vec<bool> = (0..20).map(|_| b.train(&model, &data, &spec()).is_ok()).collect();
        assert_eq!(ra, rb);
        assert!(ra.iter().filter(|ok| !**ok).count() >= 15, "{ra:?}");
        // dropout = 0 never fails.
        let c = SyntheticTrainer::with_profile(0, 0.1, 0.0, 0.0, 7);
        assert!((0..20).all(|_| c.train(&model, &data, &spec()).is_ok()));
    }

    #[test]
    fn default_profile_matches_new() {
        // `new` and `with_profile(.., 0, 0, 0)` must produce identical
        // update streams (jitter/dropout draws only happen when
        // configured).
        let (model, data) = setup();
        let a = SyntheticTrainer::new(0, 0.1);
        let b = SyntheticTrainer::with_profile(0, 0.1, 0.0, 0.0, 0);
        let (ma, _) = a.train(&model, &data, &spec()).unwrap();
        let (mb, _) = b.train(&model, &data, &spec()).unwrap();
        assert_eq!(ma, mb);
    }

    #[test]
    fn synthetic_trainer_respects_step_budget() {
        let (model, data) = setup();
        let t = SyntheticTrainer::new(0, 0.1);
        let mut s = spec();
        s.step_budget = 2;
        let (_, meta) = t.train(&model, &data, &s).unwrap();
        assert_eq!(meta.completed_steps, 2);
    }

    #[test]
    fn rust_sgd_reduces_training_loss() {
        let (model, data) = setup();
        let t = RustSgdTrainer;
        let before = t.evaluate(&model, &data).unwrap().loss;
        let mut m = model;
        for _ in 0..30 {
            let (next, _) = t
                .train(&m, &data, &TaskSpec {
                    epochs: 1,
                    batch_size: 16,
                    learning_rate: 0.02,
                    step_budget: 0,
                })
                .unwrap();
            m = next;
        }
        let after = t.evaluate(&m, &data).unwrap().loss;
        assert!(
            after < before * 0.8,
            "SGD failed to reduce loss: {before} -> {after}"
        );
    }

    #[test]
    fn rust_sgd_step_budget_limits_steps() {
        let (model, data) = setup();
        let t = RustSgdTrainer;
        let (_, meta) = t
            .train(&model, &data, &TaskSpec {
                epochs: 10,
                batch_size: 16,
                learning_rate: 0.01,
                step_budget: 3,
            })
            .unwrap();
        assert_eq!(meta.completed_steps, 3);
    }

    #[test]
    fn evaluate_is_deterministic() {
        let (model, data) = setup();
        let t = RustSgdTrainer;
        let a = t.evaluate(&model, &data).unwrap();
        let b = t.evaluate(&model, &data).unwrap();
        assert_eq!(a.loss, b.loss);
        assert_eq!(a.num_samples, 32);
    }
}
