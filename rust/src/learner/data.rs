//! Synthetic regression dataset (Housing-dataset substitute).
//!
//! The paper trains the HousingMLP on the Boston-housing-style dataset,
//! sampling 100 rows with replacement per learner — the data content is
//! irrelevant to the stress test, only its shape. We generate a
//! housing-like regression task: 8 standardized features, target = a
//! fixed nonlinear function + noise, deterministic per (seed, learner).

use crate::util::Rng;

/// A learner's local train/test split, row-major `[n, features]`.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub features: usize,
    pub x_train: Vec<f32>,
    pub y_train: Vec<f32>,
    pub x_test: Vec<f32>,
    pub y_test: Vec<f32>,
}

impl Dataset {
    /// Generate a synthetic housing-like dataset.
    pub fn synthetic_housing(
        features: usize,
        train_rows: usize,
        test_rows: usize,
        seed: u64,
    ) -> Dataset {
        let mut rng = Rng::new(seed ^ 0x0BAD_5EED);
        // Fixed "ground truth" weights shared across learners (IID-ish
        // sampling with replacement, like the paper's setup).
        let mut truth_rng = Rng::new(0xFEED_FACE);
        let w: Vec<f64> = (0..features).map(|_| truth_rng.next_gaussian()).collect();
        let gen = |rng: &mut Rng, rows: usize| -> (Vec<f32>, Vec<f32>) {
            let mut x = Vec::with_capacity(rows * features);
            let mut y = Vec::with_capacity(rows);
            for _ in 0..rows {
                let mut dot = 0.0f64;
                let mut sq = 0.0f64;
                for f in 0..features {
                    let v = rng.next_gaussian();
                    x.push(v as f32);
                    dot += w[f] * v;
                    sq += v * v;
                }
                // Mildly nonlinear target so the MLP has something to fit.
                let target = dot + 0.1 * sq / features as f64 + 0.05 * rng.next_gaussian();
                y.push(target as f32);
            }
            (x, y)
        };
        let (x_train, y_train) = gen(&mut rng, train_rows);
        let (x_test, y_test) = gen(&mut rng, test_rows);
        Dataset { features, x_train, y_train, x_test, y_test }
    }

    pub fn train_len(&self) -> usize {
        self.y_train.len()
    }

    pub fn test_len(&self) -> usize {
        self.y_test.len()
    }

    /// Iterate training batches of `batch` rows (last short batch kept).
    pub fn train_batches(&self, batch: usize) -> impl Iterator<Item = (&[f32], &[f32])> {
        BatchIter { x: &self.x_train, y: &self.y_train, features: self.features, batch, pos: 0 }
    }

    /// Iterate test batches.
    pub fn test_batches(&self, batch: usize) -> impl Iterator<Item = (&[f32], &[f32])> {
        BatchIter { x: &self.x_test, y: &self.y_test, features: self.features, batch, pos: 0 }
    }
}

struct BatchIter<'a> {
    x: &'a [f32],
    y: &'a [f32],
    features: usize,
    batch: usize,
    pos: usize,
}

impl<'a> Iterator for BatchIter<'a> {
    type Item = (&'a [f32], &'a [f32]);

    fn next(&mut self) -> Option<Self::Item> {
        if self.pos >= self.y.len() {
            return None;
        }
        let end = (self.pos + self.batch).min(self.y.len());
        let xb = &self.x[self.pos * self.features..end * self.features];
        let yb = &self.y[self.pos..end];
        self.pos = end;
        Some((xb, yb))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_are_consistent() {
        let d = Dataset::synthetic_housing(8, 100, 30, 1);
        assert_eq!(d.train_len(), 100);
        assert_eq!(d.test_len(), 30);
        assert_eq!(d.x_train.len(), 800);
        assert_eq!(d.x_test.len(), 240);
    }

    #[test]
    fn deterministic_per_seed_distinct_across_seeds() {
        let a = Dataset::synthetic_housing(4, 10, 5, 7);
        let b = Dataset::synthetic_housing(4, 10, 5, 7);
        let c = Dataset::synthetic_housing(4, 10, 5, 8);
        assert_eq!(a.x_train, b.x_train);
        assert_eq!(a.y_test, b.y_test);
        assert_ne!(a.x_train, c.x_train);
    }

    #[test]
    fn batching_covers_all_rows_once() {
        let d = Dataset::synthetic_housing(3, 25, 10, 2);
        let mut rows = 0;
        for (xb, yb) in d.train_batches(10) {
            assert_eq!(xb.len(), yb.len() * 3);
            rows += yb.len();
        }
        assert_eq!(rows, 25); // 10 + 10 + 5
        let sizes: Vec<usize> = d.train_batches(10).map(|(_, y)| y.len()).collect();
        assert_eq!(sizes, vec![10, 10, 5]);
    }

    #[test]
    fn features_are_roughly_standardized() {
        let d = Dataset::synthetic_housing(8, 2000, 10, 3);
        let n = d.x_train.len() as f64;
        let mean: f64 = d.x_train.iter().map(|&v| v as f64).sum::<f64>() / n;
        let var: f64 =
            d.x_train.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn targets_correlate_with_features() {
        // Sanity: the task must be learnable (non-degenerate targets).
        let d = Dataset::synthetic_housing(8, 500, 10, 4);
        let my: f64 = d.y_train.iter().map(|&v| v as f64).sum::<f64>() / 500.0;
        let vy: f64 =
            d.y_train.iter().map(|&v| (v as f64 - my).powi(2)).sum::<f64>() / 500.0;
        assert!(vy > 0.5, "target variance too small: {vy}");
    }
}
