//! The federation learner (paper App. B, Figs. 9–10).
//!
//! A learner runs a servicer that accepts controller RPCs:
//!
//! * `RunTask` — submits local training to the background task-pool
//!   executor and replies `Ack` immediately (the controller's
//!   fire-and-forget dispatch). On completion the executor calls
//!   `MarkTaskCompleted` back on the controller.
//! * `EvaluateModel` — evaluates synchronously and replies in-call.
//!
//! With the v3 symmetric data plane, both dispatches can also arrive as
//! chunked model streams (`ModelStreamBegin` with a `RunTask` /
//! `Evaluate` purpose): the learner ingests chunks on arrival — in the
//! connection handler, outside the training executor — through the same
//! [`StreamIngest`] engine the controller uses for uploads, and the
//! `End` ack queues the training task (or carries the eval reply).
//! Lossless streamed dispatches are recorded as the learner's *last
//! community model*, which is the shared base its delta-coded uploads
//! encode against.
//!
//! Local compute is pluggable via [`Trainer`]: the stress tests use
//! [`SyntheticTrainer`]; real training uses `runtime::XlaTrainer` (the
//! AOT-compiled JAX train/eval steps).

pub mod data;
pub mod trainer;

pub use data::Dataset;
pub use trainer::{SyntheticTrainer, Trainer};

use crate::metrics::counters::{names, Counter, CounterRegistry};
use crate::net::chaos::{connect_with_chaos, ChaosPlan};
use crate::net::retry::RetryPolicy;
use crate::net::{ClientConn, Psk, Service};
use crate::obs::{SpanCtx, SpanSink};
use crate::proto::client::{self, RpcError, StreamSend};
use crate::proto::ingest::{IngestLimits, StreamBegin, StreamIngest};
use crate::proto::wire::{fnv1a64, FNV64_INIT};
use crate::proto::{ErrorCode, Message, ModelProto, StreamPurpose, TaskSpec, PROTO_VERSION};
use crate::tensor::{ByteOrder, CodecId, DType, TensorModel};
use crate::util::clock::Clock;
use crate::util::{log_debug, log_warn, Rng, Stopwatch, ThreadPool};
use anyhow::Result;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// A learner node.
pub struct Learner {
    pub id: String,
    /// Endpoint of the upstream the learner registers with and calls
    /// back to — mutable because failover re-homes the learner onto a
    /// surviving aggregator mid-run (see [`Learner::rehome`]).
    controller_endpoint: Mutex<String>,
    psk: Psk,
    trainer: Arc<dyn Trainer>,
    dataset: Arc<Dataset>,
    /// Background training-task pool ("training task pool executor",
    /// Fig. 9). One worker: local tasks execute in submission order.
    executor: ThreadPool,
    /// Dedicated connection for completion callbacks.
    callback_conn: Mutex<Option<Box<dyn ClientConn>>>,
    /// Data-plane chunk size for completed-model uploads; 0 = one-shot
    /// `MarkTaskCompleted` (see `FederationEnv::stream_chunk_bytes`).
    stream_chunk: AtomicUsize,
    /// Wire codec for streamed uploads (resolved by the driver from
    /// `FederationEnv::upload_codec`; defaults to plain f32).
    upload_codec: Mutex<CodecId>,
    /// Codec set the controller accepted in the callback-channel
    /// handshake; a configured codec the peer negotiated away falls
    /// back to f32 instead of being refused at `Begin`.
    accepted_codecs: Mutex<Option<Vec<CodecId>>>,
    /// Mirror of `FederationEnv::delta_fallback`: retry a refused delta
    /// upload as full f32 (true, default) or surface the refusal.
    delta_fallback: AtomicBool,
    /// Last community model received over a *lossless* dispatch stream,
    /// with its identity (community round): the shared base delta-coded
    /// uploads encode against, and the base inbound delta dispatches
    /// decode against.
    last_community: Mutex<Option<(u64, Arc<TensorModel>)>>,
    /// Inbound data-plane engine for streamed dispatch.
    ingest: StreamIngest,
    /// Fault-injection plan for the callback connection (chaos
    /// harness); `None` in production.
    chaos: Mutex<Option<ChaosPlan>>,
    /// Time source for upload timing, retry backoff, chaos stalls, and
    /// the ingest GC (`Clock::sim()` under `loadtest --sim`).
    clock: Clock,
    /// Degradation counter registry shared with this learner's ingest
    /// engine (snapshotted whole by the harness).
    counters: Arc<CounterRegistry>,
    /// Uploads abandoned after the retry policy's budget ran dry.
    retry_give_ups: Counter,
    /// Streamed uploads that fell back from a base-needing codec to
    /// full f32 (the receiver lacked the shared base).
    fallback_sends: Counter,
    /// Wall-clock duration of each successful completion upload
    /// (bounded; the loadtest harness drains it per run).
    upload_timings: Mutex<Vec<Duration>>,
    /// Span recorder for learner-side work — train, upload, and each
    /// upload attempt (so severed-then-retried uploads leave a span per
    /// attempt). Parents under the dispatch context carried in the
    /// stream's `TaskMeta`; disabled by default.
    spans: Arc<SpanSink>,
    shutdown: AtomicBool,
    tasks_completed: AtomicU64,
}

/// Cap on retained upload timings, so a long-lived learner does not
/// grow the sample buffer unboundedly between harness drains.
const MAX_UPLOAD_TIMINGS: usize = 4096;

impl Learner {
    pub fn new(
        id: &str,
        controller_endpoint: &str,
        psk: Psk,
        trainer: Arc<dyn Trainer>,
        dataset: Dataset,
    ) -> Arc<Learner> {
        Self::with_clock(id, controller_endpoint, psk, trainer, dataset, Clock::system())
    }

    /// Construct against an explicit time source (`Clock::sim()` runs
    /// uploads, retries, and the ingest GC in discrete virtual time).
    pub fn with_clock(
        id: &str,
        controller_endpoint: &str,
        psk: Psk,
        trainer: Arc<dyn Trainer>,
        dataset: Dataset,
        clock: Clock,
    ) -> Arc<Learner> {
        let counters = CounterRegistry::new();
        let spans = SpanSink::new(format!("learner/{id}"), clock.clone());
        Arc::new(Learner {
            id: id.to_string(),
            controller_endpoint: Mutex::new(controller_endpoint.to_string()),
            psk,
            trainer,
            dataset: Arc::new(dataset),
            executor: ThreadPool::with_clock(1, clock.clone()),
            callback_conn: Mutex::new(None),
            stream_chunk: AtomicUsize::new(0),
            upload_codec: Mutex::new(CodecId::F32),
            accepted_codecs: Mutex::new(None),
            delta_fallback: AtomicBool::new(true),
            last_community: Mutex::new(None),
            ingest: StreamIngest::with_clock(
                IngestLimits::default(),
                clock.clone(),
                Arc::clone(&counters),
            ),
            chaos: Mutex::new(None),
            retry_give_ups: counters.counter(names::RETRY_GIVE_UPS),
            fallback_sends: counters.counter(names::FALLBACK_SENDS),
            clock,
            counters,
            upload_timings: Mutex::new(Vec::new()),
            spans,
            shutdown: AtomicBool::new(false),
            tasks_completed: AtomicU64::new(0),
        })
    }

    /// The learner's degradation counter registry (shared with its
    /// ingest engine).
    pub fn counters(&self) -> &Arc<CounterRegistry> {
        &self.counters
    }

    /// The learner's span recorder (enable via
    /// [`crate::obs::SpanSink::enable`]; drained by the harness).
    pub fn span_sink(&self) -> &Arc<SpanSink> {
        &self.spans
    }

    /// Route every future callback dial through a fault-injection plan
    /// (chaos harness). The current connection, if any, is dropped so
    /// the plan takes effect on the next call.
    pub fn set_chaos(&self, plan: ChaosPlan) {
        *self.chaos.lock().unwrap() = Some(plan);
        *self.callback_conn.lock().unwrap() = None;
    }

    /// Uploads abandoned after the retry budget ran dry.
    pub fn retry_give_ups(&self) -> u64 {
        self.retry_give_ups.get()
    }

    /// Streamed uploads that fell back to full f32.
    pub fn fallback_sends(&self) -> u64 {
        self.fallback_sends.get()
    }

    /// Drain the recorded per-upload durations (loadtest harness).
    pub fn take_upload_timings(&self) -> Vec<Duration> {
        std::mem::take(&mut *self.upload_timings.lock().unwrap())
    }

    /// Upload completed models over the streaming data plane in chunks
    /// of `bytes` (0 = one-shot).
    pub fn set_stream_chunk(&self, bytes: usize) {
        self.stream_chunk.store(bytes, Ordering::SeqCst);
    }

    pub fn stream_chunk(&self) -> usize {
        self.stream_chunk.load(Ordering::SeqCst)
    }

    /// Wire codec for streamed uploads. Delta uploads silently use f32
    /// until a lossless streamed dispatch has established a base.
    pub fn set_upload_codec(&self, codec: CodecId) {
        *self.upload_codec.lock().unwrap() = codec;
    }

    pub fn upload_codec(&self) -> CodecId {
        *self.upload_codec.lock().unwrap()
    }

    /// Mirror `FederationEnv::delta_fallback` (set by the driver).
    pub fn set_delta_fallback(&self, on: bool) {
        self.delta_fallback.store(on, Ordering::SeqCst);
    }

    /// The inbound data-plane engine (runs on this learner's clock).
    pub fn ingest(&self) -> &StreamIngest {
        &self.ingest
    }

    /// Identity of the last community model received over a lossless
    /// streamed dispatch (the learner's delta base), if any.
    pub fn last_community_round(&self) -> Option<u64> {
        self.last_community.lock().unwrap().as_ref().map(|(r, _)| *r)
    }

    /// Point the learner at a new upstream (failover re-homing). Drops
    /// the callback connection (the next call re-dials and re-runs the
    /// codec handshake against the new peer) and forgets the recorded
    /// delta base — the new aggregator does not hold our old base, so
    /// the first re-homed upload degrades to full f32 instead of
    /// shipping a delta nobody can decode.
    pub fn rehome(&self, new_endpoint: &str) {
        *self.controller_endpoint.lock().unwrap() = new_endpoint.to_string();
        *self.callback_conn.lock().unwrap() = None;
        *self.accepted_codecs.lock().unwrap() = None;
        *self.last_community.lock().unwrap() = None;
    }

    /// Register with the controller (Fig. 8 initialization).
    pub fn register(&self, own_endpoint: &str) -> Result<usize> {
        self.with_callback_conn(|conn| {
            client::register(conn, &self.id, own_endpoint, self.dataset.train_len())
        })
        .map_err(|e| anyhow::anyhow!("registering with controller: {e}"))
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    pub fn tasks_completed(&self) -> u64 {
        self.tasks_completed.load(Ordering::SeqCst)
    }

    /// Run `f` against the (lazily dialed) callback connection. A fresh
    /// connection opens with the versioned `Hello` handshake; transport
    /// failures drop the connection so the next call re-dials, while
    /// remote (application) errors keep it.
    fn with_callback_conn<T>(
        &self,
        f: impl FnOnce(&mut dyn ClientConn) -> Result<T, RpcError>,
    ) -> Result<T, RpcError> {
        let mut guard = self.callback_conn.lock().unwrap();
        if guard.is_none() {
            let endpoint = self.controller_endpoint.lock().unwrap().clone();
            let plan = self.chaos.lock().unwrap().clone();
            let mut conn = match &plan {
                Some(p) => connect_with_chaos(&endpoint, self.psk, p, &self.clock),
                None => crate::net::connect(&endpoint, self.psk),
            }
            .map_err(RpcError::Transport)?;
            let (_, accepted) = client::hello_negotiate(conn.as_mut())?;
            *self.accepted_codecs.lock().unwrap() = Some(accepted);
            *guard = Some(conn);
        }
        match f(guard.as_mut().unwrap().as_mut()) {
            Ok(v) => Ok(v),
            Err(e) => {
                if e.is_transport() {
                    *guard = None; // force reconnect next time
                }
                Err(e)
            }
        }
    }

    /// Execute one training task and call back `MarkTaskCompleted` —
    /// one-shot for small models, chunk-streamed (under the configured
    /// upload codec) when a data-plane chunk size is configured.
    fn run_train_task(self: &Arc<Self>, task_id: u64, round: u64, model: ModelProto, spec: TaskSpec) {
        let learner = Arc::clone(self);
        self.executor.spawn(move || {
            if learner.is_shutdown() {
                return;
            }
            // One-shot RunTask carries no task meta, hence no trace
            // context — the task roots its own trace if spans are on.
            let result = model
                .to_model()
                .and_then(|m| learner.train_and_upload(task_id, round, &m, &spec, SpanCtx::UNSET));
            learner.log_task_result(task_id, result);
        });
    }

    /// Streamed-dispatch variant: the model is already decoded (shared
    /// by pointer with the recorded delta base — no copy).
    fn run_train_task_model(
        self: &Arc<Self>,
        task_id: u64,
        round: u64,
        model: Arc<TensorModel>,
        spec: TaskSpec,
        ctx: SpanCtx,
    ) {
        let learner = Arc::clone(self);
        self.executor.spawn(move || {
            if learner.is_shutdown() {
                return;
            }
            let result = learner.train_and_upload(task_id, round, &model, &spec, ctx);
            learner.log_task_result(task_id, result);
        });
    }

    fn log_task_result(&self, task_id: u64, result: Result<()>) {
        match result {
            Ok(()) => {
                self.tasks_completed.fetch_add(1, Ordering::SeqCst);
                log_debug("learner", &format!("{} completed task {task_id}", self.id));
            }
            Err(e) => log_warn("learner", &format!("{} task {task_id} failed: {e:#}", self.id)),
        }
    }

    /// Train on `model` and upload the result: one-shot `MarkTaskCompleted`
    /// when no chunk size is configured, a codec-aware stream otherwise.
    /// Delta uploads encode against the recorded last community model
    /// and fall back to full f32 when no base is shared on either side.
    fn train_and_upload(
        self: &Arc<Self>,
        task_id: u64,
        round: u64,
        model: &TensorModel,
        spec: &TaskSpec,
        ctx: SpanCtx,
    ) -> Result<()> {
        let train_span = self.spans.begin("train", ctx).task(task_id).round(round);
        let (trained, meta) = self.trainer.train(model, &self.dataset, spec)?;
        train_span.end();
        let chunk = self.stream_chunk();
        // Transport faults retry through the unified policy: each
        // attempt re-dials (the connection is dropped on a transport
        // error), streams under a FRESH stream id, and replays are
        // idempotent — the controller's completed-task watermark drops
        // duplicates, and any half-delivered stream from a failed
        // attempt is reclaimed by the receiver's idle/lifetime GC.
        // Remote application errors never retry.
        let policy = RetryPolicy::rpc();
        let mut rng = Rng::new(fnv1a64(FNV64_INIT, self.id.as_bytes()) ^ task_id);
        let started = Stopwatch::start_with(&self.clock);
        let fallback = self.delta_fallback.load(Ordering::SeqCst);
        // One span brackets the whole upload (including backoff);
        // each retry attempt gets a child span, and the ATTEMPT's
        // context rides the wire meta — the controller's ingest span
        // parents under the exact attempt that delivered it.
        let upload_span = self.spans.begin("upload", ctx).task(task_id).round(round);
        let upload_ctx = upload_span.ctx();
        let upload = if chunk > 0 {
            // Each attempt returns whether the f32 fallback path fired.
            policy.run(
                &self.clock,
                &mut rng,
                |_| {
                    let attempt_span = self
                        .spans
                        .begin("upload_attempt", upload_ctx)
                        .task(task_id)
                        .round(round);
                    // Ensure the callback session (and its codec
                    // negotiation) exists before choosing a codec — a
                    // re-dial renegotiates.
                    self.with_callback_conn(|_| Ok(()))?;
                    let configured = self.upload_codec();
                    // Honor the peer's accepted set: a codec the
                    // controller negotiated away degrades along the
                    // lossless chain (delta-rle → delta → f32) instead
                    // of a refused Begin.
                    let configured = match self.accepted_codecs.lock().unwrap().as_ref() {
                        Some(accepted) => configured.degrade_to(accepted),
                        None => configured,
                    };
                    let (codec, base, base_round) = if configured.needs_base() {
                        match self.last_community.lock().unwrap().clone() {
                            Some((r, m)) => (configured, Some(m), r),
                            // No lossless streamed dispatch yet: full send.
                            None => (CodecId::F32, None, 0),
                        }
                    } else {
                        (configured, None, 0)
                    };
                    let task_spec = TaskSpec::default();
                    let meta_wire = meta.clone().with_span_ctx(attempt_span.ctx());
                    let send = StreamSend {
                        purpose: StreamPurpose::TaskCompletion,
                        task_id,
                        round,
                        learner_id: &self.id,
                        model: &trained,
                        meta: &meta_wire,
                        spec: &task_spec,
                        codec,
                        base: base.as_deref(),
                        base_round,
                        chunk_bytes: chunk.max(client::MIN_CHUNK_BYTES),
                    };
                    self.with_callback_conn(|conn| {
                        // The controller may have moved past our base
                        // (async staleness): retry full rather than
                        // dropping the round — unless the env asked
                        // refusals to surface (`delta_fallback: false`).
                        let rpc_fn = &mut |msg| client::rpc(&mut *conn, &msg);
                        if fallback {
                            client::stream_model_with_fallback_counted(rpc_fn, &send)
                                .map(|(_, fell_back)| fell_back)
                        } else {
                            client::stream_model_with(rpc_fn, &send).map(|_| false)
                        }
                    })
                },
                |e| e.is_transport(),
            )
        } else {
            policy.run(
                &self.clock,
                &mut rng,
                |_| {
                    let attempt_span = self
                        .spans
                        .begin("upload_attempt", upload_ctx)
                        .task(task_id)
                        .round(round);
                    let meta_wire = meta.clone().with_span_ctx(attempt_span.ctx());
                    let proto = ModelProto::from_model(&trained, DType::F32, ByteOrder::Little);
                    self.with_callback_conn(|conn| {
                        client::mark_task_completed(conn, task_id, &self.id, proto, meta_wire)
                    })
                    .map(|()| false)
                },
                |e| e.is_transport(),
            )
        };
        match upload {
            Ok(fell_back) => {
                if fell_back {
                    self.fallback_sends.incr();
                }
                let mut timings = self.upload_timings.lock().unwrap();
                if timings.len() < MAX_UPLOAD_TIMINGS {
                    timings.push(started.elapsed());
                }
                Ok(())
            }
            Err(give_up) => {
                if give_up.exhausted {
                    self.retry_give_ups.incr();
                }
                anyhow::bail!(
                    "completion callback: gave up after {} attempts in {:?}: {}",
                    give_up.attempts,
                    give_up.elapsed,
                    give_up.last_error
                )
            }
        }
    }

    /// Record a lossless streamed dispatch as the new delta base.
    fn record_community(&self, round: u64, codec: CodecId, model: &Arc<TensorModel>) {
        if codec.is_lossless() {
            *self.last_community.lock().unwrap() = Some((round, Arc::clone(model)));
        }
    }
}

/// The learner servicer: the [`Service`] facade exposed to the network.
pub struct LearnerServicer(pub Arc<Learner>);

impl Service for LearnerServicer {
    fn handle(&self, msg: Message) -> Message {
        let learner = &self.0;
        if learner.is_shutdown() {
            return Message::error(ErrorCode::Unavailable, "learner is shut down");
        }
        match msg {
            Message::Hello { proto_version, codecs } => {
                if proto_version == PROTO_VERSION {
                    Message::HelloAck {
                        proto_version: PROTO_VERSION,
                        component: format!("learner/{}", learner.id),
                        codecs: crate::tensor::codec::negotiate(
                            &codecs,
                            &client::SUPPORTED_CODECS,
                        ),
                    }
                } else {
                    Message::error(
                        ErrorCode::VersionMismatch,
                        format!("learner speaks v{PROTO_VERSION}, peer v{proto_version}"),
                    )
                }
            }
            Message::RunTask { task_id, round, model, spec } => {
                // Submit to the executor; Ack as soon as it is queued
                // (Fig. 9: "the executor replies with an Ack message").
                learner.run_train_task(task_id, round, model, spec);
                Message::Ack { task_id, ok: true }
            }
            Message::EvaluateModel { task_id, round: _, model } => {
                match model
                    .to_model()
                    .and_then(|m| learner.trainer.evaluate(&m, &learner.dataset))
                {
                    Ok(result) => Message::EvaluateModelReply {
                        task_id,
                        learner_id: learner.id.clone(),
                        result,
                    },
                    Err(e) => Message::error(ErrorCode::Internal, format!("eval failed: {e:#}")),
                }
            }
            Message::Heartbeat { .. } => {
                // Like the controller, use the periodic probe to sweep
                // streams abandoned by a dead peer — then report real
                // state, not a hardcoded `true`.
                learner.ingest.gc_idle();
                let health = crate::proto::HealthProbe {
                    open_rounds: 0,
                    open_streams: learner.ingest.open_streams() as u64,
                    retry_give_ups: learner.retry_give_ups(),
                };
                Message::HeartbeatAck {
                    component: format!("learner/{}", learner.id),
                    healthy: health.is_healthy(),
                    health,
                }
            }
            Message::Shutdown => {
                learner.shutdown.store(true, Ordering::SeqCst);
                Message::Ack { task_id: 0, ok: true }
            }
            // Symmetric data plane: dispatch can arrive as a chunked
            // model stream. Chunks decode here, in the connection
            // handler — outside the training executor — so training and
            // ingest overlap.
            Message::ModelStreamBegin {
                stream_id,
                task_id,
                round,
                purpose,
                learner_id,
                codec,
                base_round,
                layout,
                meta,
                spec,
            } => {
                if !matches!(purpose, StreamPurpose::RunTask | StreamPurpose::Evaluate) {
                    return Message::error(
                        ErrorCode::Unsupported,
                        "learner accepts only dispatch streams (RunTask / Evaluate)",
                    );
                }
                let base = if codec.needs_base() {
                    learner
                        .last_community
                        .lock()
                        .unwrap()
                        .clone()
                        .filter(|(r, _)| *r == base_round)
                        .map(|(_, m)| m)
                } else {
                    None
                };
                learner.ingest.begin(
                    StreamBegin {
                        stream_id,
                        task_id,
                        round,
                        purpose,
                        learner_id,
                        codec,
                        base_round,
                        layout,
                        meta,
                        spec,
                    },
                    None,
                    base,
                )
            }
            Message::ModelChunk { stream_id, seq, bytes } => {
                learner.ingest.chunk(stream_id, seq, bytes)
            }
            Message::ModelStreamEnd { stream_id, digest } => {
                let finished = match learner.ingest.end(stream_id, digest) {
                    Ok(f) => f,
                    Err(reply) => return reply,
                };
                let model = Arc::new(finished.model);
                // A lossless streamed dispatch carries the community
                // model bit-exactly: record it (with its identity) as
                // the delta base for uploads and later dispatches —
                // but only on the success paths below. The controller
                // installs its side of the base only when some learner
                // replied non-error (`any_delivered`); recording ours
                // on an error reply would let the two bases diverge
                // permanently under `delta_fallback: false`.
                match finished.purpose {
                    StreamPurpose::RunTask => {
                        // Queue training and ack, exactly like one-shot
                        // RunTask (Fig. 9).
                        learner.record_community(finished.round, finished.codec, &model);
                        let ctx = finished.meta.span_ctx();
                        learner.run_train_task_model(
                            finished.task_id,
                            finished.round,
                            model,
                            finished.spec,
                            ctx,
                        );
                        Message::Ack { task_id: finished.task_id, ok: true }
                    }
                    StreamPurpose::Evaluate => {
                        // The End reply IS the eval reply (Fig. 10's
                        // synchronous call, streamed).
                        match learner.trainer.evaluate(&model, &learner.dataset) {
                            Ok(result) => {
                                learner.record_community(
                                    finished.round,
                                    finished.codec,
                                    &model,
                                );
                                Message::EvaluateModelReply {
                                    task_id: finished.task_id,
                                    learner_id: learner.id.clone(),
                                    result,
                                }
                            }
                            Err(e) => Message::error(
                                ErrorCode::Internal,
                                format!("eval failed: {e:#}"),
                            ),
                        }
                    }
                    // begin() refused upload purposes already.
                    _ => Message::error(ErrorCode::Unsupported, "unexpected upload stream"),
                }
            }
            other => {
                Message::error(ErrorCode::Unsupported, format!("unexpected {}", other.kind()))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;
    use crate::proto::TaskMeta;
    use crate::tensor::TensorModel;
    use crate::util::Rng;
    use std::sync::Mutex as StdMutex;

    /// Controller stub capturing completions.
    struct Capture {
        completions: StdMutex<Vec<(u64, String, TaskMeta)>>,
    }
    impl Service for Capture {
        fn handle(&self, msg: Message) -> Message {
            match msg {
                Message::Hello { .. } => Message::HelloAck {
                    proto_version: PROTO_VERSION,
                    component: "capture".into(),
                    codecs: client::SUPPORTED_CODECS.to_vec(),
                },
                Message::MarkTaskCompleted { task_id, learner_id, meta, .. } => {
                    self.completions.lock().unwrap().push((task_id, learner_id, meta));
                    Message::Ack { task_id, ok: true }
                }
                Message::Register { .. } => {
                    Message::RegisterAck { accepted: true, assigned_index: 0 }
                }
                other => {
                    Message::error(ErrorCode::Unsupported, format!("unexpected {}", other.kind()))
                }
            }
        }
    }

    fn setup(tag: &str) -> (Arc<Learner>, Arc<Capture>, Box<dyn crate::net::ServerHandle>) {
        let capture = Arc::new(Capture { completions: StdMutex::new(Vec::new()) });
        let ep = format!("inproc://ctrl-{tag}");
        let handle = crate::net::serve(&ep, capture.clone(), None).unwrap();
        let spec = ModelSpec::mlp(4, 2, 8);
        let dataset = Dataset::synthetic_housing(4, 50, 20, 7);
        let learner = Learner::new(
            "l0",
            &ep,
            None,
            Arc::new(SyntheticTrainer::new(0, 0.01)),
            dataset,
        );
        let _ = spec;
        (learner, capture, handle)
    }

    fn model() -> ModelProto {
        let layout = ModelSpec::mlp(4, 2, 8).tensor_layout();
        let m = TensorModel::random_init(&layout, &mut Rng::new(5));
        ModelProto::from_model(&m, DType::F32, ByteOrder::Little)
    }

    #[test]
    fn run_task_acks_then_calls_back() {
        let (learner, capture, _h) = setup("runtask");
        let servicer = LearnerServicer(Arc::clone(&learner));
        let reply = servicer.handle(Message::RunTask {
            task_id: 9,
            round: 1,
            model: model(),
            spec: TaskSpec { epochs: 1, batch_size: 10, learning_rate: 0.1, step_budget: 0 },
        });
        assert_eq!(reply, Message::Ack { task_id: 9, ok: true });
        // Wait for the background completion callback.
        let sw = Stopwatch::start();
        while learner.tasks_completed() == 0 {
            assert!(sw.elapsed() < std::time::Duration::from_secs(5), "no completion");
            Clock::system().sleep(std::time::Duration::from_millis(2));
        }
        let completions = capture.completions.lock().unwrap();
        assert_eq!(completions.len(), 1);
        assert_eq!(completions[0].0, 9);
        assert_eq!(completions[0].1, "l0");
        assert_eq!(completions[0].2.num_samples, 50);
        assert!(completions[0].2.completed_steps > 0);
    }

    #[test]
    fn evaluate_replies_synchronously() {
        let (learner, _capture, _h) = setup("eval");
        let servicer = LearnerServicer(Arc::clone(&learner));
        let reply = servicer.handle(Message::EvaluateModel { task_id: 3, round: 1, model: model() });
        match reply {
            Message::EvaluateModelReply { task_id, learner_id, result } => {
                assert_eq!(task_id, 3);
                assert_eq!(learner_id, "l0");
                assert!(result.loss.is_finite());
                assert_eq!(result.num_samples, 20);
            }
            other => panic!("unexpected {}", other.kind()),
        }
    }

    #[test]
    fn shutdown_stops_accepting() {
        let (learner, _capture, _h) = setup("shutdown");
        let servicer = LearnerServicer(Arc::clone(&learner));
        assert_eq!(servicer.handle(Message::Shutdown), Message::Ack { task_id: 0, ok: true });
        assert!(matches!(
            servicer.handle(Message::EvaluateModel { task_id: 1, round: 1, model: model() }),
            Message::Error { .. }
        ));
    }

    #[test]
    fn heartbeat_ack_reports_real_learner_state() {
        let (learner, _capture, _h) = setup("degraded-ack");
        let servicer = LearnerServicer(Arc::clone(&learner));
        match servicer.handle(Message::Heartbeat { from: "driver".into() }) {
            Message::HeartbeatAck { component, healthy, health } => {
                assert_eq!(component, "learner/l0");
                assert!(healthy, "fresh learner must ack healthy");
                assert_eq!(health, crate::proto::HealthProbe::default());
            }
            other => panic!("unexpected {}", other.kind()),
        }
        // A learner that has abandoned an upload acks degraded — alive,
        // answering, but no longer claiming `healthy: true`.
        learner.retry_give_ups.incr();
        match servicer.handle(Message::Heartbeat { from: "driver".into() }) {
            Message::HeartbeatAck { healthy, health, .. } => {
                assert!(!healthy, "give-ups must degrade the ack");
                assert_eq!(health.retry_give_ups, 1);
            }
            other => panic!("unexpected {}", other.kind()),
        }
    }

    #[test]
    fn rehome_swaps_the_upstream_and_drops_the_delta_base() {
        let (learner, _capture, _h) = setup("rehome-a");
        // Pretend a lossless dispatch established a delta base.
        let layout = ModelSpec::mlp(4, 2, 8).tensor_layout();
        let base = Arc::new(TensorModel::random_init(&layout, &mut Rng::new(11)));
        learner.record_community(3, CodecId::Delta, &base);
        assert_eq!(learner.last_community_round(), Some(3));
        // Stand up a second capture controller and re-home onto it: the
        // base is forgotten (first upload to the new peer must be full
        // f32) and registration lands on the new endpoint.
        let capture_b = Arc::new(Capture { completions: StdMutex::new(Vec::new()) });
        let ep_b = "inproc://ctrl-rehome-b";
        let _hb = crate::net::serve(ep_b, capture_b, None).unwrap();
        learner.rehome(ep_b);
        assert_eq!(learner.last_community_round(), None);
        assert_eq!(learner.controller_endpoint.lock().unwrap().as_str(), ep_b);
        learner.register("inproc://l0").unwrap();
    }

    #[test]
    fn register_roundtrip() {
        let (learner, _capture, _h) = setup("register");
        let idx = learner.register("inproc://l0").unwrap();
        assert_eq!(idx, 0);
    }

    #[test]
    fn streamed_callback_reaches_a_real_controller() {
        // With a data-plane chunk size configured, the completion
        // callback travels as Begin/Chunk/End and the controller ingests
        // it — end to end through a real (async-protocol) controller, so
        // the community model advances on arrival.
        use crate::config::{FederationEnv, ModelSpec, Protocol};
        use crate::controller::Controller;
        use crate::tensor::TensorModel;
        use crate::util::Rng;

        let env = FederationEnv::builder("learner-stream-test")
            .learners(1)
            .model(ModelSpec::mlp(4, 2, 8))
            .protocol(Protocol::Asynchronous { staleness_alpha: 1.0 })
            .build();
        let ctrl = Controller::new(env, None).unwrap();
        let layout = ModelSpec::mlp(4, 2, 8).tensor_layout();
        ctrl.ship_model(TensorModel::random_init(&layout, &mut Rng::new(1)));
        let ep = "inproc://learner-stream-ctrl";
        let _h = crate::net::serve(ep, Arc::clone(&ctrl) as Arc<dyn Service>, None).unwrap();

        let dataset = Dataset::synthetic_housing(4, 50, 20, 7);
        let learner =
            Learner::new("l0", ep, None, Arc::new(SyntheticTrainer::new(0, 0.01)), dataset);
        learner.set_stream_chunk(crate::proto::client::MIN_CHUNK_BYTES);
        let servicer = LearnerServicer(Arc::clone(&learner));
        let reply = servicer.handle(Message::RunTask {
            task_id: 1,
            round: 0,
            model: model(),
            spec: TaskSpec { epochs: 1, batch_size: 10, learning_rate: 0.1, step_budget: 0 },
        });
        assert_eq!(reply, Message::Ack { task_id: 1, ok: true });
        let sw = Stopwatch::start();
        while learner.tasks_completed() == 0 {
            assert!(sw.elapsed() < std::time::Duration::from_secs(5), "no streamed completion");
            Clock::system().sleep(std::time::Duration::from_millis(2));
        }
        assert_eq!(ctrl.async_updates(), 1, "stream did not reach the controller");
        assert_eq!(ctrl.open_streams(), 0);
    }
}
