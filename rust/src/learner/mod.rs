//! The federation learner (paper App. B, Figs. 9–10).
//!
//! A learner runs a servicer that accepts controller RPCs:
//!
//! * `RunTask` — submits local training to the background task-pool
//!   executor and replies `Ack` immediately (the controller's
//!   fire-and-forget dispatch). On completion the executor calls
//!   `MarkTaskCompleted` back on the controller.
//! * `EvaluateModel` — evaluates synchronously and replies in-call.
//!
//! Local compute is pluggable via [`Trainer`]: the stress tests use
//! [`SyntheticTrainer`]; real training uses `runtime::XlaTrainer` (the
//! AOT-compiled JAX train/eval steps).

pub mod data;
pub mod trainer;

pub use data::Dataset;
pub use trainer::{SyntheticTrainer, Trainer};

use crate::net::{ClientConn, Psk, Service};
use crate::proto::client::{self, RpcError};
use crate::proto::{ErrorCode, Message, ModelProto, StreamPurpose, TaskSpec, PROTO_VERSION};
use crate::tensor::{ByteOrder, DType};
use crate::util::{log_debug, log_warn, ThreadPool};
use anyhow::Result;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// A learner node.
pub struct Learner {
    pub id: String,
    controller_endpoint: String,
    psk: Psk,
    trainer: Arc<dyn Trainer>,
    dataset: Arc<Dataset>,
    /// Background training-task pool ("training task pool executor",
    /// Fig. 9). One worker: local tasks execute in submission order.
    executor: ThreadPool,
    /// Dedicated connection for completion callbacks.
    callback_conn: Mutex<Option<Box<dyn ClientConn>>>,
    /// Data-plane chunk size for completed-model uploads; 0 = one-shot
    /// `MarkTaskCompleted` (see `FederationEnv::stream_chunk_bytes`).
    stream_chunk: AtomicUsize,
    shutdown: AtomicBool,
    tasks_completed: AtomicU64,
}

impl Learner {
    pub fn new(
        id: &str,
        controller_endpoint: &str,
        psk: Psk,
        trainer: Arc<dyn Trainer>,
        dataset: Dataset,
    ) -> Arc<Learner> {
        Arc::new(Learner {
            id: id.to_string(),
            controller_endpoint: controller_endpoint.to_string(),
            psk,
            trainer,
            dataset: Arc::new(dataset),
            executor: ThreadPool::new(1),
            callback_conn: Mutex::new(None),
            stream_chunk: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            tasks_completed: AtomicU64::new(0),
        })
    }

    /// Upload completed models over the streaming data plane in chunks
    /// of `bytes` (0 = one-shot).
    pub fn set_stream_chunk(&self, bytes: usize) {
        self.stream_chunk.store(bytes, Ordering::SeqCst);
    }

    pub fn stream_chunk(&self) -> usize {
        self.stream_chunk.load(Ordering::SeqCst)
    }

    /// Register with the controller (Fig. 8 initialization).
    pub fn register(&self, own_endpoint: &str) -> Result<usize> {
        self.with_callback_conn(|conn| {
            client::register(conn, &self.id, own_endpoint, self.dataset.train_len())
        })
        .map_err(|e| anyhow::anyhow!("registering with controller: {e}"))
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    pub fn tasks_completed(&self) -> u64 {
        self.tasks_completed.load(Ordering::SeqCst)
    }

    /// Run `f` against the (lazily dialed) callback connection. A fresh
    /// connection opens with the versioned `Hello` handshake; transport
    /// failures drop the connection so the next call re-dials, while
    /// remote (application) errors keep it.
    fn with_callback_conn<T>(
        &self,
        f: impl FnOnce(&mut dyn ClientConn) -> Result<T, RpcError>,
    ) -> Result<T, RpcError> {
        let mut guard = self.callback_conn.lock().unwrap();
        if guard.is_none() {
            let mut conn = crate::net::connect(&self.controller_endpoint, self.psk)
                .map_err(RpcError::Transport)?;
            client::hello(conn.as_mut())?;
            *guard = Some(conn);
        }
        match f(guard.as_mut().unwrap().as_mut()) {
            Ok(v) => Ok(v),
            Err(e) => {
                if e.is_transport() {
                    *guard = None; // force reconnect next time
                }
                Err(e)
            }
        }
    }

    /// Execute one training task and call back `MarkTaskCompleted` —
    /// one-shot for small models, chunk-streamed when a data-plane chunk
    /// size is configured.
    fn run_train_task(self: &Arc<Self>, task_id: u64, round: u64, model: ModelProto, spec: TaskSpec) {
        let learner = Arc::clone(self);
        self.executor.spawn(move || {
            if learner.is_shutdown() {
                return;
            }
            let result = (|| -> Result<()> {
                let m = model.to_model()?;
                let (trained, meta) = learner.trainer.train(&m, &learner.dataset, &spec)?;
                let chunk = learner.stream_chunk();
                let upload = if chunk > 0 {
                    learner.with_callback_conn(|conn| {
                        client::stream_model(
                            conn,
                            StreamPurpose::TaskCompletion,
                            task_id,
                            round,
                            &learner.id,
                            &trained,
                            &meta,
                            chunk,
                        )
                    })
                } else {
                    let proto = ModelProto::from_model(&trained, DType::F32, ByteOrder::Little);
                    learner.with_callback_conn(|conn| {
                        client::mark_task_completed(conn, task_id, &learner.id, proto, meta)
                    })
                };
                upload.map_err(|e| anyhow::anyhow!("completion callback: {e}"))
            })();
            match result {
                Ok(()) => {
                    learner.tasks_completed.fetch_add(1, Ordering::SeqCst);
                    log_debug("learner", &format!("{} completed task {task_id}", learner.id));
                }
                Err(e) => {
                    log_warn("learner", &format!("{} task {task_id} failed: {e:#}", learner.id))
                }
            }
        });
    }
}

/// The learner servicer: the [`Service`] facade exposed to the network.
pub struct LearnerServicer(pub Arc<Learner>);

impl Service for LearnerServicer {
    fn handle(&self, msg: Message) -> Message {
        let learner = &self.0;
        if learner.is_shutdown() {
            return Message::error(ErrorCode::Unavailable, "learner is shut down");
        }
        match msg {
            Message::Hello { proto_version } => {
                if proto_version == PROTO_VERSION {
                    Message::HelloAck {
                        proto_version: PROTO_VERSION,
                        component: format!("learner/{}", learner.id),
                    }
                } else {
                    Message::error(
                        ErrorCode::VersionMismatch,
                        format!("learner speaks v{PROTO_VERSION}, peer v{proto_version}"),
                    )
                }
            }
            Message::RunTask { task_id, round, model, spec } => {
                // Submit to the executor; Ack as soon as it is queued
                // (Fig. 9: "the executor replies with an Ack message").
                learner.run_train_task(task_id, round, model, spec);
                Message::Ack { task_id, ok: true }
            }
            Message::EvaluateModel { task_id, round: _, model } => {
                match model
                    .to_model()
                    .and_then(|m| learner.trainer.evaluate(&m, &learner.dataset))
                {
                    Ok(result) => Message::EvaluateModelReply {
                        task_id,
                        learner_id: learner.id.clone(),
                        result,
                    },
                    Err(e) => Message::error(ErrorCode::Internal, format!("eval failed: {e:#}")),
                }
            }
            Message::Heartbeat { .. } => Message::HeartbeatAck {
                component: format!("learner/{}", learner.id),
                healthy: true,
            },
            Message::Shutdown => {
                learner.shutdown.store(true, Ordering::SeqCst);
                Message::Ack { task_id: 0, ok: true }
            }
            // Learners have no inbound data plane: models arrive inline
            // with RunTask/EvaluateModel (dispatch fan-out reuses one
            // encoded buffer across all learners — streaming would undo
            // that sharing).
            other => {
                Message::error(ErrorCode::Unsupported, format!("unexpected {}", other.kind()))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;
    use crate::proto::TaskMeta;
    use crate::tensor::TensorModel;
    use crate::util::Rng;
    use std::sync::Mutex as StdMutex;

    /// Controller stub capturing completions.
    struct Capture {
        completions: StdMutex<Vec<(u64, String, TaskMeta)>>,
    }
    impl Service for Capture {
        fn handle(&self, msg: Message) -> Message {
            match msg {
                Message::Hello { .. } => Message::HelloAck {
                    proto_version: PROTO_VERSION,
                    component: "capture".into(),
                },
                Message::MarkTaskCompleted { task_id, learner_id, meta, .. } => {
                    self.completions.lock().unwrap().push((task_id, learner_id, meta));
                    Message::Ack { task_id, ok: true }
                }
                Message::Register { .. } => {
                    Message::RegisterAck { accepted: true, assigned_index: 0 }
                }
                other => {
                    Message::error(ErrorCode::Unsupported, format!("unexpected {}", other.kind()))
                }
            }
        }
    }

    fn setup(tag: &str) -> (Arc<Learner>, Arc<Capture>, Box<dyn crate::net::ServerHandle>) {
        let capture = Arc::new(Capture { completions: StdMutex::new(Vec::new()) });
        let ep = format!("inproc://ctrl-{tag}");
        let handle = crate::net::serve(&ep, capture.clone(), None).unwrap();
        let spec = ModelSpec::mlp(4, 2, 8);
        let dataset = Dataset::synthetic_housing(4, 50, 20, 7);
        let learner = Learner::new(
            "l0",
            &ep,
            None,
            Arc::new(SyntheticTrainer::new(0, 0.01)),
            dataset,
        );
        let _ = spec;
        (learner, capture, handle)
    }

    fn model() -> ModelProto {
        let layout = ModelSpec::mlp(4, 2, 8).tensor_layout();
        let m = TensorModel::random_init(&layout, &mut Rng::new(5));
        ModelProto::from_model(&m, DType::F32, ByteOrder::Little)
    }

    #[test]
    fn run_task_acks_then_calls_back() {
        let (learner, capture, _h) = setup("runtask");
        let servicer = LearnerServicer(Arc::clone(&learner));
        let reply = servicer.handle(Message::RunTask {
            task_id: 9,
            round: 1,
            model: model(),
            spec: TaskSpec { epochs: 1, batch_size: 10, learning_rate: 0.1, step_budget: 0 },
        });
        assert_eq!(reply, Message::Ack { task_id: 9, ok: true });
        // Wait for the background completion callback.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while learner.tasks_completed() == 0 {
            assert!(std::time::Instant::now() < deadline, "no completion");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let completions = capture.completions.lock().unwrap();
        assert_eq!(completions.len(), 1);
        assert_eq!(completions[0].0, 9);
        assert_eq!(completions[0].1, "l0");
        assert_eq!(completions[0].2.num_samples, 50);
        assert!(completions[0].2.completed_steps > 0);
    }

    #[test]
    fn evaluate_replies_synchronously() {
        let (learner, _capture, _h) = setup("eval");
        let servicer = LearnerServicer(Arc::clone(&learner));
        let reply = servicer.handle(Message::EvaluateModel { task_id: 3, round: 1, model: model() });
        match reply {
            Message::EvaluateModelReply { task_id, learner_id, result } => {
                assert_eq!(task_id, 3);
                assert_eq!(learner_id, "l0");
                assert!(result.loss.is_finite());
                assert_eq!(result.num_samples, 20);
            }
            other => panic!("unexpected {}", other.kind()),
        }
    }

    #[test]
    fn shutdown_stops_accepting() {
        let (learner, _capture, _h) = setup("shutdown");
        let servicer = LearnerServicer(Arc::clone(&learner));
        assert_eq!(servicer.handle(Message::Shutdown), Message::Ack { task_id: 0, ok: true });
        assert!(matches!(
            servicer.handle(Message::EvaluateModel { task_id: 1, round: 1, model: model() }),
            Message::Error { .. }
        ));
    }

    #[test]
    fn register_roundtrip() {
        let (learner, _capture, _h) = setup("register");
        let idx = learner.register("inproc://l0").unwrap();
        assert_eq!(idx, 0);
    }

    #[test]
    fn streamed_callback_reaches_a_real_controller() {
        // With a data-plane chunk size configured, the completion
        // callback travels as Begin/Chunk/End and the controller ingests
        // it — end to end through a real (async-protocol) controller, so
        // the community model advances on arrival.
        use crate::config::{FederationEnv, ModelSpec, Protocol};
        use crate::controller::Controller;
        use crate::tensor::TensorModel;
        use crate::util::Rng;

        let env = FederationEnv::builder("learner-stream-test")
            .learners(1)
            .model(ModelSpec::mlp(4, 2, 8))
            .protocol(Protocol::Asynchronous { staleness_alpha: 1.0 })
            .build();
        let ctrl = Controller::new(env, None).unwrap();
        let layout = ModelSpec::mlp(4, 2, 8).tensor_layout();
        ctrl.ship_model(TensorModel::random_init(&layout, &mut Rng::new(1)));
        let ep = "inproc://learner-stream-ctrl";
        let _h = crate::net::serve(ep, Arc::clone(&ctrl) as Arc<dyn Service>, None).unwrap();

        let dataset = Dataset::synthetic_housing(4, 50, 20, 7);
        let learner =
            Learner::new("l0", ep, None, Arc::new(SyntheticTrainer::new(0, 0.01)), dataset);
        learner.set_stream_chunk(crate::proto::client::MIN_CHUNK_BYTES);
        let servicer = LearnerServicer(Arc::clone(&learner));
        let reply = servicer.handle(Message::RunTask {
            task_id: 1,
            round: 0,
            model: model(),
            spec: TaskSpec { epochs: 1, batch_size: 10, learning_rate: 0.1, step_budget: 0 },
        });
        assert_eq!(reply, Message::Ack { task_id: 1, ok: true });
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while learner.tasks_completed() == 0 {
            assert!(std::time::Instant::now() < deadline, "no streamed completion");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert_eq!(ctrl.async_updates(), 1, "stream did not reach the controller");
        assert_eq!(ctrl.open_streams(), 0);
    }
}
