//! Tiny declarative CLI argument parser (clap replacement).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! subcommands (handled by the caller via [`Args::positional`]), defaults,
//! and auto-generated `--help` text.

use std::collections::BTreeMap;

/// A declared option.
#[derive(Debug, Clone)]
struct OptSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative command spec; call [`Command::parse`] on raw args.
#[derive(Debug, Clone, Default)]
pub struct Command {
    name: String,
    about: String,
    opts: Vec<OptSpec>,
}

/// Parsed arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    positional: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    Unknown(String),
    MissingValue(String),
    Invalid { key: String, msg: String },
    Help,
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Unknown(name) => write!(f, "unknown option --{name}"),
            CliError::MissingValue(name) => write!(f, "option --{name} requires a value"),
            CliError::Invalid { key, msg } => write!(f, "invalid value for --{key}: {msg}"),
            CliError::Help => write!(f, "help requested"),
        }
    }
}

impl std::error::Error for CliError {}

impl Command {
    pub fn new(name: &str, about: &str) -> Self {
        Command { name: name.into(), about: about.into(), opts: Vec::new() }
    }

    /// Declare `--name <value>` with an optional default.
    pub fn opt(mut self, name: &str, default: Option<&str>, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.into(),
            help: help.into(),
            default: default.map(|s| s.into()),
            is_flag: false,
        });
        self
    }

    /// Declare a boolean `--name` flag.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.into(),
            help: help.into(),
            default: None,
            is_flag: true,
        });
        self
    }

    /// Render the help screen.
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nOPTIONS:\n", self.name, self.about);
        for o in &self.opts {
            let head = if o.is_flag {
                format!("  --{}", o.name)
            } else {
                format!("  --{} <value>", o.name)
            };
            let default = o
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("{head:<28}{}{default}\n", o.help));
        }
        s.push_str("  --help                    show this message\n");
        s
    }

    /// Parse raw arguments (excluding argv[0] / the subcommand token).
    pub fn parse(&self, raw: &[String]) -> Result<Args, CliError> {
        let mut args = Args::default();
        for o in &self.opts {
            if let Some(d) = &o.default {
                args.values.insert(o.name.clone(), d.clone());
            }
            if o.is_flag {
                args.flags.insert(o.name.clone(), false);
            }
        }
        let mut i = 0;
        while i < raw.len() {
            let tok = &raw[i];
            if tok == "--help" || tok == "-h" {
                return Err(CliError::Help);
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| CliError::Unknown(key.clone()))?;
                if spec.is_flag {
                    let v = match inline_val.as_deref() {
                        Some("false" | "0" | "no") => false,
                        _ => true,
                    };
                    args.flags.insert(key, v);
                } else {
                    let v = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            raw.get(i).cloned().ok_or(CliError::MissingValue(key.clone()))?
                        }
                    };
                    args.values.insert(key, v);
                }
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(args)
    }
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str) -> Result<usize, CliError> {
        self.parse_with(key, |s| s.parse::<usize>().map_err(|e| e.to_string()))
    }

    pub fn get_u64(&self, key: &str) -> Result<u64, CliError> {
        self.parse_with(key, |s| s.parse::<u64>().map_err(|e| e.to_string()))
    }

    pub fn get_f64(&self, key: &str) -> Result<f64, CliError> {
        self.parse_with(key, |s| s.parse::<f64>().map_err(|e| e.to_string()))
    }

    /// Comma-separated usize list, e.g. `--learners 10,25,50`.
    pub fn get_usize_list(&self, key: &str) -> Result<Vec<usize>, CliError> {
        self.parse_with(key, |s| {
            s.split(',')
                .map(|t| t.trim().parse::<usize>().map_err(|e| e.to_string()))
                .collect::<Result<Vec<_>, _>>()
        })
    }

    fn parse_with<T>(
        &self,
        key: &str,
        f: impl Fn(&str) -> Result<T, String>,
    ) -> Result<T, CliError> {
        let s = self
            .get(key)
            .ok_or_else(|| CliError::MissingValue(key.to_string()))?;
        f(s).map_err(|msg| CliError::Invalid { key: key.to_string(), msg })
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.get(key).copied().unwrap_or(false)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("test", "test command")
            .opt("rounds", Some("5"), "number of rounds")
            .opt("name", None, "a name")
            .flag("verbose", "chatty output")
    }

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = cmd().parse(&s(&[])).unwrap();
        assert_eq!(a.get_usize("rounds").unwrap(), 5);
        assert!(!a.flag("verbose"));
        let a = cmd().parse(&s(&["--rounds", "9", "--verbose"])).unwrap();
        assert_eq!(a.get_usize("rounds").unwrap(), 9);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn equals_syntax_and_positional() {
        let a = cmd().parse(&s(&["--name=abc", "pos1", "pos2"])).unwrap();
        assert_eq!(a.get("name"), Some("abc"));
        assert_eq!(a.positional(), &["pos1".to_string(), "pos2".to_string()]);
    }

    #[test]
    fn flag_false_syntax() {
        let a = cmd().parse(&s(&["--verbose=false"])).unwrap();
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn errors() {
        assert!(matches!(cmd().parse(&s(&["--bogus"])), Err(CliError::Unknown(_))));
        assert!(matches!(cmd().parse(&s(&["--name"])), Err(CliError::MissingValue(_))));
        assert!(matches!(cmd().parse(&s(&["--help"])), Err(CliError::Help)));
        let a = cmd().parse(&s(&["--rounds", "abc"])).unwrap();
        assert!(matches!(a.get_usize("rounds"), Err(CliError::Invalid { .. })));
    }

    #[test]
    fn usize_list_parsing() {
        let c = Command::new("t", "t").opt("learners", Some("10,25,50"), "counts");
        let a = c.parse(&s(&[])).unwrap();
        assert_eq!(a.get_usize_list("learners").unwrap(), vec![10, 25, 50]);
        let a = c.parse(&s(&["--learners", "1, 2 ,3"])).unwrap();
        assert_eq!(a.get_usize_list("learners").unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn help_mentions_options() {
        let h = cmd().help();
        assert!(h.contains("--rounds"));
        assert!(h.contains("--verbose"));
        assert!(h.contains("[default: 5]"));
    }
}
