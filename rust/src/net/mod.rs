//! Transport layer (gRPC replacement).
//!
//! All protocol interactions are request/response RPCs over one of two
//! transports:
//!
//! * [`tcp`] — length-prefixed frames over `std::net::TcpStream`, one
//!   handler thread per connection (the paper's "distributed" deployment),
//!   optionally wrapped in the [`secure`] authenticated channel (the TLS
//!   substitute of App. B Fig. 11).
//! * [`inproc`] — an in-process service registry (the paper's
//!   "standalone/simulated" deployment). By default messages are still
//!   encoded + decoded so simulation timings include real (de)serialization
//!   cost, matching a localhost-gRPC setup minus the kernel.
//!
//! A [`Service`] handles one request and returns one reply; [`ClientConn`]
//! issues RPCs. Endpoints are parsed from strings:
//! `tcp://127.0.0.1:4250`, `inproc://controller`.
//!
//! # Protocol
//!
//! The RPC surface is split into two planes, both riding the same
//! framed request/response transport:
//!
//! ## Control plane
//!
//! Small typed messages — registration, task dispatch/acks, heartbeats,
//! shutdown — issued through the stubs in [`crate::proto::client`]
//! rather than hand-rolled `match` blocks. Sessions open with a
//! versioned `Hello`/`HelloAck` handshake
//! ([`crate::proto::PROTO_VERSION`]) that also negotiates the wire
//! codec set (`Hello` offers, `HelloAck` returns the accepted
//! intersection); failures carry a structured
//! [`crate::proto::ErrorCode`]. On tcp, every frame additionally starts
//! with the [`frame::FRAME_MAGIC`] + [`frame::FRAME_VERSION`] header, so
//! a non-MetisFL peer fails on its first bytes instead of driving an
//! unbounded allocation.
//!
//! ## Data plane (symmetric, codec-aware)
//!
//! Bulk model payloads move as a chunked stream in **both** directions
//! — learner → controller uploads AND controller → learner dispatch
//! (`RunTask` / `Evaluate` purposes, enabled together by
//! `stream_chunk_bytes`):
//!
//! ```text
//! ModelStreamBegin { stream_id, task_id, round, purpose, codec,
//!                    base_round, layout, meta, spec }
//! ModelChunk       { stream_id, seq: 0.., bytes }   (element-ordered)
//! ModelStreamEnd   { stream_id, digest: fnv1a64(payload) }
//! ```
//!
//! Each step is acked, so strict send/recv pairing is preserved on every
//! transport (including the secure channel's per-record sequence MACs);
//! the `End` ack doubles as the purpose's reply (`EvaluateModelReply`
//! for eval streams). The sender encodes one tensor at a time through
//! the stream's negotiated [`crate::tensor::WireCodec`] (`f32`, lossy
//! `bf16`, or lossless XOR-`delta` against the last acknowledged
//! community model — `base_round` names the base; a receiver without it
//! refuses with `NotFound` and the sender falls back to full f32). The
//! receiver decodes each chunk on arrival straight into arena-backed
//! tensor buffers sized from `layout` (the shared engine in
//! [`crate::proto::ingest`]) — neither side ever materializes a
//! whole-model wire buffer, receive overlaps decode, and peak extra
//! memory is O(chunk × in-flight streams) instead of O(peers × model).
//! On dispatch the controller encodes every chunk ONCE and fans the
//! same frame bytes out to all selected learners (one shared stream
//! id), so fan-out encode work is O(model), not O(learners × model).
//! The streamed and one-shot paths are property-tested
//! bitwise-identical for the lossless codecs; bf16 is bounded-error.

pub mod chaos;
pub mod frame;
pub mod inproc;
pub mod retry;
pub mod secure;
pub mod tcp;

use crate::proto::Message;
use anyhow::{bail, Result};
use std::sync::Arc;

/// A message handler: one request in, one reply out.
pub trait Service: Send + Sync {
    fn handle(&self, msg: Message) -> Message;
}

impl<F: Fn(Message) -> Message + Send + Sync> Service for F {
    fn handle(&self, msg: Message) -> Message {
        self(msg)
    }
}

/// A client connection capable of blocking RPCs.
///
/// `send`/`recv` are split so callers can time the dispatch (serialize +
/// submit) phase separately from the reply wait — the distinction the
/// paper's "task dispatch time" vs "round time" metrics rely on. Calls
/// must be strictly paired: send, then recv.
pub trait ClientConn: Send {
    /// Serialize and submit one request.
    fn send(&mut self, msg: &Message) -> Result<()>;
    /// Submit pre-encoded request bytes (broadcast fast path: the
    /// controller encodes a round's model once and fans the same bytes
    /// out to every learner — §Perf).
    fn send_raw(&mut self, bytes: &[u8]) -> Result<()>;
    /// Block for the matching reply.
    fn recv(&mut self) -> Result<Message>;

    /// Blocking request/response.
    fn rpc(&mut self, msg: &Message) -> Result<Message> {
        self.send(msg)?;
        self.recv()
    }
}

/// A running server; dropping it (or calling `shutdown`) stops the
/// accept/dispatch loop.
pub trait ServerHandle: Send {
    fn shutdown(&mut self);
    /// The concrete endpoint (with resolved port for tcp://host:0).
    fn endpoint(&self) -> String;
}

/// Pre-shared key for the secure channel (None = plaintext).
pub type Psk = Option<[u8; 32]>;

/// Parse + connect to an endpoint string.
pub fn connect(endpoint: &str, psk: Psk) -> Result<Box<dyn ClientConn>> {
    if let Some(addr) = endpoint.strip_prefix("tcp://") {
        Ok(Box::new(tcp::TcpClient::connect(addr, psk)?))
    } else if let Some(name) = endpoint.strip_prefix("inproc://") {
        Ok(Box::new(inproc::InprocClient::connect(name)?))
    } else {
        bail!("unknown endpoint scheme: {endpoint}");
    }
}

/// Parse + serve on an endpoint string.
pub fn serve(endpoint: &str, svc: Arc<dyn Service>, psk: Psk) -> Result<Box<dyn ServerHandle>> {
    if let Some(addr) = endpoint.strip_prefix("tcp://") {
        Ok(Box::new(tcp::TcpServer::bind(addr, svc, psk)?))
    } else if let Some(name) = endpoint.strip_prefix("inproc://") {
        Ok(Box::new(inproc::InprocServer::register(name, svc)?))
    } else {
        bail!("unknown endpoint scheme: {endpoint}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Echo;
    impl Service for Echo {
        fn handle(&self, msg: Message) -> Message {
            match msg {
                Message::Heartbeat { from } => {
                    Message::HeartbeatAck { component: from, healthy: true }
                }
                other => Message::error(
                    crate::proto::ErrorCode::Unsupported,
                    format!("unexpected {}", other.kind()),
                ),
            }
        }
    }

    #[test]
    fn endpoint_scheme_dispatch() {
        assert!(connect("bogus://x", None).is_err());
        assert!(serve("bogus://x", Arc::new(Echo), None).is_err());
    }

    #[test]
    fn tcp_roundtrip_plaintext() {
        let server = serve("tcp://127.0.0.1:0", Arc::new(Echo), None).unwrap();
        let mut c = connect(&server.endpoint(), None).unwrap();
        let reply = c.rpc(&Message::Heartbeat { from: "t".into() }).unwrap();
        assert_eq!(reply, Message::HeartbeatAck { component: "t".into(), healthy: true });
    }

    #[test]
    fn tcp_roundtrip_secure() {
        let psk = Some([7u8; 32]);
        let server = serve("tcp://127.0.0.1:0", Arc::new(Echo), psk).unwrap();
        let mut c = connect(&server.endpoint(), psk).unwrap();
        let reply = c.rpc(&Message::Heartbeat { from: "s".into() }).unwrap();
        assert_eq!(reply, Message::HeartbeatAck { component: "s".into(), healthy: true });
    }

    #[test]
    fn secure_psk_mismatch_fails() {
        let server = serve("tcp://127.0.0.1:0", Arc::new(Echo), Some([1u8; 32])).unwrap();
        let r = connect(&server.endpoint(), Some([2u8; 32]))
            .and_then(|mut c| c.rpc(&Message::Heartbeat { from: "x".into() }));
        assert!(r.is_err());
    }

    #[test]
    fn inproc_roundtrip() {
        let server = serve("inproc://echo-test", Arc::new(Echo), None).unwrap();
        let mut c = connect("inproc://echo-test", None).unwrap();
        let reply = c.rpc(&Message::Heartbeat { from: "i".into() }).unwrap();
        assert_eq!(reply, Message::HeartbeatAck { component: "i".into(), healthy: true });
        drop(server);
        assert!(connect("inproc://echo-test", None).is_err());
    }
}
