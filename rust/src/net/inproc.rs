//! In-process transport for simulated (standalone) federations.
//!
//! Services register in a global name registry; clients dispatch by name.
//! By default each RPC still encodes + decodes both the request and the
//! reply, so simulated runs pay the same serialization cost a localhost
//! socket would (the paper's single-host stress tests). Set
//! `METISFL_INPROC_ZEROCOPY=1` to skip the codec (useful for isolating
//! serialization in the ablation benches).

use super::{ClientConn, ServerHandle, Service};
use crate::proto::Message;
use anyhow::{bail, Result};
use once_cell::sync::Lazy;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

static REGISTRY: Lazy<Mutex<HashMap<String, Arc<dyn Service>>>> =
    Lazy::new(|| Mutex::new(HashMap::new()));

fn zerocopy() -> bool {
    static FLAG: Lazy<bool> =
        Lazy::new(|| std::env::var("METISFL_INPROC_ZEROCOPY").map(|v| v == "1").unwrap_or(false));
    *FLAG
}

/// Registered in-proc service; unregisters on drop/shutdown.
pub struct InprocServer {
    name: String,
    registered: bool,
}

impl InprocServer {
    pub fn register(name: &str, svc: Arc<dyn Service>) -> Result<InprocServer> {
        let mut reg = REGISTRY.lock().unwrap();
        if reg.contains_key(name) {
            bail!("inproc service '{name}' already registered");
        }
        reg.insert(name.to_string(), svc);
        Ok(InprocServer { name: name.to_string(), registered: true })
    }
}

impl ServerHandle for InprocServer {
    fn shutdown(&mut self) {
        if self.registered {
            REGISTRY.lock().unwrap().remove(&self.name);
            self.registered = false;
        }
    }

    fn endpoint(&self) -> String {
        format!("inproc://{}", self.name)
    }
}

impl Drop for InprocServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Client handle to a named in-proc service.
///
/// `send` performs the request serialization (the dispatch cost a socket
/// write would pay); `recv` runs the handler and deserializes the reply.
pub struct InprocClient {
    svc: Arc<dyn Service>,
    pending: Option<PendingReq>,
}

enum PendingReq {
    Encoded(Vec<u8>),
    Zerocopy(Message),
}

impl InprocClient {
    pub fn connect(name: &str) -> Result<InprocClient> {
        let reg = REGISTRY.lock().unwrap();
        match reg.get(name) {
            Some(svc) => Ok(InprocClient { svc: Arc::clone(svc), pending: None }),
            None => bail!("inproc service '{name}' not found"),
        }
    }
}

impl ClientConn for InprocClient {
    fn send(&mut self, msg: &Message) -> Result<()> {
        if self.pending.is_some() {
            bail!("inproc send() with a reply still pending");
        }
        self.pending = Some(if zerocopy() {
            PendingReq::Zerocopy(msg.clone())
        } else {
            PendingReq::Encoded(msg.encode())
        });
        Ok(())
    }

    fn send_raw(&mut self, bytes: &[u8]) -> Result<()> {
        if self.pending.is_some() {
            bail!("inproc send_raw() with a reply still pending");
        }
        // One memcpy (the socket write a TCP peer would pay).
        self.pending = Some(PendingReq::Encoded(bytes.to_vec()));
        Ok(())
    }

    fn recv(&mut self) -> Result<Message> {
        let pending =
            self.pending.take().ok_or_else(|| anyhow::anyhow!("inproc recv() without send()"))?;
        match pending {
            PendingReq::Zerocopy(msg) => Ok(self.svc.handle(msg)),
            PendingReq::Encoded(bytes) => {
                let req = Message::decode(&bytes)?;
                let reply = self.svc.handle(req);
                Message::decode(&reply.encode())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Echo;
    impl Service for Echo {
        fn handle(&self, msg: Message) -> Message {
            match msg {
                Message::Heartbeat { from } => {
                    Message::HeartbeatAck { component: from, healthy: true }
                }
                _ => Message::error(crate::proto::ErrorCode::Unsupported, "unexpected"),
            }
        }
    }

    #[test]
    fn register_connect_rpc_unregister() {
        let mut s = InprocServer::register("rt-test", Arc::new(Echo)).unwrap();
        assert_eq!(s.endpoint(), "inproc://rt-test");
        let mut c = InprocClient::connect("rt-test").unwrap();
        let r = c.rpc(&Message::Heartbeat { from: "a".into() }).unwrap();
        assert_eq!(r, Message::HeartbeatAck { component: "a".into(), healthy: true });
        s.shutdown();
        assert!(InprocClient::connect("rt-test").is_err());
    }

    #[test]
    fn duplicate_name_rejected() {
        let _s = InprocServer::register("dup-test", Arc::new(Echo)).unwrap();
        assert!(InprocServer::register("dup-test", Arc::new(Echo)).is_err());
    }

    #[test]
    fn existing_client_survives_unregister() {
        let s = InprocServer::register("surv-test", Arc::new(Echo)).unwrap();
        let mut c = InprocClient::connect("surv-test").unwrap();
        drop(s);
        // The Arc keeps the service alive for already-connected clients.
        assert!(c.rpc(&Message::Heartbeat { from: "b".into() }).is_ok());
    }
}
