//! Length-prefixed framing over any `Read`/`Write` pair.
//!
//! Frame = 8-byte header + payload bytes. The header is
//!
//! ```text
//! [0x4D 0x46] [version u8] [reserved u8] [payload length u32 LE]
//!  "M"  "F"
//! ```
//!
//! The magic bytes and version make a garbage or mismatched peer fail
//! with a *diagnosable* error on the first frame — instead of a random
//! prefix being interpreted as a length and triggering a giant
//! allocation or a hang. A maximum frame size additionally bounds what a
//! well-formed header may ask us to allocate; models of the paper's
//! largest stress-test size (10M f32 params ≈ 40 MiB) fit comfortably,
//! and larger models move over the chunked data plane anyway.

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};

/// Frame magic: ASCII "MF". Anything else on the wire is not a MetisFL
/// framed peer (an HTTP client, TLS hello, random noise, …).
pub const FRAME_MAGIC: [u8; 2] = *b"MF";

/// Framing-layer version. Bumped only when the header layout changes —
/// message-schema evolution is negotiated end-to-end via `Hello`.
pub const FRAME_VERSION: u8 = 1;

const HEADER_LEN: usize = 8;

/// 256 MiB upper bound (≈6× the largest stress-test model).
pub const MAX_FRAME: usize = 256 * 1024 * 1024;

/// Write one frame.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME {
        bail!("frame too large: {}", payload.len());
    }
    let mut header = [0u8; HEADER_LEN];
    header[..2].copy_from_slice(&FRAME_MAGIC);
    header[2] = FRAME_VERSION;
    header[4..].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    w.write_all(&header).context("frame header write")?;
    w.write_all(payload).context("frame body write")?;
    w.flush().context("frame flush")?;
    Ok(())
}

/// Read one frame (blocking). Returns `None` on clean EOF at a frame
/// boundary; bad magic / version / length are hard errors.
pub fn read_frame(r: &mut impl Read) -> Result<Option<Vec<u8>>> {
    let mut header = [0u8; HEADER_LEN];
    match r.read_exact(&mut header) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e).context("frame header read"),
    }
    if header[..2] != FRAME_MAGIC {
        bail!(
            "bad frame magic {:02x}{:02x}: peer is not speaking the MetisFL framed protocol",
            header[0],
            header[1]
        );
    }
    if header[2] != FRAME_VERSION {
        bail!(
            "frame protocol version mismatch: ours v{FRAME_VERSION}, peer v{}",
            header[2]
        );
    }
    let len = u32::from_le_bytes(header[4..].try_into().unwrap()) as usize;
    if len > MAX_FRAME {
        bail!("incoming frame too large: {len}");
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf).context("frame body read")?;
    Ok(Some(buf))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn roundtrip_frames() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        write_frame(&mut buf, &[9u8; 1000]).unwrap();
        let mut c = Cursor::new(buf);
        assert_eq!(read_frame(&mut c).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame(&mut c).unwrap().unwrap(), b"");
        assert_eq!(read_frame(&mut c).unwrap().unwrap(), vec![9u8; 1000]);
        assert!(read_frame(&mut c).unwrap().is_none());
    }

    #[test]
    fn truncated_body_is_error_not_eof() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        buf.truncate(buf.len() - 2);
        let mut c = Cursor::new(buf);
        assert!(read_frame(&mut c).is_err());
    }

    #[test]
    fn oversized_frame_rejected_without_allocation() {
        let mut buf = Vec::new();
        buf.extend(FRAME_MAGIC);
        buf.push(FRAME_VERSION);
        buf.push(0);
        buf.extend((u32::MAX).to_le_bytes());
        let mut c = Cursor::new(buf);
        let err = format!("{:#}", read_frame(&mut c).unwrap_err());
        assert!(err.contains("too large"), "{err}");
    }

    #[test]
    fn garbage_peer_fails_on_magic_not_allocation() {
        // An HTTP client says "GET ..."; the old format would read
        // 0x20544547 (~542 MB) as a length. Now it dies on magic.
        let mut c = Cursor::new(b"GET / HTTP/1.1\r\n".to_vec());
        let err = format!("{:#}", read_frame(&mut c).unwrap_err());
        assert!(err.contains("bad frame magic"), "{err}");
    }

    #[test]
    fn frame_version_mismatch_is_a_clear_error() {
        let mut buf = Vec::new();
        buf.extend(FRAME_MAGIC);
        buf.push(FRAME_VERSION + 1);
        buf.push(0);
        buf.extend(5u32.to_le_bytes());
        buf.extend(b"hello");
        let mut c = Cursor::new(buf);
        let err = format!("{:#}", read_frame(&mut c).unwrap_err());
        assert!(err.contains("version mismatch"), "{err}");
    }

    #[test]
    fn clean_eof_is_none() {
        let mut c = Cursor::new(Vec::new());
        assert!(read_frame(&mut c).unwrap().is_none());
    }
}
