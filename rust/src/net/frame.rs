//! Length-prefixed framing over any `Read`/`Write` pair.
//!
//! Frame = `u32` little-endian payload length + payload bytes. A maximum
//! frame size guards against corrupt/hostile peers; models of the paper's
//! largest stress-test size (10M f32 params ≈ 40 MiB) fit comfortably.

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};

/// 256 MiB upper bound (≈6× the largest stress-test model).
pub const MAX_FRAME: usize = 256 * 1024 * 1024;

/// Write one frame.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME {
        bail!("frame too large: {}", payload.len());
    }
    w.write_all(&(payload.len() as u32).to_le_bytes()).context("frame header write")?;
    w.write_all(payload).context("frame body write")?;
    w.flush().context("frame flush")?;
    Ok(())
}

/// Read one frame (blocking). Returns `None` on clean EOF at a frame
/// boundary.
pub fn read_frame(r: &mut impl Read) -> Result<Option<Vec<u8>>> {
    let mut header = [0u8; 4];
    match r.read_exact(&mut header) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e).context("frame header read"),
    }
    let len = u32::from_le_bytes(header) as usize;
    if len > MAX_FRAME {
        bail!("incoming frame too large: {len}");
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf).context("frame body read")?;
    Ok(Some(buf))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn roundtrip_frames() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        write_frame(&mut buf, &[9u8; 1000]).unwrap();
        let mut c = Cursor::new(buf);
        assert_eq!(read_frame(&mut c).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame(&mut c).unwrap().unwrap(), b"");
        assert_eq!(read_frame(&mut c).unwrap().unwrap(), vec![9u8; 1000]);
        assert!(read_frame(&mut c).unwrap().is_none());
    }

    #[test]
    fn truncated_body_is_error_not_eof() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        buf.truncate(buf.len() - 2);
        let mut c = Cursor::new(buf);
        assert!(read_frame(&mut c).is_err());
    }

    #[test]
    fn oversized_frame_rejected_without_allocation() {
        let mut buf = Vec::new();
        buf.extend((u32::MAX).to_le_bytes());
        let mut c = Cursor::new(buf);
        assert!(read_frame(&mut c).is_err());
    }

    #[test]
    fn clean_eof_is_none() {
        let mut c = Cursor::new(Vec::new());
        assert!(read_frame(&mut c).unwrap().is_none());
    }
}
