//! Deterministic fault injection for the data plane.
//!
//! A [`ChaosConn`] wraps any [`ClientConn`] and injects the connection
//! faults a federation actually meets in the wild — refused dials,
//! connections severed mid-stream after N sends, slow-loris trickle
//! (chunks dripped below the idle-GC radar with the closing `End`
//! suppressed, so the stream holds receiver budget), stalls (request
//! accepted, reply never comes), duplicate delivery of control-plane
//! messages, and corrupt-frame floods on the chunked model stream.
//!
//! Faults are *planned*, not sampled at runtime: a [`ChaosSpec`]
//! (loaded from an env file's `chaos:` block) is expanded once by
//! [`ChaosSpec::plan_fleet`] into one [`ChaosPlan`] per learner with a
//! seeded shuffle, so the same `(spec, seed, fleet size)` always
//! afflicts the same learners the same way — every chaos scenario is
//! reproducible from the yaml file that described it. Sever state is
//! shared across re-dials (an [`Arc`]ed counter), so a severed peer
//! stays dead no matter how many times the retry policy re-dials it.

use super::{ClientConn, Psk};
use crate::proto::Message;
use crate::util::{Clock, Rng};
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Fleet-level chaos description, as written in an env file:
///
/// ```yaml
/// chaos:
///   seed: 7
///   sever_fraction: 0.2     # fleet fraction severed mid-stream
///   sever_after_sends: 4
///   slow_loris: 1           # learners that trickle and never finish
///   drip_ms: 20
///   corrupt: 1              # corrupt-frame flooders
/// ```
///
/// Fractions are rounded to learner counts; faults are assigned to
/// *distinct* learners in a seeded shuffled order (sever, refuse,
/// stall, duplicate, slow-loris, corrupt), so overlapping requests
/// spill into "no fault" rather than stacking.
#[derive(Debug, Clone, PartialEq)]
pub struct ChaosSpec {
    /// Mixed with the run seed when assigning plans, so one env file
    /// can describe several distinct (but each reproducible) scenarios.
    pub seed: u64,
    /// Fraction of the fleet whose callback connection is severed after
    /// `sever_after_sends` sends (shared across re-dials: severed peers
    /// stay dead).
    pub sever_fraction: f64,
    pub sever_after_sends: u64,
    /// Fraction of the fleet whose dials are refused outright.
    pub refuse_fraction: f64,
    /// Fraction of the fleet that stalls: requests are accepted but no
    /// reply ever comes (emulated by holding `recv` for `stall_ms`).
    pub stall_fraction: f64,
    pub stall_ms: u64,
    /// Fraction of the fleet that delivers control-plane messages
    /// (completions, heartbeats) twice — the replay path the
    /// completed-task watermarks must absorb.
    pub duplicate_fraction: f64,
    /// Number of slow-loris learners: every model chunk is dripped
    /// after a `drip_ms` sleep and the closing `End` is suppressed, so
    /// the receiver's stream stays open, pinning its admission budget
    /// until the lifetime GC reclaims it.
    pub slow_loris: usize,
    pub drip_ms: u64,
    /// Number of corrupt-frame flooders: every model chunk's payload is
    /// corrupted before sending (digest/frame validation must reject
    /// the stream, never accept the garbage).
    pub corrupt: usize,
    /// Churn instead of permanent loss: severed peers may re-dial after
    /// this many milliseconds (0 = never, the classic permanent sever).
    /// Once a rejoin succeeds the sever budget is disarmed — the peer
    /// is back for good and its retried completions must be absorbed
    /// idempotently by the completed-task watermarks.
    pub reconnect_after_ms: u64,
    /// Two-tier runs only: kill one aggregator (picked deterministically
    /// by [`ChaosSpec::kill_victim`]) right before this round opens
    /// (1-based; 0 = off). The driver detects the death via heartbeat
    /// probes and re-homes the orphaned shard's learners.
    pub kill_aggregator_at_round: u64,
}

impl Default for ChaosSpec {
    fn default() -> ChaosSpec {
        ChaosSpec {
            seed: 0,
            sever_fraction: 0.0,
            sever_after_sends: 4,
            refuse_fraction: 0.0,
            stall_fraction: 0.0,
            stall_ms: 30_000,
            duplicate_fraction: 0.0,
            slow_loris: 0,
            drip_ms: 20,
            corrupt: 0,
            reconnect_after_ms: 0,
            kill_aggregator_at_round: 0,
        }
    }
}

impl ChaosSpec {
    /// True when no fault is configured (the default): every plan this
    /// spec produces is a no-op and connections go unwrapped.
    pub fn is_off(&self) -> bool {
        self.sever_fraction == 0.0
            && self.refuse_fraction == 0.0
            && self.stall_fraction == 0.0
            && self.duplicate_fraction == 0.0
            && self.slow_loris == 0
            && self.corrupt == 0
    }

    /// Check invariants (env loaders call this via
    /// [`crate::config::FederationEnv::validate`]).
    pub fn validate(&self) -> Result<()> {
        for (name, f) in [
            ("sever_fraction", self.sever_fraction),
            ("refuse_fraction", self.refuse_fraction),
            ("stall_fraction", self.stall_fraction),
            ("duplicate_fraction", self.duplicate_fraction),
        ] {
            if !(0.0..=1.0).contains(&f) {
                bail!("chaos {name} must be in [0, 1]");
            }
        }
        if self.sever_fraction > 0.0 && self.sever_after_sends == 0 {
            bail!("chaos sever_after_sends must be >= 1");
        }
        Ok(())
    }

    /// Expand the spec into one plan per learner, deterministically:
    /// the same `(spec, run_seed, learners)` triple always produces the
    /// same assignment. Faults go to distinct learners in a seeded
    /// shuffled order; if the requested counts exceed the fleet, the
    /// excess is dropped (never stacked).
    pub fn plan_fleet(&self, learners: usize, run_seed: u64) -> Vec<ChaosPlan> {
        let mut plans = vec![ChaosPlan::default(); learners];
        if self.is_off() || learners == 0 {
            return plans;
        }
        let mut rng =
            Rng::new(run_seed ^ self.seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0xC4A0_5EED);
        let mut order: Vec<usize> = (0..learners).collect();
        rng.shuffle(&mut order);
        let mut next = order.into_iter();
        let count = |f: f64| ((f * learners as f64).round() as usize).min(learners);
        let reconnect =
            (self.reconnect_after_ms > 0).then(|| Duration::from_millis(self.reconnect_after_ms));
        for _ in 0..count(self.sever_fraction) {
            let Some(i) = next.next() else { return plans };
            plans[i].sever_after_sends = Some(self.sever_after_sends.max(1));
            plans[i].reconnect_after = reconnect;
        }
        for _ in 0..count(self.refuse_fraction) {
            let Some(i) = next.next() else { return plans };
            plans[i].refuse_dial = true;
        }
        for _ in 0..count(self.stall_fraction) {
            let Some(i) = next.next() else { return plans };
            plans[i].hold = Some(Duration::from_millis(self.stall_ms));
        }
        for _ in 0..count(self.duplicate_fraction) {
            let Some(i) = next.next() else { return plans };
            plans[i].duplicate = true;
        }
        for _ in 0..self.slow_loris {
            let Some(i) = next.next() else { return plans };
            plans[i].drip = Some(Duration::from_millis(self.drip_ms));
        }
        for _ in 0..self.corrupt {
            let Some(i) = next.next() else { return plans };
            plans[i].corrupt_frames = true;
        }
        plans
    }

    /// Which aggregator `kill_aggregator_at_round` takes down, picked
    /// deterministically from `(spec seed, run seed, fleet size)` —
    /// the same env file always kills the same shard, so the failover
    /// scenario is reproducible end to end. `None` when the kill is
    /// off or there are no aggregators.
    pub fn kill_victim(&self, aggregators: usize, run_seed: u64) -> Option<usize> {
        if self.kill_aggregator_at_round == 0 || aggregators == 0 {
            return None;
        }
        let mut rng =
            Rng::new(run_seed ^ self.seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0xFA_110_4E4);
        Some(rng.gen_range(aggregators))
    }
}

/// Sever state shared across every connection (and re-dial) of one
/// afflicted learner: once the send budget is spent, the peer is dead
/// for good — the retry policy must give up, not resurrect it — unless
/// the plan grants a reconnect window, in which case the first re-dial
/// after the window rejoins the peer and disarms the sever budget.
#[derive(Debug, Default)]
struct ChaosState {
    sends: AtomicU64,
    severed: AtomicBool,
    /// Clock micros when the sever latched (meaningful while severed).
    severed_at_us: AtomicU64,
    /// Set when a reconnect window elapsed and a re-dial was let back
    /// in: the peer has rejoined and sends are unlimited from here on.
    reconnected: AtomicBool,
}

/// One learner's fault assignment. Cloning shares the sever state, so
/// the plan can be handed to every re-dial of the same peer.
#[derive(Debug, Clone, Default)]
pub struct ChaosPlan {
    /// Every dial attempt is refused.
    pub refuse_dial: bool,
    /// Sever the connection permanently after this many sends (counted
    /// across re-dials).
    pub sever_after_sends: Option<u64>,
    /// Churn: a severed peer's re-dial is allowed back in after this
    /// window (measured on the dialing clock); `None` keeps the sever
    /// permanent.
    pub reconnect_after: Option<Duration>,
    /// Slow-loris: sleep this long before each model chunk and suppress
    /// the closing `End`, holding the receiver's stream open.
    pub drip: Option<Duration>,
    /// Stall: hold every `recv` this long, then fail (the peer accepted
    /// the request and never replied).
    pub hold: Option<Duration>,
    /// Deliver completions/heartbeats twice (watermark replay test).
    pub duplicate: bool,
    /// Corrupt every model chunk's payload before sending.
    pub corrupt_frames: bool,
    state: Arc<ChaosState>,
}

impl ChaosPlan {
    /// A plan with no faults: connections go unwrapped.
    pub fn is_noop(&self) -> bool {
        !self.refuse_dial
            && self.sever_after_sends.is_none()
            && self.drip.is_none()
            && self.hold.is_none()
            && !self.duplicate
            && !self.corrupt_frames
    }

    /// True once the sever budget is spent (the peer is gone for good).
    pub fn severed(&self) -> bool {
        self.state.severed.load(Ordering::SeqCst)
    }

    /// A copy of this plan with its own fresh fault state: same faults,
    /// independent send budget / sever latch. Use when afflicting the
    /// *other* direction of the same link — a clone would share the
    /// budget and let one direction's traffic spend the other's.
    pub fn fresh(&self) -> ChaosPlan {
        ChaosPlan { state: Arc::new(ChaosState::default()), ..self.clone() }
    }
}

/// Dial through a chaos plan: refuse/sever faults apply at connect
/// time; all other faults wrap the live connection. A no-op plan
/// returns the raw connection with zero overhead. Drip/stall delays
/// sleep on `clock`, so simulated runs inject the same faults in
/// virtual time.
pub fn connect_with_chaos(
    endpoint: &str,
    psk: Psk,
    plan: &ChaosPlan,
    clock: &Clock,
) -> Result<Box<dyn ClientConn>> {
    if plan.is_noop() {
        return crate::net::connect(endpoint, psk);
    }
    if plan.refuse_dial {
        bail!("chaos: dial to {endpoint} refused");
    }
    if plan.severed() {
        let rejoins = plan.reconnect_after.is_some_and(|window| {
            let cut = Duration::from_micros(plan.state.severed_at_us.load(Ordering::SeqCst));
            clock.since(cut) >= window
        });
        if rejoins {
            // The churn window elapsed: this re-dial rejoins the peer
            // and disarms the sever budget for good.
            plan.state.reconnected.store(true, Ordering::SeqCst);
            plan.state.severed.store(false, Ordering::SeqCst);
        } else {
            bail!("chaos: peer severed, re-dial refused");
        }
    }
    let inner = crate::net::connect(endpoint, psk)?;
    Ok(Box::new(ChaosConn { inner, plan: plan.clone(), clock: clock.clone() }))
}

/// A [`ClientConn`] that injects the faults its [`ChaosPlan`] calls
/// for, deterministically, while keeping request/reply pairing intact
/// (duplicates drain their own extra reply).
pub struct ChaosConn {
    inner: Box<dyn ClientConn>,
    plan: ChaosPlan,
    clock: Clock,
}

impl ChaosConn {
    /// Count one send against the sever budget; severs permanently when
    /// the budget is spent.
    fn check_sever(&self) -> Result<()> {
        let Some(limit) = self.plan.sever_after_sends else { return Ok(()) };
        if self.plan.state.reconnected.load(Ordering::SeqCst) {
            // Rejoined after the churn window: the budget is disarmed.
            return Ok(());
        }
        if self.plan.severed() {
            bail!("chaos: connection severed");
        }
        let n = self.plan.state.sends.fetch_add(1, Ordering::SeqCst) + 1;
        if n > limit {
            self.plan.state.severed_at_us.store(
                u64::try_from(self.clock.now().as_micros()).unwrap_or(u64::MAX),
                Ordering::SeqCst,
            );
            self.plan.state.severed.store(true, Ordering::SeqCst);
            bail!("chaos: connection severed after {limit} sends");
        }
        Ok(())
    }
}

impl ClientConn for ChaosConn {
    fn send(&mut self, msg: &Message) -> Result<()> {
        self.check_sever()?;
        if let Some(drip) = self.plan.drip {
            if matches!(msg, Message::ModelChunk { .. }) {
                self.clock.sleep(drip);
            }
            if matches!(msg, Message::ModelStreamEnd { .. }) {
                // The loris never closes: the receiver's stream stays
                // open, pinning budget until its lifetime GC fires.
                bail!("chaos: slow-loris suppressed the stream end");
            }
        }
        if self.plan.corrupt_frames {
            if let Message::ModelChunk { stream_id, seq, bytes } = msg {
                let mut bad = bytes.clone();
                for b in bad.iter_mut().take(16) {
                    *b ^= 0xA5;
                }
                return self
                    .inner
                    .send(&Message::ModelChunk { stream_id: *stream_id, seq: *seq, bytes: bad });
            }
        }
        if self.plan.duplicate
            && matches!(msg, Message::MarkTaskCompleted { .. } | Message::Heartbeat { .. })
        {
            // Full extra delivery: the receiver handles the message
            // twice; draining the duplicate's reply here keeps the
            // caller's send/recv pairing strict.
            self.inner.send(msg)?;
            let _ = self.inner.recv()?;
        }
        self.inner.send(msg)
    }

    fn send_raw(&mut self, bytes: &[u8]) -> Result<()> {
        self.check_sever()?;
        self.inner.send_raw(bytes)
    }

    fn recv(&mut self) -> Result<Message> {
        if self.plan.severed() {
            bail!("chaos: connection severed");
        }
        if let Some(hold) = self.plan.hold {
            self.clock.sleep(hold);
            bail!("chaos: stalled peer never replied");
        }
        self.inner.recv()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{serve, Service};
    use crate::proto::ErrorCode;
    use std::sync::Mutex;

    /// Echo-ish service recording what it saw.
    struct Probe {
        heartbeats: AtomicU64,
        chunks: Mutex<Vec<Vec<u8>>>,
    }

    impl Probe {
        fn new() -> Probe {
            Probe { heartbeats: AtomicU64::new(0), chunks: Mutex::new(Vec::new()) }
        }
    }

    impl Service for Probe {
        fn handle(&self, msg: Message) -> Message {
            match msg {
                Message::Heartbeat { from } => {
                    self.heartbeats.fetch_add(1, Ordering::SeqCst);
                    Message::HeartbeatAck {
                        component: from,
                        healthy: true,
                        health: Default::default(),
                    }
                }
                Message::ModelChunk { stream_id, bytes, .. } => {
                    self.chunks.lock().unwrap().push(bytes);
                    Message::Ack { task_id: stream_id, ok: true }
                }
                other => Message::error(ErrorCode::Unsupported, other.kind()),
            }
        }
    }

    fn hb() -> Message {
        Message::Heartbeat { from: "chaos-test".into() }
    }

    #[test]
    fn noop_plan_passes_through_unwrapped() {
        let probe = Arc::new(Probe::new());
        let server = serve("inproc://chaos-noop", Arc::clone(&probe) as _, None).unwrap();
        let plan = ChaosPlan::default();
        assert!(plan.is_noop());
        let mut conn = connect_with_chaos(&server.endpoint(), None, &plan, &Clock::system()).unwrap();
        assert!(matches!(conn.rpc(&hb()).unwrap(), Message::HeartbeatAck { .. }));
        assert_eq!(probe.heartbeats.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn refuse_dial_fails_at_connect() {
        let plan = ChaosPlan { refuse_dial: true, ..ChaosPlan::default() };
        let err = connect_with_chaos("inproc://chaos-refused", None, &plan, &Clock::system()).unwrap_err();
        assert!(format!("{err:#}").contains("refused"), "{err:#}");
    }

    #[test]
    fn sever_kills_the_connection_permanently_across_redials() {
        let probe = Arc::new(Probe::new());
        let server = serve("inproc://chaos-sever", Arc::clone(&probe) as _, None).unwrap();
        let plan = ChaosPlan { sever_after_sends: Some(2), ..ChaosPlan::default() };
        let mut conn = connect_with_chaos(&server.endpoint(), None, &plan, &Clock::system()).unwrap();
        assert!(conn.rpc(&hb()).is_ok());
        assert!(conn.rpc(&hb()).is_ok());
        let err = conn.rpc(&hb()).unwrap_err();
        assert!(format!("{err:#}").contains("severed"), "{err:#}");
        assert!(plan.severed());
        // A re-dial with the same plan shares the sever state: the peer
        // stays dead, the retry policy must give up.
        let err = connect_with_chaos(&server.endpoint(), None, &plan, &Clock::system()).unwrap_err();
        assert!(format!("{err:#}").contains("severed"), "{err:#}");
        assert_eq!(probe.heartbeats.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn severed_peer_rejoins_after_the_reconnect_window() {
        let probe = Arc::new(Probe::new());
        let server = serve("inproc://chaos-rejoin", Arc::clone(&probe) as _, None).unwrap();
        let clock = Clock::sim();
        let plan = ChaosPlan {
            sever_after_sends: Some(1),
            reconnect_after: Some(Duration::from_millis(50)),
            ..ChaosPlan::default()
        };
        let mut conn = connect_with_chaos(&server.endpoint(), None, &plan, &clock).unwrap();
        assert!(conn.rpc(&hb()).is_ok());
        assert!(conn.rpc(&hb()).is_err());
        assert!(plan.severed());
        // Inside the window the re-dial is still refused.
        let err = connect_with_chaos(&server.endpoint(), None, &plan, &clock).unwrap_err();
        assert!(format!("{err:#}").contains("severed"), "{err:#}");
        // After the window the peer rejoins, and the sever budget is
        // disarmed: the rejoined link survives arbitrarily many sends.
        clock.advance_to(clock.now() + Duration::from_millis(60));
        let mut conn = connect_with_chaos(&server.endpoint(), None, &plan, &clock).unwrap();
        for _ in 0..5 {
            assert!(matches!(conn.rpc(&hb()).unwrap(), Message::HeartbeatAck { .. }));
        }
        assert!(!plan.severed());
        assert_eq!(probe.heartbeats.load(Ordering::SeqCst), 6);
    }

    #[test]
    fn plan_fleet_propagates_reconnect_windows_to_severed_plans() {
        let spec =
            ChaosSpec { sever_fraction: 0.5, reconnect_after_ms: 25, ..ChaosSpec::default() };
        let plans = spec.plan_fleet(4, 9);
        let severed: Vec<_> = plans.iter().filter(|p| p.sever_after_sends.is_some()).collect();
        assert_eq!(severed.len(), 2);
        assert!(severed.iter().all(|p| p.reconnect_after == Some(Duration::from_millis(25))));
        assert!(plans
            .iter()
            .filter(|p| p.sever_after_sends.is_none())
            .all(|p| p.reconnect_after.is_none()));
    }

    #[test]
    fn kill_victim_is_deterministic_and_gated() {
        let off = ChaosSpec::default();
        assert_eq!(off.kill_victim(4, 7), None);
        let spec = ChaosSpec { kill_aggregator_at_round: 2, ..ChaosSpec::default() };
        let v = spec.kill_victim(4, 7).unwrap();
        assert!(v < 4);
        assert_eq!(spec.kill_victim(4, 7), Some(v), "same seed, same victim");
        assert_eq!(spec.kill_victim(0, 7), None);
        // Different run seeds spread the pick across the fleet.
        let picks: std::collections::HashSet<usize> =
            (0..32).filter_map(|s| spec.kill_victim(4, s)).collect();
        assert!(picks.len() > 1);
    }

    #[test]
    fn duplicate_delivers_control_messages_twice() {
        let probe = Arc::new(Probe::new());
        let server = serve("inproc://chaos-dup", Arc::clone(&probe) as _, None).unwrap();
        let plan = ChaosPlan { duplicate: true, ..ChaosPlan::default() };
        let mut conn = connect_with_chaos(&server.endpoint(), None, &plan, &Clock::system()).unwrap();
        // One rpc from the caller's view; the service saw it twice and
        // the reply pairing stayed strict (the next rpc still works).
        assert!(matches!(conn.rpc(&hb()).unwrap(), Message::HeartbeatAck { .. }));
        assert_eq!(probe.heartbeats.load(Ordering::SeqCst), 2);
        assert!(conn.rpc(&hb()).is_ok());
        assert_eq!(probe.heartbeats.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn corrupt_frames_mangle_chunk_payloads_only() {
        let probe = Arc::new(Probe::new());
        let server = serve("inproc://chaos-corrupt", Arc::clone(&probe) as _, None).unwrap();
        let plan = ChaosPlan { corrupt_frames: true, ..ChaosPlan::default() };
        let mut conn = connect_with_chaos(&server.endpoint(), None, &plan, &Clock::system()).unwrap();
        let clean = vec![1u8, 2, 3, 4];
        let msg = Message::ModelChunk { stream_id: 9, seq: 0, bytes: clean.clone() };
        assert!(matches!(conn.rpc(&msg).unwrap(), Message::Ack { ok: true, .. }));
        let seen = probe.chunks.lock().unwrap();
        assert_eq!(seen.len(), 1);
        assert_ne!(seen[0], clean, "payload must arrive corrupted");
        assert_eq!(seen[0].len(), clean.len());
    }

    #[test]
    fn slow_loris_drips_chunks_and_suppresses_end() {
        let probe = Arc::new(Probe::new());
        let server = serve("inproc://chaos-loris", Arc::clone(&probe) as _, None).unwrap();
        let plan = ChaosPlan { drip: Some(Duration::from_millis(1)), ..ChaosPlan::default() };
        let mut conn = connect_with_chaos(&server.endpoint(), None, &plan, &Clock::system()).unwrap();
        let chunk = Message::ModelChunk { stream_id: 5, seq: 0, bytes: vec![0u8; 8] };
        assert!(conn.rpc(&chunk).is_ok());
        let err = conn.send(&Message::ModelStreamEnd { stream_id: 5, digest: 0 }).unwrap_err();
        assert!(format!("{err:#}").contains("slow-loris"), "{err:#}");
    }

    #[test]
    fn stall_holds_then_fails_recv() {
        let probe = Arc::new(Probe::new());
        let server = serve("inproc://chaos-stall", Arc::clone(&probe) as _, None).unwrap();
        let plan = ChaosPlan { hold: Some(Duration::from_millis(20)), ..ChaosPlan::default() };
        let mut conn = connect_with_chaos(&server.endpoint(), None, &plan, &Clock::system()).unwrap();
        let start = crate::util::Stopwatch::start();
        let err = conn.rpc(&hb()).unwrap_err();
        assert!(start.elapsed() >= Duration::from_millis(20));
        assert!(format!("{err:#}").contains("stalled"), "{err:#}");
    }

    #[test]
    fn plan_fleet_is_deterministic_and_disjoint() {
        let spec = ChaosSpec {
            sever_fraction: 0.2,
            slow_loris: 1,
            corrupt: 1,
            ..ChaosSpec::default()
        };
        let a = spec.plan_fleet(20, 42);
        let b = spec.plan_fleet(20, 42);
        assert_eq!(a.len(), 20);
        let describe = |plans: &[ChaosPlan]| {
            plans
                .iter()
                .map(|p| {
                    let d = (p.drip, p.hold, p.duplicate, p.corrupt_frames);
                    (p.refuse_dial, p.sever_after_sends, d)
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(describe(&a), describe(&b), "same seed, same assignment");
        let severed = a.iter().filter(|p| p.sever_after_sends.is_some()).count();
        let loris = a.iter().filter(|p| p.drip.is_some()).count();
        let corrupt = a.iter().filter(|p| p.corrupt_frames).count();
        assert_eq!((severed, loris, corrupt), (4, 1, 1));
        // Faults land on distinct learners.
        let afflicted = a.iter().filter(|p| !p.is_noop()).count();
        assert_eq!(afflicted, 6);
        // A different seed moves the assignment.
        let c = spec.plan_fleet(20, 43);
        assert_ne!(describe(&a), describe(&c));
    }

    #[test]
    fn spec_validates_and_defaults_off() {
        let spec = ChaosSpec::default();
        assert!(spec.is_off());
        assert!(spec.validate().is_ok());
        assert!(spec.plan_fleet(4, 1).iter().all(|p| p.is_noop()));
        let bad = ChaosSpec { sever_fraction: 1.5, ..ChaosSpec::default() };
        assert!(bad.validate().is_err());
        let bad = ChaosSpec { sever_fraction: 0.5, sever_after_sends: 0, ..ChaosSpec::default() };
        assert!(bad.validate().is_err());
    }
}
