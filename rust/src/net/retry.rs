//! Unified retry/timeout/backoff policy for every network operation
//! that may transiently fail: dials, control-plane RPCs, and full
//! data-plane stream sends.
//!
//! Before this module each call site hand-rolled its own policy (a
//! fixed 50 × 20 ms dial loop in `net/tcp.rs`, a silent drop-and-hope
//! reconnect in the learner's completion callback). A [`RetryPolicy`]
//! makes the three knobs explicit — capped exponential backoff with
//! seeded jitter, a per-operation deadline, and a max attempt count —
//! and gives every give-up the same shape: a [`GiveUp`] carrying the
//! last error plus how hard we tried, so callers can count it and
//! route the failure into the pacing/quorum machinery instead of
//! losing it in a log line.
//!
//! Retries are only safe because replays are idempotent: completed-task
//! watermarks drop duplicate completions, and every stream attempt uses
//! a fresh `stream_id`, so a half-delivered stream from a failed
//! attempt can never be confused with its retry (the abandoned stream
//! is reclaimed by the receiver's idle/lifetime GC). Callers decide
//! *what* is retryable — transport faults retry, remote application
//! errors never do.

use crate::util::{Clock, Rng, Stopwatch};
use std::time::Duration;

/// Capped exponential backoff with seeded jitter and a total deadline.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Give up after this many attempts (>= 1; the first try counts).
    pub max_attempts: u32,
    /// Backoff before attempt 2; doubles each further attempt.
    pub base_delay: Duration,
    /// Ceiling on any single backoff delay.
    pub max_delay: Duration,
    /// Total budget across all attempts and sleeps; an attempt is never
    /// started (nor a sleep taken) that would run past it.
    pub deadline: Duration,
    /// ± fraction of jitter applied to each delay (0 = deterministic).
    pub jitter_frac: f64,
}

/// A retry loop that ran out of attempts, deadline, or hit a
/// non-retryable error. Carries the evidence for the degradation
/// counters (`FederationReport::retry_give_ups`).
#[derive(Debug)]
pub struct GiveUp<E> {
    pub attempts: u32,
    pub elapsed: Duration,
    pub last_error: E,
    /// False when the loop stopped because the error class never
    /// retries (remote application errors), true when the policy's
    /// attempt/deadline budget ran dry on retryable failures.
    pub exhausted: bool,
}

impl RetryPolicy {
    /// Dial profile: preserves the old hard-coded loop's ~1 s total
    /// window (listeners may still be coming up) but backs off
    /// exponentially instead of hammering every 20 ms.
    pub fn dial() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 64,
            base_delay: Duration::from_millis(5),
            max_delay: Duration::from_millis(100),
            deadline: Duration::from_secs(1),
            jitter_frac: 0.2,
        }
    }

    /// Profile for a full RPC or stream send over an established (or
    /// re-establishable) connection: a few attempts, backoff in the
    /// tens of milliseconds, bounded well below a round timeout so a
    /// give-up still leaves the quorum machinery time to act.
    pub fn rpc() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 3,
            base_delay: Duration::from_millis(25),
            max_delay: Duration::from_millis(250),
            deadline: Duration::from_secs(5),
            jitter_frac: 0.2,
        }
    }

    /// Backoff before attempt `attempt + 1` (so `attempt` is the count
    /// of failures seen): `base · 2^(attempt-1)` capped at `max_delay`,
    /// with ±`jitter_frac` of seeded jitter.
    pub fn delay_for(&self, attempt: u32, rng: &mut Rng) -> Duration {
        let exp = attempt.saturating_sub(1).min(20);
        let raw = self
            .base_delay
            .saturating_mul(1u32 << exp)
            .min(self.max_delay);
        if self.jitter_frac <= 0.0 {
            return raw;
        }
        let spread = rng.gen_range_f64(-self.jitter_frac, self.jitter_frac);
        raw.mul_f64((1.0 + spread).max(0.0))
    }

    /// Run `op` until it succeeds, a non-retryable error is hit, or the
    /// attempt/deadline budget is exhausted. Elapsed time and backoff
    /// sleeps run on `clock`, so simulated runs retry in virtual time.
    /// `op` receives the 1-based attempt number; `retryable` classifies
    /// errors (transport faults retry, remote application errors must
    /// not).
    pub fn run<T, E>(
        &self,
        clock: &Clock,
        rng: &mut Rng,
        mut op: impl FnMut(u32) -> Result<T, E>,
        mut retryable: impl FnMut(&E) -> bool,
    ) -> Result<T, GiveUp<E>> {
        let start = Stopwatch::start_with(clock);
        let mut attempt = 0u32;
        loop {
            attempt += 1;
            match op(attempt) {
                Ok(v) => return Ok(v),
                Err(e) => {
                    if !retryable(&e) {
                        return Err(GiveUp {
                            attempts: attempt,
                            elapsed: start.elapsed(),
                            last_error: e,
                            exhausted: false,
                        });
                    }
                    if attempt >= self.max_attempts.max(1) {
                        return Err(GiveUp {
                            attempts: attempt,
                            elapsed: start.elapsed(),
                            last_error: e,
                            exhausted: true,
                        });
                    }
                    let delay = self.delay_for(attempt, rng);
                    if start.elapsed() + delay >= self.deadline {
                        return Err(GiveUp {
                            attempts: attempt,
                            elapsed: start.elapsed(),
                            last_error: e,
                            exhausted: true,
                        });
                    }
                    clock.sleep(delay);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 4,
            base_delay: Duration::from_micros(100),
            max_delay: Duration::from_millis(1),
            deadline: Duration::from_secs(5),
            jitter_frac: 0.0,
        }
    }

    #[test]
    fn succeeds_after_transient_failures() {
        let mut rng = Rng::new(1);
        let mut calls = 0u32;
        let out = fast().run(
            &Clock::system(),
            &mut rng,
            |attempt| {
                calls += 1;
                assert_eq!(attempt, calls);
                if attempt < 3 { Err("transient") } else { Ok(attempt) }
            },
            |_| true,
        );
        assert_eq!(out.unwrap(), 3);
        assert_eq!(calls, 3);
    }

    #[test]
    fn gives_up_after_max_attempts_with_evidence() {
        let mut rng = Rng::new(2);
        let err = fast()
            .run(&Clock::system(), &mut rng, |_| Err::<(), _>("down"), |_| true)
            .unwrap_err();
        assert_eq!(err.attempts, 4);
        assert_eq!(err.last_error, "down");
        assert!(err.exhausted);
    }

    #[test]
    fn non_retryable_errors_fail_on_first_attempt() {
        let mut rng = Rng::new(3);
        let mut calls = 0u32;
        let err = fast()
            .run(
                &Clock::system(),
                &mut rng,
                |_| {
                    calls += 1;
                    Err::<(), _>("remote: bad request")
                },
                |e| !e.starts_with("remote"),
            )
            .unwrap_err();
        assert_eq!(calls, 1);
        assert_eq!(err.attempts, 1);
        assert!(!err.exhausted, "a non-retryable error is not exhaustion");
    }

    #[test]
    fn deadline_caps_the_whole_loop() {
        let policy = RetryPolicy {
            max_attempts: 1000,
            base_delay: Duration::from_millis(20),
            max_delay: Duration::from_millis(20),
            deadline: Duration::from_millis(50),
            jitter_frac: 0.0,
        };
        let mut rng = Rng::new(4);
        let sw = Stopwatch::start();
        let err = policy
            .run(&Clock::system(), &mut rng, |_| Err::<(), _>("down"), |_| true)
            .unwrap_err();
        assert!(err.attempts < 1000, "deadline must cut the loop short");
        assert!(sw.elapsed() < Duration::from_secs(2));
    }

    #[test]
    fn sim_clock_retries_in_virtual_time() {
        // Backoff sleeps totalling ~100 real seconds complete in well
        // under a real second on the sim clock, and the deadline is
        // enforced against virtual elapsed time.
        let policy = RetryPolicy {
            max_attempts: 8,
            base_delay: Duration::from_secs(10),
            max_delay: Duration::from_secs(60),
            deadline: Duration::from_secs(45),
            jitter_frac: 0.0,
        };
        let sim = Clock::sim();
        let mut rng = Rng::new(6);
        let real = Stopwatch::start();
        let err = policy
            .run(&sim, &mut rng, |_| Err::<(), _>("down"), |_| true)
            .unwrap_err();
        assert!(err.exhausted);
        // 10s + 20s sleeps fit the 45s deadline; a third (40s) would not.
        assert_eq!(err.attempts, 3);
        assert!(err.elapsed >= Duration::from_secs(30));
        assert!(real.elapsed() < Duration::from_secs(2));
    }

    #[test]
    fn backoff_doubles_and_caps() {
        let p = RetryPolicy {
            max_attempts: 10,
            base_delay: Duration::from_millis(10),
            max_delay: Duration::from_millis(35),
            deadline: Duration::from_secs(1),
            jitter_frac: 0.0,
        };
        let mut rng = Rng::new(5);
        assert_eq!(p.delay_for(1, &mut rng), Duration::from_millis(10));
        assert_eq!(p.delay_for(2, &mut rng), Duration::from_millis(20));
        assert_eq!(p.delay_for(3, &mut rng), Duration::from_millis(35));
        assert_eq!(p.delay_for(9, &mut rng), Duration::from_millis(35));
    }

    #[test]
    fn jitter_is_seeded_and_bounded() {
        let p = RetryPolicy { jitter_frac: 0.5, ..fast() };
        let lo = p.base_delay.mul_f64(0.5);
        let hi = p.base_delay.mul_f64(1.5);
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for attempt in 1..=20 {
            let da = p.delay_for(attempt, &mut a);
            let db = p.delay_for(attempt, &mut b);
            assert_eq!(da, db, "same seed, same jitter");
            if attempt == 1 {
                assert!(da >= lo && da <= hi, "{da:?} outside [{lo:?}, {hi:?}]");
            }
        }
    }

    #[test]
    fn dial_profile_preserves_the_one_second_window() {
        let p = RetryPolicy::dial();
        assert_eq!(p.deadline, Duration::from_secs(1));
        // Worst-case sleep total within the attempt cap stays in the
        // same order of magnitude as the old 50 × 20 ms loop.
        assert!(p.base_delay < Duration::from_millis(20));
        assert!(p.max_delay <= Duration::from_millis(200));
    }
}
