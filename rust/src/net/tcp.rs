//! Framed TCP transport with optional secure channel.
//!
//! One handler thread per accepted connection; requests are processed in
//! arrival order per connection, concurrently across connections — the
//! same execution shape as a gRPC server with per-stream dispatch.

use super::frame::{read_frame, write_frame};
use super::retry::RetryPolicy;
use super::secure::{confirmation, Handshake, SecureSession};
use super::{ClientConn, Psk, ServerHandle, Service};
use crate::proto::Message;
use crate::util::{log_debug, log_warn, Clock, Rng};
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Client side of the PSK handshake (no-op when `psk` is None).
fn client_handshake(stream: &mut TcpStream, psk: &Psk) -> Result<Option<SecureSession>> {
    let Some(psk) = psk else { return Ok(None) };
    let mut entropy = entropy_rng();
    let hs = Handshake::new(&mut entropy);
    stream.write_all(&hs.nonce)?;
    let mut server_nonce = [0u8; 16];
    stream.read_exact(&mut server_nonce)?;
    // Send our confirmation, check theirs.
    let my_conf = confirmation(psk, &hs.nonce, &server_nonce, true);
    stream.write_all(&my_conf)?;
    let mut their_conf = [0u8; 32];
    stream.read_exact(&mut their_conf)?;
    let expect = confirmation(psk, &hs.nonce, &server_nonce, false);
    if their_conf != expect {
        bail!("server key confirmation failed (PSK mismatch?)");
    }
    Ok(Some(SecureSession::derive(psk, &hs.nonce, &server_nonce)))
}

/// Server side of the PSK handshake.
fn server_handshake(stream: &mut TcpStream, psk: &Psk) -> Result<Option<SecureSession>> {
    let Some(psk) = psk else { return Ok(None) };
    let mut client_nonce = [0u8; 16];
    stream.read_exact(&mut client_nonce)?;
    let mut entropy = entropy_rng();
    let hs = Handshake::new(&mut entropy);
    stream.write_all(&hs.nonce)?;
    let mut their_conf = [0u8; 32];
    stream.read_exact(&mut their_conf)?;
    let expect = confirmation(psk, &client_nonce, &hs.nonce, true);
    if their_conf != expect {
        bail!("client key confirmation failed (PSK mismatch?)");
    }
    let my_conf = confirmation(psk, &client_nonce, &hs.nonce, false);
    stream.write_all(&my_conf)?;
    Ok(Some(SecureSession::derive(psk, &client_nonce, &hs.nonce)))
}

/// Process-unique nonce entropy: time seed + counter (not a CSPRNG; the
/// channel is a TLS *simulation*, see `secure.rs`).
fn entropy_rng() -> Rng {
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let t = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0x5EED);
    Rng::new(t ^ COUNTER.fetch_add(0x9E37_79B9, Ordering::Relaxed))
}

fn send_msg(
    stream: &mut TcpStream,
    session: &mut Option<SecureSession>,
    msg: &Message,
) -> Result<()> {
    let payload = msg.encode();
    match session {
        Some(s) => write_frame(stream, &s.seal(&payload)),
        None => write_frame(stream, &payload),
    }
}

fn recv_msg(
    stream: &mut TcpStream,
    session: &mut Option<SecureSession>,
) -> Result<Option<Message>> {
    let Some(raw) = read_frame(stream)? else { return Ok(None) };
    let payload = match session {
        Some(s) => s.open(&raw)?,
        None => raw,
    };
    Ok(Some(Message::decode(&payload)?))
}

/// Blocking RPC client over one TCP connection.
pub struct TcpClient {
    stream: TcpStream,
    session: Option<SecureSession>,
}

impl TcpClient {
    pub fn connect(addr: &str, psk: Psk) -> Result<TcpClient> {
        // Brief retry window through the unified policy: learners may
        // dial the controller while its listener is still coming up.
        // Refused/unreachable sockets retry; a *handshake* failure on an
        // accepted connection is a peer disagreement and fails at once.
        // TCP is a real-OS transport: dial pacing is pinned to the
        // system clock even when the federation runs simulated time
        // (sim fleets ride the inproc transport).
        let mut rng = entropy_rng();
        let mut stream = RetryPolicy::dial()
            .run(&Clock::system(), &mut rng, |_| TcpStream::connect(addr), |_| true)
            .map_err(|give_up| {
                anyhow::anyhow!(
                    "connect {addr}: gave up after {} attempts in {:?}: {:?}",
                    give_up.attempts,
                    give_up.elapsed,
                    give_up.last_error
                )
            })?;
        stream.set_nodelay(true).ok();
        let session = client_handshake(&mut stream, &psk)
            .with_context(|| format!("handshake with {addr}"))?;
        Ok(TcpClient { stream, session })
    }
}

impl ClientConn for TcpClient {
    fn send(&mut self, msg: &Message) -> Result<()> {
        send_msg(&mut self.stream, &mut self.session, msg)
    }

    fn send_raw(&mut self, bytes: &[u8]) -> Result<()> {
        match &mut self.session {
            Some(s) => write_frame(&mut self.stream, &s.seal(bytes)),
            None => write_frame(&mut self.stream, bytes),
        }
    }

    fn recv(&mut self) -> Result<Message> {
        match recv_msg(&mut self.stream, &mut self.session)? {
            Some(reply) => Ok(reply),
            None => bail!("connection closed awaiting reply"),
        }
    }
}

/// Accept-loop server; one thread per connection.
pub struct TcpServer {
    local: String,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl TcpServer {
    pub fn bind(addr: &str, svc: Arc<dyn Service>, psk: Psk) -> Result<TcpServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = format!("tcp://{}", listener.local_addr()?);
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let local2 = local.clone();
        let accept_thread = std::thread::Builder::new()
            .name("metisfl-accept".into())
            .spawn(move || {
                // Poll with a timeout so shutdown is prompt.
                listener.set_nonblocking(true).ok();
                let mut conn_threads = Vec::new();
                while !stop2.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, peer)) => {
                            log_debug("net", &format!("{local2} accepted {peer}"));
                            let svc = Arc::clone(&svc);
                            let psk = psk;
                            let h = std::thread::Builder::new()
                                .name("metisfl-conn".into())
                                .spawn(move || {
                                    if let Err(e) = conn_loop(stream, svc, psk) {
                                        log_debug("net", &format!("conn ended: {e:#}"));
                                    }
                                })
                                .expect("spawn conn thread");
                            conn_threads.push(h);
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            Clock::system().sleep(Duration::from_millis(5));
                        }
                        Err(e) => {
                            log_warn("net", &format!("accept error: {e}"));
                            break;
                        }
                    }
                }
                // Connections close themselves when peers disconnect; we
                // do not join here to keep shutdown prompt.
            })
            .expect("spawn accept thread");
        Ok(TcpServer { local, stop, accept_thread: Some(accept_thread) })
    }
}

fn conn_loop(mut stream: TcpStream, svc: Arc<dyn Service>, psk: Psk) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut session = server_handshake(&mut stream, &psk)?;
    while let Some(msg) = recv_msg(&mut stream, &mut session)? {
        let reply = svc.handle(msg);
        send_msg(&mut stream, &mut session, &reply)?;
    }
    Ok(())
}

impl ServerHandle for TcpServer {
    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }

    fn endpoint(&self) -> String {
        self.local.clone()
    }
}

impl Drop for TcpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Count(AtomicU64);
    impl Service for Count {
        fn handle(&self, msg: Message) -> Message {
            let n = self.0.fetch_add(1, Ordering::SeqCst);
            match msg {
                Message::Heartbeat { .. } => {
                    Message::HeartbeatAck { component: format!("{n}"), healthy: true }
                }
                _ => Message::error(crate::proto::ErrorCode::Unsupported, "unexpected"),
            }
        }
    }

    #[test]
    fn sequential_rpcs_on_one_connection() {
        let svc = Arc::new(Count(AtomicU64::new(0)));
        let mut server = TcpServer::bind("127.0.0.1:0", svc, None).unwrap();
        let addr = server.endpoint().strip_prefix("tcp://").unwrap().to_string();
        let mut c = TcpClient::connect(&addr, None).unwrap();
        for i in 0..5u64 {
            let reply = c.rpc(&Message::Heartbeat { from: "t".into() }).unwrap();
            assert_eq!(
                reply,
                Message::HeartbeatAck { component: format!("{i}"), healthy: true }
            );
        }
        server.shutdown();
    }

    #[test]
    fn concurrent_connections_served() {
        let svc = Arc::new(Count(AtomicU64::new(0)));
        let server = TcpServer::bind("127.0.0.1:0", svc.clone(), None).unwrap();
        let addr = server.endpoint().strip_prefix("tcp://").unwrap().to_string();
        let mut joins = Vec::new();
        for _ in 0..4 {
            let addr = addr.clone();
            joins.push(std::thread::spawn(move || {
                let mut c = TcpClient::connect(&addr, None).unwrap();
                for _ in 0..3 {
                    c.rpc(&Message::Heartbeat { from: "x".into() }).unwrap();
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(svc.0.load(Ordering::SeqCst), 12);
    }

    #[test]
    fn large_model_payload_roundtrips() {
        use crate::proto::{ModelProto, TensorProto};
        use crate::tensor::{ByteOrder, DType, Tensor};
        struct EchoModel;
        impl Service for EchoModel {
            fn handle(&self, msg: Message) -> Message {
                match msg {
                    Message::ShipModel { model } => Message::ModelReply { model, round: 0 },
                    _ => Message::error(crate::proto::ErrorCode::Unsupported, "unexpected"),
                }
            }
        }
        let server = TcpServer::bind("127.0.0.1:0", Arc::new(EchoModel), None).unwrap();
        let addr = server.endpoint().strip_prefix("tcp://").unwrap().to_string();
        let mut c = TcpClient::connect(&addr, None).unwrap();
        let t = Tensor::new("big", vec![1024, 256], vec![1.25f32; 1024 * 256]);
        let model = ModelProto {
            tensors: vec![TensorProto::from_tensor(&t, DType::F32, ByteOrder::Little)],
        };
        let reply = c.rpc(&Message::ShipModel { model: model.clone() }).unwrap();
        match reply {
            Message::ModelReply { model: m, .. } => assert_eq!(m, model),
            other => panic!("unexpected {}", other.kind()),
        }
    }
}
