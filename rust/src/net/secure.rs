//! Authenticated encrypted channel — the TLS substitute.
//!
//! The paper secures gRPC channels with SSL certificates (App. B,
//! Fig. 11). Offline we cannot link a TLS stack, so we exercise the same
//! code-path shape with a pre-shared-key channel:
//!
//! 1. **Handshake**: both sides exchange 16-byte random nonces, derive a
//!    session key `k = HMAC-SHA256(psk, "metisfl-session" ‖ nonce_c ‖
//!    nonce_s)`, and exchange key-confirmation MACs (mutual
//!    authentication; mismatched PSKs fail here).
//! 2. **Records**: every frame is AES-128-CTR encrypted under `k[0..16]`
//!    with a per-record counter IV, then authenticated with
//!    HMAC-SHA256(k[16..32]) over (seq ‖ ciphertext) — encrypt-then-MAC.
//!
//! This is a *simulation* of TLS for benchmarking purposes (per-byte
//! crypto cost on the wire path), documented in DESIGN.md §Substitutions.
//! Do not reuse as a production transport.

use aes::cipher::{BlockEncrypt, KeyInit};
use aes::Aes128;
use anyhow::{bail, Result};
use hmac::{Hmac, Mac};
use sha2::Sha256;

type HmacSha256 = Hmac<Sha256>;

const CONFIRM_C: &[u8] = b"metisfl-confirm-client";
const CONFIRM_S: &[u8] = b"metisfl-confirm-server";

/// Session state after a successful handshake.
pub struct SecureSession {
    enc_key: Aes128,
    mac_key: [u8; 16],
    send_seq: u64,
    recv_seq: u64,
}

/// Nonce material exchanged in the clear during the handshake.
pub struct Handshake {
    pub nonce: [u8; 16],
}

impl Handshake {
    pub fn new(entropy: &mut crate::util::Rng) -> Handshake {
        let mut nonce = [0u8; 16];
        for c in nonce.chunks_exact_mut(8) {
            c.copy_from_slice(&entropy.next_u64().to_le_bytes());
        }
        Handshake { nonce }
    }
}

fn hkdf(psk: &[u8; 32], client_nonce: &[u8; 16], server_nonce: &[u8; 16]) -> [u8; 32] {
    let mut mac = <HmacSha256 as Mac>::new_from_slice(psk).expect("hmac key");
    mac.update(b"metisfl-session");
    mac.update(client_nonce);
    mac.update(server_nonce);
    mac.finalize().into_bytes().into()
}

/// Key-confirmation MAC each side sends to prove PSK knowledge.
pub fn confirmation(
    psk: &[u8; 32],
    client_nonce: &[u8; 16],
    server_nonce: &[u8; 16],
    is_client: bool,
) -> [u8; 32] {
    let session = hkdf(psk, client_nonce, server_nonce);
    let mut mac = <HmacSha256 as Mac>::new_from_slice(&session).expect("hmac key");
    mac.update(if is_client { CONFIRM_C } else { CONFIRM_S });
    mac.finalize().into_bytes().into()
}

impl SecureSession {
    /// Derive a session from the PSK and both handshake nonces.
    pub fn derive(psk: &[u8; 32], client_nonce: &[u8; 16], server_nonce: &[u8; 16]) -> Self {
        let session = hkdf(psk, client_nonce, server_nonce);
        let mut enc = [0u8; 16];
        enc.copy_from_slice(&session[..16]);
        let mut mac_key = [0u8; 16];
        mac_key.copy_from_slice(&session[16..]);
        SecureSession {
            enc_key: Aes128::new(&enc.into()),
            mac_key,
            send_seq: 0,
            recv_seq: 0,
        }
    }

    /// Constant-time-ish comparison (length + fold over XOR).
    fn ct_eq(a: &[u8], b: &[u8]) -> bool {
        a.len() == b.len() && a.iter().zip(b).fold(0u8, |acc, (x, y)| acc | (x ^ y)) == 0
    }

    fn keystream_xor(&self, seq: u64, data: &mut [u8]) {
        // AES-128-CTR with IV = seq ‖ block counter.
        let mut block_idx: u64 = 0;
        for chunk in data.chunks_mut(16) {
            let mut block = [0u8; 16];
            block[..8].copy_from_slice(&seq.to_le_bytes());
            block[8..].copy_from_slice(&block_idx.to_le_bytes());
            let mut b = block.into();
            self.enc_key.encrypt_block(&mut b);
            for (d, k) in chunk.iter_mut().zip(b.iter()) {
                *d ^= k;
            }
            block_idx += 1;
        }
    }

    fn record_mac(&self, seq: u64, ciphertext: &[u8]) -> [u8; 32] {
        let mut mac = <HmacSha256 as Mac>::new_from_slice(&self.mac_key).expect("hmac key");
        mac.update(&seq.to_le_bytes());
        mac.update(ciphertext);
        mac.finalize().into_bytes().into()
    }

    /// Encrypt+authenticate one outgoing record.
    pub fn seal(&mut self, plaintext: &[u8]) -> Vec<u8> {
        let seq = self.send_seq;
        self.send_seq += 1;
        let mut out = Vec::with_capacity(plaintext.len() + 32);
        out.extend_from_slice(plaintext);
        self.keystream_xor(seq, &mut out);
        let tag = self.record_mac(seq, &out);
        out.extend_from_slice(&tag);
        out
    }

    /// Verify+decrypt one incoming record.
    pub fn open(&mut self, record: &[u8]) -> Result<Vec<u8>> {
        if record.len() < 32 {
            bail!("secure record too short");
        }
        let seq = self.recv_seq;
        let (ciphertext, tag) = record.split_at(record.len() - 32);
        let expect = self.record_mac(seq, ciphertext);
        if !Self::ct_eq(tag, &expect) {
            bail!("secure record MAC mismatch (seq {seq})");
        }
        self.recv_seq += 1;
        let mut out = ciphertext.to_vec();
        self.keystream_xor(seq, &mut out);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn pair(psk_c: [u8; 32], psk_s: [u8; 32]) -> (SecureSession, SecureSession, [u8; 32], [u8; 32]) {
        let mut rng = Rng::new(1);
        let hc = Handshake::new(&mut rng);
        let hs = Handshake::new(&mut rng);
        let client = SecureSession::derive(&psk_c, &hc.nonce, &hs.nonce);
        let server = SecureSession::derive(&psk_s, &hc.nonce, &hs.nonce);
        let conf_c = confirmation(&psk_c, &hc.nonce, &hs.nonce, true);
        let conf_c_expected = confirmation(&psk_s, &hc.nonce, &hs.nonce, true);
        (client, server, conf_c, conf_c_expected)
    }

    #[test]
    fn seal_open_roundtrip() {
        let (mut c, mut s, _, _) = pair([9u8; 32], [9u8; 32]);
        for msg in [&b"hello"[..], &[0u8; 0][..], &[0xAB; 1000][..]] {
            let sealed = c.seal(msg);
            if !msg.is_empty() {
                assert_ne!(&sealed[..msg.len()], msg); // actually encrypted
            }
            let opened = s.open(&sealed).unwrap();
            assert_eq!(opened, msg);
        }
    }

    #[test]
    fn bidirectional_sequences_independent() {
        let (mut c, mut s, _, _) = pair([3u8; 32], [3u8; 32]);
        let a = c.seal(b"from client");
        // Server->client uses the server's own send_seq starting at 0.
        let b = s.seal(b"from server");
        assert_eq!(s.open(&a).unwrap(), b"from client");
        assert_eq!(c.open(&b).unwrap(), b"from server");
    }

    #[test]
    fn tampering_detected() {
        let (mut c, mut s, _, _) = pair([5u8; 32], [5u8; 32]);
        let mut sealed = c.seal(b"payload");
        sealed[0] ^= 1;
        assert!(s.open(&sealed).is_err());
    }

    #[test]
    fn replay_detected_via_sequence() {
        let (mut c, mut s, _, _) = pair([5u8; 32], [5u8; 32]);
        let sealed = c.seal(b"one");
        assert!(s.open(&sealed).is_ok());
        // Replaying the same record must fail (MAC binds seq=1 now).
        assert!(s.open(&sealed).is_err());
    }

    #[test]
    fn psk_mismatch_breaks_confirmation_and_records() {
        let (mut c, mut s, conf_c, conf_c_expected) = pair([1u8; 32], [2u8; 32]);
        assert_ne!(conf_c, conf_c_expected);
        let sealed = c.seal(b"x");
        assert!(s.open(&sealed).is_err());
    }

    #[test]
    fn short_record_rejected() {
        let (_, mut s, _, _) = pair([5u8; 32], [5u8; 32]);
        assert!(s.open(&[0u8; 10]).is_err());
    }
}
