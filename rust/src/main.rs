//! MetisFL launcher.
//!
//! Subcommands mirror the paper's process roles (Fig. 8):
//!
//! * `metisfl driver --env <file>`      — full lifecycle from an env file
//! * `metisfl controller --env <file>`  — standalone controller process
//! * `metisfl aggregator --env <file> --upstream <ep>` — shard aggregator tier
//! * `metisfl learner --env <file> --index <i> --controller <ep>`
//! * `metisfl simulate [...]`           — quick in-proc federation
//! * `metisfl stress [...]`             — one cross-framework stress cell
//! * `metisfl loadtest [...]`           — open-loop arrivals + chaos gates
//! * `metisfl replay --trace <file>`    — re-drive a recorded run, verify bitwise
//! * `metisfl trace dump|diff [...]`    — timeline view / first-divergence bisection
//! * `metisfl metrics [...]`            — Prometheus text exposition of a registry
//! * `metisfl table1`                   — print the qualitative matrix
//!
//! Multi-process deployment: start the controller first, then learners,
//! then `driver` (or use `simulate`, which hosts everything in-process).

use metisfl::cli::{CliError, Command};
use metisfl::config::{FederationEnv, ModelSpec, Protocol, TrainerKind};
use metisfl::net::Service;
use metisfl::util::{log_info, Clock};
use std::sync::Arc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn usage() -> String {
    "metisfl <driver|controller|aggregator|learner|simulate|stress|loadtest|replay|trace|metrics|\
     table1|bench-check> [options]\n\
     Run `metisfl <subcommand> --help` for options."
        .to_string()
}

fn run(args: &[String]) -> anyhow::Result<()> {
    let Some(sub) = args.first() else {
        println!("{}", usage());
        return Ok(());
    };
    let rest = &args[1..];
    match sub.as_str() {
        "driver" => cmd_driver(rest),
        "controller" => cmd_controller(rest),
        "aggregator" => cmd_aggregator(rest),
        "learner" => cmd_learner(rest),
        "simulate" => cmd_simulate(rest),
        "stress" => cmd_stress(rest),
        "loadtest" => cmd_loadtest(rest),
        "replay" => cmd_replay(rest),
        "trace" => cmd_trace(rest),
        "metrics" => cmd_metrics(rest),
        "table1" => {
            println!("{}", metisfl::baselines::capabilities::render_table());
            Ok(())
        }
        "bench-check" => cmd_bench_check(rest),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand '{other}'\n{}", usage()),
    }
}

fn parse(cmd: &Command, raw: &[String]) -> anyhow::Result<metisfl::cli::Args> {
    match cmd.parse(raw) {
        Ok(a) => Ok(a),
        Err(CliError::Help) => {
            println!("{}", cmd.help());
            std::process::exit(0);
        }
        Err(e) => Err(e.into()),
    }
}

fn cmd_driver(raw: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("metisfl driver", "run a full federation from an env file")
        .opt("env", None, "federated environment YAML/JSON file")
        .opt("record", None, "write the root controller's replayable trace to this file")
        .flag("distributed", "use localhost TCP instead of in-proc");
    let a = parse(&cmd, raw)?;
    let env_file = a
        .get("env")
        .ok_or_else(|| anyhow::anyhow!("--env <file> is required"))?;
    let env = FederationEnv::from_file(env_file)?;
    let report = if let Some(path) = a.get("record") {
        if a.flag("distributed") {
            anyhow::bail!("--record runs on the env's own transport; drop --distributed");
        }
        let (report, trace) = metisfl::driver::run_recorded(&env)?;
        let bytes = trace.ok_or_else(|| anyhow::anyhow!("recording produced no trace"))?;
        std::fs::write(path, &bytes).map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
        println!("trace written to {path} ({} bytes)", bytes.len());
        report
    } else if a.flag("distributed") {
        metisfl::driver::run_distributed(&env)?
    } else {
        metisfl::driver::run_simulated(&env)?
    };
    print_report(&report);
    // A run with a scheduled aggregator kill emits the failover report
    // the CI bench gate bounds (bench_out/failover.json); the row label
    // is the env name, so the baseline key stays stable per scenario.
    if env.chaos.kill_aggregator_at_round > 0 {
        let mut w = metisfl::harness::ReportWriter::new(
            "failover",
            &["scenario", "failovers", "rehomed_learners", "rounds_to_recover"],
        );
        w.row(vec![
            env.name.clone(),
            report.failovers.to_string(),
            report.rehomed_learners.to_string(),
            report.rounds_to_recover.to_string(),
        ]);
        w.emit()?;
    }
    Ok(())
}

fn cmd_controller(raw: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("metisfl controller", "run a standalone controller process")
        .opt("env", None, "federated environment YAML/JSON file")
        .opt("listen", Some("tcp://127.0.0.1:42500"), "endpoint to serve on");
    let a = parse(&cmd, raw)?;
    let env = FederationEnv::from_file(
        a.get("env").ok_or_else(|| anyhow::anyhow!("--env <file> is required"))?,
    )?;
    let controller = metisfl::controller::Controller::new(env, None)?;
    let server = metisfl::net::serve(
        a.get("listen").unwrap(),
        Arc::clone(&controller) as Arc<dyn Service>,
        None,
    )?;
    log_info("main", &format!("controller serving on {}", server.endpoint()));
    while !controller.is_shutdown() {
        Clock::system().sleep(std::time::Duration::from_millis(100));
    }
    log_info("main", "controller received shutdown");
    Ok(())
}

fn cmd_aggregator(raw: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new(
        "metisfl aggregator",
        "run an intermediate aggregator owning one learner shard",
    )
    .opt("env", None, "federated environment YAML/JSON file")
    .opt("id", Some("agg-0"), "aggregator id (used as upstream learner id)")
    .opt("upstream", Some("tcp://127.0.0.1:42500"), "root controller endpoint")
    .opt("listen", Some("tcp://127.0.0.1:0"), "endpoint to serve the shard on")
    .opt("shard-size", Some("0"), "learners in this shard (0 = env.learners / aggregators)");
    let a = parse(&cmd, raw)?;
    let env = FederationEnv::from_file(
        a.get("env").ok_or_else(|| anyhow::anyhow!("--env <file> is required"))?,
    )?;
    let mut shard_size = a.get_usize("shard-size")?;
    if shard_size == 0 {
        shard_size = env.learners / env.topology.aggregators.max(1);
    }
    let node = metisfl::controller::hierarchy::AggregatorNode::new(
        a.get("id").unwrap(),
        a.get("upstream").unwrap(),
        &env,
        shard_size.max(1),
        None,
    )?;
    let server = metisfl::net::serve(
        a.get("listen").unwrap(),
        Arc::new(metisfl::controller::hierarchy::AggregatorServicer(Arc::clone(&node)))
            as Arc<dyn Service>,
        None,
    )?;
    // Wait for the shard before announcing upstream, so the root's
    // registration barrier reflects fully-formed shards (topology-aware
    // registration: learners → aggregator → controller).
    node.inner()
        .wait_for_learners(shard_size.max(1), std::time::Duration::from_secs(300))?;
    node.register(&server.endpoint(), shard_size.max(1) * env.samples_per_learner)?;
    log_info(
        "main",
        &format!("aggregator {} serving shard on {}", a.get("id").unwrap(), server.endpoint()),
    );
    while !node.is_shutdown() {
        Clock::system().sleep(std::time::Duration::from_millis(100));
    }
    log_info("main", "aggregator received shutdown");
    Ok(())
}

fn cmd_learner(raw: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("metisfl learner", "run a standalone learner process")
        .opt("env", None, "federated environment YAML/JSON file")
        .opt("index", Some("0"), "learner index (data shard)")
        .opt("controller", Some("tcp://127.0.0.1:42500"), "controller endpoint")
        .opt("listen", Some("tcp://127.0.0.1:0"), "endpoint to serve on");
    let a = parse(&cmd, raw)?;
    let env = FederationEnv::from_file(
        a.get("env").ok_or_else(|| anyhow::anyhow!("--env <file> is required"))?,
    )?;
    let index = a.get_usize("index")?;
    let dataset = metisfl::learner::Dataset::synthetic_housing(
        env.model.input_dim,
        env.samples_per_learner,
        env.samples_per_learner,
        env.seed ^ ((index as u64) << 8),
    );
    let trainer: Arc<dyn metisfl::learner::Trainer> = match &env.trainer {
        TrainerKind::Synthetic { step_time_us, hetero } => Arc::new(
            metisfl::learner::SyntheticTrainer::for_fleet(*step_time_us, hetero, env.seed, index),
        ),
        TrainerKind::Xla { artifacts_dir } => {
            Arc::new(metisfl::runtime::XlaTrainer::load(artifacts_dir, &env.model)?)
        }
    };
    let learner = metisfl::learner::Learner::new(
        &format!("learner-{index}"),
        a.get("controller").unwrap(),
        None,
        trainer,
        dataset,
    );
    learner.set_stream_chunk(env.effective_stream_chunk());
    learner.set_upload_codec(env.upload_codec());
    learner.set_delta_fallback(env.delta_fallback);
    let server = metisfl::net::serve(
        a.get("listen").unwrap(),
        Arc::new(metisfl::learner::LearnerServicer(Arc::clone(&learner))) as Arc<dyn Service>,
        None,
    )?;
    learner.register(&server.endpoint())?;
    log_info("main", &format!("learner-{index} serving on {}", server.endpoint()));
    while !learner.is_shutdown() {
        Clock::system().sleep(std::time::Duration::from_millis(100));
    }
    Ok(())
}

fn cmd_simulate(raw: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("metisfl simulate", "quick in-process federation")
        .opt("learners", Some("10"), "number of learners")
        .opt("rounds", Some("3"), "federation rounds")
        .opt("layers", Some("10"), "hidden layers")
        .opt("units", Some("32"), "units per hidden layer")
        .opt("protocol", Some("sync"), "sync | semisync | async")
        .opt("backend", Some("chunked"), "aggregation: sequential | parallel | chunked | xla")
        .opt("artifacts", None, "artifacts dir (enables real XLA training)")
        .flag("distributed", "use localhost TCP instead of in-proc");
    let a = parse(&cmd, raw)?;
    let protocol = match a.get("protocol").unwrap() {
        "sync" => Protocol::Synchronous,
        "semisync" => Protocol::SemiSynchronous { lambda: 1.0 },
        "async" => Protocol::Asynchronous { staleness_alpha: 0.5 },
        other => anyhow::bail!("unknown protocol '{other}'"),
    };
    let mut agg = metisfl::config::AggregationSpec::default();
    agg.backend = match a.get("backend").unwrap() {
        "sequential" => metisfl::config::AggregationBackend::Sequential,
        "parallel" => metisfl::config::AggregationBackend::Parallel,
        "chunked" => metisfl::config::AggregationBackend::Chunked,
        "xla" => metisfl::config::AggregationBackend::Xla,
        other => anyhow::bail!("unknown backend '{other}'"),
    };
    let mut builder = FederationEnv::builder("simulate")
        .learners(a.get_usize("learners")?)
        .rounds(a.get_usize("rounds")?)
        .model(ModelSpec::mlp(8, a.get_usize("layers")?, a.get_usize("units")?))
        .protocol(protocol)
        .aggregation(agg);
    if let Some(dir) = a.get("artifacts") {
        builder = builder.trainer(TrainerKind::Xla { artifacts_dir: dir.to_string() });
    }
    let env = builder.build();
    let report = if a.flag("distributed") {
        metisfl::driver::run_distributed(&env)?
    } else {
        metisfl::driver::run_simulated(&env)?
    };
    print_report(&report);
    Ok(())
}

fn cmd_stress(raw: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("metisfl stress", "one cross-framework stress cell (Figs. 5-7)")
        .opt("learners", Some("10"), "number of learners")
        .opt("layers", Some("10"), "hidden layers")
        .opt("units", Some("32"), "units per hidden layer");
    let a = parse(&cmd, raw)?;
    let config = metisfl::harness::FigureConfig {
        name: "stress",
        spec: ModelSpec::mlp(8, a.get_usize("layers")?, a.get_usize("units")?),
        learner_counts: vec![a.get_usize("learners")?],
        frameworks: metisfl::baselines::Framework::ALL.to_vec(),
        seed: 42,
    };
    metisfl::harness::figure_sweep(config).emit_panels()?;
    Ok(())
}

fn cmd_loadtest(raw: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new(
        "metisfl loadtest",
        "open-loop arrival loadtest: per-phase p50/p99/p999 + chaos degradation gates",
    )
    .opt("env", None, "env file supplying fleet/model/chaos/quorum settings")
    .opt("learners", Some("8"), "fleet size")
    .opt("rate", Some("200"), "open-loop arrival rate, learners/second")
    .opt("rounds", Some("2"), "federation rounds")
    .opt("seed", Some("42"), "run seed (chaos, arrivals, data shards)")
    .opt("chunk", Some("2048"), "stream chunk bytes (chaos faults act on chunks)")
    .opt("quorum", Some("1.0"), "deadline-quorum fraction (1.0 = full barrier)")
    .opt("record", None, "write a deterministic trace of the run to this file")
    .flag("quick", "CI smoke preset (ignores the sizing options)")
    .flag("sim", "run on a simulated clock: virtual arrivals/compute/timeouts")
    .flag(
        "spans",
        "trace spans on every process; the table lands as 'loadtest_spans' so the \
         perf gate bounds the instrumentation overhead separately",
    )
    .flag(
        "verify-equivalence",
        "re-run the surviving fleet without chaos; fail unless the community \
         model matches bitwise",
    );
    let a = parse(&cmd, raw)?;
    let mut cfg = metisfl::harness::LoadtestConfig::quick();
    if !a.flag("quick") {
        cfg.learners = a.get_usize("learners")?;
        cfg.rate = a.get_f64("rate")?;
        cfg.rounds = a.get_usize("rounds")?;
        cfg.seed = a.get_u64("seed")?;
        cfg.stream_chunk_bytes = a.get_usize("chunk")?;
        cfg.quorum_fraction = a.get_f64("quorum")?;
    }
    if let Some(env_file) = a.get("env") {
        // The env file wins for everything it can express; CLI sizing
        // flags only apply to env-less runs.
        let env = FederationEnv::from_file(env_file)?;
        cfg.learners = env.learners;
        cfg.rounds = env.rounds;
        cfg.model = env.model.clone();
        cfg.chaos = env.chaos.clone();
        cfg.quorum_fraction = env.quorum_fraction;
        cfg.stream_chunk_bytes = env.stream_chunk_bytes;
        cfg.task_timeout_ms = env.task_timeout_ms;
        cfg.seed = env.seed;
        cfg.wire_codec = env.wire_codec;
        if let TrainerKind::Synthetic { step_time_us, .. } = &env.trainer {
            cfg.step_time_us = *step_time_us;
        }
    }
    cfg.sim = a.flag("sim");
    cfg.record = a.get("record").is_some();
    cfg.spans = a.flag("spans");
    let report = if a.flag("verify-equivalence") {
        let eq = metisfl::harness::verify_chaos_equivalence(&cfg)?;
        println!(
            "chaos equivalence OK: community digest {:#018x} reproduced by {} \
             survivor(s) without chaos",
            eq.chaos.community_digest,
            eq.survivors.len()
        );
        eq.chaos
    } else {
        metisfl::harness::run_loadtest(&cfg)?
    };
    report.table().emit()?;
    println!(
        "fleet {} · registered {} · dials refused {} · rounds {} · completions/round {:?}",
        report.fleet,
        report.registered,
        report.refused_dials,
        report.rounds_completed,
        report.completed_per_round,
    );
    println!(
        "degradation: retry give-ups {} · streams refused {} · streams gc'd {} · \
         delta fallbacks {} · late folds {} · peak ingest {} B",
        report.retry_give_ups,
        report.streams_refused,
        report.streams_gced,
        report.fallback_sends,
        report.late_folds,
        report.peak_wire_ingest_bytes,
    );
    println!(
        "community model: round {} digest {:#018x}",
        report.community_round, report.community_digest
    );
    if let Some(path) = a.get("record") {
        let bytes = report
            .trace
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("run produced no trace despite --record"))?;
        std::fs::write(path, bytes).map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
        println!("trace: {} bytes -> {path} (verify with `metisfl replay --trace {path}`)", bytes.len());
    }
    Ok(())
}

fn cmd_replay(raw: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new(
        "metisfl replay",
        "re-drive a recorded controller trace on a simulated clock and verify the \
         community model reproduces bitwise",
    )
    .opt("trace", None, "trace file written by `loadtest --record`")
    .flag("strict-counters", "also fail on replayable-counter drift (digest always gates)");
    let a = parse(&cmd, raw)?;
    let path = a
        .get("trace")
        .ok_or_else(|| anyhow::anyhow!("--trace <file> is required"))?;
    let bytes = std::fs::read(path).map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
    let outcome = metisfl::runtime::trace::replay_trace(&bytes)?;
    println!(
        "replayed {} event(s): recorded digest {:#018x}, replayed digest {:#018x}",
        outcome.events, outcome.recorded_digest, outcome.replayed_digest
    );
    let drift = outcome.counter_diffs();
    for (name, rec, rep) in &drift {
        println!("counter drift: {name}: recorded {rec}, replayed {rep}");
    }
    if let Some(d) = &outcome.divergence {
        anyhow::bail!(
            "replay diverged: {d}\n\
             (bisect: re-record the scenario and compare the two trace files with \
             `metisfl trace diff --a <old> --b <new>`; render either timeline with \
             `metisfl trace dump --trace <file>`)"
        );
    }
    if a.flag("strict-counters") && !drift.is_empty() {
        anyhow::bail!("replay drifted on {} replayable counter(s)", drift.len());
    }
    println!("replay OK: community model reproduced bitwise");
    Ok(())
}

fn cmd_trace(raw: &[String]) -> anyhow::Result<()> {
    match raw.first().map(String::as_str) {
        Some("dump") => cmd_trace_dump(&raw[1..]),
        Some("diff") => cmd_trace_diff(&raw[1..]),
        Some("--help") | Some("-h") | None => {
            println!(
                "metisfl trace <dump|diff> [options]\n\
                 dump  — render a recorded trace as a per-tick timeline\n\
                 diff  — first-divergence bisection between two traces"
            );
            Ok(())
        }
        Some(other) => anyhow::bail!("unknown trace subcommand '{other}' (expected dump|diff)"),
    }
}

fn cmd_trace_dump(raw: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new(
        "metisfl trace dump",
        "render a recorded MFTR1 trace as a human-readable per-tick timeline",
    )
    .opt("trace", None, "trace file written by `loadtest --record` / `driver --record`");
    let a = parse(&cmd, raw)?;
    let path = a
        .get("trace")
        .ok_or_else(|| anyhow::anyhow!("--trace <file> is required"))?;
    let bytes = std::fs::read(path).map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
    let trace = metisfl::runtime::trace::Trace::decode(&bytes)?;
    let env_name = FederationEnv::from_yaml(&trace.env_source)
        .map(|e| e.name)
        .unwrap_or_else(|_| "<unparseable env>".to_string());
    println!(
        "trace of '{env_name}': {} event(s), community digest {:#018x}",
        trace.events.len(),
        trace.community_digest
    );
    for (i, (tick, ev)) in trace.events.iter().enumerate() {
        println!("{i:>6}  {:>12.3}ms  {}", tick.as_secs_f64() * 1e3, describe_event(ev));
    }
    if !trace.counters.is_empty() {
        println!("footer counters ({}):", trace.counters.len());
        for (name, v) in &trace.counters {
            println!("        {name} = {v}");
        }
    }
    Ok(())
}

fn cmd_trace_diff(raw: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new(
        "metisfl trace diff",
        "bisect two recorded traces to their first diverging event (span batches are \
         observability payload and are ignored)",
    )
    .opt("a", None, "first trace file (e.g. the committed/known-good recording)")
    .opt("b", None, "second trace file (e.g. the re-recorded run under test)");
    let a = parse(&cmd, raw)?;
    let pa = a.get("a").ok_or_else(|| anyhow::anyhow!("--a <file> is required"))?;
    let pb = a.get("b").ok_or_else(|| anyhow::anyhow!("--b <file> is required"))?;
    let ta = metisfl::runtime::trace::Trace::decode(
        &std::fs::read(pa).map_err(|e| anyhow::anyhow!("reading {pa}: {e}"))?,
    )?;
    let tb = metisfl::runtime::trace::Trace::decode(
        &std::fs::read(pb).map_err(|e| anyhow::anyhow!("reading {pb}: {e}"))?,
    )?;
    if ta.env_source != tb.env_source {
        println!("note: the embedded environments differ; diffing timelines anyway");
    }
    // Spans are observability payload riding the trace: two equivalent
    // runs may batch them differently (thread interleaving assigns span
    // ids), so the divergence walk sees only the replayable timeline.
    let timeline = |t: &metisfl::runtime::trace::Trace| -> Vec<(
        std::time::Duration,
        metisfl::runtime::trace::TraceEvent,
    )> {
        t.events
            .iter()
            .filter(|(_, ev)| !matches!(ev, metisfl::runtime::trace::TraceEvent::Spans { .. }))
            .cloned()
            .collect()
    };
    let (ea, eb) = (timeline(&ta), timeline(&tb));
    let n = ea.len().min(eb.len());
    for i in 0..n {
        let (tick_a, ev_a) = &ea[i];
        let (tick_b, ev_b) = &eb[i];
        if tick_a != tick_b || ev_a != ev_b {
            println!("first divergence at event {i}:");
            println!("  a: tick {:>12.3}ms  {}", tick_a.as_secs_f64() * 1e3, describe_event(ev_a));
            println!("  b: tick {:>12.3}ms  {}", tick_b.as_secs_f64() * 1e3, describe_event(ev_b));
            anyhow::bail!("traces diverge at event {i}");
        }
    }
    if ea.len() != eb.len() {
        let (longer, tick, ev) =
            if ea.len() > eb.len() { ("a", &ea[n].0, &ea[n].1) } else { ("b", &eb[n].0, &eb[n].1) };
        println!(
            "timelines agree for {n} event(s); {longer} continues at tick {:>.3}ms with: {}",
            tick.as_secs_f64() * 1e3,
            describe_event(ev)
        );
        anyhow::bail!(
            "traces diverge at event {n}: a has {} event(s), b has {}",
            ea.len(),
            eb.len()
        );
    }
    if ta.community_digest != tb.community_digest {
        anyhow::bail!(
            "timelines match event-for-event but the sealed digests differ: \
             {:#018x} vs {:#018x} (non-replayable state leaked into the math)",
            ta.community_digest,
            tb.community_digest
        );
    }
    for (name, va) in &ta.counters {
        let vb = tb.counters.get(name).copied().unwrap_or(0);
        if *va != vb {
            println!("footer counter drift: {name}: a {va}, b {vb}");
        }
    }
    println!("traces identical: {n} event(s), digest {:#018x}", ta.community_digest);
    Ok(())
}

/// One human-readable line (or indented block, for span batches) per
/// trace event — `trace dump` must render every [`TraceEvent`] variant.
fn describe_event(ev: &metisfl::runtime::trace::TraceEvent) -> String {
    use metisfl::runtime::trace::TraceEvent as E;
    let join = |ids: &[String]| ids.join(", ");
    match ev {
        E::Inbound { wire } => match metisfl::proto::Message::decode(wire) {
            Ok(m) => format!("inbound {} ({} B)", m.kind(), wire.len()),
            Err(_) => format!("inbound <undecodable> ({} B)", wire.len()),
        },
        E::RoundOpen { round, ids } => {
            format!("round {round} open, expecting {}: {}", ids.len(), join(ids))
        }
        E::RoundClose { round, arrived } => {
            format!("round {round} close, arrived {}: {}", arrived.len(), join(arrived))
        }
        E::Aggregate { round, ids } => {
            format!("aggregate round {round} over {} contribution(s): {}", ids.len(), join(ids))
        }
        E::MarkOutstanding { id } => format!("mark outstanding: {id}"),
        E::BaseSet { id, round } => format!("delta base for {id} pinned at round {round}"),
        E::Spans { spans } => {
            let mut s = format!("{} span(s):", spans.len());
            for sp in spans {
                s.push_str(&format!(
                    "\n          trace {:#018x} span {:#06x} parent {:#06x}  {:<14} \
                     round {} task {}{}  [{:.3}ms .. {:.3}ms]",
                    sp.trace_id,
                    sp.span_id,
                    sp.parent,
                    sp.op,
                    sp.round,
                    sp.task_id,
                    if sp.peer.is_empty() {
                        String::new()
                    } else {
                        format!(" peer {}", sp.peer)
                    },
                    sp.t_start.as_secs_f64() * 1e3,
                    sp.t_end.as_secs_f64() * 1e3,
                ));
            }
            s
        }
    }
}

fn cmd_metrics(raw: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new(
        "metisfl metrics",
        "render a metrics registry snapshot in Prometheus text exposition format",
    )
    .opt(
        "addr",
        None,
        "scrape a live `observability.listen_addr` exposition listener (host:port)",
    )
    .opt("env", None, "env file: construct the controller and render its registry schema");
    let a = parse(&cmd, raw)?;
    if let Some(addr) = a.get("addr") {
        use std::io::{Read, Write};
        let mut stream = std::net::TcpStream::connect(addr)
            .map_err(|e| anyhow::anyhow!("connecting {addr}: {e}"))?;
        stream.write_all(b"GET /metrics HTTP/1.0\r\nConnection: close\r\n\r\n")?;
        let mut resp = String::new();
        stream.read_to_string(&mut resp)?;
        let body = resp.split_once("\r\n\r\n").map(|(_, b)| b).unwrap_or(resp.as_str());
        print!("{body}");
        return Ok(());
    }
    let env_file = a
        .get("env")
        .ok_or_else(|| anyhow::anyhow!("one of --addr <host:port> or --env <file> is required"))?;
    let env = FederationEnv::from_file(env_file)?;
    let controller = metisfl::controller::Controller::new(env, None)?;
    print!("{}", metisfl::obs::render_prometheus(&controller.counters().full_snapshot()));
    Ok(())
}

/// Metrics the CI perf gate tracks: (report name, column, lower-is-
/// better). Every row of the named report contributes a
/// `<report>/<row>/<column>` metric; which ones actually gate is
/// decided by what the committed baseline lists. Throughput columns are
/// higher-is-better (the gate fails on drops); wire-size ratios are
/// lower-is-better (the gate fails on *growth* — a codec regression
/// that re-inflates the wire). Timing columns are deliberately
/// excluded — quick-mode wall-clock on shared CI cores is too noisy
/// for a hard gate; throughput floors and deterministic size ratios
/// are not.
const GATED_METRICS: &[(&str, &str, bool)] = &[
    ("codec_ablation", "enc+dec MB/s", false),
    ("agg_ablation_axpy", "GB/s (best)", false),
    ("codec_ablation_wire", "wire frac of f32", true),
    // Straggler-spread ratio vs fixed-budget sync on the 10×-skew
    // fleet: lower is better; a ratio drifting toward 1.0 means the
    // pacing/quorum machinery stopped absorbing stragglers.
    ("sched_ablation", "spread frac of sync", true),
    // Root-tier ingest bytes under a 2-tier topology as a fraction of
    // the flat run's: lower is better; drifting toward 1.0 means the
    // aggregator tier stopped shielding the root (partial sums are no
    // longer replacing per-learner uploads).
    ("topo_ablation", "root ingest frac of flat", true),
    // Loadtest round/upload p99 latency floors: lower is better. An
    // exception to the no-timing rule above — p99 over the open-loop
    // run is far less noisy than a single wall-clock sample, and the
    // committed baseline leaves generous headroom for shared CI cores.
    ("loadtest", "p99_ms", true),
    // The same ceilings with span tracing on (`loadtest --quick
    // --spans`): the gate is what bounds the instrumentation overhead —
    // if spans cost more than the threshold over the spans-on baseline,
    // the observability plane got too expensive to leave enabled.
    ("loadtest_spans", "p99_ms", true),
    // Rounds to re-home a chaos-killed aggregator's shard and complete
    // a full round on the new topology: lower is better, and the
    // baseline's ceiling is the acceptance bar (a drift upward means
    // failover stopped recovering within the round budget).
    ("failover", "rounds_to_recover", true),
];

/// Is the named metric lower-is-better? (Direction travels with the
/// metric spec, not the baseline file, so a stale baseline cannot flip
/// a gate's meaning.)
fn metric_lower_is_better(key: &str) -> bool {
    GATED_METRICS
        .iter()
        .any(|(report, column, lower)| {
            *lower
                && key.starts_with(&format!("{report}/"))
                && key.ends_with(&format!("/{column}"))
        })
}

fn cmd_bench_check(raw: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new(
        "metisfl bench-check",
        "merge bench_out/*.json into one report and gate against a baseline",
    )
    .opt("dir", Some("bench_out"), "directory holding per-bench JSON reports")
    .opt("out", None, "write the merged BENCH_<sha>.json here")
    .opt("baseline", None, "BENCH_baseline.json to compare against (omit to skip the gate)")
    .opt("threshold", Some("0.25"), "max allowed fractional throughput drop");
    let a = parse(&cmd, raw)?;
    let dir = std::path::Path::new(a.get("dir").unwrap());
    let threshold = a.get_f64("threshold")?;

    // Merge every per-bench report and extract the gated metrics.
    use metisfl::json::{parse as jparse, to_string_pretty, Value};
    let mut reports: Vec<Value> = Vec::new();
    let mut metrics: std::collections::BTreeMap<String, Value> = Default::default();
    let mut entries: Vec<_> = std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", dir.display()))?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "json"))
        .collect();
    entries.sort();
    for path in entries {
        let v = jparse(&std::fs::read_to_string(&path)?)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        let (Some(name), Some(headers), Some(rows)) = (
            v.get("name").and_then(|x| x.as_str()).map(str::to_string),
            v.get("headers").and_then(|x| x.as_array()).map(|a| a.to_vec()),
            v.get("rows").and_then(|x| x.as_array()).map(|a| a.to_vec()),
        ) else {
            continue; // not a ReportWriter file
        };
        for (report, column, _lower) in GATED_METRICS {
            if name != *report {
                continue;
            }
            let Some(col) = headers.iter().position(|h| h.as_str() == Some(*column)) else {
                continue;
            };
            for row in &rows {
                let cells = row.as_array().unwrap_or(&[]);
                let (Some(label), Some(cell)) =
                    (cells.first().and_then(|c| c.as_str()), cells.get(col))
                else {
                    continue;
                };
                if let Some(value) = cell.as_str().and_then(|s| s.parse::<f64>().ok()) {
                    metrics.insert(format!("{name}/{label}/{column}"), value.into());
                }
            }
        }
        reports.push(v);
    }
    if reports.is_empty() {
        anyhow::bail!("no bench reports found under {}", dir.display());
    }
    let merged = Value::object(vec![
        ("schema", 1usize.into()),
        ("metrics", Value::Object(metrics.clone())),
        ("reports", Value::Array(reports)),
    ]);
    if let Some(out) = a.get("out") {
        std::fs::write(out, to_string_pretty(&merged))?;
        println!("wrote {out}");
    }

    // Gate: every baseline metric present in the current run must not
    // have moved against its direction by more than `threshold` —
    // throughput must not drop, wire-size ratios must not grow.
    let Some(baseline_path) = a.get("baseline") else {
        println!("no --baseline given; merged {} metrics without gating", metrics.len());
        return Ok(());
    };
    let baseline = jparse(&std::fs::read_to_string(baseline_path)?)
        .map_err(|e| anyhow::anyhow!("parsing {baseline_path}: {e}"))?;
    let empty: std::collections::BTreeMap<String, Value> = Default::default();
    let base_metrics = baseline
        .get("metrics")
        .and_then(|m| m.as_object())
        .unwrap_or(&empty);
    let mut regressions = Vec::new();
    let mut compared = 0usize;
    for (key, base) in base_metrics {
        let Some(base) = base.as_f64() else { continue };
        let Some(cur) = metrics.get(key).and_then(|v| v.as_f64()) else {
            println!("warning: baseline metric '{key}' missing from this run");
            continue;
        };
        compared += 1;
        let regressed = if metric_lower_is_better(key) {
            let ceiling = base * (1.0 + threshold);
            let verdict = if cur > ceiling { "REGRESSION" } else { "ok" };
            println!(
                "{verdict:>10}  {key}: baseline {base:.3}, current {cur:.3} \
                 (ceiling {ceiling:.3}, lower is better)"
            );
            cur > ceiling
        } else {
            let floor = base * (1.0 - threshold);
            let verdict = if cur < floor { "REGRESSION" } else { "ok" };
            println!(
                "{verdict:>10}  {key}: baseline {base:.2}, current {cur:.2} (floor {floor:.2})"
            );
            cur < floor
        };
        if regressed {
            regressions.push(key.clone());
        }
    }
    if compared == 0 {
        anyhow::bail!("baseline {baseline_path} shares no metrics with this run");
    }
    if !regressions.is_empty() {
        anyhow::bail!(
            "perf gate tripped >{:.0}% on {} metric(s): {} — if intentional, apply the \
             'perf-regression-ok' label (see .github/bench/README.md)",
            threshold * 100.0,
            regressions.len(),
            regressions.join(", ")
        );
    }
    println!("bench gate passed ({compared} metric(s) within {:.0}%)", threshold * 100.0);
    Ok(())
}

fn print_report(report: &metisfl::driver::FederationReport) {
    println!("\nfederation '{}' finished in {:?}", report.env_name, report.wall_clock);
    println!(
        "{:<7} {:>14} {:>14} {:>14} {:>14} {:>12}",
        "round", "train_disp", "train_round", "aggregation", "fed_round", "eval_loss"
    );
    for r in &report.round_metrics {
        println!(
            "{:<7} {:>14} {:>14} {:>14} {:>14} {:>12}",
            r.round,
            format!("{:?}", r.train_dispatch),
            format!("{:?}", r.train_round),
            format!("{:?}", r.aggregation),
            format!("{:?}", r.federation_round),
            r.community_eval_loss.map(|l| format!("{l:.4}")).unwrap_or_else(|| "-".into()),
        );
    }
    if report.missed_heartbeats > 0 {
        println!("missed heartbeats: {}", report.missed_heartbeats);
    }
    if report.failovers > 0 {
        println!(
            "failovers: {} ({} learner(s) re-homed, recovered in {} round(s))",
            report.failovers, report.rehomed_learners, report.rounds_to_recover
        );
    }
}
