//! Secure-aggregation substrates (Table 1, "Privacy & Security").
//!
//! Two schemes, both *simulations* of the production mechanisms the
//! compared frameworks use (DESIGN.md §Substitutions):
//!
//! * [`masking`] — pairwise-PRG additive masking in the style of
//!   LightSecAgg (FedML) / Salvia (Flower): masks cancel in the sum, so
//!   the controller only ever sees masked individual updates.
//! * [`ckks`] — a mock of PALISADE's CKKS used by MetisFL: fixed-point
//!   encoding, additively homomorphic ciphertexts, approximation noise,
//!   and realistic ciphertext expansion (i64 per f32 + metadata).

pub mod ckks;
pub mod dp;
pub mod masking;

pub use ckks::{Ciphertext, CkksContext};
pub use dp::{privatize_update, DpConfig};
pub use masking::PairwiseMasker;
