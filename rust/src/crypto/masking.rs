//! Pairwise additive masking for secure aggregation.
//!
//! Learner `i` adds, for every other learner `j`, a pseudorandom vector
//! derived from the shared pair secret `s_ij`: with sign `+` if `i < j`
//! and `−` if `i > j`. Summed across all learners the masks cancel
//! exactly, so the controller can aggregate without seeing any individual
//! update in the clear. (Dropout recovery — LightSecAgg's actual
//! contribution — is out of scope; the federation drops the whole round
//! if a masked learner fails, which our failure-injection tests assert.)
//!
//! Masks are generated in i32 "ring" space and added to a fixed-point
//! encoding of the update so cancellation is *exact* (float masks would
//! leave rounding residue).

use sha2::{Digest, Sha256};

/// Fixed-point scale: f32 → i32 with ~6 decimal digits preserved.
const SCALE: f64 = (1u64 << 20) as f64;

/// Per-learner masking state for one round.
pub struct PairwiseMasker {
    pub learner_index: usize,
    pub total_learners: usize,
    pub round: u64,
    group_secret: [u8; 32],
}

impl PairwiseMasker {
    pub fn new(
        learner_index: usize,
        total_learners: usize,
        round: u64,
        group_secret: [u8; 32],
    ) -> Self {
        assert!(learner_index < total_learners);
        PairwiseMasker { learner_index, total_learners, round, group_secret }
    }

    /// The pair secret both endpoints derive identically.
    fn pair_seed(&self, a: usize, b: usize, chunk: u64) -> [u8; 32] {
        let (lo, hi) = if a < b { (a, b) } else { (b, a) };
        let mut h = Sha256::new();
        h.update(b"metisfl-pair-mask");
        h.update(self.group_secret);
        h.update((lo as u64).to_le_bytes());
        h.update((hi as u64).to_le_bytes());
        h.update(self.round.to_le_bytes());
        h.update(chunk.to_le_bytes());
        h.finalize().into()
    }

    /// PRG expansion of a pair seed into i32 mask words.
    fn expand(&self, other: usize, out: &mut [i64], sign: i64) {
        let mut chunk = 0u64;
        let mut filled = 0usize;
        while filled < out.len() {
            let block = self.pair_seed(self.learner_index, other, chunk);
            for w in block.chunks_exact(4) {
                if filled >= out.len() {
                    break;
                }
                let v = i32::from_le_bytes([w[0], w[1], w[2], w[3]]) as i64;
                out[filled] += sign * v;
                filled += 1;
            }
            chunk += 1;
        }
    }

    /// Encode `values` in fixed point and add this learner's net mask.
    /// Returns the masked i64 vector sent to the controller.
    pub fn mask(&self, values: &[f32]) -> Vec<i64> {
        let mut out: Vec<i64> =
            values.iter().map(|&v| (v as f64 * SCALE).round() as i64).collect();
        for j in 0..self.total_learners {
            if j == self.learner_index {
                continue;
            }
            let sign = if self.learner_index < j { 1 } else { -1 };
            self.expand(j, &mut out, sign);
        }
        out
    }

    /// Controller-side: sum masked vectors from **all** participating
    /// learners and decode. Panics if lengths mismatch.
    pub fn unmask_sum(masked: &[Vec<i64>]) -> Vec<f32> {
        assert!(!masked.is_empty());
        let n = masked[0].len();
        let mut acc = vec![0i64; n];
        for m in masked {
            assert_eq!(m.len(), n, "masked vector length mismatch");
            for (a, v) in acc.iter_mut().zip(m) {
                *a = a.wrapping_add(*v);
            }
        }
        acc.into_iter().map(|v| (v as f64 / SCALE) as f32).collect()
    }

    /// Fixed-point quantization error bound per element per learner.
    pub fn quantization_eps(num_learners: usize) -> f32 {
        (num_learners as f64 / SCALE) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;
    use crate::util::Rng;

    fn gen_updates(rng: &mut Rng, n_learners: usize, dim: usize) -> Vec<Vec<f32>> {
        (0..n_learners)
            .map(|_| (0..dim).map(|_| rng.next_gaussian() as f32).collect())
            .collect()
    }

    #[test]
    fn masks_cancel_in_the_sum() {
        let mut rng = Rng::new(10);
        let n = 5;
        let dim = 257;
        let updates = gen_updates(&mut rng, n, dim);
        let secret = [9u8; 32];
        let masked: Vec<Vec<i64>> = (0..n)
            .map(|i| PairwiseMasker::new(i, n, 3, secret).mask(&updates[i]))
            .collect();
        let sum = PairwiseMasker::unmask_sum(&masked);
        for d in 0..dim {
            let expect: f32 = updates.iter().map(|u| u[d]).sum();
            let eps = PairwiseMasker::quantization_eps(n) * 4.0 + 1e-4;
            assert!((sum[d] - expect).abs() <= eps, "d={d}: {} vs {expect}", sum[d]);
        }
    }

    #[test]
    fn individual_masked_updates_look_random() {
        let update = vec![0.0f32; 64]; // all-zero plaintext
        let masked = PairwiseMasker::new(0, 3, 0, [1u8; 32]).mask(&update);
        // A zero update must not produce a zero (or low-entropy) vector.
        let nonzero = masked.iter().filter(|&&v| v != 0).count();
        assert!(nonzero > 60, "only {nonzero} nonzero mask words");
    }

    #[test]
    fn different_rounds_produce_different_masks() {
        let update = vec![1.0f32; 32];
        let m0 = PairwiseMasker::new(0, 2, 0, [1u8; 32]).mask(&update);
        let m1 = PairwiseMasker::new(0, 2, 1, [1u8; 32]).mask(&update);
        assert_ne!(m0, m1);
    }

    #[test]
    fn missing_learner_breaks_unmasking() {
        let mut rng = Rng::new(11);
        let n = 4;
        let updates = gen_updates(&mut rng, n, 32);
        let secret = [2u8; 32];
        let masked: Vec<Vec<i64>> = (0..n - 1) // one learner dropped
            .map(|i| PairwiseMasker::new(i, n, 0, secret).mask(&updates[i]))
            .collect();
        let sum = PairwiseMasker::unmask_sum(&masked);
        let expect: f32 = updates[..n - 1].iter().map(|u| u[0]).sum();
        // Residual masks dominate; the "sum" must be garbage.
        assert!((sum[0] - expect).abs() > 1.0, "masks unexpectedly cancelled");
    }

    #[test]
    fn single_learner_is_identity_quantized() {
        let update = vec![1.5f32, -2.25, 0.0];
        let masked = PairwiseMasker::new(0, 1, 0, [0u8; 32]).mask(&update);
        let sum = PairwiseMasker::unmask_sum(&[masked]);
        for (a, b) in sum.iter().zip(&update) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn prop_cancellation_for_random_sizes() {
        prop_check("mask cancellation", 20, |g| {
            let n = g.usize_in(2..6);
            let dim = g.usize_in(1..100);
            let round = g.rng().next_u64() % 1000;
            let mut rng = Rng::new(g.rng().next_u64());
            let updates = gen_updates(&mut rng, n, dim);
            let secret = [g.rng().next_u64() as u8; 32];
            let masked: Vec<Vec<i64>> = (0..n)
                .map(|i| PairwiseMasker::new(i, n, round, secret).mask(&updates[i]))
                .collect();
            let sum = PairwiseMasker::unmask_sum(&masked);
            for d in 0..dim {
                let expect: f32 = updates.iter().map(|u| u[d]).sum();
                let eps = PairwiseMasker::quantization_eps(n) * 4.0 + 1e-3;
                assert!((sum[d] - expect).abs() <= eps);
            }
        });
    }
}
