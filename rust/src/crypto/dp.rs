//! Differential privacy for learner updates (Table 1, "Private
//! Training" — all compared frameworks support DP; here it is the
//! standard Gaussian mechanism applied learner-side before upload).
//!
//! Pipeline per update: clip the update delta to an L2 ball of radius
//! `clip_norm`, then add isotropic Gaussian noise with
//! `σ = noise_multiplier · clip_norm`. The ε accounting helper uses the
//! classic analytic bound for the Gaussian mechanism (Dwork & Roth,
//! Thm. A.1): one application is (ε, δ)-DP for
//! `σ ≥ clip · sqrt(2 ln(1.25/δ)) / ε`.

use crate::tensor::TensorModel;
use crate::util::Rng;

/// Gaussian-mechanism parameters.
#[derive(Debug, Clone, Copy)]
pub struct DpConfig {
    /// L2 clipping radius for the model *delta* (update − reference).
    pub clip_norm: f64,
    /// σ / clip_norm.
    pub noise_multiplier: f64,
}

impl DpConfig {
    /// ε for one release at a given δ (analytic Gaussian bound).
    pub fn epsilon(&self, delta: f64) -> f64 {
        assert!(delta > 0.0 && delta < 1.0);
        (2.0 * (1.25 / delta).ln()).sqrt() / self.noise_multiplier
    }

    /// Noise σ needed for (ε, δ)-DP with this clip norm.
    pub fn sigma_for(epsilon: f64, delta: f64, clip_norm: f64) -> f64 {
        clip_norm * (2.0 * (1.25 / delta).ln()).sqrt() / epsilon
    }
}

/// L2 norm of the delta `update − reference`.
pub fn delta_l2(update: &TensorModel, reference: &TensorModel) -> f64 {
    update
        .tensors
        .iter()
        .zip(&reference.tensors)
        .flat_map(|(u, r)| u.data.iter().zip(&r.data))
        .map(|(u, r)| {
            let d = (*u - *r) as f64;
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

/// Privatize a learner update in place: clip its delta from `reference`
/// to `cfg.clip_norm`, then add N(0, σ²) noise per element. Returns the
/// pre-clip delta norm (useful for telemetry/adaptive clipping).
pub fn privatize_update(
    update: &mut TensorModel,
    reference: &TensorModel,
    cfg: &DpConfig,
    rng: &mut Rng,
) -> f64 {
    let norm = delta_l2(update, reference);
    let scale = if norm > cfg.clip_norm { cfg.clip_norm / norm } else { 1.0 };
    let sigma = (cfg.noise_multiplier * cfg.clip_norm) as f32;
    for (ut, rt) in update.tensors.iter_mut().zip(&reference.tensors) {
        for (u, r) in ut.data.iter_mut().zip(&rt.data) {
            let clipped = r + (*u - r) * scale as f32;
            *u = clipped + sigma * rng.next_gaussian() as f32;
        }
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;
    use crate::util::prop::prop_check;

    fn models(seed: u64) -> (TensorModel, TensorModel) {
        let layout = ModelSpec::mlp(4, 2, 8).tensor_layout();
        let mut rng = Rng::new(seed);
        let reference = TensorModel::random_init(&layout, &mut rng);
        let update = TensorModel::random_init(&layout, &mut rng);
        (reference, update)
    }

    #[test]
    fn clipping_bounds_the_delta_norm() {
        let (reference, mut update) = models(1);
        let cfg = DpConfig { clip_norm: 0.5, noise_multiplier: 0.0 }; // no noise
        let pre = privatize_update(&mut update, &reference, &cfg, &mut Rng::new(2));
        assert!(pre > 0.5, "test premise: unclipped norm should exceed clip");
        let post = delta_l2(&update, &reference);
        assert!((post - 0.5).abs() < 1e-3, "post-clip norm {post}");
    }

    #[test]
    fn small_updates_pass_unclipped() {
        let (reference, _) = models(3);
        let mut update = reference.clone();
        update.tensors[0].data[0] += 0.01;
        let cfg = DpConfig { clip_norm: 10.0, noise_multiplier: 0.0 };
        privatize_update(&mut update, &reference, &cfg, &mut Rng::new(4));
        assert!((update.tensors[0].data[0] - reference.tensors[0].data[0] - 0.01).abs() < 1e-6);
    }

    #[test]
    fn noise_has_requested_scale() {
        let (reference, _) = models(5);
        let mut update = reference.clone(); // zero delta → pure noise out
        let cfg = DpConfig { clip_norm: 1.0, noise_multiplier: 0.1 };
        privatize_update(&mut update, &reference, &cfg, &mut Rng::new(6));
        let n = update.param_count() as f64;
        let var: f64 = update
            .tensors
            .iter()
            .zip(&reference.tensors)
            .flat_map(|(u, r)| u.data.iter().zip(&r.data))
            .map(|(u, r)| ((u - r) as f64).powi(2))
            .sum::<f64>()
            / n;
        let sigma = var.sqrt();
        assert!((sigma - 0.1).abs() < 0.02, "measured σ {sigma}");
    }

    #[test]
    fn epsilon_accounting_roundtrips() {
        let cfg = DpConfig { clip_norm: 1.0, noise_multiplier: 2.0 };
        let eps = cfg.epsilon(1e-5);
        let sigma = DpConfig::sigma_for(eps, 1e-5, 1.0);
        assert!((sigma - 2.0).abs() < 1e-9);
        // More noise → smaller ε.
        let tighter = DpConfig { clip_norm: 1.0, noise_multiplier: 4.0 };
        assert!(tighter.epsilon(1e-5) < eps);
    }

    #[test]
    fn prop_clip_invariant_any_radius() {
        prop_check("post-clip norm <= radius", 30, |g| {
            let (reference, mut update) = models(g.rng().next_u64());
            let clip = g.f64_in(0.01, 5.0);
            let cfg = DpConfig { clip_norm: clip, noise_multiplier: 0.0 };
            privatize_update(&mut update, &reference, &cfg, &mut Rng::new(1));
            let post = delta_l2(&update, &reference);
            assert!(post <= clip * 1.001 + 1e-6, "post {post} > clip {clip}");
        });
    }
}
