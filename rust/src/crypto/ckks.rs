//! Mock-CKKS additively homomorphic encryption (PALISADE substitute).
//!
//! Real CKKS encrypts fixed-point-encoded vectors under RLWE; ciphertexts
//! support addition and carry approximation noise. This mock preserves
//! exactly those *interface properties* on the aggregation path:
//!
//! * `encrypt` fixed-point-encodes f32 → i64 at scale 2^30, adds a
//!   keyed pseudorandom pad (per-ciphertext nonce) and small Gaussian
//!   noise (the CKKS approximation error),
//! * `add` is element-wise i64 addition with nonce-set union,
//! * `decrypt` re-derives and subtracts all pads, then rescales.
//!
//! Ciphertext expansion is 2× payload (i64 vs f32) plus nonce metadata,
//! in the same ballpark as CKKS's practical expansion for packed vectors.
//! **Not secure cryptography** — a benchmarking stand-in (DESIGN.md
//! §Substitutions).

use anyhow::{bail, Result};
use sha2::{Digest, Sha256};

const SCALE: f64 = (1u64 << 30) as f64;

/// Homomorphic context bound to a symmetric key.
#[derive(Clone)]
pub struct CkksContext {
    key: [u8; 32],
    /// Std-dev of injected approximation noise, in plaintext units.
    pub noise_std: f64,
}

/// An "encrypted" vector: padded fixed-point words + pad nonces.
#[derive(Debug, Clone, PartialEq)]
pub struct Ciphertext {
    pub nonces: Vec<u64>,
    pub data: Vec<i64>,
}

impl Ciphertext {
    /// Serialized size in bytes (payload + nonce metadata).
    pub fn byte_size(&self) -> usize {
        self.data.len() * 8 + self.nonces.len() * 8 + 16
    }
}

impl CkksContext {
    pub fn new(key: [u8; 32]) -> CkksContext {
        CkksContext { key, noise_std: 1e-6 }
    }

    fn pad_word(&self, nonce: u64, index: usize) -> i64 {
        // Keyed PRG: SHA-256(key ‖ nonce ‖ block)[lane] as i64 words.
        let block = index / 4;
        let lane = index % 4;
        let mut h = Sha256::new();
        h.update(b"metisfl-ckks-pad");
        h.update(self.key);
        h.update(nonce.to_le_bytes());
        h.update((block as u64).to_le_bytes());
        let d = h.finalize();
        let off = lane * 8;
        i64::from_le_bytes(d[off..off + 8].try_into().unwrap())
    }

    /// Encrypt a plaintext vector under a fresh `nonce`.
    pub fn encrypt(&self, values: &[f32], nonce: u64, rng: &mut crate::util::Rng) -> Ciphertext {
        let data = values
            .iter()
            .enumerate()
            .map(|(i, &v)| {
                let noise = rng.next_gaussian() * self.noise_std;
                let m = ((v as f64 + noise) * SCALE).round() as i64;
                m.wrapping_add(self.pad_word(nonce, i))
            })
            .collect();
        Ciphertext { nonces: vec![nonce], data }
    }

    /// Homomorphic addition (consumes neither side).
    pub fn add(&self, a: &Ciphertext, b: &Ciphertext) -> Result<Ciphertext> {
        if a.data.len() != b.data.len() {
            bail!("ciphertext length mismatch: {} vs {}", a.data.len(), b.data.len());
        }
        let mut nonces = a.nonces.clone();
        nonces.extend_from_slice(&b.nonces);
        let data = a
            .data
            .iter()
            .zip(&b.data)
            .map(|(x, y)| x.wrapping_add(*y))
            .collect();
        Ok(Ciphertext { nonces, data })
    }

    /// Sum many ciphertexts.
    pub fn sum(&self, cts: &[Ciphertext]) -> Result<Ciphertext> {
        let mut iter = cts.iter();
        let first = iter.next().ok_or_else(|| anyhow::anyhow!("empty ciphertext sum"))?;
        let mut acc = first.clone();
        for ct in iter {
            acc = self.add(&acc, ct)?;
        }
        Ok(acc)
    }

    /// Decrypt by stripping every pad recorded in `nonces`.
    pub fn decrypt(&self, ct: &Ciphertext) -> Vec<f32> {
        let mut out = Vec::with_capacity(ct.data.len());
        for (i, &w) in ct.data.iter().enumerate() {
            let mut m = w;
            for &n in &ct.nonces {
                m = m.wrapping_sub(self.pad_word(n, i));
            }
            out.push((m as f64 / SCALE) as f32);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn encrypt_decrypt_roundtrip() {
        let ctx = CkksContext::new([4u8; 32]);
        let mut rng = Rng::new(1);
        let pt = vec![1.5f32, -2.25, 0.0, 1e3];
        let ct = ctx.encrypt(&pt, 77, &mut rng);
        let back = ctx.decrypt(&ct);
        for (a, b) in pt.iter().zip(&back) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn ciphertext_hides_plaintext() {
        let ctx = CkksContext::new([4u8; 32]);
        let mut rng = Rng::new(2);
        let ct = ctx.encrypt(&[0.0f32; 64], 1, &mut rng);
        let nonzero = ct.data.iter().filter(|&&v| v != 0).count();
        assert!(nonzero > 60);
    }

    #[test]
    fn homomorphic_sum_matches_plain_sum() {
        let ctx = CkksContext::new([8u8; 32]);
        let mut rng = Rng::new(3);
        let pts: Vec<Vec<f32>> = (0..5)
            .map(|_| (0..33).map(|_| rng.next_gaussian() as f32).collect())
            .collect();
        let cts: Vec<Ciphertext> =
            pts.iter().enumerate().map(|(i, p)| ctx.encrypt(p, i as u64, &mut rng)).collect();
        let sum_ct = ctx.sum(&cts).unwrap();
        let sum = ctx.decrypt(&sum_ct);
        for d in 0..33 {
            let expect: f32 = pts.iter().map(|p| p[d]).sum();
            assert!((sum[d] - expect).abs() < 1e-2, "d={d}");
        }
    }

    #[test]
    fn wrong_key_decrypts_garbage() {
        let ctx = CkksContext::new([1u8; 32]);
        let other = CkksContext::new([2u8; 32]);
        let mut rng = Rng::new(4);
        let pt = vec![1.0f32; 16];
        let ct = ctx.encrypt(&pt, 9, &mut rng);
        let wrong = other.decrypt(&ct);
        let close = wrong.iter().zip(&pt).filter(|(a, b)| (**a - **b).abs() < 0.1).count();
        assert!(close < 4, "wrong key should not decrypt");
    }

    #[test]
    fn mismatched_lengths_rejected() {
        let ctx = CkksContext::new([0u8; 32]);
        let mut rng = Rng::new(5);
        let a = ctx.encrypt(&[1.0], 0, &mut rng);
        let b = ctx.encrypt(&[1.0, 2.0], 1, &mut rng);
        assert!(ctx.add(&a, &b).is_err());
        assert!(ctx.sum(&[]).is_err());
    }

    #[test]
    fn expansion_is_about_2x_payload() {
        let ctx = CkksContext::new([0u8; 32]);
        let mut rng = Rng::new(6);
        let ct = ctx.encrypt(&vec![0.5f32; 1000], 0, &mut rng);
        let plain_bytes = 1000 * 4;
        assert!(ct.byte_size() >= 2 * plain_bytes);
        assert!(ct.byte_size() < 3 * plain_bytes);
    }
}
