//! Observability plane: correlated span tracing ([`span`]) and metrics
//! exposition ([`expo`]).
//!
//! Counters/gauges/histograms live in [`crate::metrics`]; this module
//! is the layer that makes a *running federation* inspectable — spans
//! correlate distributed work into causal trees (carried across the
//! wire by `TaskMeta`'s trace-context tail), and the exposition path
//! renders live registry snapshots in Prometheus text format
//! (`metisfl metrics`, the `observability.listen_addr` side listener).

pub mod expo;
pub mod span;

pub use expo::{render_prometheus, ExpoServer};
pub use span::{assert_single_tree, ActiveSpan, Span, SpanCtx, SpanSink};
