//! Metrics exposition: Prometheus text rendering + a live side listener.
//!
//! [`render_prometheus`] turns a [`MetricsSnapshot`] into Prometheus
//! text format 0.0.4 (counters and gauges as single samples, histograms
//! as quantile summaries), which is what `metisfl metrics` prints and
//! what the optional [`ExpoServer`] serves live. The server is a
//! deliberately minimal HTTP/1.0 responder on `std::net` — one accept
//! loop, every request answered with a fresh snapshot, connection
//! closed — because the consumer is `curl`/Prometheus scraping a
//! long-running loadtest, not a web framework's worth of surface. It
//! is enabled by the `observability: {listen_addr: ...}` env block.

use crate::metrics::{MetricsRegistry, MetricsSnapshot};
use crate::util::logging::{log_info, log_warn};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Render a snapshot as Prometheus text format 0.0.4. Counter names
/// get a `metisfl_` prefix and a `_total` suffix (the exporter
/// convention for monotone series); histograms render as summaries
/// (`{quantile="..."}` samples + `_sum` + `_count`), in seconds.
pub fn render_prometheus(snap: &MetricsSnapshot) -> String {
    let mut out = String::new();
    for (name, v) in &snap.counters {
        out.push_str(&format!("# TYPE metisfl_{name}_total counter\n"));
        out.push_str(&format!("metisfl_{name}_total {v}\n"));
    }
    for (name, v) in &snap.gauges {
        out.push_str(&format!("# TYPE metisfl_{name} gauge\n"));
        out.push_str(&format!("metisfl_{name} {v}\n"));
    }
    for (name, h) in &snap.histograms {
        out.push_str(&format!("# TYPE metisfl_{name}_seconds summary\n"));
        for (label, q) in [("0.5", 0.5), ("0.99", 0.99), ("0.999", 0.999)] {
            if let Some(d) = h.quantile(q) {
                out.push_str(&format!(
                    "metisfl_{name}_seconds{{quantile=\"{label}\"}} {}\n",
                    d.as_secs_f64()
                ));
            }
        }
        out.push_str(&format!("metisfl_{name}_seconds_sum {}\n", h.total().as_secs_f64()));
        out.push_str(&format!("metisfl_{name}_seconds_count {}\n", h.count()));
    }
    out
}

/// Live metrics endpoint: serves the owning registry's current snapshot
/// to every HTTP request on `listen_addr`. Stop with
/// [`ExpoServer::stop`] (also called on drop).
pub struct ExpoServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl ExpoServer {
    /// Bind `listen_addr` (e.g. `127.0.0.1:9464`; port 0 picks a free
    /// one) and serve `registry` snapshots until stopped.
    pub fn serve(listen_addr: &str, registry: Arc<MetricsRegistry>) -> std::io::Result<ExpoServer> {
        let listener = TcpListener::bind(listen_addr)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("metisfl-expo".into())
            .spawn(move || {
                log_info("expo", &format!("serving metrics on http://{addr}/metrics"));
                for conn in listener.incoming() {
                    if stop2.load(Ordering::Acquire) {
                        break;
                    }
                    match conn {
                        Ok(stream) => {
                            if let Err(e) = respond(stream, &registry) {
                                log_warn("expo", &format!("scrape failed: {e}"));
                            }
                        }
                        Err(e) => log_warn("expo", &format!("accept failed: {e}")),
                    }
                }
            })?;
        Ok(ExpoServer { addr, stop, handle: Some(handle) })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Shut the listener down and join the accept thread.
    pub fn stop(&mut self) {
        if self.handle.is_none() {
            return;
        }
        self.stop.store(true, Ordering::Release);
        // Unblock the accept loop with a throwaway connection.
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_secs(1));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ExpoServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn respond(mut stream: TcpStream, registry: &MetricsRegistry) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    stream.set_write_timeout(Some(Duration::from_secs(2)))?;
    // Drain (and ignore) the request line + headers; any path serves
    // metrics. A scraper that sends nothing within the timeout is
    // answered anyway — the body is the whole protocol.
    let mut buf = [0u8; 1024];
    let _ = stream.read(&mut buf);
    let body = render_prometheus(&registry.full_snapshot());
    let header = format!(
        "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\nContent-Length: {}\r\n\r\n",
        body.len()
    );
    stream.write_all(header.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_metric_types() {
        let reg = MetricsRegistry::new();
        reg.counter("late_folds").add(3);
        reg.gauge("open_streams").set(2);
        reg.histogram("round").record(Duration::from_millis(250));
        let text = render_prometheus(&reg.full_snapshot());
        assert!(text.contains("metisfl_late_folds_total 3"));
        assert!(text.contains("metisfl_open_streams 2"));
        assert!(text.contains("metisfl_round_seconds{quantile=\"0.99\"}"));
        assert!(text.contains("metisfl_round_seconds_count 1"));
        // An empty histogram renders count 0 and no quantile samples.
        reg.histogram("empty");
        let text = render_prometheus(&reg.full_snapshot());
        assert!(text.contains("metisfl_empty_seconds_count 0"));
        assert!(!text.contains("metisfl_empty_seconds{"));
    }

    #[test]
    fn server_serves_live_snapshots_and_stops_cleanly() {
        let reg = MetricsRegistry::new();
        reg.counter("late_folds").add(7);
        let mut srv = ExpoServer::serve("127.0.0.1:0", Arc::clone(&reg)).unwrap();
        let addr = srv.addr();

        let scrape = |path: &str| -> String {
            let mut s = TcpStream::connect(addr).unwrap();
            write!(s, "GET {path} HTTP/1.0\r\nHost: x\r\n\r\n").unwrap();
            let _ = s.shutdown(std::net::Shutdown::Write);
            let mut out = String::new();
            s.read_to_string(&mut out).unwrap();
            out
        };

        let first = scrape("/metrics");
        assert!(first.starts_with("HTTP/1.0 200 OK"));
        assert!(first.contains("metisfl_late_folds_total 7"));

        // Live: a second scrape sees the updated value.
        reg.counter("late_folds").add(1);
        assert!(scrape("/").contains("metisfl_late_folds_total 8"));

        srv.stop();
        srv.stop(); // idempotent
    }
}
