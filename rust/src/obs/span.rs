//! Correlated span tracing: who caused what, across processes.
//!
//! End-of-run counter totals say *how much* happened; they cannot say
//! *why* a particular upload retried or which round a late fold belongs
//! to. Spans fill that gap: every interesting operation (dispatch,
//! ingest, aggregate, round barrier, shard fold, train, upload, retry
//! attempt) records a [`Span`] — an interval on the component's
//! [`Clock`] plus identity fields — into its component's [`SpanSink`].
//! Causality crosses the wire as a compact [`SpanCtx`] (`trace_id` +
//! parent `span_id`) riding `TaskMeta`'s tolerant trailing fields, so
//! one `trace_id` stitches root → aggregator → learner → retry →
//! late-fold into a single tree no matter how many processes the work
//! touched.
//!
//! Recording is built to be cheap enough to leave compiled in:
//!
//! * A disabled sink (the default) costs one relaxed atomic load per
//!   would-be span; no ids are allocated and nothing is stored.
//! * An enabled sink appends to one of a small fixed set of
//!   mutex-guarded rings selected by thread id, so concurrent writers
//!   (dispatch pool, ingest threads, arrival threads) rarely contend on
//!   the same lock. Rings are bounded: once full, the oldest span is
//!   overwritten and a drop counter bumps — tracing can never grow
//!   memory without bound on a long run.
//!
//! Span ids are deterministic per sink (a component-name hash in the
//! high bits, a sequence counter in the low bits), which keeps sim-run
//! traces reproducible and makes ids self-describing in dumps.

use crate::proto::wire::{fnv1a64, FNV64_INIT};
use crate::util::clock::{Clock, Timestamp};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Ring shards per sink. Writers pick one by thread id, so up to this
/// many threads record without touching the same mutex.
const SHARDS: usize = 8;

/// Default per-sink span capacity (across all shards).
const DEFAULT_CAP: usize = 65_536;

/// The wire-portable slice of a span: the correlation id of the whole
/// causal tree plus the immediate parent's span id. `trace_id == 0`
/// means "no trace context" (pre-span peers, disabled sinks).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SpanCtx {
    pub trace_id: u64,
    pub parent_span: u64,
}

impl SpanCtx {
    /// The absent context: roots a fresh trace when used with
    /// [`SpanSink::begin`].
    pub const UNSET: SpanCtx = SpanCtx { trace_id: 0, parent_span: 0 };

    pub fn is_set(&self) -> bool {
        self.trace_id != 0
    }
}

/// One completed operation interval, with enough identity to join it
/// back to rounds, tasks, and streams.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    pub trace_id: u64,
    pub span_id: u64,
    /// span_id of the causing span (0 = trace root).
    pub parent: u64,
    /// Operation name — a closed, code-defined vocabulary ("dispatch",
    /// "ingest", "train", "retry_attempt", ...).
    pub op: &'static str,
    /// The remote party involved, when there is one (learner id,
    /// aggregator id); empty otherwise.
    pub peer: String,
    pub round: u64,
    pub task_id: u64,
    pub stream_id: u64,
    pub t_start: Timestamp,
    pub t_end: Timestamp,
}

impl Span {
    pub fn ctx(&self) -> SpanCtx {
        SpanCtx { trace_id: self.trace_id, parent_span: self.span_id }
    }
}

#[derive(Default)]
struct Shard {
    spans: VecDeque<Span>,
}

/// Per-component span recorder. Cheap to consult when disabled; bounded
/// and shard-locked when enabled. Components create one at construction
/// (see `Controller::span_sink`, `Learner::span_sink`) and tests or the
/// harness enable + drain it.
pub struct SpanSink {
    component: String,
    clock: Clock,
    enabled: AtomicBool,
    /// High 32 bits of every span id this sink allocates.
    id_prefix: u64,
    seq: AtomicU64,
    shards: Vec<Mutex<Shard>>,
    cap_per_shard: usize,
    dropped: AtomicU64,
}

impl SpanSink {
    /// A sink for `component`, stamping intervals from `clock`.
    /// Starts disabled.
    pub fn new(component: impl Into<String>, clock: Clock) -> Arc<SpanSink> {
        let component = component.into();
        let id_prefix = (fnv1a64(FNV64_INIT, component.as_bytes()) & 0xFFFF_FFFF) << 32;
        Arc::new(SpanSink {
            component,
            clock,
            enabled: AtomicBool::new(false),
            id_prefix,
            seq: AtomicU64::new(1),
            shards: (0..SHARDS).map(|_| Mutex::new(Shard::default())).collect(),
            cap_per_shard: DEFAULT_CAP / SHARDS,
            dropped: AtomicU64::new(0),
        })
    }

    pub fn component(&self) -> &str {
        &self.component
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Release);
    }

    pub fn enable(&self) {
        self.set_enabled(true);
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Spans overwritten because a ring shard was full.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    fn next_id(&self) -> u64 {
        // Low 32 bits wrap within the component prefix; a sink would
        // need 4 billion spans in one run to collide.
        self.id_prefix | (self.seq.fetch_add(1, Ordering::Relaxed) & 0xFFFF_FFFF)
    }

    /// Open a span under `ctx` (a fresh trace root when `ctx` is
    /// unset). The span records itself on drop / [`ActiveSpan::end`].
    /// On a disabled sink this is inert and `ctx()` passes the incoming
    /// context through unchanged, so a spans-off component in the
    /// middle of a federation does not sever the tree.
    pub fn begin(self: &Arc<Self>, op: &'static str, ctx: SpanCtx) -> ActiveSpan {
        if !self.is_enabled() {
            return ActiveSpan { sink: None, span: None, passthrough: ctx };
        }
        let span_id = self.next_id();
        let trace_id = if ctx.is_set() { ctx.trace_id } else { span_id };
        let span = Span {
            trace_id,
            span_id,
            parent: ctx.parent_span,
            op,
            peer: String::new(),
            round: 0,
            task_id: 0,
            stream_id: 0,
            t_start: self.clock.now(),
            t_end: Timestamp::ZERO,
        };
        ActiveSpan { sink: Some(Arc::clone(self)), span: Some(span), passthrough: ctx }
    }

    fn record(&self, mut span: Span) {
        span.t_end = self.clock.now().max(span.t_start);
        let shard = thread_shard();
        let mut g = self.shards[shard].lock().unwrap();
        if g.spans.len() >= self.cap_per_shard {
            g.spans.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        g.spans.push_back(span);
    }

    /// Remove and return every recorded span, ordered by start time
    /// (then span id, for a stable order under simulated time's equal
    /// timestamps).
    pub fn drain(&self) -> Vec<Span> {
        let mut all: Vec<Span> = Vec::new();
        for shard in &self.shards {
            all.extend(shard.lock().unwrap().spans.drain(..));
        }
        all.sort_by_key(|s| (s.t_start, s.span_id));
        all
    }

    /// Non-destructive copy of every recorded span, same order as
    /// [`drain`](SpanSink::drain).
    pub fn snapshot(&self) -> Vec<Span> {
        let mut all = Vec::new();
        for shard in &self.shards {
            all.extend(shard.lock().unwrap().spans.iter().cloned());
        }
        all.sort_by_key(|s| (s.t_start, s.span_id));
        all
    }
}

impl std::fmt::Debug for SpanSink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SpanSink")
            .field("component", &self.component)
            .field("enabled", &self.is_enabled())
            .finish()
    }
}

fn thread_shard() -> usize {
    // Thread ids are unique per live thread; hashing the Debug repr
    // avoids the unstable `as_u64()` API.
    use std::hash::{Hash, Hasher};
    let mut h = std::collections::hash_map::DefaultHasher::new();
    std::thread::current().id().hash(&mut h);
    (h.finish() as usize) % SHARDS
}

/// An open span. Annotate it with builder-style setters, hand its
/// [`ctx`](ActiveSpan::ctx) to downstream work (locally or via
/// `TaskMeta`), and let it record on drop (or call
/// [`end`](ActiveSpan::end) to close it at a precise point).
pub struct ActiveSpan {
    sink: Option<Arc<SpanSink>>,
    span: Option<Span>,
    /// Incoming context, forwarded verbatim when the sink is disabled.
    passthrough: SpanCtx,
}

impl ActiveSpan {
    /// The context downstream spans should parent under.
    pub fn ctx(&self) -> SpanCtx {
        match &self.span {
            Some(s) => s.ctx(),
            None => self.passthrough,
        }
    }

    pub fn peer(mut self, peer: &str) -> ActiveSpan {
        if let Some(s) = self.span.as_mut() {
            s.peer = peer.to_string();
        }
        self
    }

    pub fn round(mut self, round: u64) -> ActiveSpan {
        if let Some(s) = self.span.as_mut() {
            s.round = round;
        }
        self
    }

    pub fn task(mut self, task_id: u64) -> ActiveSpan {
        if let Some(s) = self.span.as_mut() {
            s.task_id = task_id;
        }
        self
    }

    pub fn stream(mut self, stream_id: u64) -> ActiveSpan {
        if let Some(s) = self.span.as_mut() {
            s.stream_id = stream_id;
        }
        self
    }

    /// Close and record the span now.
    pub fn end(self) {}
}

impl Drop for ActiveSpan {
    fn drop(&mut self) {
        if let (Some(sink), Some(span)) = (self.sink.take(), self.span.take()) {
            sink.record(span);
        }
    }
}

/// Check that `spans` form a single connected tree: exactly one root
/// (parent absent from the set), every other span's parent present, and
/// every span sharing one trace id. Returns the root's span_id.
/// Test/tooling helper — this is the acceptance predicate for
/// cross-process correlation.
pub fn assert_single_tree(spans: &[Span]) -> Result<u64, String> {
    if spans.is_empty() {
        return Err("no spans recorded".into());
    }
    let trace = spans[0].trace_id;
    if let Some(s) = spans.iter().find(|s| s.trace_id != trace) {
        return Err(format!(
            "multiple traces: {trace:#x} and {:#x} (span '{}' from '{}')",
            s.trace_id, s.op, s.peer
        ));
    }
    let ids: std::collections::HashSet<u64> = spans.iter().map(|s| s.span_id).collect();
    if ids.len() != spans.len() {
        return Err("duplicate span ids".into());
    }
    let roots: Vec<&Span> = spans.iter().filter(|s| !ids.contains(&s.parent)).collect();
    match roots.as_slice() {
        [root] => Ok(root.span_id),
        [] => Err("no root span (parent cycle?)".into()),
        many => Err(format!(
            "{} disconnected roots: {:?}",
            many.len(),
            many.iter().map(|s| s.op).collect::<Vec<_>>()
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn disabled_sink_records_nothing_and_passes_ctx_through() {
        let sink = SpanSink::new("test", Clock::system());
        let incoming = SpanCtx { trace_id: 9, parent_span: 4 };
        let sp = sink.begin("op", incoming);
        assert_eq!(sp.ctx(), incoming);
        sp.end();
        assert!(sink.drain().is_empty());
    }

    #[test]
    fn enabled_sink_roots_traces_and_parents_children() {
        let sink = SpanSink::new("test", Clock::system());
        sink.enable();
        let root = sink.begin("root", SpanCtx::UNSET).round(3);
        let root_ctx = root.ctx();
        assert!(root_ctx.is_set());
        let child = sink.begin("child", root_ctx).peer("l1").task(7);
        let child_ctx = child.ctx();
        assert_eq!(child_ctx.trace_id, root_ctx.trace_id);
        child.end();
        root.end();
        let spans = sink.drain();
        assert_eq!(spans.len(), 2);
        assert_single_tree(&spans).unwrap();
        let child_span = spans.iter().find(|s| s.op == "child").unwrap();
        assert_eq!(child_span.parent, root_ctx.parent_span);
        assert_eq!(child_span.peer, "l1");
        assert_eq!(child_span.task_id, 7);
        assert!(sink.drain().is_empty(), "drain must consume");
    }

    #[test]
    fn span_intervals_follow_the_sim_clock() {
        let clock = Clock::sim();
        let sink = SpanSink::new("test", clock.clone());
        sink.enable();
        clock.advance_to(Duration::from_secs(10));
        let sp = sink.begin("op", SpanCtx::UNSET);
        clock.advance_to(Duration::from_secs(12));
        sp.end();
        let spans = sink.drain();
        assert_eq!(spans[0].t_start, Duration::from_secs(10));
        assert_eq!(spans[0].t_end, Duration::from_secs(12));
    }

    #[test]
    fn ring_is_bounded_and_counts_drops() {
        let sink = SpanSink::new("test", Clock::system());
        sink.enable();
        for _ in 0..(DEFAULT_CAP / SHARDS) + 10 {
            sink.begin("op", SpanCtx::UNSET).end();
        }
        // Single-threaded: every span landed in one shard.
        assert_eq!(sink.dropped(), 10);
        assert_eq!(sink.snapshot().len(), DEFAULT_CAP / SHARDS);
    }

    #[test]
    fn span_ids_carry_the_component_prefix() {
        let a = SpanSink::new("controller", Clock::system());
        let b = SpanSink::new("learner/l1", Clock::system());
        a.enable();
        b.enable();
        a.begin("op", SpanCtx::UNSET).end();
        b.begin("op", SpanCtx::UNSET).end();
        let (sa, sb) = (a.drain(), b.drain());
        assert_ne!(sa[0].span_id >> 32, sb[0].span_id >> 32);
        assert_eq!(sa[0].span_id & 0xFFFF_FFFF, sb[0].span_id & 0xFFFF_FFFF);
    }

    #[test]
    fn single_tree_rejects_forests_and_mixed_traces() {
        let mk = |trace_id, span_id, parent| Span {
            trace_id,
            span_id,
            parent,
            op: "op",
            peer: String::new(),
            round: 0,
            task_id: 0,
            stream_id: 0,
            t_start: Timestamp::ZERO,
            t_end: Timestamp::ZERO,
        };
        assert!(assert_single_tree(&[mk(1, 10, 0), mk(1, 11, 10)]).is_ok());
        assert!(assert_single_tree(&[mk(1, 10, 0), mk(1, 11, 99)]).is_err());
        assert!(assert_single_tree(&[mk(1, 10, 0), mk(2, 11, 10)]).is_err());
        assert!(assert_single_tree(&[]).is_err());
    }
}
