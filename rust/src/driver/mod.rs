//! The federation driver (paper App. B, Fig. 8).
//!
//! Lifecycle: **Initialization** — start the controller, start and
//! register the learners, ship the initial model state (tensors only to
//! the controller; the learners get model + recipe); **Monitoring** —
//! periodic heartbeats to every process; **Shutdown** — learners first,
//! then the controller.
//!
//! Two deployments, matching the paper's Deployment rows:
//! [`run_simulated`] (in-process transport) and [`run_distributed`]
//! (framed TCP on localhost).

use crate::config::{FederationEnv, Protocol, SecureSpec, TopologySpec, TrainerKind, TransportKind};
use crate::controller::health::{FailureDetector, PeerStatus};
use crate::controller::hierarchy::{AggregatorNode, AggregatorServicer};
use crate::controller::{scheduling, Controller};
use crate::harness::loadtest::model_digest;
use crate::learner::{Dataset, Learner, LearnerServicer, SyntheticTrainer, Trainer};
use crate::metrics::{OpMetrics, RoundReport};
use crate::net::{Psk, ServerHandle};
use crate::proto::client;
use crate::tensor::TensorModel;
use crate::util::{log_info, log_warn, Clock, Rng, Stopwatch};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Final outcome of a federation run.
#[derive(Debug, Clone)]
pub struct FederationReport {
    pub env_name: String,
    pub round_metrics: Vec<RoundReport>,
    pub op_metrics: OpMetrics,
    /// Community eval loss of the last evaluated round.
    pub final_loss: Option<f64>,
    pub wall_clock: Duration,
    /// Heartbeat probes that failed during monitoring.
    pub missed_heartbeats: u64,
    /// Controller high-water mark of wire-payload bytes held during
    /// model ingest (see [`Controller::peak_wire_ingest_bytes`]): with
    /// one-shot uploads this grows with learners × model size, with the
    /// streaming data plane it is bounded by chunk × in-flight streams.
    pub peak_wire_ingest_bytes: usize,
    /// The data-plane chunk size senders actually used: 0 when the run
    /// was one-shot, otherwise `stream_chunk_bytes` clamped up to the
    /// sender floor (sub-floor configs are clamped silently on the wire
    /// but surfaced here, plus a one-time warning at env-load time).
    pub effective_stream_chunk_bytes: usize,
    /// Stream payload bytes that actually crossed the controller's wire
    /// (dispatch egress + upload ingress), in encoded form. 0 for
    /// one-shot runs (the gauges cover the streamed data plane).
    pub wire_bytes_sent: u64,
    /// f32-equivalent bytes the wire codecs kept *off* the wire:
    /// `raw volume - wire_bytes_sent`. Divide by rounds for the
    /// compression ablation's bytes-per-round rows.
    pub wire_bytes_saved: u64,
    /// Encoded stream bytes the (root) controller *received* over its
    /// upload ingest. Deterministic for a fixed env + seed, so the
    /// topology ablation gates on the 2-tier/flat ratio of this total:
    /// a root behind aggregators ingests O(aggregators) partial sums
    /// instead of O(learners) uploads.
    pub wire_ingest_bytes: u64,
    /// Inbound streams the controller refused at admission (open-slot
    /// cap or aggregate ingest budget) — graceful-degradation evidence
    /// that overload sheds load instead of wedging.
    pub streams_refused: u64,
    /// Streams reclaimed by the idle/lifetime GC (disconnected or
    /// slow-loris peers whose buffers were released).
    pub streams_gced: u64,
    /// Operations abandoned after the unified retry policy exhausted
    /// its attempts: learner upload give-ups plus controller
    /// single-target dispatch give-ups.
    pub retry_give_ups: u64,
    /// Delta→f32 fallback sends (both directions): streams restarted at
    /// full precision because the peer lost the negotiated delta base.
    pub fallback_sends: u64,
    /// FNV-1a digest over the final community model's exact f32 bits
    /// (0 when no community model exists). Two runs that must be
    /// bitwise identical — e.g. a flat fleet vs the same fleet behind
    /// aggregators — compare equal here.
    pub community_digest: u64,
    /// Aggregator failovers the driver executed mid-run: shard owners
    /// the failure detector declared dead whose learners were re-homed
    /// onto survivors. 0 for flat runs and kills never scheduled.
    pub failovers: u64,
    /// Learners re-homed onto surviving aggregators across all
    /// failovers.
    pub rehomed_learners: u64,
    /// Rounds from the kill round (inclusive) to the first round every
    /// surviving aggregator completed — the recovery metric the CI
    /// bench gate bounds, lower is better. 0 when no failover ran.
    pub rounds_to_recover: u64,
    /// One-call snapshot of the run's [`CounterRegistry`] set: the
    /// controller's registry with every learner's merged in, keyed by
    /// [`crate::metrics::counters::names`]. The scalar degradation
    /// fields above are views into the same counters, kept as the
    /// stable report surface; this map is what the trace recorder
    /// embeds and the replay gate compares wholesale.
    ///
    /// [`CounterRegistry`]: crate::metrics::counters::CounterRegistry
    pub counters: BTreeMap<String, u64>,
}

/// Unique per-process run counter so in-proc endpoint names never clash
/// across concurrent tests.
fn next_run_id() -> u64 {
    static RUN: AtomicU64 = AtomicU64::new(0);
    RUN.fetch_add(1, Ordering::SeqCst)
}

/// Build one trainer per learner index from the env. Synthetic fleets
/// honor the heterogeneity profile: learner `i` runs at `step_time_us ×
/// speed_factors[i % len]` with the configured jitter/dropout, each
/// instance seeded independently (and deterministically) from the env
/// seed.
fn trainers_for(env: &FederationEnv) -> Result<Vec<Arc<dyn Trainer>>> {
    match &env.trainer {
        TrainerKind::Synthetic { step_time_us, hetero } => Ok((0..env.learners)
            .map(|i| {
                Arc::new(SyntheticTrainer::for_fleet(*step_time_us, hetero, env.seed, i))
                    as Arc<dyn Trainer>
            })
            .collect()),
        TrainerKind::Xla { artifacts_dir } => {
            let t: Arc<dyn Trainer> =
                Arc::new(crate::runtime::XlaTrainer::load(artifacts_dir, &env.model)?);
            Ok((0..env.learners).map(|_| Arc::clone(&t)).collect())
        }
    }
}

/// The deterministic initial community model every deployment of `env`
/// starts from. Exported so reference computations (tests, benches) can
/// reproduce a run's exact starting bits without driving a federation.
pub fn initial_model(env: &FederationEnv) -> TensorModel {
    let mut init_rng = Rng::new(env.seed ^ 0x5EED_0F_0E715); // "metis" seed salt
    TensorModel::random_init(&env.model.tensor_layout(), &mut init_rng)
}

/// The deterministic dataset of learner `index` under `env` — the same
/// bits whether the learner sits behind an aggregator or talks to the
/// controller directly. Replays the driver's shared seed sequence, so
/// learner `i`'s data is independent of which other learners exist.
pub fn learner_dataset(env: &FederationEnv, index: usize) -> Dataset {
    let mut data_rng = Rng::new(env.seed);
    let mut seed = 0u64;
    for i in 0..=index {
        seed = data_rng.split(i as u64).next_u64();
    }
    Dataset::synthetic_housing(
        env.model.input_dim,
        env.samples_per_learner,
        env.samples_per_learner, // paper: same 100 samples for test
        seed,
    )
}

/// Heartbeat monitor over every component endpoint. Dropped via
/// [`Monitor::stop`] at shutdown.
struct Monitor {
    stop: Arc<AtomicBool>,
    missed: Arc<AtomicU64>,
    handle: std::thread::JoinHandle<()>,
}

impl Monitor {
    fn spawn(endpoints: Vec<String>, period: Duration, psk: Psk) -> Monitor {
        let stop = Arc::new(AtomicBool::new(false));
        let missed = Arc::new(AtomicU64::new(0));
        let handle = {
            let stop = Arc::clone(&stop);
            let missed = Arc::clone(&missed);
            std::thread::Builder::new()
                .name("metisfl-monitor".into())
                .spawn(move || {
                    while !stop.load(Ordering::SeqCst) {
                        for ep in &endpoints {
                            if stop.load(Ordering::SeqCst) {
                                return;
                            }
                            let healthy = crate::net::connect(ep, psk)
                                .map_err(client::RpcError::Transport)
                                .and_then(|mut c| client::heartbeat(c.as_mut(), "driver"))
                                .map(|(_, healthy)| healthy)
                                .unwrap_or(false);
                            if !healthy {
                                missed.fetch_add(1, Ordering::SeqCst);
                                log_warn("driver", &format!("heartbeat missed for {ep}"));
                            }
                        }
                        // Sleep in short slices so shutdown is prompt even
                        // with long heartbeat periods.
                        let sw = Stopwatch::start();
                        while sw.elapsed() < period && !stop.load(Ordering::SeqCst) {
                            Clock::system().sleep(Duration::from_millis(10).min(period));
                        }
                    }
                })
                .expect("spawn monitor")
        };
        Monitor { stop, missed, handle }
    }

    fn stop(self) -> u64 {
        self.stop.store(true, Ordering::SeqCst);
        let _ = self.handle.join();
        self.missed.load(Ordering::SeqCst)
    }
}

/// Run a simulated (in-process) federation with the env's trainer.
pub fn run_simulated(env: &FederationEnv) -> Result<FederationReport> {
    let trainers = trainers_for(env)?;
    run_with_trainer(env, |idx| Arc::clone(&trainers[idx]))
}

/// Run a distributed (localhost TCP) federation with the env's trainer.
pub fn run_distributed(env: &FederationEnv) -> Result<FederationReport> {
    let mut env = env.clone();
    if !matches!(env.transport, TransportKind::Tcp { .. }) {
        env.transport = TransportKind::Tcp { base_port: 0 };
    }
    let trainers = trainers_for(&env)?;
    run_with_trainer(&env, |idx| Arc::clone(&trainers[idx]))
}

/// Run the env's federation and also record the (root) controller's
/// deterministic trace (`metisfl driver --record`). Recording starts
/// before the first registration frame and seals right after the last
/// round, so chaos and failover wire events — a dead aggregator's
/// deregistration, the re-homed shard's refreshed weights — are part of
/// the replayable timeline.
pub fn run_recorded(env: &FederationEnv) -> Result<(FederationReport, Option<Vec<u8>>)> {
    let trainers = trainers_for(env)?;
    run_federation(env, |idx| Arc::clone(&trainers[idx]), true)
}

/// Core driver: run a federation with a caller-supplied trainer factory
/// (one call per learner index).
pub fn run_with_trainer(
    env: &FederationEnv,
    make_trainer: impl Fn(usize) -> Arc<dyn Trainer>,
) -> Result<FederationReport> {
    run_federation(env, make_trainer, false).map(|(report, _)| report)
}

fn run_federation(
    env: &FederationEnv,
    make_trainer: impl Fn(usize) -> Arc<dyn Trainer>,
    record: bool,
) -> Result<(FederationReport, Option<Vec<u8>>)> {
    env.validate()?;
    if env.secure != SecureSpec::None {
        bail!(
            "secure aggregation runs through the crypto API \
             (see examples/secure_aggregation.rs and DESIGN.md §Substitutions)"
        );
    }
    if !env.topology.is_flat() {
        return run_two_tier(env, make_trainer, record);
    }
    let run = next_run_id();
    let sw = Stopwatch::start();
    let psk: Psk = None;

    // --- Initialization (Fig. 8) --------------------------------------
    let controller = Controller::new(env.clone(), psk)?;
    // Route log timestamps through the run's clock (system here, but
    // the seam keeps driver logs and sim-clock harness logs uniform).
    crate::util::logging::set_clock(controller.clock().clone());
    if env.observability.spans {
        controller.span_sink().enable();
    }
    let mut expo = start_expo(env, &controller)?;
    if record {
        // Before serving: registrations are part of the recorded
        // timeline.
        controller.start_recording();
    }
    let (ctrl_endpoint, _ctrl_server) = serve_component(
        env,
        &format!("ctrl-{run}"),
        0,
        Arc::clone(&controller) as Arc<dyn crate::net::Service>,
        psk,
    )?;
    log_info("driver", &format!("controller up at {ctrl_endpoint}"));

    let mut learner_servers: Vec<Box<dyn ServerHandle>> = Vec::new();
    let mut learners: Vec<Arc<Learner>> = Vec::new();
    let mut learner_endpoints: Vec<String> = Vec::new();
    // Deterministic chaos assignment: the same env + seed always
    // afflicts the same learner indices with the same faults.
    let chaos_plans = env.chaos.plan_fleet(env.learners, env.seed);
    let mut expected_registrations = env.learners;
    for i in 0..env.learners {
        let learner = Learner::new(
            &format!("learner-{i}"),
            &ctrl_endpoint,
            psk,
            make_trainer(i),
            learner_dataset(env, i),
        );
        learner.set_stream_chunk(env.effective_stream_chunk());
        learner.set_upload_codec(env.upload_codec());
        learner.set_delta_fallback(env.delta_fallback);
        if env.observability.spans {
            learner.span_sink().enable();
        }
        let (ep, server) = serve_component(
            env,
            &format!("learner-{run}-{i}"),
            (i + 1) as u16,
            Arc::new(LearnerServicer(Arc::clone(&learner))) as Arc<dyn crate::net::Service>,
            psk,
        )?;
        let plan = &chaos_plans[i];
        if !plan.is_noop() {
            learner.set_chaos(plan.clone());
        }
        if plan.refuse_dial {
            // Every dial from this learner is chaos-refused: it can
            // never register, so the fleet the controller waits for
            // shrinks by one (quorum decides whether rounds survive).
            expected_registrations -= 1;
            log_warn(
                "driver",
                &format!("learner-{i}: chaos refuses its dials; running unregistered"),
            );
        } else {
            learner.register(&ep).with_context(|| format!("registering learner-{i}"))?;
            if !plan.is_noop() {
                // The same faults afflict the dispatch direction of the
                // link, with an independent budget (a shared one would
                // let upload traffic spend the dispatch sever budget).
                controller.set_dispatch_chaos(&format!("learner-{i}"), plan.fresh());
            }
        }
        learner_endpoints.push(ep);
        learner_servers.push(server);
        learners.push(learner);
    }
    controller.wait_for_learners(expected_registrations, Duration::from_secs(30))?;

    // Ship the initial model state (tensors only — Fig. 8).
    controller.ship_model(initial_model(env));

    // --- Monitoring: heartbeat thread ----------------------------------
    let monitor = Monitor::spawn(
        std::iter::once(ctrl_endpoint.clone()).chain(learner_endpoints.iter().cloned()).collect(),
        Duration::from_millis(env.heartbeat_ms),
        psk,
    );

    // --- Federated training --------------------------------------------
    let mut round_rng = Rng::new(env.seed ^ 0xD157);
    let round_metrics: Vec<RoundReport> = match env.protocol {
        Protocol::Asynchronous { .. } => {
            scheduling::run_async_session(&controller, env.rounds, &mut round_rng)?
        }
        _ => {
            let mut reports = Vec::with_capacity(env.rounds);
            for round in 1..=env.rounds as u64 {
                let report = scheduling::run_round(&controller, round, &mut round_rng)?;
                log_info(
                    "driver",
                    &format!(
                        "round {round}/{}: fed_round={:?} agg={:?} loss={:?}",
                        env.rounds,
                        report.federation_round,
                        report.aggregation,
                        report.community_eval_loss
                    ),
                );
                reports.push(report);
            }
            reports
        }
    };

    // Seal the trace before any shutdown traffic: Shutdown frames are
    // not part of the replayable timeline.
    let trace = if record { controller.finish_recording() } else { None };

    // --- Shutdown: learners first, then controller (Fig. 8) ------------
    let missed_heartbeats = monitor.stop();
    for ep in &learner_endpoints {
        if let Ok(mut c) = crate::net::connect(ep, psk) {
            let _ = client::shutdown(c.as_mut());
        }
    }
    if let Ok(mut c) = crate::net::connect(&ctrl_endpoint, psk) {
        let _ = client::shutdown(c.as_mut());
    }
    for mut s in learner_servers {
        s.shutdown();
    }
    if let Some(e) = expo.as_mut() {
        e.stop();
    }

    let final_loss = round_metrics.iter().rev().find_map(|r| r.community_eval_loss);
    let (wire_sent, wire_raw) = controller.wire_bytes_totals();
    let learner_give_ups: u64 = learners.iter().map(|l| l.retry_give_ups()).sum();
    let learner_fallbacks: u64 = learners.iter().map(|l| l.fallback_sends()).sum();
    let mut counters = controller.counters().snapshot();
    for l in &learners {
        l.counters().merge_into(&mut counters);
    }
    Ok((
        FederationReport {
            env_name: env.name.clone(),
            round_metrics,
            op_metrics: controller.metrics(),
            final_loss,
            wall_clock: sw.elapsed(),
            missed_heartbeats,
            peak_wire_ingest_bytes: controller.peak_wire_ingest_bytes(),
            effective_stream_chunk_bytes: env.effective_stream_chunk(),
            wire_bytes_sent: wire_sent,
            wire_bytes_saved: wire_raw.saturating_sub(wire_sent),
            wire_ingest_bytes: controller.ingest().recv_wire_bytes(),
            retry_give_ups: controller.retry_give_ups() + learner_give_ups,
            fallback_sends: controller.fallback_sends() + learner_fallbacks,
            streams_refused: controller.ingest().streams_refused(),
            streams_gced: controller.ingest().streams_gced(),
            community_digest: controller.community().map(|(m, _)| model_digest(&m)).unwrap_or(0),
            failovers: 0,
            rehomed_learners: 0,
            rounds_to_recover: 0,
            counters,
        },
        trace,
    ))
}

/// Two-tier run: root controller ← aggregator shard owners ← learners.
///
/// Learners register with (and upload to) their shard's aggregator; each
/// round the root opens a barrier over the aggregators, every aggregator
/// runs a full local round on its shard (dispatch, quorum, fold) and
/// forwards exactly one weighted partial sum upstream. The root then folds
/// `aggregators` partials instead of `learners` uploads, so its peak wire
/// ingest is bounded by O(chunk × aggregators).
fn run_two_tier(
    env: &FederationEnv,
    make_trainer: impl Fn(usize) -> Arc<dyn Trainer>,
    record: bool,
) -> Result<(FederationReport, Option<Vec<u8>>)> {
    let topo = &env.topology;
    if matches!(env.protocol, Protocol::Asynchronous { .. }) {
        bail!("topology.aggregators > 1 requires a synchronous or semi-synchronous protocol");
    }
    if topo.aggregators > env.learners {
        bail!(
            "topology.aggregators ({}) exceeds the learner fleet ({})",
            topo.aggregators,
            env.learners
        );
    }
    let run = next_run_id();
    let sw = Stopwatch::start();
    let psk: Psk = None;

    // --- Root controller: sees only the aggregator tier ---------------
    let mut root_env = env.clone();
    root_env.learners = topo.aggregators;
    root_env.topology = TopologySpec::default();
    let controller = Controller::new(root_env, psk)?;
    crate::util::logging::set_clock(controller.clock().clone());
    if env.observability.spans {
        controller.span_sink().enable();
    }
    // The side listener serves the ROOT's registry; shard registries are
    // folded into the final report's counter snapshot instead.
    let mut expo = start_expo(env, &controller)?;
    if record {
        // Before serving: the aggregator tier's registrations (and a
        // failover's re-registrations) are part of the recorded
        // timeline.
        controller.start_recording();
    }
    let (ctrl_endpoint, ctrl_server) = serve_component(
        env,
        &format!("ctrl-{run}"),
        0,
        Arc::clone(&controller) as Arc<dyn crate::net::Service>,
        psk,
    )?;
    log_info(
        "driver",
        &format!(
            "two-tier root at {ctrl_endpoint} ({} aggregators over {} learners)",
            topo.aggregators, env.learners
        ),
    );

    // --- Aggregator tier ----------------------------------------------
    let mut shard_sizes = vec![0usize; topo.aggregators];
    for i in 0..env.learners {
        shard_sizes[topo.shard_of(i)] += 1;
    }
    let mut agg_nodes: Vec<Arc<AggregatorNode>> = Vec::new();
    let mut agg_endpoints: Vec<String> = Vec::new();
    let mut agg_servers: Vec<Box<dyn ServerHandle>> = Vec::new();
    for s in 0..topo.aggregators {
        let node =
            AggregatorNode::new(&format!("agg-{s}"), &ctrl_endpoint, env, shard_sizes[s], psk)?;
        if env.observability.spans {
            node.inner().span_sink().enable();
        }
        let (ep, server) = serve_component(
            env,
            &format!("agg-{run}-{s}"),
            (s + 1) as u16,
            Arc::new(AggregatorServicer(Arc::clone(&node))) as Arc<dyn crate::net::Service>,
            psk,
        )?;
        agg_endpoints.push(ep);
        agg_servers.push(server);
        agg_nodes.push(node);
    }

    // --- Learner fleet: each learner dials its shard's aggregator ------
    let mut learner_servers: Vec<Box<dyn ServerHandle>> = Vec::new();
    let mut learners: Vec<Arc<Learner>> = Vec::new();
    let mut learner_endpoints: Vec<String> = Vec::new();
    let chaos_plans = env.chaos.plan_fleet(env.learners, env.seed);
    let mut expected_per_shard = shard_sizes.clone();
    for i in 0..env.learners {
        let shard = topo.shard_of(i);
        let learner = Learner::new(
            &format!("learner-{i}"),
            &agg_endpoints[shard],
            psk,
            make_trainer(i),
            learner_dataset(env, i),
        );
        learner.set_stream_chunk(env.effective_stream_chunk());
        learner.set_upload_codec(env.upload_codec());
        learner.set_delta_fallback(env.delta_fallback);
        if env.observability.spans {
            learner.span_sink().enable();
        }
        let (ep, server) = serve_component(
            env,
            &format!("learner-{run}-{i}"),
            (topo.aggregators + 1 + i) as u16,
            Arc::new(LearnerServicer(Arc::clone(&learner))) as Arc<dyn crate::net::Service>,
            psk,
        )?;
        let plan = &chaos_plans[i];
        if !plan.is_noop() {
            learner.set_chaos(plan.clone());
        }
        if plan.refuse_dial {
            expected_per_shard[shard] -= 1;
            log_warn(
                "driver",
                &format!("learner-{i}: chaos refuses its dials; running unregistered"),
            );
        } else {
            learner.register(&ep).with_context(|| format!("registering learner-{i}"))?;
            if !plan.is_noop() {
                agg_nodes[shard].inner().set_dispatch_chaos(&format!("learner-{i}"), plan.fresh());
            }
        }
        learner_endpoints.push(ep);
        learner_servers.push(server);
        learners.push(learner);
    }

    // Topology-aware registration barrier: each aggregator first waits
    // for its own shard, then announces itself (with the shard's total
    // sample count as its weight) to the root, which in turn waits for
    // the full aggregator tier.
    for s in 0..topo.aggregators {
        agg_nodes[s]
            .inner()
            .wait_for_learners(expected_per_shard[s], Duration::from_secs(30))
            .with_context(|| format!("shard {s} registration barrier"))?;
        agg_nodes[s]
            .register(&agg_endpoints[s], expected_per_shard[s] * env.samples_per_learner)
            .with_context(|| format!("registering agg-{s} upstream"))?;
    }
    controller.wait_for_learners(topo.aggregators, Duration::from_secs(30))?;

    controller.ship_model(initial_model(env));

    let monitor = Monitor::spawn(
        std::iter::once(ctrl_endpoint.clone())
            .chain(agg_endpoints.iter().cloned())
            .chain(learner_endpoints.iter().cloned())
            .collect(),
        Duration::from_millis(env.heartbeat_ms),
        psk,
    );

    // --- Federated training over the tree ------------------------------
    // Chaos kill plan: the env may schedule one aggregator's crash-stop
    // at the top of a round. The same env + seed always selects the
    // same victim; failover re-homes its orphaned shard onto the
    // survivors before that round runs.
    let kill_round = env.chaos.kill_aggregator_at_round;
    let victim = env.chaos.kill_victim(topo.aggregators, env.seed);
    let mut shard_of: Vec<usize> = (0..env.learners).map(|i| topo.shard_of(i)).collect();
    let mut live_aggregators = topo.aggregators;
    let mut failovers = 0u64;
    let mut rehomed_learners = 0u64;
    let mut rounds_to_recover = 0u64;
    let mut round_rng = Rng::new(env.seed ^ 0xD157);
    let mut round_metrics = Vec::with_capacity(env.rounds);
    for round in 1..=env.rounds as u64 {
        if let Some(v) = victim.filter(|_| round == kill_round) {
            // --- Failover: kill, detect, re-home ------------------------
            let victim_id = format!("agg-{v}");
            log_warn("driver", &format!("chaos: crash-stopping {victim_id} at round {round}"));
            agg_nodes[v].kill();

            // Detect the death through the probe path, not by fiat: the
            // detector sees only misses once the node crash-stops, and
            // declares Dead after `dead_after` of them.
            let detector = FailureDetector::new(env.health, controller.clock().clone());
            while detector.status(&victim_id) != PeerStatus::Dead {
                let outcome = crate::net::connect(&agg_endpoints[v], psk)
                    .map_err(client::RpcError::Transport)
                    .and_then(|mut c| client::heartbeat_probe(c.as_mut(), "driver"));
                match outcome {
                    Ok((_, healthy, _)) => detector.observe_ack(&victim_id, healthy),
                    Err(_) => detector.observe_miss(&victim_id),
                }
                controller.clock().sleep(env.health.interval());
            }
            log_warn("driver", &format!("{victim_id} declared dead; re-homing its shard"));

            // Root-side removal goes over the wire so a recorded trace
            // replays the failover exactly.
            {
                let mut c = crate::net::connect(&ctrl_endpoint, psk)?;
                client::deregister(c.as_mut(), &victim_id)
                    .map_err(|e| anyhow::anyhow!("deregistering {victim_id} at root: {e}"))?;
            }

            // Re-home the orphaned shard round-robin over the survivors
            // (both sides in index order, so tests can reconstruct the
            // exact plan for the bitwise reference fold). Re-homing
            // drops each learner's delta base: the first dispatch from
            // the new aggregator degrades to full f32 and re-seeds it.
            let orphans: Vec<usize> = (0..env.learners).filter(|&i| shard_of[i] == v).collect();
            let survivors: Vec<usize> = (0..topo.aggregators).filter(|&s| s != v).collect();
            let plan = crate::controller::hierarchy::rehome_assignments(
                orphans.len(),
                survivors.len(),
            );
            for (j, &i) in orphans.iter().enumerate() {
                let target = survivors[plan[j]];
                learners[i].rehome(&agg_endpoints[target]);
                learners[i]
                    .register(&learner_endpoints[i])
                    .with_context(|| format!("re-homing learner-{i} onto agg-{target}"))?;
                shard_of[i] = target;
            }
            rehomed_learners += orphans.len() as u64;

            // Refresh every survivor's upstream registration so the
            // root's sample weights match the new shard memberships
            // (Deregister + Register — the graceful re-target path).
            for &s in &survivors {
                let members = shard_of.iter().filter(|&&x| x == s).count();
                agg_nodes[s].deregister().with_context(|| format!("re-targeting agg-{s}"))?;
                agg_nodes[s]
                    .register(&agg_endpoints[s], members * env.samples_per_learner)
                    .with_context(|| format!("re-registering agg-{s} upstream"))?;
            }
            live_aggregators = survivors.len();
            failovers += 1;
        }
        let report = scheduling::run_round(&controller, round, &mut round_rng)?;
        log_info(
            "driver",
            &format!(
                "round {round}/{}: fed_round={:?} agg={:?} loss={:?} (two-tier)",
                env.rounds, report.federation_round, report.aggregation, report.community_eval_loss
            ),
        );
        if failovers > 0 && rounds_to_recover == 0 && report.completed == live_aggregators {
            // First fully-reported round at the new topology; the count
            // includes the kill round itself.
            rounds_to_recover = round - kill_round + 1;
        }
        round_metrics.push(report);
    }

    // Seal the trace before any shutdown traffic: Shutdown frames are
    // not part of the replayable timeline.
    let trace = if record { controller.finish_recording() } else { None };

    // --- Shutdown: learners, then aggregators, then root ---------------
    let missed_heartbeats = monitor.stop();
    for ep in learner_endpoints.iter().chain(agg_endpoints.iter()) {
        if let Ok(mut c) = crate::net::connect(ep, psk) {
            let _ = client::shutdown(c.as_mut());
        }
    }
    if let Ok(mut c) = crate::net::connect(&ctrl_endpoint, psk) {
        let _ = client::shutdown(c.as_mut());
    }
    for mut s in learner_servers.into_iter().chain(agg_servers) {
        s.shutdown();
    }
    if let Some(e) = expo.as_mut() {
        e.stop();
    }
    drop(ctrl_server);

    let final_loss = round_metrics.iter().rev().find_map(|r| r.community_eval_loss);
    let (wire_sent, wire_raw) = controller.wire_bytes_totals();
    let learner_give_ups: u64 = learners.iter().map(|l| l.retry_give_ups()).sum();
    let learner_fallbacks: u64 = learners.iter().map(|l| l.fallback_sends()).sum();
    let agg_give_ups: u64 = agg_nodes.iter().map(|n| n.retry_give_ups()).sum();
    let agg_fallbacks: u64 = agg_nodes.iter().map(|n| n.fallback_sends()).sum();
    let mut counters = controller.counters().snapshot();
    for n in &agg_nodes {
        n.inner().counters().merge_into(&mut counters);
    }
    for l in &learners {
        l.counters().merge_into(&mut counters);
    }
    Ok((
        FederationReport {
            env_name: env.name.clone(),
            round_metrics,
            op_metrics: controller.metrics(),
            final_loss,
            wall_clock: sw.elapsed(),
            missed_heartbeats,
            // Root-tier counters only: the acceptance criterion is that
            // the ROOT's ingest stays O(chunk × aggregators) however
            // large the learner fleet grows.
            peak_wire_ingest_bytes: controller.peak_wire_ingest_bytes(),
            effective_stream_chunk_bytes: env.effective_stream_chunk(),
            wire_bytes_sent: wire_sent,
            wire_bytes_saved: wire_raw.saturating_sub(wire_sent),
            wire_ingest_bytes: controller.ingest().recv_wire_bytes(),
            retry_give_ups: controller.retry_give_ups() + agg_give_ups + learner_give_ups,
            fallback_sends: controller.fallback_sends() + agg_fallbacks + learner_fallbacks,
            streams_refused: controller.ingest().streams_refused(),
            streams_gced: controller.ingest().streams_gced(),
            community_digest: controller.community().map(|(m, _)| model_digest(&m)).unwrap_or(0),
            failovers,
            rehomed_learners,
            rounds_to_recover,
            counters,
        },
        trace,
    ))
}

/// Start the env's optional live metrics listener over the (root)
/// controller's registry. `observability.listen_addr: ""` (the default)
/// keeps the plane fully off — no socket, no thread.
fn start_expo(
    env: &FederationEnv,
    controller: &Arc<Controller>,
) -> Result<Option<crate::obs::ExpoServer>> {
    if env.observability.listen_addr.is_empty() {
        return Ok(None);
    }
    let server = crate::obs::ExpoServer::serve(
        &env.observability.listen_addr,
        Arc::clone(controller.counters()),
    )
    .map_err(|e| {
        anyhow::anyhow!("observability listener {}: {e}", env.observability.listen_addr)
    })?;
    log_info(
        "driver",
        &format!(
            "metrics exposition at http://{}/metrics (`metisfl metrics --addr {0}`)",
            server.addr()
        ),
    );
    Ok(Some(server))
}

/// Serve a component on the env's transport; returns (endpoint, handle).
fn serve_component(
    env: &FederationEnv,
    inproc_name: &str,
    port_offset: u16,
    svc: Arc<dyn crate::net::Service>,
    psk: Psk,
) -> Result<(String, Box<dyn ServerHandle>)> {
    match env.transport {
        TransportKind::InProc => {
            let ep = format!("inproc://{inproc_name}");
            let server = crate::net::serve(&ep, svc, psk)?;
            Ok((ep, server))
        }
        TransportKind::Tcp { base_port } => {
            let port = if base_port == 0 { 0 } else { base_port + port_offset };
            let server = crate::net::serve(&format!("tcp://127.0.0.1:{port}"), svc, psk)?;
            let ep = server.endpoint();
            Ok((ep, server))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;

    fn small_env(name: &str) -> FederationEnv {
        FederationEnv::builder(name)
            .learners(3)
            .rounds(2)
            .model(ModelSpec::mlp(4, 2, 8))
            .samples_per_learner(20)
            .batch_size(10)
            .heartbeat_ms(50)
            .build()
    }

    #[test]
    fn simulated_sync_federation_completes() {
        let report = run_simulated(&small_env("sim-sync")).unwrap();
        assert_eq!(report.round_metrics.len(), 2);
        for r in &report.round_metrics {
            assert_eq!(r.participants, 3);
            assert_eq!(r.completed, 3);
            assert!(r.community_eval_loss.unwrap().is_finite());
            assert!(r.federation_round >= r.aggregation);
        }
        assert!(report.final_loss.is_some());
    }

    #[test]
    fn distributed_tcp_federation_completes() {
        let report = run_distributed(&small_env("sim-tcp")).unwrap();
        assert_eq!(report.round_metrics.len(), 2);
        assert_eq!(report.round_metrics[0].completed, 3);
    }

    #[test]
    fn semi_sync_protocol_runs() {
        let mut env = small_env("sim-semisync");
        env.protocol = Protocol::SemiSynchronous { lambda: 2.0 };
        let report = run_simulated(&env).unwrap();
        assert_eq!(report.round_metrics.len(), 2);
        assert_eq!(report.round_metrics[0].completed, 3);
    }

    #[test]
    fn async_protocol_runs() {
        let mut env = small_env("sim-async");
        env.protocol = Protocol::Asynchronous { staleness_alpha: 0.5 };
        env.rounds = 2;
        let report = run_simulated(&env).unwrap();
        assert_eq!(report.round_metrics.len(), 2);
    }

    #[test]
    fn secure_env_is_rejected_with_pointer_to_example() {
        let mut env = small_env("sim-secure");
        env.secure = SecureSpec::Masking;
        let err = format!("{:#}", run_simulated(&env).unwrap_err());
        assert!(err.contains("secure_aggregation"), "{err}");
    }

    #[test]
    fn rust_sgd_federation_loss_decreases() {
        let mut env = small_env("sim-sgd");
        env.rounds = 6;
        env.learning_rate = 0.02;
        let report = run_with_trainer(&env, |_| Arc::new(crate::learner::trainer::RustSgdTrainer))
            .unwrap();
        let first = report.round_metrics.first().unwrap().community_eval_loss.unwrap();
        let last = report.round_metrics.last().unwrap().community_eval_loss.unwrap();
        assert!(
            last < first,
            "federated training failed to reduce loss: {first} -> {last}"
        );
    }
}
