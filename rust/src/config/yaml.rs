//! Indentation-based YAML-subset parser.
//!
//! Supports the subset used by MetisFL environment files:
//!
//! * nested mappings via 2+ space indentation,
//! * block lists (`- item`, including `- key: value` object items),
//! * inline scalars: strings (bare or quoted), ints, floats, bools, null,
//! * `#` comments and blank lines.
//!
//! Anchors, multi-line strings, flow collections, and tags are not
//! supported (and not used by our config files).

use crate::json::Value;
use std::collections::BTreeMap;

#[derive(Debug)]
pub struct YamlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for YamlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "yaml parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for YamlError {}

struct Line {
    indent: usize,
    text: String,
    num: usize,
}

/// Parse a YAML-subset document into a JSON value tree.
pub fn parse(src: &str) -> Result<Value, YamlError> {
    let lines: Vec<Line> = src
        .lines()
        .enumerate()
        .filter_map(|(i, raw)| {
            let no_comment = strip_comment(raw);
            let trimmed = no_comment.trim_end();
            if trimmed.trim().is_empty() {
                return None;
            }
            let indent = trimmed.len() - trimmed.trim_start().len();
            Some(Line { indent, text: trimmed.trim_start().to_string(), num: i + 1 })
        })
        .collect();
    if lines.is_empty() {
        return Ok(Value::Object(BTreeMap::new()));
    }
    let mut pos = 0;
    let v = parse_block(&lines, &mut pos, lines[0].indent)?;
    if pos != lines.len() {
        return Err(YamlError {
            line: lines[pos].num,
            msg: "unexpected dedent/indent structure".into(),
        });
    }
    Ok(v)
}

fn strip_comment(raw: &str) -> String {
    let mut out = String::new();
    let mut in_squote = false;
    let mut in_dquote = false;
    for c in raw.chars() {
        match c {
            '\'' if !in_dquote => in_squote = !in_squote,
            '"' if !in_squote => in_dquote = !in_dquote,
            '#' if !in_squote && !in_dquote => break,
            _ => {}
        }
        out.push(c);
    }
    out
}

fn parse_block(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Value, YamlError> {
    if lines[*pos].text.starts_with("- ") || lines[*pos].text == "-" {
        parse_list(lines, pos, indent)
    } else {
        parse_map(lines, pos, indent)
    }
}

fn parse_map(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Value, YamlError> {
    let mut map = BTreeMap::new();
    while *pos < lines.len() {
        let line = &lines[*pos];
        if line.indent < indent {
            break;
        }
        if line.indent > indent {
            return Err(YamlError { line: line.num, msg: "unexpected indent".into() });
        }
        let (key, rest) = split_key(line)?;
        *pos += 1;
        let value = if rest.is_empty() {
            // Nested block (map or list) or empty value.
            if *pos < lines.len() && lines[*pos].indent > indent {
                let child_indent = lines[*pos].indent;
                parse_block(lines, pos, child_indent)?
            } else {
                Value::Null
            }
        } else {
            scalar(rest)
        };
        map.insert(key, value);
    }
    Ok(Value::Object(map))
}

fn parse_list(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Value, YamlError> {
    let mut items = Vec::new();
    while *pos < lines.len() {
        let line = &lines[*pos];
        if line.indent != indent || !(line.text.starts_with("- ") || line.text == "-") {
            if line.indent >= indent && !line.text.starts_with('-') {
                break;
            }
            if line.indent < indent {
                break;
            }
            return Err(YamlError { line: line.num, msg: "malformed list item".into() });
        }
        let body = line.text.strip_prefix('-').unwrap().trim_start().to_string();
        if body.is_empty() {
            *pos += 1;
            if *pos < lines.len() && lines[*pos].indent > indent {
                let child = lines[*pos].indent;
                items.push(parse_block(lines, pos, child)?);
            } else {
                items.push(Value::Null);
            }
        } else if body.contains(": ") || body.ends_with(':') {
            // `- key: value` starts an inline object item; subsequent
            // more-indented lines extend it.
            let virtual_line = Line { indent: indent + 2, text: body, num: line.num };
            let mut sub: Vec<Line> = vec![virtual_line];
            *pos += 1;
            while *pos < lines.len() && lines[*pos].indent >= indent + 2 {
                sub.push(Line {
                    indent: lines[*pos].indent,
                    text: lines[*pos].text.clone(),
                    num: lines[*pos].num,
                });
                *pos += 1;
            }
            let mut sub_pos = 0;
            let obj = parse_map(&sub, &mut sub_pos, indent + 2)?;
            items.push(obj);
        } else {
            items.push(scalar(&body));
            *pos += 1;
        }
    }
    Ok(Value::Array(items))
}

fn split_key(line: &Line) -> Result<(String, &str), YamlError> {
    let text = &line.text;
    let idx = text
        .find(':')
        .ok_or_else(|| YamlError { line: line.num, msg: format!("expected 'key:' in '{text}'") })?;
    let key = text[..idx].trim();
    if key.is_empty() {
        return Err(YamlError { line: line.num, msg: "empty key".into() });
    }
    let key = unquote(key);
    Ok((key, text[idx + 1..].trim()))
}

fn unquote(s: &str) -> String {
    let b = s.as_bytes();
    if b.len() >= 2
        && ((b[0] == b'"' && b[b.len() - 1] == b'"') || (b[0] == b'\'' && b[b.len() - 1] == b'\''))
    {
        s[1..s.len() - 1].to_string()
    } else {
        s.to_string()
    }
}

/// Interpret a scalar token (types inferred like YAML 1.2 core schema).
fn scalar(s: &str) -> Value {
    let t = s.trim();
    match t {
        "null" | "~" | "" => return Value::Null,
        "true" | "True" => return Value::Bool(true),
        "false" | "False" => return Value::Bool(false),
        _ => {}
    }
    let bytes = t.as_bytes();
    if bytes[0] == b'"' || bytes[0] == b'\'' {
        return Value::String(unquote(t));
    }
    if let Ok(n) = t.parse::<f64>() {
        // Reject things like "1.2.3" (parse::<f64> would fail anyway) and
        // leading-plus oddities are fine.
        return Value::Number(n);
    }
    // Inline flow list of scalars: [a, b, c]
    if t.starts_with('[') && t.ends_with(']') {
        let inner = &t[1..t.len() - 1];
        if inner.trim().is_empty() {
            return Value::Array(vec![]);
        }
        return Value::Array(inner.split(',').map(|p| scalar(p.trim())).collect());
    }
    Value::String(t.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flat_mapping() {
        let v = parse("name: demo\nlearners: 10\nlr: 0.01\nsecure: false\n").unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("demo"));
        assert_eq!(v.get("learners").unwrap().as_usize(), Some(10));
        assert_eq!(v.get("lr").unwrap().as_f64(), Some(0.01));
        assert_eq!(v.get("secure").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn parses_nested_mapping() {
        let src = "model:\n  hidden_layers: 100\n  hidden_units: 32\nrounds: 3\n";
        let v = parse(src).unwrap();
        assert_eq!(v.get("model").unwrap().get("hidden_layers").unwrap().as_usize(), Some(100));
        assert_eq!(v.get("rounds").unwrap().as_usize(), Some(3));
    }

    #[test]
    fn parses_lists() {
        let src = "hosts:\n  - alpha\n  - beta\nsizes: [1, 2, 3]\n";
        let v = parse(src).unwrap();
        let hosts = v.get("hosts").unwrap().as_array().unwrap();
        assert_eq!(hosts.len(), 2);
        assert_eq!(hosts[0].as_str(), Some("alpha"));
        let sizes = v.get("sizes").unwrap().as_array().unwrap();
        assert_eq!(sizes.iter().filter_map(|x| x.as_usize()).collect::<Vec<_>>(), vec![1, 2, 3]);
    }

    #[test]
    fn parses_object_list_items() {
        let src = "learners:\n  - host: a\n    port: 1\n  - host: b\n    port: 2\n";
        let v = parse(src).unwrap();
        let ls = v.get("learners").unwrap().as_array().unwrap();
        assert_eq!(ls.len(), 2);
        assert_eq!(ls[0].get("host").unwrap().as_str(), Some("a"));
        assert_eq!(ls[1].get("port").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let src = "# header\n\na: 1  # trailing\n# mid\nb: 'x # not comment'\n";
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("b").unwrap().as_str(), Some("x # not comment"));
    }

    #[test]
    fn quoted_strings_preserve_type() {
        let v = parse("a: \"123\"\nb: 123\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_str(), Some("123"));
        assert_eq!(v.get("b").unwrap().as_usize(), Some(123));
    }

    #[test]
    fn empty_doc_is_empty_object() {
        assert_eq!(parse("").unwrap(), Value::Object(Default::default()));
        assert_eq!(parse("# only comments\n").unwrap(), Value::Object(Default::default()));
    }

    #[test]
    fn error_has_line_number() {
        let e = parse("a: 1\n   bogus line without colon\n").unwrap_err();
        assert_eq!(e.line, 2);
    }
}
