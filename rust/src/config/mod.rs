//! Federated-environment configuration.
//!
//! The paper drives an FL workflow from a "federated environment" YAML
//! file plus a model/data recipe (§3, Fig. 3). This module supplies:
//!
//! * [`yaml`] — an indentation-based YAML-subset parser (offline build:
//!   no serde_yaml) producing [`crate::json::Value`] trees,
//! * [`env`] — the typed [`FederationEnv`] with a builder and
//!   YAML/JSON loaders, and [`ModelSpec`] describing the paper's
//!   HousingMLP variants (100k / 1M / 10M parameters).

pub mod env;
pub mod yaml;

pub use env::{
    AggregationBackend, AggregationSpec, FederationEnv, FederationEnvBuilder, HeteroFleetSpec,
    ModelSpec, ObservabilitySpec, Protocol, SecureSpec, SelectorSpec, TopologySpec, TrainerKind,
    TransportKind, WireCodecChoice,
};
