//! Typed federation environment (the paper's YAML env + model recipe).

use crate::controller::health::HealthSpec;
use crate::json::Value;
use crate::net::chaos::ChaosSpec;
use crate::tensor::CodecId;
use anyhow::{bail, Context, Result};

/// Data-plane wire codec selection (`wire_codec` env field). The
/// concrete per-path codecs are resolved by
/// [`FederationEnv::dispatch_codec`] / [`FederationEnv::upload_codec`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WireCodecChoice {
    /// Pick the best lossless codec the deployment supports: delta when
    /// the data plane streams (the stream establishes the shared base),
    /// plain f32 otherwise. Never picks a lossy codec.
    #[default]
    Auto,
    /// Always tensor-as-bytes f32 (the §3 baseline).
    F32,
    /// Half-precision bf16 on uploads (and on dispatch too when
    /// `bf16_dispatch` is set). Lossy — bounded-error, not bitwise.
    Bf16,
    /// XOR-delta against the last acknowledged community model, falling
    /// back to full f32 when no base is shared (see `delta_fallback`).
    Delta,
    /// Entropy-coded delta: the XOR residual is byte-shuffled and
    /// zero-run-length encoded per chunk (lossless, with a raw escape so
    /// adversarial payloads never expand past f32 + a small header).
    /// Same base/fallback semantics as `delta`.
    DeltaRle,
}

impl WireCodecChoice {
    pub fn name(self) -> &'static str {
        match self {
            WireCodecChoice::Auto => "auto",
            WireCodecChoice::F32 => "f32",
            WireCodecChoice::Bf16 => "bf16",
            WireCodecChoice::Delta => "delta",
            WireCodecChoice::DeltaRle => "delta-rle",
        }
    }

    pub fn parse(s: &str) -> Result<WireCodecChoice> {
        Ok(match s {
            "auto" => WireCodecChoice::Auto,
            "f32" => WireCodecChoice::F32,
            "bf16" => WireCodecChoice::Bf16,
            "delta" => WireCodecChoice::Delta,
            "delta-rle" | "delta_rle" => WireCodecChoice::DeltaRle,
            other => bail!("unknown wire codec '{other}' (auto|f32|bf16|delta|delta-rle)"),
        })
    }
}

/// Hierarchical aggregation topology (`topology:` env block). The
/// default is the flat (single-tier) topology every earlier release
/// ran: all learners speak to the root controller directly. With
/// `aggregators > 0` the driver interposes that many aggregator nodes
/// between the root and the fleet: learners are assigned round-robin
/// by index (shard `i` owns learners `i, i+A, i+2A, …` — see
/// [`TopologySpec::shard_of`]), each aggregator folds its shard's
/// arrivals locally, and the root ingests one partial sum per shard.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TopologySpec {
    /// Number of intermediate aggregator nodes; 0 (default) = flat.
    pub aggregators: usize,
    /// Shard-local quorum fraction in (0, 1], or 0.0 (default) to
    /// inherit the env's `quorum_fraction`. Each aggregator closes its
    /// shard barrier at `ceil(q × shard_dispatched)` arrivals, which
    /// rolls up to the root's own quorum over shards.
    pub shard_quorum: f64,
}

impl Default for TopologySpec {
    fn default() -> TopologySpec {
        TopologySpec { aggregators: 0, shard_quorum: 0.0 }
    }
}

impl TopologySpec {
    /// Single-tier topology (no aggregators interposed)?
    pub fn is_flat(&self) -> bool {
        self.aggregators == 0
    }

    /// Shard owning learner `index`: round-robin over aggregators, so
    /// fleet heterogeneity (speed factors cycle by index) spreads
    /// across shards instead of concentrating in one.
    pub fn shard_of(&self, index: usize) -> usize {
        if self.aggregators == 0 {
            0
        } else {
            index % self.aggregators
        }
    }

    /// Effective shard-local quorum: the explicit `shard_quorum` when
    /// set, else the env-wide `quorum_fraction`.
    pub fn effective_shard_quorum(&self, env_quorum: f64) -> f64 {
        if self.shard_quorum > 0.0 {
            self.shard_quorum
        } else {
            env_quorum
        }
    }
}

/// Observability plane (`observability:` block): live Prometheus-text
/// metrics exposition and causal span tracing. Both default off — the
/// hot path pays only a relaxed atomic load per would-be span.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct ObservabilitySpec {
    /// Bind address for the metrics side listener (e.g.
    /// `127.0.0.1:9464`); empty (default) = no listener. The driver
    /// serves the controller's registry as Prometheus text format on
    /// `GET /metrics` (see [`crate::obs::ExpoServer`]).
    pub listen_addr: String,
    /// Record causal spans on every component's
    /// [`crate::obs::SpanSink`] (controller, aggregators, learners).
    pub spans: bool,
}

/// Communication/aggregation protocol (Table 1, "Communication Protocol").
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Protocol {
    /// Classic FedAvg rounds: all selected learners train, controller
    /// aggregates when every update has arrived.
    Synchronous,
    /// Semi-synchronous (Stripelis et al. 2022b): learners train for a
    /// fixed wall-clock budget `lambda` (here: a per-round step budget
    /// scaler) and the controller aggregates whatever arrived.
    SemiSynchronous { lambda: f64 },
    /// Asynchronous: the controller updates the community model on every
    /// learner completion, discounted by staleness^(-alpha) mixing.
    Asynchronous { staleness_alpha: f64 },
}

/// Which implementation performs tensor aggregation on the controller.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AggregationBackend {
    /// One thread, tensor after tensor (paper's "MetisFL gRPC" line).
    Sequential,
    /// One pool task per model tensor (paper's "MetisFL gRPC + OpenMP").
    Parallel,
    /// Chunk-partitioned element sweep with reusable scratch buffers:
    /// parallelism scales with cores regardless of tensor layout, and
    /// steady-state rounds allocate nothing. Bitwise identical results
    /// to Sequential/Parallel.
    Chunked,
    /// Offload the weighted sum to the AOT-compiled Pallas fedavg kernel
    /// via PJRT (ablation backend).
    Xla,
}

/// Global aggregation rule + backend.
#[derive(Debug, Clone, PartialEq)]
pub struct AggregationSpec {
    pub rule: String, // fedavg | fedadam | fedyogi | fedadagrad
    pub backend: AggregationBackend,
    /// Worker threads for the Parallel backend (0 = hardware threads).
    pub threads: usize,
    /// Server learning rate for adaptive rules (FedAdam/Yogi/Adagrad).
    pub server_lr: f64,
}

impl Default for AggregationSpec {
    fn default() -> Self {
        AggregationSpec {
            rule: "fedavg".into(),
            backend: AggregationBackend::Parallel,
            threads: 0,
            server_lr: 0.1,
        }
    }
}

/// Secure-aggregation configuration (Table 1, "Privacy & Security").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SecureSpec {
    None,
    /// Pairwise-PRG additive masking (LightSecAgg/Salvia analog).
    Masking,
    /// Mock-CKKS additively homomorphic aggregation (PALISADE analog).
    Ckks,
}

/// Per-learner heterogeneity for the synthetic trainer — the knob that
/// turns a uniform stress fleet into the straggler-ridden deployments
/// the pacing subsystem exists for. Learner `i` models one SGD step as
/// `step_time_us × speed_factors[i % len]` (empty = uniform 1×), with
/// optional per-task wall-clock jitter and a dropout probability
/// (a dropped task never calls back — the round-timeout / quorum path
/// handles it).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct HeteroFleetSpec {
    /// Per-learner step-time multipliers, cycled by learner index.
    pub speed_factors: Vec<f64>,
    /// Uniform ± fraction applied to each task's modeled compute time.
    pub jitter_frac: f64,
    /// Probability a training task silently fails (no completion).
    pub dropout: f64,
}

impl HeteroFleetSpec {
    pub fn is_uniform(&self) -> bool {
        self.speed_factors.is_empty() && self.jitter_frac == 0.0 && self.dropout == 0.0
    }

    /// Step-time multiplier for learner `index`.
    pub fn factor(&self, index: usize) -> f64 {
        if self.speed_factors.is_empty() {
            1.0
        } else {
            self.speed_factors[index % self.speed_factors.len()]
        }
    }
}

/// What executes a learner's local training task.
#[derive(Debug, Clone, PartialEq)]
pub enum TrainerKind {
    /// Real local training: AOT-compiled JAX train/eval steps via PJRT.
    Xla { artifacts_dir: String },
    /// Stress-test trainer: produces parameter-shaped noise updates with a
    /// calibrated compute-time model. Matches the paper's stress tests,
    /// which measure controller ops, not learning quality. `hetero`
    /// (default uniform) gives each learner its own speed/jitter/dropout
    /// profile for heterogeneous-fleet scenarios.
    Synthetic { step_time_us: u64, hetero: HeteroFleetSpec },
}

/// Participant-selection policy (`selector` env block).
#[derive(Debug, Clone, PartialEq, Default)]
pub enum SelectorSpec {
    /// Derive from the `participation` fraction (1.0 = everyone, else a
    /// uniform random fraction) — the paper's evaluation setting.
    #[default]
    Participation,
    /// The `k` learners with the oldest last participation (never-
    /// participated learners first).
    Freshness { k: usize },
    /// Pacing-aware: prefer fast/reliable learners by profile score,
    /// with a freshness floor — any learner idle for at least
    /// `freshness_rounds` rounds (or never scheduled) is force-included
    /// ahead of the score ranking, so slow sites keep contributing.
    Pacing { k: usize, freshness_rounds: u64 },
}

/// Transport between driver/controller/learners.
#[derive(Debug, Clone, PartialEq)]
pub enum TransportKind {
    /// In-process channels (paper's "standalone/simulated" deployment).
    InProc,
    /// Framed TCP on localhost (paper's "distributed" deployment).
    Tcp { base_port: u16 },
}

/// The HousingMLP model family used by the paper's stress tests:
/// `hidden_layers` densely connected layers of `hidden_units` each
/// (100k → 32 units, 1M → 100 units, 10M → 320 units; §4.2 fn. 4).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelSpec {
    pub input_dim: usize,
    pub hidden_layers: usize,
    pub hidden_units: usize,
    pub output_dim: usize,
}

impl ModelSpec {
    pub fn mlp(input_dim: usize, hidden_layers: usize, hidden_units: usize) -> ModelSpec {
        ModelSpec { input_dim, hidden_layers, hidden_units, output_dim: 1 }
    }

    /// Paper's 100k-parameter variant (100 layers × 32 units).
    pub fn paper_100k() -> ModelSpec {
        ModelSpec::mlp(8, 100, 32)
    }

    /// Paper's 1M-parameter variant (100 layers × 100 units).
    pub fn paper_1m() -> ModelSpec {
        ModelSpec::mlp(8, 100, 100)
    }

    /// Paper's 10M-parameter variant (100 layers × 320 units).
    pub fn paper_10m() -> ModelSpec {
        ModelSpec::mlp(8, 100, 320)
    }

    /// Named variant used in artifact filenames ("mlp100k" etc.).
    pub fn variant_name(&self) -> String {
        format!(
            "mlp_l{}_u{}_in{}_out{}",
            self.hidden_layers, self.hidden_units, self.input_dim, self.output_dim
        )
    }

    /// Per-tensor layout: (name, shape) for every weight/bias, in order.
    /// This is the `k` of the paper's per-tensor parallel aggregation.
    pub fn tensor_layout(&self) -> Vec<(String, Vec<usize>)> {
        let mut out = Vec::with_capacity(2 * self.hidden_layers + 2);
        let mut fan_in = self.input_dim;
        for l in 0..self.hidden_layers {
            out.push((format!("dense_{l}/w"), vec![fan_in, self.hidden_units]));
            out.push((format!("dense_{l}/b"), vec![self.hidden_units]));
            fan_in = self.hidden_units;
        }
        out.push(("head/w".into(), vec![fan_in, self.output_dim]));
        out.push(("head/b".into(), vec![self.output_dim]));
        out
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        self.tensor_layout().iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }

    /// Number of tensors (`k` in Fig. 4).
    pub fn tensor_count(&self) -> usize {
        2 * self.hidden_layers + 2
    }
}

/// A fully-specified federation environment.
#[derive(Debug, Clone, PartialEq)]
pub struct FederationEnv {
    pub name: String,
    pub learners: usize,
    pub rounds: usize,
    pub protocol: Protocol,
    pub model: ModelSpec,
    pub aggregation: AggregationSpec,
    pub secure: SecureSpec,
    pub trainer: TrainerKind,
    pub transport: TransportKind,
    /// Learner participation per round, in (0, 1]; the paper runs 1.0.
    pub participation: f64,
    /// Participant-selection policy; [`SelectorSpec::Participation`]
    /// (default) derives the classic all/random-fraction selector from
    /// `participation`.
    pub selector: SelectorSpec,
    /// Deadline-quorum fraction for sync/semi-sync rounds, in (0, 1]:
    /// the round aggregates as soon as `ceil(quorum_fraction ×
    /// dispatched)` learners completed (or the task timeout fires),
    /// reweighting by the actual participants. 1.0 (default) = classic
    /// all-or-timeout rounds. Completions that miss the cut are folded
    /// into the community model through the async staleness path
    /// instead of being dropped.
    pub quorum_fraction: f64,
    /// Staleness exponent for late-completion folding under
    /// `quorum_fraction < 1.0` (same discount law as the async
    /// protocol's `staleness_alpha`).
    pub quorum_late_alpha: f64,
    pub samples_per_learner: usize,
    pub batch_size: usize,
    pub local_epochs: usize,
    pub learning_rate: f64,
    pub seed: u64,
    /// Driver heartbeat period in milliseconds.
    pub heartbeat_ms: u64,
    /// Per-task timeout in milliseconds (learners exceeding it are dropped
    /// from the round — failure injection tests rely on this).
    pub task_timeout_ms: u64,
    /// Data-plane chunk size in bytes for learner → controller model
    /// uploads. 0 (default) = one-shot `MarkTaskCompleted`; > 0 streams
    /// completed models as `ModelStreamBegin`/`ModelChunk`/`ModelStreamEnd`
    /// so controller-side peak *wire* ingest memory is bounded by
    /// chunk × in-flight learners instead of learners × model size.
    /// Values below the sender's 1 KiB floor
    /// (`proto::client::MIN_CHUNK_BYTES`) are clamped up to it (a
    /// warning is logged once at env-load time; the effective value is
    /// surfaced as `FederationReport::effective_stream_chunk_bytes`).
    /// Results are bitwise identical either way. When positive, the
    /// controller ALSO streams dispatch (train/eval fan-out) over the
    /// same chunked data plane — the v3 symmetric data plane.
    pub stream_chunk_bytes: usize,
    /// Data-plane wire codec (`auto | f32 | bf16 | delta | delta-rle`).
    pub wire_codec: WireCodecChoice,
    /// bf16 per-codec field: also apply bf16 to controller → learner
    /// dispatch (lossy model broadcast — learners train on a rounded
    /// model). Default false: bf16 compresses uploads only.
    pub bf16_dispatch: bool,
    /// delta per-codec field: when a peer lacks the shared base, retry
    /// with a full f32 stream (true, default) instead of surfacing the
    /// refusal as a dispatch/upload error (false).
    pub delta_fallback: bool,
    /// Deterministic fault injection (`chaos:` block): which fractions
    /// of the fleet get which connection faults, expanded per learner
    /// by [`ChaosSpec::plan_fleet`] from `seed`. Default: all off.
    pub chaos: ChaosSpec,
    /// Hierarchical aggregation (`topology:` block): how many
    /// aggregator nodes to interpose between the root controller and
    /// the fleet, and the shard-local quorum. Default: flat.
    pub topology: TopologySpec,
    /// Fleet health monitoring (`health:` block): heartbeat probe
    /// period plus the missed-beat thresholds at which the failure
    /// detector suspects / declares a peer dead. Consumed by the
    /// driver's monitor and (in two-tier runs) the failover path.
    pub health: HealthSpec,
    /// Observability plane (`observability:` block): metrics exposition
    /// listener + span tracing toggle. Default: both off.
    pub observability: ObservabilitySpec,
}

impl FederationEnv {
    pub fn builder(name: &str) -> FederationEnvBuilder {
        FederationEnvBuilder::new(name)
    }

    /// Load from a YAML-subset environment file (paper Fig. 3).
    pub fn from_yaml(src: &str) -> Result<FederationEnv> {
        let v = super::yaml::parse(src).map_err(|e| anyhow::anyhow!(e.to_string()))?;
        Self::from_value(&v)
    }

    /// Load from an already-parsed value tree (YAML or JSON).
    pub fn from_value(v: &Value) -> Result<FederationEnv> {
        let name = v
            .get("name")
            .and_then(|x| x.as_str())
            .unwrap_or("federation")
            .to_string();
        let mut b = FederationEnvBuilder::new(&name);
        if let Some(n) = v.get("learners").and_then(|x| x.as_usize()) {
            b = b.learners(n);
        }
        if let Some(n) = v.get("rounds").and_then(|x| x.as_usize()) {
            b = b.rounds(n);
        }
        if let Some(m) = v.get("model") {
            let input_dim = m.get("input_dim").and_then(|x| x.as_usize()).unwrap_or(8);
            let layers = m.get("hidden_layers").and_then(|x| x.as_usize()).unwrap_or(100);
            let units = m.get("hidden_units").and_then(|x| x.as_usize()).unwrap_or(32);
            let mut spec = ModelSpec::mlp(input_dim, layers, units);
            if let Some(o) = m.get("output_dim").and_then(|x| x.as_usize()) {
                spec.output_dim = o;
            }
            b = b.model(spec);
        }
        if let Some(p) = v.get("protocol") {
            let kind = p
                .get("kind")
                .and_then(|x| x.as_str())
                .or_else(|| p.as_str())
                .unwrap_or("synchronous");
            let proto = match kind {
                "synchronous" | "sync" => Protocol::Synchronous,
                "semi_synchronous" | "semi-sync" | "semisync" => Protocol::SemiSynchronous {
                    lambda: p.get("lambda").and_then(|x| x.as_f64()).unwrap_or(1.0),
                },
                "asynchronous" | "async" => Protocol::Asynchronous {
                    staleness_alpha: p
                        .get("staleness_alpha")
                        .and_then(|x| x.as_f64())
                        .unwrap_or(0.5),
                },
                other => bail!("unknown protocol kind '{other}'"),
            };
            b = b.protocol(proto);
        }
        if let Some(a) = v.get("aggregation") {
            let mut spec = AggregationSpec::default();
            if let Some(r) = a.get("rule").and_then(|x| x.as_str()) {
                spec.rule = r.to_string();
            }
            if let Some(be) = a.get("backend").and_then(|x| x.as_str()) {
                spec.backend = match be {
                    "sequential" => AggregationBackend::Sequential,
                    "parallel" => AggregationBackend::Parallel,
                    "chunked" => AggregationBackend::Chunked,
                    "xla" => AggregationBackend::Xla,
                    other => bail!("unknown aggregation backend '{other}'"),
                };
            }
            if let Some(t) = a.get("threads").and_then(|x| x.as_usize()) {
                spec.threads = t;
            }
            if let Some(lr) = a.get("server_lr").and_then(|x| x.as_f64()) {
                spec.server_lr = lr;
            }
            b = b.aggregation(spec);
        }
        if let Some(s) = v.get("secure").and_then(|x| x.as_str()) {
            b = b.secure(match s {
                "none" => SecureSpec::None,
                "masking" => SecureSpec::Masking,
                "ckks" => SecureSpec::Ckks,
                other => bail!("unknown secure mode '{other}'"),
            });
        }
        if let Some(t) = v.get("trainer") {
            let kind = t.get("kind").and_then(|x| x.as_str()).unwrap_or("synthetic");
            b = b.trainer(match kind {
                "xla" => TrainerKind::Xla {
                    artifacts_dir: t
                        .get("artifacts_dir")
                        .and_then(|x| x.as_str())
                        .unwrap_or("artifacts")
                        .to_string(),
                },
                "synthetic" => {
                    let mut hetero = HeteroFleetSpec::default();
                    if let Some(fs) = t.get("speed_factors").and_then(|x| x.as_array()) {
                        hetero.speed_factors = fs
                            .iter()
                            .map(|f| {
                                f.as_f64().ok_or_else(|| {
                                    anyhow::anyhow!("speed_factors entries must be numbers")
                                })
                            })
                            .collect::<Result<Vec<f64>>>()?;
                    }
                    if let Some(j) = t.get("jitter").and_then(|x| x.as_f64()) {
                        hetero.jitter_frac = j;
                    }
                    if let Some(d) = t.get("dropout").and_then(|x| x.as_f64()) {
                        hetero.dropout = d;
                    }
                    TrainerKind::Synthetic {
                        step_time_us: t
                            .get("step_time_us")
                            .and_then(|x| x.as_u64())
                            .unwrap_or(0),
                        hetero,
                    }
                }
                other => bail!("unknown trainer kind '{other}'"),
            });
        }
        if let Some(s) = v.get("selector") {
            let kind = s
                .get("kind")
                .and_then(|x| x.as_str())
                .or_else(|| s.as_str())
                .unwrap_or("participation");
            let k = s.get("k").and_then(|x| x.as_usize()).unwrap_or(1);
            b = b.selector(match kind {
                "participation" => SelectorSpec::Participation,
                "freshness" => SelectorSpec::Freshness { k },
                "pacing" => SelectorSpec::Pacing {
                    k,
                    freshness_rounds: s
                        .get("freshness_rounds")
                        .and_then(|x| x.as_u64())
                        .unwrap_or(4),
                },
                other => bail!("unknown selector kind '{other}' (participation|freshness|pacing)"),
            });
        }
        if let Some(x) = v.get("quorum_fraction").and_then(|x| x.as_f64()) {
            b = b.quorum_fraction(x);
        }
        if let Some(x) = v.get("quorum_late_alpha").and_then(|x| x.as_f64()) {
            b = b.quorum_late_alpha(x);
        }
        if let Some(t) = v.get("transport") {
            let kind = t.get("kind").and_then(|x| x.as_str()).or_else(|| t.as_str());
            b = b.transport(match kind.unwrap_or("inproc") {
                "inproc" => TransportKind::InProc,
                "tcp" => TransportKind::Tcp {
                    base_port: t.get("base_port").and_then(|x| x.as_u64()).unwrap_or(42500) as u16,
                },
                other => bail!("unknown transport kind '{other}'"),
            });
        }
        if let Some(x) = v.get("participation").and_then(|x| x.as_f64()) {
            b = b.participation(x);
        }
        if let Some(x) = v.get("samples_per_learner").and_then(|x| x.as_usize()) {
            b = b.samples_per_learner(x);
        }
        if let Some(x) = v.get("batch_size").and_then(|x| x.as_usize()) {
            b = b.batch_size(x);
        }
        if let Some(x) = v.get("local_epochs").and_then(|x| x.as_usize()) {
            b = b.local_epochs(x);
        }
        if let Some(x) = v.get("learning_rate").and_then(|x| x.as_f64()) {
            b = b.learning_rate(x);
        }
        if let Some(x) = v.get("seed").and_then(|x| x.as_u64()) {
            b = b.seed(x);
        }
        if let Some(x) = v.get("heartbeat_ms").and_then(|x| x.as_u64()) {
            b = b.heartbeat_ms(x);
        }
        if let Some(x) = v.get("task_timeout_ms").and_then(|x| x.as_u64()) {
            b = b.task_timeout_ms(x);
        }
        if let Some(x) = v.get("stream_chunk_bytes").and_then(|x| x.as_usize()) {
            warn_once_on_clamped_chunk(x);
            b = b.stream_chunk_bytes(x);
        }
        if let Some(s) = v.get("wire_codec").and_then(|x| x.as_str()) {
            b = b.wire_codec(WireCodecChoice::parse(s)?);
        }
        if let Some(x) = v.get("bf16_dispatch").and_then(|x| x.as_bool()) {
            b = b.bf16_dispatch(x);
        }
        if let Some(x) = v.get("delta_fallback").and_then(|x| x.as_bool()) {
            b = b.delta_fallback(x);
        }
        if let Some(c) = v.get("chaos") {
            let mut spec = ChaosSpec::default();
            if let Some(x) = c.get("seed").and_then(|x| x.as_u64()) {
                spec.seed = x;
            }
            if let Some(x) = c.get("sever_fraction").and_then(|x| x.as_f64()) {
                spec.sever_fraction = x;
            }
            if let Some(x) = c.get("sever_after_sends").and_then(|x| x.as_u64()) {
                spec.sever_after_sends = x;
            }
            if let Some(x) = c.get("refuse_fraction").and_then(|x| x.as_f64()) {
                spec.refuse_fraction = x;
            }
            if let Some(x) = c.get("stall_fraction").and_then(|x| x.as_f64()) {
                spec.stall_fraction = x;
            }
            if let Some(x) = c.get("stall_ms").and_then(|x| x.as_u64()) {
                spec.stall_ms = x;
            }
            if let Some(x) = c.get("duplicate_fraction").and_then(|x| x.as_f64()) {
                spec.duplicate_fraction = x;
            }
            if let Some(x) = c.get("slow_loris").and_then(|x| x.as_usize()) {
                spec.slow_loris = x;
            }
            if let Some(x) = c.get("drip_ms").and_then(|x| x.as_u64()) {
                spec.drip_ms = x;
            }
            if let Some(x) = c.get("corrupt").and_then(|x| x.as_usize()) {
                spec.corrupt = x;
            }
            if let Some(x) = c.get("reconnect_after_ms").and_then(|x| x.as_u64()) {
                spec.reconnect_after_ms = x;
            }
            if let Some(x) = c.get("kill_aggregator_at_round").and_then(|x| x.as_u64()) {
                spec.kill_aggregator_at_round = x;
            }
            b = b.chaos(spec);
        }
        if let Some(t) = v.get("topology") {
            let mut spec = TopologySpec::default();
            if let Some(x) = t.get("aggregators").and_then(|x| x.as_usize()) {
                spec.aggregators = x;
            }
            if let Some(x) = t.get("shard_quorum").and_then(|x| x.as_f64()) {
                spec.shard_quorum = x;
            }
            b = b.topology(spec);
        }
        if let Some(h) = v.get("health") {
            let mut spec = HealthSpec::default();
            if let Some(x) = h.get("interval_ms").and_then(|x| x.as_u64()) {
                spec.interval_ms = x;
            }
            if let Some(x) = h.get("suspect_after").and_then(|x| x.as_u64()) {
                spec.suspect_after = x as u32;
            }
            if let Some(x) = h.get("dead_after").and_then(|x| x.as_u64()) {
                spec.dead_after = x as u32;
            }
            if let Some(x) = h.get("ewma_alpha").and_then(|x| x.as_f64()) {
                spec.ewma_alpha = x;
            }
            b = b.health(spec);
        }
        if let Some(ob) = v.get("observability") {
            let mut spec = ObservabilitySpec::default();
            if let Some(x) = ob.get("listen_addr").and_then(|x| x.as_str()) {
                spec.listen_addr = x.to_string();
            }
            if let Some(x) = ob.get("spans").and_then(|x| x.as_bool()) {
                spec.spans = x;
            }
            b = b.observability(spec);
        }
        b.try_build()
    }

    /// Load from a file (YAML `.yaml`/`.yml` or JSON `.json`).
    pub fn from_file(path: &str) -> Result<FederationEnv> {
        let src = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        if path.ends_with(".json") {
            let v = crate::json::parse(&src).map_err(|e| anyhow::anyhow!(e.to_string()))?;
            Self::from_value(&v)
        } else {
            Self::from_yaml(&src)
        }
    }

    /// Emit the environment as YAML that [`FederationEnv::from_yaml`]
    /// parses back to an identical value — the serializer the trace
    /// recorder embeds in trace headers so a replay can rebuild this
    /// run's exact environment without the original env file. Every
    /// field is written explicitly (no default elision), keeping the
    /// round-trip independent of builder-default drift.
    pub fn to_yaml_source(&self) -> String {
        // Quote strings the subset parser would mis-type as numbers or
        // keywords; bare tokens stay bare for readability.
        fn scalar(s: &str) -> String {
            let bare = !s.is_empty()
                && s.chars()
                    .all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.' | '/'))
                && s.parse::<f64>().is_err()
                && !matches!(s, "true" | "false" | "null" | "~");
            if bare {
                s.to_string()
            } else {
                format!("\"{s}\"")
            }
        }
        let mut o = String::with_capacity(1024);
        o.push_str(&format!("name: {}\n", scalar(&self.name)));
        o.push_str(&format!("learners: {}\n", self.learners));
        o.push_str(&format!("rounds: {}\n", self.rounds));
        match self.protocol {
            Protocol::Synchronous => o.push_str("protocol: synchronous\n"),
            Protocol::SemiSynchronous { lambda } => {
                o.push_str("protocol:\n  kind: semi_synchronous\n");
                o.push_str(&format!("  lambda: {lambda}\n"));
            }
            Protocol::Asynchronous { staleness_alpha } => {
                o.push_str("protocol:\n  kind: asynchronous\n");
                o.push_str(&format!("  staleness_alpha: {staleness_alpha}\n"));
            }
        }
        o.push_str("model:\n");
        o.push_str(&format!("  input_dim: {}\n", self.model.input_dim));
        o.push_str(&format!("  hidden_layers: {}\n", self.model.hidden_layers));
        o.push_str(&format!("  hidden_units: {}\n", self.model.hidden_units));
        o.push_str(&format!("  output_dim: {}\n", self.model.output_dim));
        o.push_str("aggregation:\n");
        o.push_str(&format!("  rule: {}\n", scalar(&self.aggregation.rule)));
        let backend = match self.aggregation.backend {
            AggregationBackend::Sequential => "sequential",
            AggregationBackend::Parallel => "parallel",
            AggregationBackend::Chunked => "chunked",
            AggregationBackend::Xla => "xla",
        };
        o.push_str(&format!("  backend: {backend}\n"));
        o.push_str(&format!("  threads: {}\n", self.aggregation.threads));
        o.push_str(&format!("  server_lr: {}\n", self.aggregation.server_lr));
        let secure = match self.secure {
            SecureSpec::None => "none",
            SecureSpec::Masking => "masking",
            SecureSpec::Ckks => "ckks",
        };
        o.push_str(&format!("secure: {secure}\n"));
        match &self.trainer {
            TrainerKind::Xla { artifacts_dir } => {
                o.push_str("trainer:\n  kind: xla\n");
                o.push_str(&format!("  artifacts_dir: {}\n", scalar(artifacts_dir)));
            }
            TrainerKind::Synthetic { step_time_us, hetero } => {
                o.push_str("trainer:\n  kind: synthetic\n");
                o.push_str(&format!("  step_time_us: {step_time_us}\n"));
                if !hetero.speed_factors.is_empty() {
                    let fs: Vec<String> =
                        hetero.speed_factors.iter().map(|f| f.to_string()).collect();
                    o.push_str(&format!("  speed_factors: [{}]\n", fs.join(", ")));
                }
                o.push_str(&format!("  jitter: {}\n", hetero.jitter_frac));
                o.push_str(&format!("  dropout: {}\n", hetero.dropout));
            }
        }
        match &self.transport {
            TransportKind::InProc => o.push_str("transport: inproc\n"),
            TransportKind::Tcp { base_port } => {
                o.push_str("transport:\n  kind: tcp\n");
                o.push_str(&format!("  base_port: {base_port}\n"));
            }
        }
        o.push_str(&format!("participation: {}\n", self.participation));
        match &self.selector {
            SelectorSpec::Participation => o.push_str("selector: participation\n"),
            SelectorSpec::Freshness { k } => {
                o.push_str("selector:\n  kind: freshness\n");
                o.push_str(&format!("  k: {k}\n"));
            }
            SelectorSpec::Pacing { k, freshness_rounds } => {
                o.push_str("selector:\n  kind: pacing\n");
                o.push_str(&format!("  k: {k}\n"));
                o.push_str(&format!("  freshness_rounds: {freshness_rounds}\n"));
            }
        }
        o.push_str(&format!("quorum_fraction: {}\n", self.quorum_fraction));
        o.push_str(&format!("quorum_late_alpha: {}\n", self.quorum_late_alpha));
        o.push_str(&format!("samples_per_learner: {}\n", self.samples_per_learner));
        o.push_str(&format!("batch_size: {}\n", self.batch_size));
        o.push_str(&format!("local_epochs: {}\n", self.local_epochs));
        o.push_str(&format!("learning_rate: {}\n", self.learning_rate));
        o.push_str(&format!("seed: {}\n", self.seed));
        o.push_str(&format!("heartbeat_ms: {}\n", self.heartbeat_ms));
        o.push_str(&format!("task_timeout_ms: {}\n", self.task_timeout_ms));
        o.push_str(&format!("stream_chunk_bytes: {}\n", self.stream_chunk_bytes));
        o.push_str(&format!("wire_codec: {}\n", self.wire_codec.name()));
        o.push_str(&format!("bf16_dispatch: {}\n", self.bf16_dispatch));
        o.push_str(&format!("delta_fallback: {}\n", self.delta_fallback));
        let c = &self.chaos;
        o.push_str("chaos:\n");
        o.push_str(&format!("  seed: {}\n", c.seed));
        o.push_str(&format!("  sever_fraction: {}\n", c.sever_fraction));
        o.push_str(&format!("  sever_after_sends: {}\n", c.sever_after_sends));
        o.push_str(&format!("  refuse_fraction: {}\n", c.refuse_fraction));
        o.push_str(&format!("  stall_fraction: {}\n", c.stall_fraction));
        o.push_str(&format!("  stall_ms: {}\n", c.stall_ms));
        o.push_str(&format!("  duplicate_fraction: {}\n", c.duplicate_fraction));
        o.push_str(&format!("  slow_loris: {}\n", c.slow_loris));
        o.push_str(&format!("  drip_ms: {}\n", c.drip_ms));
        o.push_str(&format!("  corrupt: {}\n", c.corrupt));
        o.push_str(&format!("  reconnect_after_ms: {}\n", c.reconnect_after_ms));
        o.push_str(&format!("  kill_aggregator_at_round: {}\n", c.kill_aggregator_at_round));
        o.push_str("topology:\n");
        o.push_str(&format!("  aggregators: {}\n", self.topology.aggregators));
        o.push_str(&format!("  shard_quorum: {}\n", self.topology.shard_quorum));
        let h = &self.health;
        o.push_str("health:\n");
        o.push_str(&format!("  interval_ms: {}\n", h.interval_ms));
        o.push_str(&format!("  suspect_after: {}\n", h.suspect_after));
        o.push_str(&format!("  dead_after: {}\n", h.dead_after));
        o.push_str(&format!("  ewma_alpha: {}\n", h.ewma_alpha));
        o.push_str("observability:\n");
        o.push_str(&format!("  listen_addr: {}\n", scalar(&self.observability.listen_addr)));
        o.push_str(&format!("  spans: {}\n", self.observability.spans));
        o
    }

    /// Validate invariants; called by `build()` in debug builds and by
    /// loaders always.
    pub fn validate(&self) -> Result<()> {
        if self.learners == 0 {
            bail!("learners must be >= 1");
        }
        if !(self.participation > 0.0 && self.participation <= 1.0) {
            bail!("participation must be in (0, 1]");
        }
        if self.batch_size == 0 || self.samples_per_learner == 0 {
            bail!("batch_size and samples_per_learner must be >= 1");
        }
        if self.model.hidden_layers == 0 || self.model.hidden_units == 0 {
            bail!("model must have at least one hidden layer/unit");
        }
        // Codecs ride the chunked stream: an explicit non-default codec
        // with streaming off would silently do nothing — refuse instead.
        if matches!(
            self.wire_codec,
            WireCodecChoice::Bf16 | WireCodecChoice::Delta | WireCodecChoice::DeltaRle
        ) && self.stream_chunk_bytes == 0
        {
            bail!(
                "wire_codec: {} requires stream_chunk_bytes > 0 (codecs ride the streamed \
                 data plane; one-shot messages are always f32)",
                self.wire_codec.name()
            );
        }
        if self.bf16_dispatch && self.wire_codec != WireCodecChoice::Bf16 {
            bail!("bf16_dispatch: true requires wire_codec: bf16");
        }
        if !(self.quorum_fraction > 0.0 && self.quorum_fraction <= 1.0) {
            bail!("quorum_fraction must be in (0, 1]");
        }
        if self.quorum_late_alpha < 0.0 {
            bail!("quorum_late_alpha must be >= 0");
        }
        match &self.selector {
            SelectorSpec::Participation => {}
            SelectorSpec::Freshness { k } => {
                if *k == 0 {
                    bail!("selector k must be >= 1");
                }
            }
            SelectorSpec::Pacing { k, freshness_rounds } => {
                if *k == 0 {
                    bail!("selector k must be >= 1");
                }
                if *freshness_rounds == 0 {
                    bail!("selector freshness_rounds must be >= 1");
                }
            }
        }
        if let TrainerKind::Synthetic { hetero, .. } = &self.trainer {
            if hetero.speed_factors.iter().any(|f| !(*f > 0.0)) {
                bail!("trainer speed_factors must all be > 0");
            }
            if !(0.0..1.0).contains(&hetero.jitter_frac) {
                bail!("trainer jitter must be in [0, 1)");
            }
            if !(0.0..1.0).contains(&hetero.dropout) {
                bail!("trainer dropout must be in [0, 1)");
            }
        }
        self.chaos.validate()?;
        self.health.validate()?;
        if self.chaos.kill_aggregator_at_round > 0 && self.topology.aggregators < 2 {
            bail!(
                "chaos kill_aggregator_at_round requires a topology with >= 2 aggregators \
                 (failover needs a surviving shard to re-home onto)"
            );
        }
        if !self.topology.is_flat() {
            if self.topology.aggregators > self.learners {
                bail!(
                    "topology: {} aggregators for {} learners (every shard must own \
                     at least one learner)",
                    self.topology.aggregators,
                    self.learners
                );
            }
            if self.topology.shard_quorum < 0.0 || self.topology.shard_quorum > 1.0 {
                bail!("topology shard_quorum must be in (0, 1] (or 0 to inherit)");
            }
        } else if self.topology.shard_quorum != 0.0 {
            bail!("topology shard_quorum requires aggregators > 0");
        }
        match self.protocol {
            Protocol::SemiSynchronous { lambda } if lambda <= 0.0 => {
                bail!("semi-sync lambda must be > 0")
            }
            Protocol::Asynchronous { staleness_alpha } if staleness_alpha < 0.0 => {
                bail!("staleness_alpha must be >= 0")
            }
            _ => Ok(()),
        }
    }

    /// Effective data-plane chunk size: 0 = one-shot; positive values
    /// are clamped up to the sender floor
    /// ([`crate::proto::client::MIN_CHUNK_BYTES`]). This is the value
    /// senders actually use, surfaced in `FederationReport`.
    pub fn effective_stream_chunk(&self) -> usize {
        if self.stream_chunk_bytes == 0 {
            0
        } else {
            self.stream_chunk_bytes.max(crate::proto::client::MIN_CHUNK_BYTES)
        }
    }

    /// Concrete codec for learner → controller model uploads.
    pub fn upload_codec(&self) -> CodecId {
        match self.wire_codec {
            WireCodecChoice::F32 => CodecId::F32,
            WireCodecChoice::Bf16 => CodecId::Bf16,
            WireCodecChoice::Delta => CodecId::Delta,
            WireCodecChoice::DeltaRle => CodecId::DeltaRle,
            // Auto: delta codecs need the streamed dispatch to
            // establish the shared base; without streaming, stay on
            // plain f32. With streaming, prefer the entropy-coded
            // delta-rle wire (CI-gated since PR 4); peers that only
            // speak delta negotiate down via the Hello intersection.
            WireCodecChoice::Auto => {
                if self.effective_stream_chunk() > 0 {
                    CodecId::DeltaRle
                } else {
                    CodecId::F32
                }
            }
        }
    }

    /// Concrete codec for controller → learner streamed dispatch (only
    /// consulted when `stream_chunk_bytes > 0`).
    pub fn dispatch_codec(&self) -> CodecId {
        match self.wire_codec {
            WireCodecChoice::F32 => CodecId::F32,
            // Lossy dispatch is opt-in: learners would train on a
            // rounded model.
            WireCodecChoice::Bf16 => {
                if self.bf16_dispatch {
                    CodecId::Bf16
                } else {
                    CodecId::F32
                }
            }
            WireCodecChoice::DeltaRle | WireCodecChoice::Auto => CodecId::DeltaRle,
            WireCodecChoice::Delta => CodecId::Delta,
        }
    }
}

/// Log (once per process) when a sub-floor `stream_chunk_bytes` is
/// loaded from an env file — the value silently clamping up used to
/// make "why is my chunk size ignored?" a debugging session.
fn warn_once_on_clamped_chunk(configured: usize) {
    use std::sync::Once;
    static WARNED: Once = Once::new();
    let floor = crate::proto::client::MIN_CHUNK_BYTES;
    if configured > 0 && configured < floor {
        WARNED.call_once(|| {
            crate::util::log_warn(
                "config",
                &format!(
                    "stream_chunk_bytes {configured} is below the {floor}-byte sender floor; \
                     using {floor} (see FederationReport::effective_stream_chunk_bytes)"
                ),
            );
        });
    }
}

/// Builder for [`FederationEnv`] with paper-matching defaults.
#[derive(Debug, Clone)]
pub struct FederationEnvBuilder {
    env: FederationEnv,
}

impl FederationEnvBuilder {
    pub fn new(name: &str) -> Self {
        FederationEnvBuilder {
            env: FederationEnv {
                name: name.to_string(),
                learners: 10,
                rounds: 1,
                protocol: Protocol::Synchronous,
                model: ModelSpec::paper_100k(),
                aggregation: AggregationSpec::default(),
                secure: SecureSpec::None,
                trainer: TrainerKind::Synthetic {
                    step_time_us: 0,
                    hetero: HeteroFleetSpec::default(),
                },
                transport: TransportKind::InProc,
                participation: 1.0,
                selector: SelectorSpec::Participation,
                quorum_fraction: 1.0,
                quorum_late_alpha: 0.5,
                samples_per_learner: 100,
                batch_size: 100,
                local_epochs: 1,
                learning_rate: 0.01,
                seed: 42,
                heartbeat_ms: 500,
                task_timeout_ms: 60_000,
                stream_chunk_bytes: 0,
                wire_codec: WireCodecChoice::Auto,
                bf16_dispatch: false,
                delta_fallback: true,
                chaos: ChaosSpec::default(),
                topology: TopologySpec::default(),
                health: HealthSpec::default(),
                observability: ObservabilitySpec::default(),
            },
        }
    }

    pub fn learners(mut self, n: usize) -> Self {
        self.env.learners = n;
        self
    }
    pub fn rounds(mut self, n: usize) -> Self {
        self.env.rounds = n;
        self
    }
    pub fn protocol(mut self, p: Protocol) -> Self {
        self.env.protocol = p;
        self
    }
    pub fn model(mut self, m: ModelSpec) -> Self {
        self.env.model = m;
        self
    }
    pub fn aggregation(mut self, a: AggregationSpec) -> Self {
        self.env.aggregation = a;
        self
    }
    pub fn secure(mut self, s: SecureSpec) -> Self {
        self.env.secure = s;
        self
    }
    pub fn trainer(mut self, t: TrainerKind) -> Self {
        self.env.trainer = t;
        self
    }
    pub fn transport(mut self, t: TransportKind) -> Self {
        self.env.transport = t;
        self
    }
    pub fn participation(mut self, f: f64) -> Self {
        self.env.participation = f;
        self
    }
    pub fn selector(mut self, s: SelectorSpec) -> Self {
        self.env.selector = s;
        self
    }
    pub fn quorum_fraction(mut self, q: f64) -> Self {
        self.env.quorum_fraction = q;
        self
    }
    pub fn quorum_late_alpha(mut self, a: f64) -> Self {
        self.env.quorum_late_alpha = a;
        self
    }
    pub fn samples_per_learner(mut self, n: usize) -> Self {
        self.env.samples_per_learner = n;
        self
    }
    pub fn batch_size(mut self, n: usize) -> Self {
        self.env.batch_size = n;
        self
    }
    pub fn local_epochs(mut self, n: usize) -> Self {
        self.env.local_epochs = n;
        self
    }
    pub fn learning_rate(mut self, lr: f64) -> Self {
        self.env.learning_rate = lr;
        self
    }
    pub fn seed(mut self, s: u64) -> Self {
        self.env.seed = s;
        self
    }
    pub fn heartbeat_ms(mut self, ms: u64) -> Self {
        self.env.heartbeat_ms = ms;
        self
    }
    pub fn task_timeout_ms(mut self, ms: u64) -> Self {
        self.env.task_timeout_ms = ms;
        self
    }
    pub fn stream_chunk_bytes(mut self, bytes: usize) -> Self {
        self.env.stream_chunk_bytes = bytes;
        self
    }
    pub fn wire_codec(mut self, c: WireCodecChoice) -> Self {
        self.env.wire_codec = c;
        self
    }
    pub fn bf16_dispatch(mut self, on: bool) -> Self {
        self.env.bf16_dispatch = on;
        self
    }
    pub fn delta_fallback(mut self, on: bool) -> Self {
        self.env.delta_fallback = on;
        self
    }
    pub fn chaos(mut self, c: ChaosSpec) -> Self {
        self.env.chaos = c;
        self
    }
    pub fn topology(mut self, t: TopologySpec) -> Self {
        self.env.topology = t;
        self
    }
    pub fn health(mut self, h: HealthSpec) -> Self {
        self.env.health = h;
        self
    }
    pub fn observability(mut self, o: ObservabilitySpec) -> Self {
        self.env.observability = o;
        self
    }

    pub fn build(self) -> FederationEnv {
        debug_assert!(self.env.validate().is_ok(), "{:?}", self.env.validate());
        self.env
    }

    /// [`FederationEnvBuilder::build`] that surfaces invalid configs as
    /// an `Err` instead of a debug panic — what the file loaders use,
    /// so a bad env file is a typed error for the operator.
    pub fn try_build(self) -> Result<FederationEnv> {
        self.env.validate()?;
        Ok(self.env)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_variant_param_counts_match_footnote_4() {
        // §4.2 fn. 4: 100k → 32 units, 1M → 100 units, 10M → 320 units.
        let p100k = ModelSpec::paper_100k().param_count();
        let p1m = ModelSpec::paper_1m().param_count();
        let p10m = ModelSpec::paper_10m().param_count();
        assert!((90_000..130_000).contains(&p100k), "{p100k}");
        assert!((900_000..1_100_000).contains(&p1m), "{p1m}");
        assert!((9_500_000..10_600_000).contains(&p10m), "{p10m}");
    }

    #[test]
    fn tensor_layout_shapes_chain() {
        let m = ModelSpec::mlp(8, 3, 16);
        let layout = m.tensor_layout();
        assert_eq!(layout.len(), 8); // 3×(w,b) + head(w,b)
        assert_eq!(layout[0].1, vec![8, 16]);
        assert_eq!(layout[2].1, vec![16, 16]);
        assert_eq!(layout[6].1, vec![16, 1]);
        assert_eq!(m.tensor_count(), layout.len());
        let total: usize = layout.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
        assert_eq!(total, m.param_count());
    }

    #[test]
    fn builder_defaults_match_paper_workload() {
        let env = FederationEnv::builder("t").build();
        assert_eq!(env.samples_per_learner, 100);
        assert_eq!(env.batch_size, 100);
        assert_eq!(env.participation, 1.0);
        assert_eq!(env.protocol, Protocol::Synchronous);
        assert!(env.validate().is_ok());
    }

    #[test]
    fn yaml_roundtrip_full_env() {
        let src = r#"
name: stress
learners: 25
rounds: 4
model:
  input_dim: 8
  hidden_layers: 100
  hidden_units: 100
protocol:
  kind: semi_synchronous
  lambda: 2.0
aggregation:
  rule: fedavg
  backend: sequential
  threads: 4
secure: masking
trainer:
  kind: synthetic
  step_time_us: 150
transport:
  kind: tcp
  base_port: 43000
participation: 0.5
seed: 7
"#;
        let env = FederationEnv::from_yaml(src).unwrap();
        assert_eq!(env.name, "stress");
        assert_eq!(env.learners, 25);
        assert_eq!(env.model.hidden_units, 100);
        assert_eq!(env.protocol, Protocol::SemiSynchronous { lambda: 2.0 });
        assert_eq!(env.aggregation.backend, AggregationBackend::Sequential);
        assert_eq!(env.aggregation.threads, 4);
        assert_eq!(env.secure, SecureSpec::Masking);
        assert_eq!(
            env.trainer,
            TrainerKind::Synthetic { step_time_us: 150, hetero: HeteroFleetSpec::default() }
        );
        assert_eq!(env.transport, TransportKind::Tcp { base_port: 43000 });
        assert_eq!(env.participation, 0.5);
        assert_eq!(env.seed, 7);
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut env = FederationEnv::builder("t").build();
        env.learners = 0;
        assert!(env.validate().is_err());
        let mut env = FederationEnv::builder("t").build();
        env.participation = 0.0;
        assert!(env.validate().is_err());
        let mut env = FederationEnv::builder("t").build();
        env.protocol = Protocol::SemiSynchronous { lambda: -1.0 };
        assert!(env.validate().is_err());
        assert!(FederationEnv::from_yaml("protocol: warp_speed\n").is_err());
    }

    #[test]
    fn variant_name_is_stable() {
        assert_eq!(ModelSpec::paper_100k().variant_name(), "mlp_l100_u32_in8_out1");
    }

    #[test]
    fn stream_chunk_bytes_defaults_off_and_parses() {
        let env = FederationEnv::builder("t").build();
        assert_eq!(env.stream_chunk_bytes, 0);
        assert_eq!(env.effective_stream_chunk(), 0);
        let env = FederationEnv::from_yaml("stream_chunk_bytes: 65536\n").unwrap();
        assert_eq!(env.stream_chunk_bytes, 65536);
        assert_eq!(env.effective_stream_chunk(), 65536);
    }

    #[test]
    fn sub_floor_chunk_is_clamped_with_effective_value_surfaced() {
        let floor = crate::proto::client::MIN_CHUNK_BYTES;
        // Loading a sub-floor value parses (warning logged once) and the
        // effective chunk is the floor — what senders actually use.
        let env = FederationEnv::from_yaml("stream_chunk_bytes: 10\n").unwrap();
        assert_eq!(env.stream_chunk_bytes, 10);
        assert_eq!(env.effective_stream_chunk(), floor);
    }

    #[test]
    fn wire_codec_parses_and_resolves() {
        let env = FederationEnv::builder("t").build();
        assert_eq!(env.wire_codec, WireCodecChoice::Auto);
        assert!(env.delta_fallback);
        assert!(!env.bf16_dispatch);
        // Auto without streaming: everything stays f32.
        assert_eq!(env.upload_codec(), CodecId::F32);
        // Auto with streaming: the entropy-coded lossless delta wire on
        // both planes (delta-only peers negotiate down at Hello).
        let env = FederationEnv::from_yaml("stream_chunk_bytes: 2048\n").unwrap();
        assert_eq!(env.upload_codec(), CodecId::DeltaRle);
        assert_eq!(env.dispatch_codec(), CodecId::DeltaRle);
        // Explicit delta still means plain delta on both planes.
        let env =
            FederationEnv::from_yaml("stream_chunk_bytes: 2048\nwire_codec: delta\n").unwrap();
        assert_eq!(env.upload_codec(), CodecId::Delta);
        assert_eq!(env.dispatch_codec(), CodecId::Delta);
        // bf16 compresses uploads; dispatch stays lossless unless opted in.
        let env =
            FederationEnv::from_yaml("stream_chunk_bytes: 2048\nwire_codec: bf16\n").unwrap();
        assert_eq!(env.upload_codec(), CodecId::Bf16);
        assert_eq!(env.dispatch_codec(), CodecId::F32);
        let env = FederationEnv::from_yaml(
            "stream_chunk_bytes: 2048\nwire_codec: bf16\nbf16_dispatch: true\n",
        )
        .unwrap();
        assert_eq!(env.dispatch_codec(), CodecId::Bf16);
        let env = FederationEnv::from_yaml(
            "stream_chunk_bytes: 2048\nwire_codec: delta\ndelta_fallback: false\n",
        )
        .unwrap();
        assert_eq!(env.upload_codec(), CodecId::Delta);
        assert!(!env.delta_fallback);
        // The entropy-coded delta wire resolves on both planes; both
        // spellings parse.
        for src in [
            "stream_chunk_bytes: 2048\nwire_codec: delta-rle\n",
            "stream_chunk_bytes: 2048\nwire_codec: delta_rle\n",
        ] {
            let env = FederationEnv::from_yaml(src).unwrap();
            assert_eq!(env.wire_codec, WireCodecChoice::DeltaRle);
            assert_eq!(env.upload_codec(), CodecId::DeltaRle);
            assert_eq!(env.dispatch_codec(), CodecId::DeltaRle);
        }
        assert!(FederationEnv::from_yaml("wire_codec: zstd\n").is_err());
    }

    #[test]
    fn explicit_codec_without_streaming_is_a_typed_error() {
        // A non-default codec with streaming off would silently do
        // nothing — loaders refuse it instead.
        for src in [
            "wire_codec: bf16\n",
            "wire_codec: delta\n",
            "wire_codec: delta-rle\n",
            "stream_chunk_bytes: 2048\nbf16_dispatch: true\n",
        ] {
            let err = format!("{:#}", FederationEnv::from_yaml(src).unwrap_err());
            assert!(
                err.contains("wire_codec") || err.contains("bf16_dispatch"),
                "{src}: {err}"
            );
        }
    }

    #[test]
    fn scheduling_fields_parse_and_default() {
        let env = FederationEnv::builder("t").build();
        assert_eq!(env.selector, SelectorSpec::Participation);
        assert_eq!(env.quorum_fraction, 1.0);
        assert_eq!(env.quorum_late_alpha, 0.5);

        let src = r#"
quorum_fraction: 0.6
quorum_late_alpha: 1.5
selector:
  kind: pacing
  k: 3
  freshness_rounds: 2
trainer:
  kind: synthetic
  step_time_us: 200
  speed_factors: [1, 2, 10]
  jitter: 0.1
  dropout: 0.05
"#;
        let env = FederationEnv::from_yaml(src).unwrap();
        assert_eq!(env.quorum_fraction, 0.6);
        assert_eq!(env.quorum_late_alpha, 1.5);
        assert_eq!(env.selector, SelectorSpec::Pacing { k: 3, freshness_rounds: 2 });
        match &env.trainer {
            TrainerKind::Synthetic { step_time_us, hetero } => {
                assert_eq!(*step_time_us, 200);
                assert_eq!(hetero.speed_factors, vec![1.0, 2.0, 10.0]);
                assert_eq!(hetero.factor(0), 1.0);
                assert_eq!(hetero.factor(2), 10.0);
                assert_eq!(hetero.factor(3), 1.0); // cycles
                assert_eq!(hetero.jitter_frac, 0.1);
                assert_eq!(hetero.dropout, 0.05);
                assert!(!hetero.is_uniform());
            }
            other => panic!("unexpected trainer {other:?}"),
        }

        let env = FederationEnv::from_yaml("selector:\n  kind: freshness\n  k: 2\n").unwrap();
        assert_eq!(env.selector, SelectorSpec::Freshness { k: 2 });
    }

    #[test]
    fn scheduling_fields_are_validated() {
        for src in [
            "quorum_fraction: 0.0\n",
            "quorum_fraction: 1.5\n",
            "quorum_late_alpha: -1\n",
            "selector:\n  kind: pacing\n  k: 0\n",
            "selector:\n  kind: pacing\n  k: 2\n  freshness_rounds: 0\n",
            "selector:\n  kind: warp\n",
            "trainer:\n  kind: synthetic\n  speed_factors: [1, 0]\n",
            "trainer:\n  kind: synthetic\n  jitter: 1.5\n",
            "trainer:\n  kind: synthetic\n  dropout: 1.0\n",
        ] {
            assert!(FederationEnv::from_yaml(src).is_err(), "{src} should be rejected");
        }
    }

    #[test]
    fn chunked_backend_parses_from_yaml() {
        let env = FederationEnv::from_yaml(
            "aggregation:\n  rule: fedavg\n  backend: chunked\n  threads: 2\n",
        )
        .unwrap();
        assert_eq!(env.aggregation.backend, AggregationBackend::Chunked);
        assert_eq!(env.aggregation.threads, 2);
        assert!(FederationEnv::from_yaml("aggregation:\n  backend: warp\n").is_err());
    }

    #[test]
    fn topology_block_parses_and_validates() {
        // Default: flat, exactly what every pre-v6 env ran.
        let plain = FederationEnv::from_yaml("learners: 8\n").unwrap();
        assert!(plain.topology.is_flat());
        assert_eq!(plain.topology.effective_shard_quorum(plain.quorum_fraction), 1.0);

        let env = FederationEnv::from_yaml(
            "learners: 12\nquorum_fraction: 0.75\ntopology:\n  aggregators: 4\n  \
             shard_quorum: 0.5\n",
        )
        .unwrap();
        assert!(!env.topology.is_flat());
        assert_eq!(env.topology.aggregators, 4);
        assert_eq!(env.topology.shard_quorum, 0.5);
        assert_eq!(env.topology.effective_shard_quorum(env.quorum_fraction), 0.5);
        // Round-robin shard assignment.
        assert_eq!(env.topology.shard_of(0), 0);
        assert_eq!(env.topology.shard_of(5), 1);
        assert_eq!(env.topology.shard_of(11), 3);

        // shard_quorum 0 inherits the env-wide quorum.
        let env = FederationEnv::from_yaml(
            "learners: 12\nquorum_fraction: 0.75\ntopology:\n  aggregators: 3\n",
        )
        .unwrap();
        assert_eq!(env.topology.shard_quorum, 0.0);
        assert_eq!(env.topology.effective_shard_quorum(env.quorum_fraction), 0.75);

        // More shards than learners, out-of-range shard quorum, and a
        // shard quorum without aggregators are all load-time errors.
        assert!(FederationEnv::from_yaml("learners: 2\ntopology:\n  aggregators: 3\n").is_err());
        assert!(FederationEnv::from_yaml(
            "learners: 8\ntopology:\n  aggregators: 2\n  shard_quorum: 1.5\n"
        )
        .is_err());
        assert!(FederationEnv::from_yaml("learners: 8\ntopology:\n  shard_quorum: 0.5\n")
            .is_err());
    }

    #[test]
    fn to_yaml_source_roundtrips_defaults_and_maximal_envs() {
        // Builder defaults round-trip exactly.
        let env = FederationEnv::builder("plain").build();
        let back = FederationEnv::from_yaml(&env.to_yaml_source()).unwrap();
        assert_eq!(env, back);

        // A maximal env exercising every enum arm and optional block.
        let mut env = FederationEnv::builder("chaos-max")
            .learners(12)
            .rounds(7)
            .protocol(Protocol::SemiSynchronous { lambda: 1.5 })
            .model(ModelSpec { input_dim: 6, hidden_layers: 3, hidden_units: 16, output_dim: 2 })
            .aggregation(AggregationSpec {
                rule: "fedadam".into(),
                backend: AggregationBackend::Chunked,
                threads: 3,
                server_lr: 0.05,
            })
            .secure(SecureSpec::Masking)
            .trainer(TrainerKind::Synthetic {
                step_time_us: 250,
                hetero: HeteroFleetSpec {
                    speed_factors: vec![1.0, 2.5, 10.0],
                    jitter_frac: 0.1,
                    dropout: 0.05,
                },
            })
            .transport(TransportKind::Tcp { base_port: 43999 })
            .participation(0.75)
            .selector(SelectorSpec::Pacing { k: 4, freshness_rounds: 2 })
            .quorum_fraction(0.6)
            .quorum_late_alpha(1.25)
            .learning_rate(0.015)
            .seed(99)
            .stream_chunk_bytes(4096)
            .wire_codec(WireCodecChoice::DeltaRle)
            .chaos(ChaosSpec {
                seed: 11,
                sever_fraction: 0.2,
                sever_after_sends: 3,
                refuse_fraction: 0.1,
                stall_fraction: 0.1,
                stall_ms: 250,
                duplicate_fraction: 0.25,
                slow_loris: 1,
                drip_ms: 5,
                corrupt: 1,
                reconnect_after_ms: 40,
                kill_aggregator_at_round: 2,
            })
            .topology(TopologySpec { aggregators: 3, shard_quorum: 0.5 })
            .health(HealthSpec {
                interval_ms: 200,
                suspect_after: 2,
                dead_after: 4,
                ewma_alpha: 0.3,
            })
            .build();
        env.delta_fallback = false;
        let back = FederationEnv::from_yaml(&env.to_yaml_source()).unwrap();
        assert_eq!(env, back);

        // Async protocol + xla trainer + freshness selector arms, and a
        // name the parser would otherwise type as a number.
        let env = FederationEnv::builder("1234")
            .protocol(Protocol::Asynchronous { staleness_alpha: 0.5 })
            .trainer(TrainerKind::Xla { artifacts_dir: "artifacts/run 1".into() })
            .selector(SelectorSpec::Freshness { k: 2 })
            .build();
        let back = FederationEnv::from_yaml(&env.to_yaml_source()).unwrap();
        assert_eq!(env, back);
    }

    #[test]
    fn chaos_block_parses_and_validates() {
        let env = FederationEnv::from_yaml(
            "chaos:\n  seed: 7\n  sever_fraction: 0.2\n  sever_after_sends: 4\n  \
             slow_loris: 1\n  drip_ms: 5\n  corrupt: 1\n  duplicate_fraction: 0.1\n",
        )
        .unwrap();
        assert!(!env.chaos.is_off());
        assert_eq!(env.chaos.seed, 7);
        assert_eq!(env.chaos.sever_fraction, 0.2);
        assert_eq!(env.chaos.sever_after_sends, 4);
        assert_eq!(env.chaos.slow_loris, 1);
        assert_eq!(env.chaos.drip_ms, 5);
        assert_eq!(env.chaos.corrupt, 1);
        assert_eq!(env.chaos.duplicate_fraction, 0.1);
        // Default: off, and absent from unrelated env files.
        let plain = FederationEnv::from_yaml("learners: 3\n").unwrap();
        assert!(plain.chaos.is_off());
        // Invalid fractions are refused at load time.
        assert!(FederationEnv::from_yaml("chaos:\n  sever_fraction: 1.5\n").is_err());
        assert!(FederationEnv::from_yaml(
            "chaos:\n  sever_fraction: 0.5\n  sever_after_sends: 0\n"
        )
        .is_err());
        // The aggregator kill needs a survivor to fail over onto.
        assert!(FederationEnv::from_yaml("chaos:\n  kill_aggregator_at_round: 1\n").is_err());
        assert!(FederationEnv::from_yaml(
            "learners: 4\ntopology:\n  aggregators: 1\nchaos:\n  kill_aggregator_at_round: 1\n"
        )
        .is_err());
        let env = FederationEnv::from_yaml(
            "learners: 4\ntopology:\n  aggregators: 2\nchaos:\n  kill_aggregator_at_round: 2\n  \
             reconnect_after_ms: 30\n",
        )
        .unwrap();
        assert_eq!(env.chaos.kill_aggregator_at_round, 2);
        assert_eq!(env.chaos.reconnect_after_ms, 30);
    }

    #[test]
    fn health_block_parses_defaults_and_validates() {
        // Absent block: production-safe defaults.
        let plain = FederationEnv::from_yaml("learners: 3\n").unwrap();
        assert_eq!(plain.health, HealthSpec::default());
        assert!(plain.health.validate().is_ok());

        let env = FederationEnv::from_yaml(
            "health:\n  interval_ms: 50\n  suspect_after: 2\n  dead_after: 6\n  \
             ewma_alpha: 0.4\n",
        )
        .unwrap();
        assert_eq!(
            env.health,
            HealthSpec { interval_ms: 50, suspect_after: 2, dead_after: 6, ewma_alpha: 0.4 }
        );

        for src in [
            "health:\n  interval_ms: 0\n",
            "health:\n  suspect_after: 0\n",
            "health:\n  suspect_after: 5\n  dead_after: 3\n",
            "health:\n  ewma_alpha: 0\n",
            "health:\n  ewma_alpha: 1.5\n",
        ] {
            assert!(FederationEnv::from_yaml(src).is_err(), "{src} should be rejected");
        }
    }
}
