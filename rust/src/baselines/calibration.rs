//! Calibration constants + measurement for the framework models.
//!
//! The interpreter-overhead factors below are the documented inputs to
//! the behavioural models (DESIGN.md §Substitutions). They come from
//! well-known language-benchmark ratios, chosen *conservatively* (lower
//! than commonly measured) so the modelled gaps under-, not over-state
//! the paper's:
//!
//! * [`PICKLE_TAX`] — CPython pickling of ndarray lists vs raw memcpy:
//!   per-element tag dispatch + float widening; ≈4× the element-wise
//!   cost already paid by the tagged codec in `pyserde` (which itself is
//!   ≈3–4× slower than the bytes codec, compounding to the ~10–20×
//!   serialization gap the paper observes).
//! * [`PYTHON_LOOP_TAX`] — pure-Python float loops vs native: CPython
//!   runs ~30–80× slower on float arithmetic; we use 24 on top of the
//!   per-element work, landing IBM-FL-style fusion in the paper's
//!   measured 40–100× aggregation band.
//!
//! [`measure`] derives the *machine-specific* primitives every run: raw
//! axpy throughput, pool dispatch overhead, and codec throughputs. The
//! 1-core parallel-speedup model ([`ParallelModel`]) uses them to report
//! what the OpenMP aggregator would do at the paper's 32 hardware
//! threads (clearly labelled as modelled in all outputs).

use crate::tensor::ops;
use crate::util::{Stopwatch, ThreadPool};
use std::time::Duration;

/// Pickle interpreter tax (see module docs).
pub const PICKLE_TAX: u32 = 4;

/// Pure-Python loop tax (see module docs).
pub const PYTHON_LOOP_TAX: u32 = 24;

/// The paper testbed's core count, used by the parallel model when real
/// hardware parallelism is unavailable (this image has 1 core).
pub const PAPER_CORES: usize = 32;

/// Machine-measured primitive costs.
#[derive(Debug, Clone)]
pub struct Calibration {
    /// Sequential weighted-sum throughput (f32 elements / second).
    pub axpy_elems_per_sec: f64,
    /// Pool task spawn+join overhead per task.
    pub pool_task_overhead: Duration,
    /// Bytes-codec throughput (bytes/second, encode+decode).
    pub bytes_codec_bps: f64,
    /// Hardware threads actually available.
    pub hardware_threads: usize,
}

/// Measure the primitives on this machine (~20 ms).
pub fn measure() -> Calibration {
    // axpy throughput over a cache-busting buffer.
    let n = 1 << 20; // 1M f32 = 4 MiB
    let x = vec![1.0f32; n];
    let mut acc = vec![0.5f32; n];
    let sw = Stopwatch::start();
    let reps = 8;
    for _ in 0..reps {
        ops::axpy(&mut acc, &x, 0.25);
    }
    let axpy_elems_per_sec = (n * reps) as f64 / sw.elapsed_secs();

    // Pool overhead: time 256 empty tasks.
    let pool = ThreadPool::new(2);
    let sw = Stopwatch::start();
    let tasks = 256;
    pool.parallel_for(tasks, |_| {});
    let pool_task_overhead = sw.elapsed() / tasks as u32;

    // Bytes codec throughput.
    let t = crate::tensor::Tensor::new("cal", vec![n], x.clone());
    let sw = Stopwatch::start();
    let enc = t.encode_data(crate::tensor::DType::F32, crate::tensor::ByteOrder::Little);
    let _ = crate::tensor::Tensor::decode_data(
        "cal",
        vec![n],
        crate::tensor::DType::F32,
        crate::tensor::ByteOrder::Little,
        &enc,
    )
    .unwrap();
    let bytes_codec_bps = (2 * enc.len()) as f64 / sw.elapsed_secs();

    Calibration {
        axpy_elems_per_sec,
        pool_task_overhead,
        bytes_codec_bps,
        hardware_threads: crate::util::threadpool::hardware_threads(),
    }
}

/// Models what the per-tensor-parallel aggregator achieves with `cores`
/// hardware threads, from a measured sequential time (DESIGN.md
/// §Substitutions — this image has 1 core, the paper's testbed had 32).
#[derive(Debug, Clone)]
pub struct ParallelModel {
    pub cores: usize,
    pub pool_task_overhead: Duration,
}

impl ParallelModel {
    pub fn paper_machine(cal: &Calibration) -> ParallelModel {
        ParallelModel { cores: PAPER_CORES, pool_task_overhead: cal.pool_task_overhead }
    }

    /// T_par = T_seq / min(cores, tensors) + spawn overhead · tensors/cores.
    ///
    /// Per-tensor parallelism is embarrassingly parallel (no cross-tensor
    /// dependency, Fig. 4), so ideal speedup is capped by whichever is
    /// smaller: core count or tensor count; per-task overhead is the
    /// measured pool dispatch cost.
    pub fn parallel_time(&self, seq: Duration, tensors: usize) -> Duration {
        let speedup = self.cores.min(tensors.max(1)) as u32;
        let spawn_waves = tensors.div_ceil(self.cores.max(1)) as u32;
        seq / speedup + self.pool_task_overhead * spawn_waves
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_returns_sane_values() {
        let cal = measure();
        assert!(cal.axpy_elems_per_sec > 1e7, "{:?}", cal); // >10M elem/s
        assert!(cal.bytes_codec_bps > 1e7);
        assert!(cal.pool_task_overhead < Duration::from_millis(5));
        assert!(cal.hardware_threads >= 1);
    }

    #[test]
    fn parallel_model_caps_speedup_by_tensor_count() {
        let m = ParallelModel { cores: 32, pool_task_overhead: Duration::ZERO };
        let seq = Duration::from_millis(320);
        assert_eq!(m.parallel_time(seq, 202), Duration::from_millis(10));
        // Only 4 tensors → speedup 4, not 32.
        assert_eq!(m.parallel_time(seq, 4), Duration::from_millis(80));
        assert_eq!(m.parallel_time(seq, 1), seq);
    }

    #[test]
    fn parallel_model_charges_spawn_overhead() {
        let m = ParallelModel { cores: 4, pool_task_overhead: Duration::from_micros(10) };
        let t = m.parallel_time(Duration::from_millis(4), 8);
        // 4ms/4 + 10µs * ceil(8/4) = 1ms + 20µs
        assert_eq!(t, Duration::from_micros(1020));
    }
}
