//! Behavioural models of the compared FL frameworks (Figs. 5–7, Table 2).
//!
//! The paper benchmarks NVFlare, Flower, FedML and IBM FL against
//! MetisFL. Those frameworks cannot be installed in this offline image,
//! so each is modelled by the *mechanisms* the paper credits for the
//! performance gap — executing real work, not sleeps:
//!
//! * **Serialization**: MetisFL ships tensors as raw bytes (`memcpy`);
//!   Python frameworks pickle object graphs ([`pyserde`] implements a
//!   tagged element-wise encoding) and IBM FL adds an HTTP/JSON-ish
//!   base64 envelope.
//! * **Aggregation**: MetisFL aggregates in-place per tensor (parallel or
//!   sequential); numpy-style controllers allocate full-model temporaries
//!   per learner (`a = a + w*m`), and pure-Python paths pay an
//!   interpreter tax modelled as repeated element work with a documented,
//!   calibration-derived factor ([`calibration`]).
//! * **Dispatch**: MetisFL submits tasks through pooled async callbacks;
//!   the others serialize per-learner sends, and NVFlare's workflow engine
//!   exchanges extra control messages per task.
//!
//! [`capabilities`] carries the qualitative feature matrix (Table 1).

pub mod calibration;
pub mod capabilities;
pub mod pyserde;

use crate::tensor::TensorModel;

/// The frameworks compared in the paper's evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Framework {
    /// MetisFL with the parallel (OpenMP-analog) aggregator.
    MetisFLOmp,
    /// MetisFL with sequential aggregation ("MetisFL gRPC").
    MetisFL,
    Flower,
    FedML,
    NVFlare,
    IbmFL,
}

impl Framework {
    pub const ALL: [Framework; 6] = [
        Framework::NVFlare,
        Framework::Flower,
        Framework::FedML,
        Framework::IbmFL,
        Framework::MetisFL,
        Framework::MetisFLOmp,
    ];

    /// Label used in figure/table rows (matches the paper's legends).
    pub fn label(self) -> &'static str {
        match self {
            Framework::MetisFLOmp => "MetisFL gRPC+OMP",
            Framework::MetisFL => "MetisFL gRPC",
            Framework::Flower => "Flower",
            Framework::FedML => "FedML",
            Framework::NVFlare => "NVFlare",
            Framework::IbmFL => "IBM FL",
        }
    }
}

/// How a framework serializes a model for the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CodecKind {
    /// Flatten + dump raw bytes (MetisFL §3).
    BytesTensor,
    /// Pickle-style tagged element-wise object encoding.
    Pickle,
    /// Pickle + base64 HTTP envelope (IBM FL's Flask/AMQP path).
    PickleBase64,
}

/// How a framework aggregates learner models.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AggKind {
    /// One pool task per tensor (MetisFL + OpenMP, Fig. 4).
    ParallelTensor,
    /// One thread, tensor after tensor (MetisFL gRPC).
    SequentialTensor,
    /// numpy-style: full-model temporaries per learner
    /// (`acc = acc + w*m` allocates twice per learner).
    NumpyTemporaries,
    /// Pure-Python loop: element work repeated `tax` times (documented
    /// interpreter-overhead model; see `calibration`).
    PythonLoop { tax: u32 },
}

/// How a framework dispatches tasks to learners.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchKind {
    /// Pooled async submissions with immediate Acks (MetisFL).
    AsyncPooled,
    /// One learner at a time, each paying `control_msgs` extra
    /// request/reply control messages (workflow engines).
    SequentialPerLearner { control_msgs: usize },
}

/// A framework's controller behavioural profile.
#[derive(Debug, Clone, Copy)]
pub struct FrameworkProfile {
    pub framework: Framework,
    pub codec: CodecKind,
    /// Aggregation strategy.
    pub agg: AggKind,
    pub dispatch: DispatchKind,
    /// True when the controller's compute is serialized by a global
    /// interpreter lock (no intra-op parallelism whatsoever).
    pub gil: bool,
    /// Dispatch-side pickle tax: how many times the codec's element work
    /// is repeated to model interpreter-bound (de)serialization.
    pub serde_tax: u32,
    /// Eval dispatch uses a lighter path than train dispatch (IBM FL's
    /// "extremely fast evaluation task dispatching", §4.2).
    pub eval_fast: bool,
}

impl FrameworkProfile {
    /// The per-framework profiles (constants justified in
    /// [`calibration`] and DESIGN.md §Substitutions).
    pub fn of(framework: Framework) -> FrameworkProfile {
        match framework {
            Framework::MetisFLOmp => FrameworkProfile {
                framework,
                codec: CodecKind::BytesTensor,
                agg: AggKind::ParallelTensor,
                dispatch: DispatchKind::AsyncPooled,
                gil: false,
                serde_tax: 1,
                eval_fast: false,
            },
            Framework::MetisFL => FrameworkProfile {
                framework,
                codec: CodecKind::BytesTensor,
                agg: AggKind::SequentialTensor,
                dispatch: DispatchKind::AsyncPooled,
                gil: false,
                serde_tax: 1,
                eval_fast: false,
            },
            Framework::Flower => FrameworkProfile {
                framework,
                codec: CodecKind::Pickle,
                agg: AggKind::NumpyTemporaries,
                dispatch: DispatchKind::SequentialPerLearner { control_msgs: 0 },
                gil: true,
                serde_tax: calibration::PICKLE_TAX,
                eval_fast: false,
            },
            Framework::FedML => FrameworkProfile {
                framework,
                codec: CodecKind::Pickle,
                agg: AggKind::NumpyTemporaries,
                dispatch: DispatchKind::SequentialPerLearner { control_msgs: 0 },
                gil: true,
                // MPI pickles the state dict once per rank but avoids the
                // gRPC re-encode; lighter tax than Flower's path.
                serde_tax: calibration::PICKLE_TAX / 2,
                eval_fast: false,
            },
            Framework::NVFlare => FrameworkProfile {
                framework,
                codec: CodecKind::Pickle,
                agg: AggKind::NumpyTemporaries,
                // Scatter-and-gather workflow: per-task control exchanges
                // dominate dispatch (slowest dispatcher in Figs. 5–7 a/d).
                dispatch: DispatchKind::SequentialPerLearner { control_msgs: 4 },
                gil: true,
                serde_tax: calibration::PICKLE_TAX * 2,
                eval_fast: false,
            },
            Framework::IbmFL => FrameworkProfile {
                framework,
                codec: CodecKind::PickleBase64,
                // Fusion handlers iterate party updates in Python.
                agg: AggKind::PythonLoop { tax: calibration::PYTHON_LOOP_TAX },
                dispatch: DispatchKind::SequentialPerLearner { control_msgs: 1 },
                gil: true,
                serde_tax: calibration::PICKLE_TAX,
                eval_fast: true,
            },
        }
    }

    /// Aggregate with this profile's strategy. `pool` drives the
    /// ParallelTensor backend; returns the new community model.
    pub fn aggregate(
        &self,
        models: &[std::sync::Arc<TensorModel>],
        coeffs: &[f64],
        pool: &crate::util::ThreadPool,
    ) -> TensorModel {
        use crate::controller::aggregation::{Backend, WeightedSum};
        match self.agg {
            AggKind::ParallelTensor => {
                // One pool task per tensor (Fig. 4). Reuses the real
                // production engine.
                let backend = Backend::Parallel(std::sync::Arc::new(
                    crate::util::ThreadPool::new(pool.size()),
                ));
                WeightedSum::compute(models, coeffs, &backend).expect("aggregate")
            }
            AggKind::SequentialTensor => {
                WeightedSum::compute(models, coeffs, &Backend::Sequential).expect("aggregate")
            }
            AggKind::NumpyTemporaries => {
                let refs: Vec<&TensorModel> = models.iter().map(|m| m.as_ref()).collect();
                numpy_style_aggregate(&refs, coeffs)
            }
            AggKind::PythonLoop { tax } => {
                let refs: Vec<&TensorModel> = models.iter().map(|m| m.as_ref()).collect();
                python_loop_aggregate(&refs, coeffs, tax)
            }
        }
    }
}

/// numpy-style aggregation: `acc = acc + w * m` where both ops allocate a
/// fresh full-model temporary (exactly what `sum(w*m for ...)` does on
/// ndarray lists).
pub fn numpy_style_aggregate(models: &[&TensorModel], coeffs: &[f64]) -> TensorModel {
    let mut acc: Vec<Vec<f32>> = models[0]
        .tensors
        .iter()
        .map(|t| t.data.iter().map(|v| v * coeffs[0] as f32).collect())
        .collect();
    for (m, &c) in models.iter().zip(coeffs).skip(1) {
        let mut next = Vec::with_capacity(acc.len());
        for (a, t) in acc.iter().zip(&m.tensors) {
            // temp = w * m  (allocation 1)
            let temp: Vec<f32> = t.data.iter().map(|v| v * c as f32).collect();
            // acc' = acc + temp  (allocation 2)
            let summed: Vec<f32> = a.iter().zip(&temp).map(|(x, y)| x + y).collect();
            next.push(summed);
        }
        acc = next;
    }
    TensorModel::new(
        models[0]
            .tensors
            .iter()
            .zip(acc)
            .map(|(t, data)| crate::tensor::Tensor::new(t.name.clone(), t.shape.clone(), data))
            .collect(),
    )
}

/// Pure-Python-loop aggregation model: the element work is repeated
/// `tax` times to account for interpreter overhead (boxed floats, dynamic
/// dispatch). The factor comes from `calibration::PYTHON_LOOP_TAX`.
pub fn python_loop_aggregate(models: &[&TensorModel], coeffs: &[f64], tax: u32) -> TensorModel {
    let mut out = models[0].clone();
    for t in &mut out.tensors {
        for v in t.data.iter_mut() {
            *v *= coeffs[0] as f32;
        }
    }
    for (m, &c) in models.iter().zip(coeffs).skip(1) {
        for (acc_t, t) in out.tensors.iter_mut().zip(&m.tensors) {
            for _ in 0..tax {
                for (a, v) in acc_t.data.iter_mut().zip(&t.data) {
                    // The repeated runs recompute the same value — the
                    // final iteration leaves the correct result.
                    *a = (*a - c as f32 * v) + c as f32 * v; // touch
                }
            }
            for (a, v) in acc_t.data.iter_mut().zip(&t.data) {
                *a += c as f32 * v;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;
    use crate::controller::aggregation::{Backend, WeightedSum};
    use crate::util::{Rng, ThreadPool};

    fn models(n: usize) -> Vec<std::sync::Arc<TensorModel>> {
        let layout = ModelSpec::mlp(4, 3, 8).tensor_layout();
        let mut rng = Rng::new(1);
        (0..n)
            .map(|_| std::sync::Arc::new(TensorModel::random_init(&layout, &mut rng)))
            .collect()
    }

    #[test]
    fn all_aggregation_models_agree_numerically() {
        let ms = models(5);
        let coeffs = [0.1, 0.2, 0.3, 0.25, 0.15];
        let truth = WeightedSum::compute(&ms, &coeffs, &Backend::Sequential).unwrap();
        let pool = ThreadPool::new(2);
        for fw in Framework::ALL {
            let p = FrameworkProfile::of(fw);
            let got = p.aggregate(&ms, &coeffs, &pool);
            let diff = truth.max_abs_diff(&got);
            assert!(diff < 1e-4, "{}: diff {diff}", fw.label());
        }
    }

    #[test]
    fn profiles_reflect_paper_qualities() {
        assert!(!FrameworkProfile::of(Framework::MetisFLOmp).gil);
        assert!(FrameworkProfile::of(Framework::Flower).gil);
        assert_eq!(
            FrameworkProfile::of(Framework::MetisFL).codec,
            CodecKind::BytesTensor
        );
        assert_eq!(
            FrameworkProfile::of(Framework::IbmFL).codec,
            CodecKind::PickleBase64
        );
        assert!(FrameworkProfile::of(Framework::IbmFL).eval_fast);
        assert!(matches!(
            FrameworkProfile::of(Framework::NVFlare).dispatch,
            DispatchKind::SequentialPerLearner { control_msgs: 4 }
        ));
    }

    #[test]
    fn labels_match_paper_legends() {
        assert_eq!(Framework::MetisFLOmp.label(), "MetisFL gRPC+OMP");
        assert_eq!(Framework::IbmFL.label(), "IBM FL");
        assert_eq!(Framework::ALL.len(), 6);
    }
}
