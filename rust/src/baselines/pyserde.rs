//! Pickle-style and base64 model codecs (the baselines' wire formats).
//!
//! MetisFL's §3 argument is that other frameworks serialize models as
//! object graphs: every element travels with type information rather
//! than as one raw byte blob. [`pickle_encode`] reproduces that shape —
//! per-tensor headers plus a tag byte + f64 payload per element — and
//! [`base64_encode`] adds IBM FL's HTTP-transport envelope. Both do real
//! per-element work, so their cost scales the way the paper's
//! measurements do.

use crate::tensor::TensorModel;
use anyhow::{bail, Result};

const TAG_FLOAT: u8 = 0x46; // 'F'
const TAG_TENSOR: u8 = 0x54; // 'T'

/// Pickle-style encoding: tagged, element-wise, f64-widened.
pub fn pickle_encode(model: &TensorModel, tax: u32) -> Vec<u8> {
    let mut out = Vec::with_capacity(model.param_count() * 9 + model.tensor_count() * 64);
    for _ in 0..tax.max(1) {
        out.clear();
        for t in &model.tensors {
            out.push(TAG_TENSOR);
            out.extend((t.name.len() as u32).to_le_bytes());
            out.extend(t.name.as_bytes());
            out.extend((t.shape.len() as u32).to_le_bytes());
            for &d in &t.shape {
                out.extend((d as u64).to_le_bytes());
            }
            for &v in &t.data {
                out.push(TAG_FLOAT);
                out.extend((v as f64).to_le_bytes());
            }
        }
    }
    out
}

/// Decode the pickle-style format back into a model.
pub fn pickle_decode(bytes: &[u8], tax: u32) -> Result<TensorModel> {
    let mut model = None;
    for _ in 0..tax.max(1) {
        let mut tensors = Vec::new();
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if *pos + n > bytes.len() {
                bail!("pickle underrun");
            }
            let s = &bytes[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        while pos < bytes.len() {
            if bytes[pos] != TAG_TENSOR {
                bail!("expected tensor tag at {pos}");
            }
            pos += 1;
            let name_len = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
            let name = String::from_utf8(take(&mut pos, name_len)?.to_vec())
                .map_err(|_| anyhow::anyhow!("bad name"))?;
            let rank = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap()) as usize);
            }
            let count: usize = shape.iter().product();
            let mut data = Vec::with_capacity(count);
            for _ in 0..count {
                if bytes[pos] != TAG_FLOAT {
                    bail!("expected float tag at {pos}");
                }
                pos += 1;
                data.push(f64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap()) as f32);
            }
            tensors.push(crate::tensor::Tensor::new(name, shape, data));
        }
        model = Some(TensorModel::new(tensors));
    }
    model.ok_or_else(|| anyhow::anyhow!("tax must be >= 1"))
}

const B64: &[u8; 64] = b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

/// Standard base64 (the IBM FL HTTP-envelope step).
pub fn base64_encode(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len().div_ceil(3) * 4);
    for chunk in data.chunks(3) {
        let b = [chunk[0], *chunk.get(1).unwrap_or(&0), *chunk.get(2).unwrap_or(&0)];
        let n = ((b[0] as u32) << 16) | ((b[1] as u32) << 8) | b[2] as u32;
        out.push(B64[(n >> 18) as usize & 63]);
        out.push(B64[(n >> 12) as usize & 63]);
        out.push(if chunk.len() > 1 { B64[(n >> 6) as usize & 63] } else { b'=' });
        out.push(if chunk.len() > 2 { B64[n as usize & 63] } else { b'=' });
    }
    out
}

/// Base64 decode (inverse of [`base64_encode`]).
pub fn base64_decode(data: &[u8]) -> Result<Vec<u8>> {
    if data.len() % 4 != 0 {
        bail!("base64 length not a multiple of 4");
    }
    let val = |c: u8| -> Result<u32> {
        Ok(match c {
            b'A'..=b'Z' => (c - b'A') as u32,
            b'a'..=b'z' => (c - b'a' + 26) as u32,
            b'0'..=b'9' => (c - b'0' + 52) as u32,
            b'+' => 62,
            b'/' => 63,
            _ => bail!("bad base64 char {c}"),
        })
    };
    let mut out = Vec::with_capacity(data.len() / 4 * 3);
    for chunk in data.chunks_exact(4) {
        let pad = chunk.iter().filter(|&&c| c == b'=').count();
        let n = (val(chunk[0])? << 18)
            | (val(chunk[1])? << 12)
            | (if chunk[2] == b'=' { 0 } else { val(chunk[2])? } << 6)
            | (if chunk[3] == b'=' { 0 } else { val(chunk[3])? });
        out.push((n >> 16) as u8);
        if pad < 2 {
            out.push((n >> 8) as u8);
        }
        if pad < 1 {
            out.push(n as u8);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpec;
    use crate::tensor::{ByteOrder, DType};
    use crate::util::Rng;

    fn model() -> TensorModel {
        let layout = ModelSpec::mlp(4, 2, 8).tensor_layout();
        TensorModel::random_init(&layout, &mut Rng::new(9))
    }

    #[test]
    fn pickle_roundtrip_exact() {
        let m = model();
        let bytes = pickle_encode(&m, 1);
        let back = pickle_decode(&bytes, 1).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn pickle_is_materially_larger_than_bytes_codec() {
        let m = model();
        let pickled = pickle_encode(&m, 1).len();
        let raw: usize = m
            .tensors
            .iter()
            .map(|t| t.encode_data(DType::F32, ByteOrder::Little).len())
            .sum();
        // 9 bytes/elem (tag + f64) vs 4 bytes/elem.
        assert!(pickled > 2 * raw, "pickled={pickled} raw={raw}");
    }

    #[test]
    fn pickle_rejects_corruption() {
        let m = model();
        let mut bytes = pickle_encode(&m, 1);
        bytes[0] = 0xFF;
        assert!(pickle_decode(&bytes, 1).is_err());
        bytes.truncate(10);
        assert!(pickle_decode(&bytes, 1).is_err());
    }

    #[test]
    fn base64_roundtrip_all_lengths() {
        for len in 0..32 {
            let data: Vec<u8> = (0..len as u8).collect();
            let enc = base64_encode(&data);
            assert_eq!(base64_decode(&enc).unwrap(), data, "len {len}");
        }
    }

    #[test]
    fn base64_known_vector() {
        assert_eq!(base64_encode(b"Man"), b"TWFu");
        assert_eq!(base64_encode(b"Ma"), b"TWE=");
        assert_eq!(base64_encode(b"M"), b"TQ==");
        assert!(base64_decode(b"TWF!").is_err());
    }

    #[test]
    fn tax_multiplies_work_not_output() {
        let m = model();
        let once = pickle_encode(&m, 1);
        let thrice = pickle_encode(&m, 3);
        assert_eq!(once, thrice); // same bytes, 3x the work
        assert_eq!(pickle_decode(&thrice, 3).unwrap(), m);
    }
}
