//! Qualitative capability matrix — regenerates the paper's Table 1.
//!
//! Each framework declares its feature set; [`render_table`] prints the
//! same dimension/row structure the paper reports. Values transcribe the
//! paper's own Table 1 (they describe the *compared systems*, not our
//! reimplementation — except the MetisFL column, which this repo
//! implements and the test suite asserts).

use super::Framework;

/// One framework's qualitative capabilities (Table 1 rows).
#[derive(Debug, Clone)]
pub struct Capabilities {
    pub name: &'static str,
    // Deployment
    pub standalone: bool,
    pub distributed: bool,
    pub cross_silo: bool,
    pub cross_device: bool,
    pub containerized: bool,
    // ML environment
    pub backends: &'static [&'static str],
    pub local_opt: bool,
    pub global_opt: bool,
    // Data partitioning
    pub horizontal: bool,
    pub vertical: bool,
    // Privacy & security
    pub private_training: bool,
    pub secure_aggregation: &'static str,
    pub crypto_library: &'static str,
    // Communication
    pub centralized: bool,
    pub decentralized: bool,
    pub hierarchical: bool,
    pub tls: bool,
    pub network: &'static str,
    // Protocol
    pub synchronous: bool,
    pub asynchronous: bool,
    // Software
    pub aggregator_language: &'static str,
}

/// The Table-1 matrix. MetisFL's column reflects this reproduction.
pub fn capabilities(fw: Framework) -> Capabilities {
    match fw {
        Framework::MetisFL | Framework::MetisFLOmp => Capabilities {
            name: "MetisFL",
            standalone: true,
            distributed: true,
            cross_silo: true,
            cross_device: true,
            containerized: true,
            backends: &["Torch", "TF"],
            local_opt: true,
            global_opt: true,
            horizontal: true,
            vertical: false,
            private_training: true,
            secure_aggregation: "FHE",
            crypto_library: "PALISADE",
            centralized: true,
            decentralized: false,
            hierarchical: false,
            tls: true,
            network: "gRPC",
            synchronous: true,
            asynchronous: true,
            aggregator_language: "C++ (here: Rust)",
        },
        Framework::NVFlare => Capabilities {
            name: "Nvidia FLARE",
            standalone: true,
            distributed: true,
            cross_silo: true,
            cross_device: false,
            containerized: true,
            backends: &["Torch", "TF", "MONAI"],
            local_opt: true,
            global_opt: true,
            horizontal: true,
            vertical: false,
            private_training: true,
            secure_aggregation: "FHE",
            crypto_library: "TenSeal",
            centralized: true,
            decentralized: false,
            hierarchical: false,
            tls: true,
            network: "gRPC",
            synchronous: true,
            asynchronous: false,
            aggregator_language: "Python",
        },
        Framework::Flower => Capabilities {
            name: "Flower",
            standalone: true,
            distributed: true,
            cross_silo: true,
            cross_device: true,
            containerized: true,
            backends: &["Torch", "TF", "MX", "JAX"],
            local_opt: true,
            global_opt: true,
            horizontal: true,
            vertical: false,
            private_training: true,
            secure_aggregation: "Masking/FHE",
            crypto_library: "native",
            centralized: true,
            decentralized: false,
            hierarchical: false,
            tls: true,
            network: "gRPC",
            synchronous: true,
            asynchronous: false,
            aggregator_language: "Python",
        },
        Framework::FedML => Capabilities {
            name: "FedML",
            standalone: true,
            distributed: true,
            cross_silo: true,
            cross_device: true,
            containerized: true,
            backends: &["Torch", "TF", "MX", "JAX"],
            local_opt: true,
            global_opt: true,
            horizontal: true,
            vertical: false,
            private_training: true,
            secure_aggregation: "Masking/FHE",
            crypto_library: "native",
            centralized: true,
            decentralized: true,
            hierarchical: false,
            tls: true,
            network: "MPI",
            synchronous: true,
            asynchronous: false,
            aggregator_language: "Python",
        },
        Framework::IbmFL => Capabilities {
            name: "IBM FL",
            standalone: true,
            distributed: true,
            cross_silo: true,
            cross_device: false,
            containerized: true,
            backends: &["Torch", "TF"],
            local_opt: true,
            global_opt: true,
            horizontal: true,
            vertical: false,
            private_training: true,
            secure_aggregation: "FHE",
            crypto_library: "HElayers",
            centralized: true,
            decentralized: false,
            hierarchical: false,
            tls: true,
            network: "AMQP",
            synchronous: true,
            asynchronous: false,
            aggregator_language: "Python",
        },
    }
}

fn mark(b: bool) -> &'static str {
    if b {
        "yes"
    } else {
        "no"
    }
}

/// Render the Table-1 matrix as aligned markdown.
pub fn render_table() -> String {
    let frameworks = [
        Framework::NVFlare,
        Framework::Flower,
        Framework::FedML,
        Framework::IbmFL,
        Framework::MetisFL,
    ];
    let caps: Vec<Capabilities> = frameworks.iter().map(|&f| capabilities(f)).collect();
    let mut rows: Vec<(String, Vec<String>)> = Vec::new();
    let all = |f: fn(&Capabilities) -> String| -> Vec<String> { caps.iter().map(f).collect() };
    rows.push(("— Deployment —".into(), vec![String::new(); caps.len()]));
    rows.push(("Standalone".into(), all(|c| mark(c.standalone).into())));
    rows.push(("Distributed".into(), all(|c| mark(c.distributed).into())));
    rows.push(("Cross-Silo".into(), all(|c| mark(c.cross_silo).into())));
    rows.push(("Cross-Device".into(), all(|c| mark(c.cross_device).into())));
    rows.push(("Containerized".into(), all(|c| mark(c.containerized).into())));
    rows.push(("— ML Environment —".into(), vec![String::new(); caps.len()]));
    rows.push(("Backend".into(), all(|c| c.backends.join(" "))));
    rows.push(("LocalOpt".into(), all(|c| mark(c.local_opt).into())));
    rows.push(("GlobalOpt".into(), all(|c| mark(c.global_opt).into())));
    rows.push(("— Data Partitioning —".into(), vec![String::new(); caps.len()]));
    rows.push(("Horizontal".into(), all(|c| mark(c.horizontal).into())));
    rows.push(("Vertical".into(), all(|c| mark(c.vertical).into())));
    rows.push(("— Privacy & Security —".into(), vec![String::new(); caps.len()]));
    rows.push(("Private Training".into(), all(|c| mark(c.private_training).into())));
    rows.push(("Secure Aggregation".into(), all(|c| c.secure_aggregation.into())));
    rows.push(("Crypto Library".into(), all(|c| c.crypto_library.into())));
    rows.push(("— Communication —".into(), vec![String::new(); caps.len()]));
    rows.push(("Centralized".into(), all(|c| mark(c.centralized).into())));
    rows.push(("Decentralized".into(), all(|c| mark(c.decentralized).into())));
    rows.push(("Hierarchical".into(), all(|c| mark(c.hierarchical).into())));
    rows.push(("TLS".into(), all(|c| mark(c.tls).into())));
    rows.push(("Network".into(), all(|c| c.network.into())));
    rows.push(("— Communication Protocol —".into(), vec![String::new(); caps.len()]));
    rows.push(("Synchronous".into(), all(|c| mark(c.synchronous).into())));
    rows.push(("Asynchronous".into(), all(|c| mark(c.asynchronous).into())));
    rows.push(("— Software —".into(), vec![String::new(); caps.len()]));
    rows.push(("Aggregator".into(), all(|c| c.aggregator_language.into())));

    let mut out = String::new();
    out.push_str(&format!("| {:<24} ", "Dimension"));
    for c in &caps {
        out.push_str(&format!("| {:<18} ", c.name));
    }
    out.push_str("|\n");
    out.push_str(&format!("|{}", "-".repeat(26)));
    for _ in &caps {
        out.push_str(&format!("|{}", "-".repeat(20)));
    }
    out.push_str("|\n");
    for (label, values) in rows {
        out.push_str(&format!("| {label:<24} "));
        for v in values {
            out.push_str(&format!("| {v:<18} "));
        }
        out.push_str("|\n");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metisfl_is_the_only_async_framework() {
        // The paper's Table-1 differentiator this repo actually implements
        // (controller::scheduling::asynchronous + its tests).
        for fw in Framework::ALL {
            let c = capabilities(fw);
            assert_eq!(c.asynchronous, c.name == "MetisFL", "{}", c.name);
        }
    }

    #[test]
    fn metisfl_aggregator_is_not_python() {
        for fw in Framework::ALL {
            let c = capabilities(fw);
            if c.name == "MetisFL" {
                assert!(!c.aggregator_language.contains("Python"));
            } else {
                assert_eq!(c.aggregator_language, "Python");
            }
        }
    }

    #[test]
    fn no_framework_supports_vertical_partitioning() {
        for fw in Framework::ALL {
            assert!(!capabilities(fw).vertical);
        }
    }

    #[test]
    fn table_renders_all_frameworks_and_sections() {
        let t = render_table();
        for name in ["Nvidia FLARE", "Flower", "FedML", "IBM FL", "MetisFL"] {
            assert!(t.contains(name), "missing {name}");
        }
        for section in ["Deployment", "Privacy & Security", "Communication Protocol"] {
            assert!(t.contains(section), "missing {section}");
        }
        assert!(t.lines().count() > 25);
    }
}
