//! Hot-path element-wise kernels for aggregation.
//!
//! The controller's dominant op is the weighted sum `acc += w · x` over
//! megabytes of `f32`. Per-tensor backends issue one call per learner
//! per tensor (Fig. 4); the chunked backend issues one call per learner
//! per *span* — the slice of a tensor that falls inside a worker's
//! element range — so the same kernels serve both partitions. [`dot`]
//! doubles as the chunk-local partial sum behind
//! `ThreadPool::reduce_chunks` norm bookkeeping. The implementations are
//! written to let LLVM auto-vectorize: plain zip loops, no bounds checks
//! in the body. `benches/agg_ablation.rs` measures them against the
//! naive form.

/// `acc[i] += w * x[i]` — the FedAvg accumulation kernel.
///
/// Written as a plain zip loop: LLVM fully autovectorizes it, and the
/// §Perf pass measured the hand-unrolled 8-wide variant 20% *slower*
/// (the manual unroll defeated vectorization; see EXPERIMENTS.md §Perf
/// and `benches/agg_ablation.rs`, which still measures the old form as
/// `axpy_unrolled`).
#[inline]
pub fn axpy(acc: &mut [f32], x: &[f32], w: f32) {
    assert_eq!(acc.len(), x.len(), "axpy length mismatch");
    for (a, b) in acc.iter_mut().zip(x) {
        *a += w * b;
    }
}

/// `out[i] = w * x[i]` — initialize an accumulator from the first learner.
#[inline]
pub fn scaled_copy(out: &mut [f32], x: &[f32], w: f32) {
    assert_eq!(out.len(), x.len(), "scaled_copy length mismatch");
    for (o, b) in out.iter_mut().zip(x) {
        *o = w * b;
    }
}

/// The §Perf pass's rejected hand-unrolled axpy, kept for the ablation
/// bench so the regression stays measurable.
pub fn axpy_unrolled(acc: &mut [f32], x: &[f32], w: f32) {
    assert_eq!(acc.len(), x.len(), "axpy length mismatch");
    let mut ac = acc.chunks_exact_mut(8);
    let mut xc = x.chunks_exact(8);
    for (a, b) in (&mut ac).zip(&mut xc) {
        a[0] += w * b[0];
        a[1] += w * b[1];
        a[2] += w * b[2];
        a[3] += w * b[3];
        a[4] += w * b[4];
        a[5] += w * b[5];
        a[6] += w * b[6];
        a[7] += w * b[7];
    }
    for (a, b) in ac.into_remainder().iter_mut().zip(xc.remainder()) {
        *a += w * b;
    }
}

/// `v[i] *= s`.
#[inline]
pub fn scale(v: &mut [f32], s: f32) {
    for x in v.iter_mut() {
        *x *= s;
    }
}

/// `out[i] = a[i] - b[i]` (model deltas for adaptive server optimizers).
#[inline]
pub fn sub(out: &mut [f32], a: &[f32], b: &[f32]) {
    assert_eq!(out.len(), a.len());
    assert_eq!(a.len(), b.len());
    for i in 0..out.len() {
        out[i] = a[i] - b[i];
    }
}

/// Dot product (f64 accumulator for stability).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| *x as f64 * *y as f64).sum()
}

/// Reference axpy used by tests (indexed form, no iterator fusion).
pub fn axpy_naive(acc: &mut [f32], x: &[f32], w: f32) {
    assert_eq!(acc.len(), x.len());
    for i in 0..acc.len() {
        acc[i] += w * x[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn axpy_matches_naive() {
        prop_check("axpy == naive", 100, |g| {
            let x = g.vec_f32(0..200);
            let mut acc: Vec<f32> = x.iter().map(|v| v * 0.5).collect();
            let mut acc2 = acc.clone();
            let w = g.f32_in(-2.0, 2.0);
            axpy(&mut acc, &x, w);
            axpy_naive(&mut acc2, &x, w);
            assert_eq!(acc, acc2);
        });
    }

    #[test]
    fn scaled_copy_matches_manual() {
        prop_check("scaled_copy", 100, |g| {
            let x = g.vec_f32(0..100);
            let w = g.f32_in(-3.0, 3.0);
            let mut out = vec![7.0f32; x.len()];
            scaled_copy(&mut out, &x, w);
            for (o, b) in out.iter().zip(&x) {
                assert_eq!(*o, w * b);
            }
        });
    }

    #[test]
    fn axpy_handles_non_multiple_of_eight() {
        for n in [0usize, 1, 7, 8, 9, 15, 16, 17] {
            let x: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let mut acc = vec![1.0f32; n];
            axpy(&mut acc, &x, 2.0);
            for (i, a) in acc.iter().enumerate() {
                assert_eq!(*a, 1.0 + 2.0 * i as f32);
            }
        }
    }

    #[test]
    fn sub_and_dot() {
        let a = [3.0f32, 4.0, 5.0];
        let b = [1.0f32, 1.0, 1.0];
        let mut out = [0.0f32; 3];
        sub(&mut out, &a, &b);
        assert_eq!(out, [2.0, 3.0, 4.0]);
        assert_eq!(dot(&a, &b), 12.0);
    }

    #[test]
    fn scale_in_place() {
        let mut v = vec![1.0f32, -2.0, 3.0];
        scale(&mut v, -2.0);
        assert_eq!(v, vec![-2.0, 4.0, -6.0]);
    }

    #[test]
    #[should_panic]
    fn axpy_length_mismatch_panics() {
        let mut acc = vec![0.0f32; 3];
        axpy(&mut acc, &[1.0, 2.0], 1.0);
    }
}
