//! Model tensors and the paper's tensor-as-bytes representation.
//!
//! MetisFL ships models over the network "as a sequence of tensors with
//! each tensor being represented in a byte protobuf data type ... by first
//! flattening each tensor/matrix, then dumping the tensor (as bytes), and
//! finally constructing a proto message that represents the structure of
//! the original tensor ... e.g. tensor's byte order and data type" (§3).
//!
//! In-memory, tensors hold `f32` (the training dtype); the wire encoding
//! ([`Tensor::encode_data`] / [`Tensor::decode_data`]) supports `f32`,
//! `f64` and `bf16` payloads in either byte order, so the codec tests can
//! exercise cross-endian / mixed-precision reconstruction.

pub mod codec;
pub mod ops;

pub use codec::{CodecId, WireCodec};

use anyhow::{bail, Result};

/// Wire element type of an encoded tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    F64,
    Bf16,
}

impl DType {
    pub fn size_bytes(self) -> usize {
        match self {
            DType::F32 => 4,
            DType::F64 => 8,
            DType::Bf16 => 2,
        }
    }

    pub fn code(self) -> u8 {
        match self {
            DType::F32 => 0,
            DType::F64 => 1,
            DType::Bf16 => 2,
        }
    }

    pub fn from_code(c: u8) -> Result<DType> {
        Ok(match c {
            0 => DType::F32,
            1 => DType::F64,
            2 => DType::Bf16,
            _ => bail!("unknown dtype code {c}"),
        })
    }
}

/// Wire byte order of an encoded tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ByteOrder {
    Little,
    Big,
}

impl ByteOrder {
    pub fn code(self) -> u8 {
        match self {
            ByteOrder::Little => 0,
            ByteOrder::Big => 1,
        }
    }

    pub fn from_code(c: u8) -> Result<ByteOrder> {
        Ok(match c {
            0 => ByteOrder::Little,
            1 => ByteOrder::Big,
            _ => bail!("unknown byte order code {c}"),
        })
    }
}

/// A named, shaped, f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(name: impl Into<String>, shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        let t = Tensor { name: name.into(), shape, data };
        assert_eq!(t.data.len(), t.elem_count(), "shape/data mismatch for {}", t.name);
        t
    }

    pub fn zeros(name: impl Into<String>, shape: Vec<usize>) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { name: name.into(), shape, data: vec![0.0; n] }
    }

    pub fn elem_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_size(&self, dtype: DType) -> usize {
        self.elem_count() * dtype.size_bytes()
    }

    /// Flatten-and-dump (paper §3): encode elements as raw bytes in the
    /// requested dtype and byte order.
    pub fn encode_data(&self, dtype: DType, order: ByteOrder) -> Vec<u8> {
        if (dtype, order) == (DType::F32, ByteOrder::Little) {
            // Hot path: one memcpy on little-endian hosts (§Perf: ~5×
            // over the per-element encode); shared with the wire codecs
            // via `codec::encode_f32_slice_le`.
            return codec::encode_f32_slice_le(&self.data);
        }
        let mut out = Vec::with_capacity(self.byte_size(dtype));
        match (dtype, order) {
            (DType::F32, ByteOrder::Little) => unreachable!(),
            (DType::F32, ByteOrder::Big) => {
                out.extend(self.data.iter().flat_map(|v| v.to_be_bytes()));
            }
            (DType::F64, ByteOrder::Little) => {
                out.extend(self.data.iter().flat_map(|v| (*v as f64).to_le_bytes()));
            }
            (DType::F64, ByteOrder::Big) => {
                out.extend(self.data.iter().flat_map(|v| (*v as f64).to_be_bytes()));
            }
            (DType::Bf16, o) => {
                for v in &self.data {
                    let b = f32_to_bf16_bits(*v);
                    match o {
                        ByteOrder::Little => out.extend(b.to_le_bytes()),
                        ByteOrder::Big => out.extend(b.to_be_bytes()),
                    }
                }
            }
        }
        out
    }

    /// Reconstruct element data from wire bytes (inverse of
    /// [`Tensor::encode_data`]).
    pub fn decode_data(
        name: impl Into<String>,
        shape: Vec<usize>,
        dtype: DType,
        order: ByteOrder,
        bytes: &[u8],
    ) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if bytes.len() != n * dtype.size_bytes() {
            bail!(
                "tensor byte length mismatch: expected {} ({} elems × {}B), got {}",
                n * dtype.size_bytes(),
                n,
                dtype.size_bytes(),
                bytes.len()
            );
        }
        let mut data = Vec::with_capacity(n);
        match (dtype, order) {
            #[cfg(target_endian = "little")]
            (DType::F32, ByteOrder::Little) => {
                // Hot path: bulk memcpy (see encode_data).
                // SAFETY: `bytes.len() == n * 4` was validated above; any
                // bit pattern is a valid f32; the destination was reserved
                // for exactly `n` elements.
                unsafe {
                    std::ptr::copy_nonoverlapping(
                        bytes.as_ptr(),
                        data.as_mut_ptr() as *mut u8,
                        n * 4,
                    );
                    data.set_len(n);
                }
            }
            #[cfg(target_endian = "big")]
            (DType::F32, ByteOrder::Little) => {
                for c in bytes.chunks_exact(4) {
                    data.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
                }
            }
            (DType::F32, ByteOrder::Big) => {
                for c in bytes.chunks_exact(4) {
                    data.push(f32::from_be_bytes([c[0], c[1], c[2], c[3]]));
                }
            }
            (DType::F64, ByteOrder::Little) => {
                for c in bytes.chunks_exact(8) {
                    data.push(f64::from_le_bytes(c.try_into().unwrap()) as f32);
                }
            }
            (DType::F64, ByteOrder::Big) => {
                for c in bytes.chunks_exact(8) {
                    data.push(f64::from_be_bytes(c.try_into().unwrap()) as f32);
                }
            }
            (DType::Bf16, o) => {
                for c in bytes.chunks_exact(2) {
                    let bits = match o {
                        ByteOrder::Little => u16::from_le_bytes([c[0], c[1]]),
                        ByteOrder::Big => u16::from_be_bytes([c[0], c[1]]),
                    };
                    data.push(bf16_bits_to_f32(bits));
                }
            }
        }
        Ok(Tensor { name: name.into(), shape, data })
    }
}

/// Decode `bytes` (encoded per `dtype`/`order`) into `dst` f32 slots.
/// `bytes.len()` must equal `dst.len() * dtype.size_bytes()`.
///
/// This is the span-granular core of [`Tensor::decode_data`], exposed so
/// the streaming data plane can decode arriving `ModelChunk` payloads
/// directly into a partially-filled tensor buffer — no whole-model wire
/// buffer ever exists on the receiver. Element values are bit-identical
/// to a [`Tensor::decode_data`] pass over the same bytes.
pub fn decode_elems_into(dtype: DType, order: ByteOrder, bytes: &[u8], dst: &mut [f32]) {
    assert_eq!(
        bytes.len(),
        dst.len() * dtype.size_bytes(),
        "decode span byte/element mismatch"
    );
    match (dtype, order) {
        (DType::F32, ByteOrder::Little) => {
            for (c, d) in bytes.chunks_exact(4).zip(dst.iter_mut()) {
                *d = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            }
        }
        (DType::F32, ByteOrder::Big) => {
            for (c, d) in bytes.chunks_exact(4).zip(dst.iter_mut()) {
                *d = f32::from_be_bytes([c[0], c[1], c[2], c[3]]);
            }
        }
        (DType::F64, ByteOrder::Little) => {
            for (c, d) in bytes.chunks_exact(8).zip(dst.iter_mut()) {
                *d = f64::from_le_bytes(c.try_into().unwrap()) as f32;
            }
        }
        (DType::F64, ByteOrder::Big) => {
            for (c, d) in bytes.chunks_exact(8).zip(dst.iter_mut()) {
                *d = f64::from_be_bytes(c.try_into().unwrap()) as f32;
            }
        }
        (DType::Bf16, o) => {
            for (c, d) in bytes.chunks_exact(2).zip(dst.iter_mut()) {
                let bits = match o {
                    ByteOrder::Little => u16::from_le_bytes([c[0], c[1]]),
                    ByteOrder::Big => u16::from_be_bytes([c[0], c[1]]),
                };
                *d = bf16_bits_to_f32(bits);
            }
        }
    }
}

/// Spans of a global element range across a model's tensors.
///
/// Given the prefix-sum `offsets` from [`TensorModel::tensor_offsets`]
/// and a range of the model's flat element space, yields
/// `(tensor_index, local_range)` pairs in tensor order covering exactly
/// that range. Zero-element tensors are skipped. This lets a worker
/// sweep an arbitrary contiguous chunk of the element space without the
/// model ever being materialized as one flat buffer.
pub struct FlatSpans<'a> {
    offsets: &'a [usize],
    pos: usize,
    end: usize,
    tensor: usize,
}

impl<'a> FlatSpans<'a> {
    /// `range` must lie within `0..offsets.last()`.
    pub fn new(offsets: &'a [usize], range: std::ops::Range<usize>) -> FlatSpans<'a> {
        assert!(offsets.len() >= 2, "offsets must cover at least zero tensors plus total");
        let total = *offsets.last().unwrap();
        assert!(range.end <= total, "range {range:?} exceeds element count {total}");
        // Largest t with offsets[t] <= pos; empty tensors at pos sort
        // before it, so offsets[t + 1] > pos is guaranteed.
        let tensor = if range.start >= range.end {
            offsets.len() - 1 // exhausted immediately
        } else {
            offsets.partition_point(|&o| o <= range.start) - 1
        };
        FlatSpans { offsets, pos: range.start, end: range.end, tensor }
    }
}

impl Iterator for FlatSpans<'_> {
    /// `(tensor_index, local_element_range)`.
    type Item = (usize, std::ops::Range<usize>);

    fn next(&mut self) -> Option<Self::Item> {
        while self.pos < self.end {
            let t = self.tensor;
            let t_start = self.offsets[t];
            let t_end = self.offsets[t + 1];
            if t_end <= self.pos {
                // Zero-element tensor (or one fully before pos): skip.
                self.tensor += 1;
                continue;
            }
            let lo = self.pos - t_start;
            let hi = t_end.min(self.end) - t_start;
            self.pos = t_start + hi;
            self.tensor += 1;
            return Some((t, lo..hi));
        }
        None
    }
}

/// Round-to-nearest-even f32 → bf16 bit pattern.
pub fn f32_to_bf16_bits(v: f32) -> u16 {
    let bits = v.to_bits();
    if v.is_nan() {
        return ((bits >> 16) as u16) | 0x0040; // quiet NaN
    }
    let round_bit = 0x0000_8000u32;
    let lower = bits & 0xFFFF;
    let mut upper = (bits >> 16) as u16;
    if lower > round_bit || (lower == round_bit && (upper & 1) == 1) {
        upper = upper.wrapping_add(1);
    }
    upper
}

/// bf16 bit pattern → f32.
pub fn bf16_bits_to_f32(bits: u16) -> f32 {
    f32::from_bits((bits as u32) << 16)
}

/// A model as an ordered sequence of tensors — the unit the controller
/// stores, ships, and aggregates (one pool task per tensor, Fig. 4).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorModel {
    pub tensors: Vec<Tensor>,
}

impl TensorModel {
    pub fn new(tensors: Vec<Tensor>) -> TensorModel {
        TensorModel { tensors }
    }

    /// Zero-initialized model matching a layout.
    pub fn zeros(layout: &[(String, Vec<usize>)]) -> TensorModel {
        TensorModel {
            tensors: layout
                .iter()
                .map(|(n, s)| Tensor::zeros(n.clone(), s.clone()))
                .collect(),
        }
    }

    /// Random-normal initialized model (He-like scaling per tensor fan-in).
    pub fn random_init(layout: &[(String, Vec<usize>)], rng: &mut crate::util::Rng) -> TensorModel {
        TensorModel {
            tensors: layout
                .iter()
                .map(|(n, s)| {
                    let count: usize = s.iter().product();
                    let fan_in = s.first().copied().unwrap_or(1).max(1);
                    let scale = (2.0 / fan_in as f64).sqrt() as f32;
                    let mut data = vec![0.0f32; count];
                    // Biases (rank-1) start at zero like the reference model.
                    if s.len() > 1 {
                        rng.fill_gaussian_f32(&mut data, scale);
                    }
                    Tensor::new(n.clone(), s.clone(), data)
                })
                .collect(),
        }
    }

    pub fn tensor_count(&self) -> usize {
        self.tensors.len()
    }

    pub fn param_count(&self) -> usize {
        self.tensors.iter().map(|t| t.elem_count()).sum()
    }

    pub fn byte_size_f32(&self) -> usize {
        self.param_count() * 4
    }

    /// Concatenate all tensors into one flat vector (the layout the L2
    /// `train_step(flat_params, ...)` artifact consumes).
    pub fn to_flat(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.param_count());
        for t in &self.tensors {
            out.extend_from_slice(&t.data);
        }
        out
    }

    /// Rebuild a model from a flat vector using `layout` for names/shapes.
    pub fn from_flat(layout: &[(String, Vec<usize>)], flat: &[f32]) -> Result<TensorModel> {
        let expected: usize = layout.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
        if flat.len() != expected {
            bail!("flat length {} != layout total {}", flat.len(), expected);
        }
        let mut tensors = Vec::with_capacity(layout.len());
        let mut off = 0;
        for (name, shape) in layout {
            let n: usize = shape.iter().product();
            tensors.push(Tensor::new(name.clone(), shape.clone(), flat[off..off + n].to_vec()));
            off += n;
        }
        Ok(TensorModel { tensors })
    }

    /// Layout (name, shape) pairs of this model.
    pub fn layout(&self) -> Vec<(String, Vec<usize>)> {
        self.tensors.iter().map(|t| (t.name.clone(), t.shape.clone())).collect()
    }

    /// Exclusive prefix sums of tensor element counts:
    /// `offsets[i]..offsets[i+1]` is tensor `i`'s slice of the model's
    /// flat element space (`offsets.len() == tensor_count() + 1`,
    /// `offsets.last() == param_count()`). This is the index map the
    /// chunk-partitioned aggregation backend sweeps over.
    pub fn tensor_offsets(&self) -> Vec<usize> {
        let mut offsets = Vec::with_capacity(self.tensors.len() + 1);
        let mut total = 0usize;
        offsets.push(0);
        for t in &self.tensors {
            total += t.elem_count();
            offsets.push(total);
        }
        offsets
    }

    /// Max absolute element difference against another model.
    pub fn max_abs_diff(&self, other: &TensorModel) -> f32 {
        self.tensors
            .iter()
            .zip(&other.tensors)
            .flat_map(|(a, b)| a.data.iter().zip(&b.data).map(|(x, y)| (x - y).abs()))
            .fold(0.0, f32::max)
    }

    /// L2 norm of all parameters.
    pub fn l2_norm(&self) -> f64 {
        self.tensors
            .iter()
            .flat_map(|t| t.data.iter())
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;
    use crate::util::Rng;

    #[test]
    fn encode_decode_roundtrip_f32_both_orders() {
        let t = Tensor::new("w", vec![2, 3], vec![1.0, -2.5, 3.25, 0.0, f32::MIN, f32::MAX]);
        for order in [ByteOrder::Little, ByteOrder::Big] {
            let bytes = t.encode_data(DType::F32, order);
            assert_eq!(bytes.len(), 24);
            let back = Tensor::decode_data("w", vec![2, 3], DType::F32, order, &bytes).unwrap();
            assert_eq!(back, t);
        }
    }

    #[test]
    fn f64_roundtrip_is_exact_for_f32_values() {
        let t = Tensor::new("w", vec![4], vec![1.5, -0.25, 1e30, -1e-30]);
        for order in [ByteOrder::Little, ByteOrder::Big] {
            let bytes = t.encode_data(DType::F64, order);
            assert_eq!(bytes.len(), 32);
            let back = Tensor::decode_data("w", vec![4], DType::F64, order, &bytes).unwrap();
            assert_eq!(back.data, t.data);
        }
    }

    #[test]
    fn bf16_roundtrip_within_tolerance() {
        let t = Tensor::new("w", vec![3], vec![1.0, -3.14159, 1234.5]);
        let bytes = t.encode_data(DType::Bf16, ByteOrder::Little);
        assert_eq!(bytes.len(), 6);
        let back = Tensor::decode_data("w", vec![3], DType::Bf16, ByteOrder::Little, &bytes).unwrap();
        for (a, b) in t.data.iter().zip(&back.data) {
            let rel = (a - b).abs() / a.abs().max(1e-6);
            assert!(rel < 0.01, "a={a} b={b}");
        }
    }

    #[test]
    fn bf16_special_values() {
        assert_eq!(bf16_bits_to_f32(f32_to_bf16_bits(0.0)), 0.0);
        assert!(bf16_bits_to_f32(f32_to_bf16_bits(f32::NAN)).is_nan());
        assert_eq!(bf16_bits_to_f32(f32_to_bf16_bits(f32::INFINITY)), f32::INFINITY);
        assert_eq!(bf16_bits_to_f32(f32_to_bf16_bits(1.0)), 1.0);
    }

    #[test]
    fn decode_rejects_length_mismatch() {
        let r = Tensor::decode_data("w", vec![2], DType::F32, ByteOrder::Little, &[0u8; 7]);
        assert!(r.is_err());
    }

    #[test]
    fn flat_roundtrip_preserves_model() {
        let layout = crate::config::ModelSpec::mlp(4, 3, 8).tensor_layout();
        let mut rng = Rng::new(1);
        let m = TensorModel::random_init(&layout, &mut rng);
        let flat = m.to_flat();
        assert_eq!(flat.len(), m.param_count());
        let back = TensorModel::from_flat(&layout, &flat).unwrap();
        assert_eq!(back, m);
        assert_eq!(back.layout(), layout);
    }

    #[test]
    fn from_flat_rejects_wrong_length() {
        let layout = crate::config::ModelSpec::mlp(4, 2, 8).tensor_layout();
        assert!(TensorModel::from_flat(&layout, &[0.0; 3]).is_err());
    }

    #[test]
    fn random_init_biases_zero_weights_nonzero() {
        let layout = crate::config::ModelSpec::mlp(4, 2, 8).tensor_layout();
        let mut rng = Rng::new(2);
        let m = TensorModel::random_init(&layout, &mut rng);
        for t in &m.tensors {
            if t.shape.len() == 1 {
                assert!(t.data.iter().all(|&x| x == 0.0), "{} should be zero", t.name);
            } else {
                assert!(t.data.iter().any(|&x| x != 0.0), "{} should be random", t.name);
            }
        }
    }

    #[test]
    fn prop_codec_roundtrips_for_random_shapes() {
        prop_check("tensor codec roundtrip", 100, |g| {
            let shape = g.shape(3, 512);
            let n: usize = shape.iter().product();
            let data: Vec<f32> = (0..n).map(|_| g.rng().next_gaussian() as f32).collect();
            let t = Tensor::new("t", shape.clone(), data);
            let order = if g.bool() { ByteOrder::Little } else { ByteOrder::Big };
            let bytes = t.encode_data(DType::F32, order);
            let back = Tensor::decode_data("t", shape, DType::F32, order, &bytes).unwrap();
            assert_eq!(back.data, t.data);
        });
    }

    #[test]
    fn prop_decode_elems_into_matches_decode_data_bitwise() {
        prop_check("decode_elems_into == decode_data", 60, |g| {
            let shape = g.shape(2, 256);
            let n: usize = shape.iter().product();
            let data: Vec<f32> = (0..n).map(|_| g.rng().next_gaussian() as f32).collect();
            let t = Tensor::new("t", shape.clone(), data);
            let dtype = match g.usize_in(0..3) {
                0 => DType::F32,
                1 => DType::F64,
                _ => DType::Bf16,
            };
            let order = if g.bool() { ByteOrder::Little } else { ByteOrder::Big };
            let bytes = t.encode_data(dtype, order);
            let whole = Tensor::decode_data("t", shape, dtype, order, &bytes).unwrap();
            // Decode the same bytes span-wise at an arbitrary element split.
            let mut out = vec![0.0f32; n];
            let esz = dtype.size_bytes();
            let split = g.usize_in(0..n + 1);
            decode_elems_into(dtype, order, &bytes[..split * esz], &mut out[..split]);
            decode_elems_into(dtype, order, &bytes[split * esz..], &mut out[split..]);
            for (a, b) in whole.data.iter().zip(&out) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        });
    }

    #[test]
    fn tensor_offsets_are_prefix_sums() {
        let m = TensorModel::new(vec![
            Tensor::new("a", vec![2, 3], vec![0.0; 6]),
            Tensor::new("b", vec![4], vec![0.0; 4]),
            Tensor::new("c", vec![1], vec![0.0]),
        ]);
        assert_eq!(m.tensor_offsets(), vec![0, 6, 10, 11]);
        assert_eq!(*m.tensor_offsets().last().unwrap(), m.param_count());
    }

    #[test]
    fn flat_spans_cover_ranges_exactly() {
        let offsets = [0usize, 6, 10, 11];
        // Full range.
        let spans: Vec<_> = FlatSpans::new(&offsets, 0..11).collect();
        assert_eq!(spans, vec![(0, 0..6), (1, 0..4), (2, 0..1)]);
        // Range inside one tensor.
        let spans: Vec<_> = FlatSpans::new(&offsets, 2..5).collect();
        assert_eq!(spans, vec![(0, 2..5)]);
        // Range straddling a boundary, starting exactly on one.
        let spans: Vec<_> = FlatSpans::new(&offsets, 6..11).collect();
        assert_eq!(spans, vec![(1, 0..4), (2, 0..1)]);
        // Empty range.
        assert_eq!(FlatSpans::new(&offsets, 4..4).count(), 0);
    }

    #[test]
    fn flat_spans_skip_zero_element_tensors() {
        // Tensors with a zero dim contribute no elements.
        let offsets = [0usize, 0, 5, 5, 9];
        let spans: Vec<_> = FlatSpans::new(&offsets, 0..9).collect();
        assert_eq!(spans, vec![(1, 0..5), (3, 0..4)]);
        let spans: Vec<_> = FlatSpans::new(&offsets, 5..9).collect();
        assert_eq!(spans, vec![(3, 0..4)]);
    }

    #[test]
    fn prop_flat_spans_partition_matches_serial_sweep() {
        prop_check("flat spans partition", 60, |g| {
            let k = g.usize_in(1..8);
            let counts: Vec<usize> = (0..k).map(|_| g.usize_in(0..20)).collect();
            let mut offsets = vec![0usize];
            for c in &counts {
                offsets.push(offsets.last().unwrap() + c);
            }
            let total = *offsets.last().unwrap();
            let chunks = g.usize_in(1..6);
            let chunk = total.div_ceil(chunks.max(1)).max(1);
            // Concatenating span sweeps over chunked ranges must visit
            // every (tensor, local index) pair exactly once, in order.
            let mut visited: Vec<(usize, usize)> = Vec::new();
            let mut lo = 0;
            while lo < total {
                let hi = (lo + chunk).min(total);
                for (t, local) in FlatSpans::new(&offsets, lo..hi) {
                    for i in local {
                        visited.push((t, i));
                    }
                }
                lo = hi;
            }
            let expect: Vec<(usize, usize)> = counts
                .iter()
                .enumerate()
                .flat_map(|(t, &c)| (0..c).map(move |i| (t, i)))
                .collect();
            assert_eq!(visited, expect);
        });
    }

    #[test]
    fn model_norms_and_diffs() {
        let a = TensorModel::new(vec![Tensor::new("x", vec![2], vec![3.0, 4.0])]);
        let b = TensorModel::new(vec![Tensor::new("x", vec![2], vec![3.0, 4.5])]);
        assert!((a.l2_norm() - 5.0).abs() < 1e-9);
        assert_eq!(a.max_abs_diff(&b), 0.5);
        assert_eq!(a.byte_size_f32(), 8);
    }
}
